//! Core census micro/meso benchmarks: the algorithm ladder (naive ->
//! Batagelj-Mrvar -> merged traversal) and the parallel engine's
//! policy x accumulation matrix, scheduled on one persistent executor.
//! This is the harness behind the §Perf numbers in EXPERIMENTS.md.

use triadic::bench::Bench;
use triadic::census::{
    batagelj_mrvar, census_parallel_on, merged, naive, Accumulation, ParallelConfig,
};
use triadic::graph::generators::power_law;
use triadic::sched::{Executor, Policy};

fn main() {
    let mut b = Bench::from_env(10);
    let exec = Executor::with_workers(4);

    // algorithm ladder on a mid-size scale-free graph
    let g = power_law(5_000, 2.2, 10.0, 42);
    println!(
        "# graph: n={} arcs={} dyads={}",
        g.node_count(),
        g.arc_count(),
        g.dyad_count()
    );
    let small = power_law(300, 2.2, 8.0, 42);
    b.run("naive_oracle_n300", || naive::census(&small));
    b.run("batagelj_mrvar_n300", || batagelj_mrvar::census(&small));
    b.run("merged_n300", || merged::census(&small));

    b.run("batagelj_mrvar_n5000", || batagelj_mrvar::census(&g));
    b.run("merged_n5000", || merged::census(&g));

    // O(m) scaling check: double the arcs, expect ~2x the time
    for &(n, d) in &[(5_000usize, 10.0f64), (10_000, 10.0), (20_000, 10.0)] {
        let gg = power_law(n, 2.2, d, 7);
        b.run(&format!("merged_m{}k", gg.arc_count() / 1000), || {
            merged::census(&gg)
        });
    }

    // parallel engine: policies x accumulation (ablation) on the
    // persistent pool
    for policy in [
        Policy::Static { chunk: 1024 },
        Policy::Dynamic { chunk: 256 },
        Policy::Guided { min_chunk: 64 },
    ] {
        for (acc, acc_name) in [
            (Accumulation::Bank { slots: 64 }, "bank64"),
            (Accumulation::PerThread, "private"),
        ] {
            let cfg = ParallelConfig {
                threads: 4,
                policy,
                accumulation: acc,
            };
            b.run(&format!("parallel_{}_{}_t4", policy.name(), acc_name), || {
                census_parallel_on(&g, &cfg, &exec)
            });
        }
    }

    // contention ablation: bank slot counts (paper chose 64)
    for slots in [1usize, 4, 16, 64, 256] {
        let cfg = ParallelConfig {
            threads: 4,
            policy: Policy::dynamic_default(),
            accumulation: Accumulation::Bank { slots },
        };
        b.run(&format!("bank_slots_{slots}_t4"), || {
            census_parallel_on(&g, &cfg, &exec)
        });
    }

    println!("# executor: {:?}", exec.stats());
}
