//! Pool-reuse ablation: the persistent shared executor versus the old
//! per-call scoped thread spawn, on repeated small-graph censuses — the
//! coordinator's serving-path pattern, where a request stream of many
//! small jobs pays thread spawn/teardown on every call without a
//! persistent pool. Acceptance target: >= 2x on 1k-node graphs.

use triadic::bench::Bench;
use triadic::census::{census_parallel_on, census_parallel_scoped, Accumulation, ParallelConfig};
use triadic::graph::generators::power_law;
use triadic::sched::{Executor, Policy};

fn main() {
    let mut b = Bench::from_env(40);
    let threads = 4;
    let exec = Executor::with_workers(threads);

    for &n in &[1_000usize, 4_000, 16_000] {
        let g = power_law(n, 2.2, 8.0, 42);
        let cfg = ParallelConfig {
            threads,
            policy: Policy::dynamic_default(),
            accumulation: Accumulation::PerThread,
        };
        let persistent = b
            .run(&format!("census_n{n}_persistent_pool_t{threads}"), || {
                census_parallel_on(&g, &cfg, &exec)
            })
            .mean_s;
        let scoped = b
            .run(&format!("census_n{n}_scoped_spawn_t{threads}"), || {
                census_parallel_scoped(&g, &cfg)
            })
            .mean_s;
        println!(
            "# n={n}: persistent pool is {:.2}x the per-call spawn baseline \
             (spawn {:.1} us vs pool {:.1} us)",
            scoped / persistent.max(1e-12),
            scoped * 1e6,
            persistent * 1e6
        );
    }
    println!("# executor: {:?}", exec.stats());
}
