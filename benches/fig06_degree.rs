//! FIG6 bench: regenerate the outdegree-distribution figure and time
//! the generation + characterization pipeline.

use triadic::bench::Bench;
use triadic::figures::{fig6, Scale};

fn main() {
    let mut b = Bench::from_env(3);
    let out = b.run("fig06_degree_small", || fig6(Scale::Small));
    let _ = out;
    println!("\n{}", fig6(Scale::Small));
}
