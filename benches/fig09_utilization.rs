//! FIG9 bench: regenerate the XMT CPU-utilization timeline (orkut @ 8
//! procs) and time the simulation.

use triadic::bench::Bench;
use triadic::figures::{fig9, Scale};

fn main() {
    let mut b = Bench::from_env(3);
    b.run("fig09_utilization_small", || fig9(Scale::Small));
    println!("\n{}", fig9(Scale::Small));
}
