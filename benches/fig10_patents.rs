//! FIG10 bench: the patents-network three-machine comparison
//! (exec time + speedup across 1..128 processors).

use triadic::bench::Bench;
use triadic::figures::{fig10, Scale};

fn main() {
    let mut b = Bench::from_env(3);
    b.run("fig10_patents_small", || fig10(Scale::Small));
    println!("\n{}", fig10(Scale::Small));
}
