//! FIG11 bench: the orkut-network three-machine comparison.

use triadic::bench::Bench;
use triadic::figures::{fig11, Scale};

fn main() {
    let mut b = Bench::from_env(3);
    b.run("fig11_orkut_small", || fig11(Scale::Small));
    println!("\n{}", fig11(Scale::Small));
}
