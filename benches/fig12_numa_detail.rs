//! FIG12 bench: NUMA parallel-efficiency detail at 32-48 cores, plus a
//! measured pinned-vs-unpinned accumulation ablation.
//!
//! The simulated series (fig12 table) models the paper's 48-core
//! Magny-Cours box. Since the executor now places seats, chunk slabs
//! and accumulation banks per socket and can pin workers to their
//! socket's CPU set, this bench runs the census twice on a synthetic
//! two-socket topology:
//!
//! * `pinned_banked`   — `PinMode::Sockets` + `Accumulation::Banked`
//!   (one bank per socket, the NUMA-local write path), and
//! * `unpinned_global` — `PinMode::None` + the paper's global
//!   `Bank { slots: 64 }` (every worker hashes into one shared bank).
//!
//! Both censuses must be byte-identical to the serial merged oracle
//! (the `"pass"` gate CI greps for). The JSON records the wall-clock
//! pair, the per-socket busy-time imbalance, the local/remote steal
//! split and the bank write-locality split — on a real multi-socket
//! host the remote-write count is the contention the banked layout
//! removes; in the single-socket container it still verifies the
//! accounting plumbing end to end.

use triadic::bench::Bench;
use triadic::census::{census_parallel_on, merged, Accumulation, ParallelConfig, ParallelRun};
use triadic::figures::{fig12, Scale};
use triadic::graph::GraphSpec;
use triadic::sched::{Executor, ExecutorConfig, PinMode, Policy, Topology};
use triadic::simulator::{simulate, NumaMachine, WorkloadProfile};

fn main() {
    let mut b = Bench::from_env(3);
    b.run("fig12_numa_detail_small", || fig12(Scale::Small));
    println!("\n{}", fig12(Scale::Small));

    // measured: the same dynamic policy on a synthetic 2-socket (4+4)
    // executor; the paper's machine is modeled per-core by the simulator
    let workers = 8;
    let spec = GraphSpec::orkut(10_000);
    let g = spec.generate();
    let prof = WorkloadProfile::from_graph(spec.name, &g);
    let want = merged::census(&g);

    let run_with = |pin: PinMode, accumulation: Accumulation| -> ParallelRun {
        let exec = Executor::with_topology(
            ExecutorConfig {
                workers,
                max_concurrent_jobs: 0,
                pin,
            },
            Topology::synthetic(vec![4, 4]),
        );
        let cfg = ParallelConfig {
            threads: workers,
            policy: Policy::dynamic_default(),
            accumulation,
        };
        census_parallel_on(&g, &cfg, &exec)
    };

    let pinned = run_with(PinMode::Sockets, Accumulation::Banked);
    let unpinned = run_with(PinMode::None, Accumulation::Bank { slots: 64 });
    let pass = pinned.census == want && unpinned.census == want;
    assert!(pass, "pinned/unpinned censuses must match the serial merged oracle");

    let bank_sums = |run: &ParallelRun| -> (u64, u64, usize, usize) {
        match &run.bank {
            Some(t) => (
                t.local_writes.iter().sum(),
                t.remote_writes.iter().sum(),
                t.banks,
                t.slots,
            ),
            None => (0, 0, 0, 0),
        }
    };
    let (pin_local_w, pin_remote_w, pin_banks, pin_slots) = bank_sums(&pinned);
    let (unp_local_w, unp_remote_w, unp_banks, unp_slots) = bank_sums(&unpinned);

    let numa = NumaMachine::magny_cours();
    let sim = simulate(&numa, &prof, workers, Policy::dynamic_default());
    // SimResult::balance is mean/max (higher is better); invert to the
    // executor's max/mean imbalance convention
    let predicted_imbalance = 1.0 / sim.balance().max(1e-12);

    println!(
        "# pinned_banked: wall={:.3}s pinned_workers={} imbalance={:.3} steals local={} \
         remote={} bank_writes local={pin_local_w} remote={pin_remote_w} \
         ({pin_banks} banks x {pin_slots} slots)",
        pinned.stats.wall,
        pinned.stats.pinned_workers,
        pinned.stats.socket_imbalance(),
        pinned.stats.local_steals,
        pinned.stats.remote_steals,
    );
    println!(
        "# unpinned_global: wall={:.3}s pinned_workers={} imbalance={:.3} steals local={} \
         remote={} bank_writes local={unp_local_w} remote={unp_remote_w} \
         ({unp_banks} banks x {unp_slots} slots)",
        unpinned.stats.wall,
        unpinned.stats.pinned_workers,
        unpinned.stats.socket_imbalance(),
        unpinned.stats.local_steals,
        unpinned.stats.remote_steals,
    );
    println!(
        "# sockets: busy={:?} measured_imbalance={:.3} \
         predicted_imbalance={predicted_imbalance:.3}",
        pinned.stats.socket_busy(),
        pinned.stats.socket_imbalance(),
    );

    let json = format!(
        concat!(
            "{{\"schema_version\":2,\"bench\":\"fig12_numa\",\"nodes\":{},\"arcs\":{},",
            "\"workers\":{},\"sockets\":{},",
            "\"pinned_banked_wall_seconds\":{:.6},\"unpinned_global_wall_seconds\":{:.6},",
            "\"pinned_workers\":{},",
            "\"pinned_socket_imbalance\":{:.4},\"unpinned_socket_imbalance\":{:.4},",
            "\"predicted_imbalance\":{:.4},",
            "\"pinned_local_steals\":{},\"pinned_remote_steals\":{},",
            "\"unpinned_local_steals\":{},\"unpinned_remote_steals\":{},",
            "\"pinned_bank_local_writes\":{},\"pinned_bank_remote_writes\":{},",
            "\"unpinned_bank_local_writes\":{},\"unpinned_bank_remote_writes\":{},",
            "\"simulated_makespan_seconds\":{:.6},\"census_identical\":{},\"pass\":{}}}\n"
        ),
        g.node_count(),
        g.arc_count(),
        workers,
        pinned.stats.socket_busy().len(),
        pinned.stats.wall,
        unpinned.stats.wall,
        pinned.stats.pinned_workers,
        pinned.stats.socket_imbalance(),
        unpinned.stats.socket_imbalance(),
        predicted_imbalance,
        pinned.stats.local_steals,
        pinned.stats.remote_steals,
        unpinned.stats.local_steals,
        unpinned.stats.remote_steals,
        pin_local_w,
        pin_remote_w,
        unp_local_w,
        unp_remote_w,
        sim.makespan,
        pass,
        pass,
    );
    std::fs::write("BENCH_fig12_numa.json", &json).expect("writing BENCH_fig12_numa.json");
    println!("# wrote BENCH_fig12_numa.json");
}
