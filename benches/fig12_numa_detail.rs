//! FIG12 bench: NUMA parallel-efficiency detail at 32-48 cores, plus a
//! measured-vs-predicted socket-balance check.
//!
//! The simulated series (fig12 table) models the paper's 48-core
//! Magny-Cours box. Since the executor now places seats and chunk slabs
//! per socket, this bench also runs a *measured* census on a synthetic
//! two-socket topology and compares the executor's per-socket busy-time
//! imbalance (and local/remote steal split) against the simulator's
//! predicted balance for the same worker count — recorded in
//! `BENCH_fig12_numa.json`. No pass/fail gate: the container is
//! single-socket, so the measured number tracks the placement logic,
//! not real NUMA latency.

use triadic::bench::Bench;
use triadic::census::{census_parallel_on, ParallelConfig};
use triadic::figures::{fig12, Scale};
use triadic::graph::GraphSpec;
use triadic::sched::{Executor, ExecutorConfig, Policy, Topology};
use triadic::simulator::{simulate, NumaMachine, WorkloadProfile};

fn main() {
    let mut b = Bench::from_env(3);
    b.run("fig12_numa_detail_small", || fig12(Scale::Small));
    println!("\n{}", fig12(Scale::Small));

    // measured: the same dynamic policy on a synthetic 2-socket (4+4)
    // executor; the paper's machine is modeled per-core by the simulator
    let workers = 8;
    let spec = GraphSpec::orkut(10_000);
    let g = spec.generate();
    let prof = WorkloadProfile::from_graph(spec.name, &g);
    let exec = Executor::with_topology(
        ExecutorConfig {
            workers,
            max_concurrent_jobs: 0,
        },
        Topology::synthetic(vec![4, 4]),
    );
    let cfg = ParallelConfig {
        threads: workers,
        policy: Policy::dynamic_default(),
        ..ParallelConfig::default()
    };
    let run = census_parallel_on(&g, &cfg, &exec);
    let measured_imbalance = run.stats.socket_imbalance();
    let busy = run.stats.socket_busy();

    let numa = NumaMachine::magny_cours();
    let sim = simulate(&numa, &prof, workers, Policy::dynamic_default());
    // SimResult::balance is mean/max (higher is better); invert to the
    // executor's max/mean imbalance convention
    let predicted_imbalance = 1.0 / sim.balance().max(1e-12);

    println!(
        "# sockets: busy={busy:?} measured_imbalance={measured_imbalance:.3} \
         predicted_imbalance={predicted_imbalance:.3} steals local={} remote={}",
        run.stats.local_steals, run.stats.remote_steals
    );

    let json = format!(
        concat!(
            "{{\"schema_version\":1,\"bench\":\"fig12_numa\",\"nodes\":{},\"arcs\":{},",
            "\"workers\":{},\"sockets\":{},",
            "\"measured_socket_imbalance\":{:.4},\"predicted_imbalance\":{:.4},",
            "\"local_steals\":{},\"remote_steals\":{},",
            "\"simulated_makespan_seconds\":{:.6},\"measured_wall_seconds\":{:.6}}}\n"
        ),
        g.node_count(),
        g.arc_count(),
        workers,
        busy.len(),
        measured_imbalance,
        predicted_imbalance,
        run.stats.local_steals,
        run.stats.remote_steals,
        sim.makespan,
        run.stats.wall,
    );
    std::fs::write("BENCH_fig12_numa.json", &json).expect("writing BENCH_fig12_numa.json");
    println!("# wrote BENCH_fig12_numa.json");
}
