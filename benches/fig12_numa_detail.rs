//! FIG12 bench: NUMA parallel-efficiency detail at 32-48 cores.

use triadic::bench::Bench;
use triadic::figures::{fig12, Scale};

fn main() {
    let mut b = Bench::from_env(3);
    b.run("fig12_numa_detail_small", || fig12(Scale::Small));
    println!("\n{}", fig12(Scale::Small));
}
