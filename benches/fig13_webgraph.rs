//! FIG13 bench: webgraph scaling on the 512-processor XMT (64-512).

use triadic::bench::Bench;
use triadic::figures::{fig13, Scale};

fn main() {
    let mut b = Bench::from_env(3);
    b.run("fig13_webgraph_small", || fig13(Scale::Small));
    println!("\n{}", fig13(Scale::Small));
}
