//! Hub-bitmap hybrid kernel vs the degree-ordered run-merge kernel.
//!
//! After the degree-descending relabel the heavy hub rows are nodes
//! `0..k`; the hybrid kernel classifies hub-involving dyads with packed
//! 2-bit-direction bitmap words (AND + popcount) instead of three-run
//! merges. This bench pins the trade on a 100k-node power-law graph:
//! the parallel engine runs over the natural CSR, the degree-ordered
//! direction-split form and the hub-split form, the censuses are
//! asserted byte-identical, and the speedup ratios land in
//! `BENCH_hub.json`.
//!
//! Gate: `"pass"` is true iff the hybrid kernel beats the plain
//! degree-ordered kernel (`speedup_vs_degree > 1.0`) — CI's perf-smoke
//! job greps for it. The comparison holds preprocessing constant (both
//! sides pay the same relabel + split; the bitmap build is reported
//! separately as one-off cost).

use triadic::bench::Bench;
use triadic::census::{census_hybrid_on, census_parallel_on, ParallelConfig};
use triadic::graph::generators::power_law;
use triadic::graph::relabel;
use triadic::graph::HubSplit;
use triadic::sched::Executor;

const NODES: usize = 100_000;

fn main() {
    let mut b = Bench::from_env(10);
    let threads = 4;
    let exec = Executor::with_workers(threads);

    eprintln!("# generating {NODES}-node power-law graph...");
    let g = power_law(NODES, 2.2, 8.0, 11);
    println!("# graph: n={} arcs={} dyads={}", g.node_count(), g.arc_count(), g.dyad_count());

    let t_prep = std::time::Instant::now();
    let (_relabeling, split) = relabel::degree_split(&g, threads);
    let prep_split_seconds = t_prep.elapsed().as_secs_f64();
    let t_hub = std::time::Instant::now();
    let hub = HubSplit::build(split);
    let prep_hub_seconds = t_hub.elapsed().as_secs_f64();
    println!(
        "# degree relabel + direction split: {prep_split_seconds:.3}s, {} hub bitmap rows: \
         {prep_hub_seconds:.3}s (one-off)",
        hub.hub_count()
    );
    assert!(hub.hub_count() > 0, "power-law graph must promote hub rows");

    let cfg = ParallelConfig {
        threads,
        ..ParallelConfig::default()
    };

    // identity first: timing means nothing if the kernels disagree
    let natural_run = census_parallel_on(&g, &cfg, &exec);
    let degree_run = census_parallel_on(hub.split(), &cfg, &exec);
    let hybrid_run = census_hybrid_on(&hub, &cfg, &exec);
    assert_eq!(natural_run.census, degree_run.census, "degree-ordered census diverged");
    assert_eq!(natural_run.census, hybrid_run.census, "hybrid census diverged");

    let parallel_natural = b
        .run(&format!("parallel_natural_t{threads}"), || {
            census_parallel_on(&g, &cfg, &exec)
        })
        .mean_s;
    let parallel_degree = b
        .run(&format!("parallel_degree_t{threads}"), || {
            census_parallel_on(hub.split(), &cfg, &exec)
        })
        .mean_s;
    let hybrid = b
        .run(&format!("hybrid_hub_t{threads}"), || {
            census_hybrid_on(&hub, &cfg, &exec)
        })
        .mean_s;

    let speedup_vs_natural = parallel_natural / hybrid.max(1e-12);
    let speedup_vs_degree = parallel_degree / hybrid.max(1e-12);
    let pass = speedup_vs_degree > 1.0;
    println!(
        "# hybrid(t{threads}): {:.1} ms vs degree {:.1} ms ({speedup_vs_degree:.2}x) vs natural \
         {:.1} ms ({speedup_vs_natural:.2}x) pass={pass}",
        hybrid * 1e3,
        parallel_degree * 1e3,
        parallel_natural * 1e3
    );

    let json = format!(
        concat!(
            "{{\"schema_version\":1,\"bench\":\"hub_kernel\",\"nodes\":{},\"arcs\":{},",
            "\"threads\":{},\"hub_rows\":{},",
            "\"prep_split_seconds\":{:.6},\"prep_hub_seconds\":{:.6},",
            "\"parallel_natural_seconds\":{:.6},\"parallel_degree_seconds\":{:.6},",
            "\"hybrid_seconds\":{:.6},",
            "\"speedup_vs_natural\":{:.4},\"speedup_vs_degree\":{:.4},",
            "\"census_identical\":true,\"pass\":{}}}\n"
        ),
        g.node_count(),
        g.arc_count(),
        threads,
        hub.hub_count(),
        prep_split_seconds,
        prep_hub_seconds,
        parallel_natural,
        parallel_degree,
        hybrid,
        speedup_vs_natural,
        speedup_vs_degree,
        pass,
    );
    std::fs::write("BENCH_hub.json", &json).expect("writing BENCH_hub.json");
    println!("# wrote BENCH_hub.json");
}
