//! Hub-bitmap hybrid kernel vs the degree-ordered run-merge kernel,
//! plus the scalar-vs-wide dense-kernel ablation.
//!
//! After the degree-descending relabel the heavy hub rows are nodes
//! `0..k`; the hybrid kernel classifies hub-involving dyads with packed
//! 2-bit-direction bitmap words (AND + popcount) instead of three-run
//! merges. The dense hub×hub path now has two kernels: the reference
//! scalar word loop and the 4-wide unrolled loop that skips range
//! masking for every word past the dyad boundary. This bench pins both
//! trades on a 100k-node power-law graph: the parallel engine runs over
//! the natural CSR, the degree-ordered direction-split form and the
//! hub-split form under each kernel, every census is asserted
//! byte-identical, and the speedup ratios land in `BENCH_hub.json`.
//!
//! Gate: `"pass"` is true iff the wide hybrid beats the plain
//! degree-ordered kernel (`speedup_vs_degree > 1.0`) AND beats the
//! scalar hybrid (`speedup_wide_vs_scalar > 1.0`) — CI's perf-smoke job
//! greps for it. The comparison holds preprocessing constant (both
//! sides pay the same relabel + split; the bitmap build is reported
//! separately as one-off cost).

use triadic::bench::Bench;
use triadic::census::{census_hybrid_with, census_parallel_on, HubKernelMode, ParallelConfig};
use triadic::graph::generators::power_law;
use triadic::graph::relabel;
use triadic::graph::HubSplit;
use triadic::sched::{CancelToken, Executor};

const NODES: usize = 100_000;

fn main() {
    let mut b = Bench::from_env(10);
    let threads = 4;
    let exec = Executor::with_workers(threads);

    eprintln!("# generating {NODES}-node power-law graph...");
    let g = power_law(NODES, 2.2, 8.0, 11);
    println!("# graph: n={} arcs={} dyads={}", g.node_count(), g.arc_count(), g.dyad_count());

    let t_prep = std::time::Instant::now();
    let (_relabeling, split) = relabel::degree_split(&g, threads);
    let prep_split_seconds = t_prep.elapsed().as_secs_f64();
    let t_hub = std::time::Instant::now();
    let hub = HubSplit::build(split);
    let prep_hub_seconds = t_hub.elapsed().as_secs_f64();
    println!(
        "# degree relabel + direction split: {prep_split_seconds:.3}s, {} hub bitmap rows: \
         {prep_hub_seconds:.3}s (one-off)",
        hub.hub_count()
    );
    assert!(hub.hub_count() > 0, "power-law graph must promote hub rows");

    let cfg = ParallelConfig {
        threads,
        ..ParallelConfig::default()
    };
    let never = CancelToken::new();
    let hybrid_run = |mode: HubKernelMode| {
        census_hybrid_with(&hub, &cfg, &exec, &never, mode).expect("fresh token never cancels")
    };

    // identity first: timing means nothing if any kernel disagrees
    let natural_run = census_parallel_on(&g, &cfg, &exec);
    let degree_run = census_parallel_on(hub.split(), &cfg, &exec);
    let scalar_run = hybrid_run(HubKernelMode::Scalar);
    let wide_run = hybrid_run(HubKernelMode::Wide);
    assert_eq!(natural_run.census, degree_run.census, "degree-ordered census diverged");
    assert_eq!(natural_run.census, scalar_run.census, "scalar hybrid census diverged");
    assert_eq!(natural_run.census, wide_run.census, "wide hybrid census diverged");

    let parallel_natural = b
        .run(&format!("parallel_natural_t{threads}"), || {
            census_parallel_on(&g, &cfg, &exec)
        })
        .mean_s;
    let parallel_degree = b
        .run(&format!("parallel_degree_t{threads}"), || {
            census_parallel_on(hub.split(), &cfg, &exec)
        })
        .mean_s;
    let hybrid_scalar = b
        .run(&format!("hybrid_hub_scalar_t{threads}"), || {
            hybrid_run(HubKernelMode::Scalar)
        })
        .mean_s;
    let hybrid_wide = b
        .run(&format!("hybrid_hub_wide_t{threads}"), || {
            hybrid_run(HubKernelMode::Wide)
        })
        .mean_s;

    let speedup_vs_natural = parallel_natural / hybrid_wide.max(1e-12);
    let speedup_vs_degree = parallel_degree / hybrid_wide.max(1e-12);
    let speedup_wide_vs_scalar = hybrid_scalar / hybrid_wide.max(1e-12);
    let pass = speedup_vs_degree > 1.0 && speedup_wide_vs_scalar > 1.0;
    println!(
        "# hybrid_wide(t{threads}): {:.1} ms vs scalar {:.1} ms ({speedup_wide_vs_scalar:.2}x) \
         vs degree {:.1} ms ({speedup_vs_degree:.2}x) vs natural {:.1} ms \
         ({speedup_vs_natural:.2}x) pass={pass}",
        hybrid_wide * 1e3,
        hybrid_scalar * 1e3,
        parallel_degree * 1e3,
        parallel_natural * 1e3
    );

    let json = format!(
        concat!(
            "{{\"schema_version\":2,\"bench\":\"hub_kernel\",\"nodes\":{},\"arcs\":{},",
            "\"threads\":{},\"hub_rows\":{},",
            "\"prep_split_seconds\":{:.6},\"prep_hub_seconds\":{:.6},",
            "\"parallel_natural_seconds\":{:.6},\"parallel_degree_seconds\":{:.6},",
            "\"hybrid_scalar_seconds\":{:.6},\"hybrid_wide_seconds\":{:.6},",
            "\"speedup_vs_natural\":{:.4},\"speedup_vs_degree\":{:.4},",
            "\"speedup_wide_vs_scalar\":{:.4},",
            "\"census_identical\":true,\"pass\":{}}}\n"
        ),
        g.node_count(),
        g.arc_count(),
        threads,
        hub.hub_count(),
        prep_split_seconds,
        prep_hub_seconds,
        parallel_natural,
        parallel_degree,
        hybrid_scalar,
        hybrid_wide,
        speedup_vs_natural,
        speedup_vs_degree,
        speedup_wide_vs_scalar,
        pass,
    );
    std::fs::write("BENCH_hub.json", &json).expect("writing BENCH_hub.json");
    println!("# wrote BENCH_hub.json");
}
