//! Natural vs degree-descending vertex ordering on a skewed graph.
//!
//! The degree-descending relabel + direction-split preprocessing exists
//! because power-law degree skew dominates traversal cost and load
//! balance. This bench pins the trade on a 100k-node power-law graph:
//! the `merged` (serial) and `parallel` engines run over the natural
//! CSR and over the degree-ordered direction-split form, the censuses
//! are asserted byte-identical (ordering must never change results),
//! and the speedup ratios — plus the one-off preprocessing cost — are
//! recorded in `BENCH_ordering.json` for the CI bench trajectory.
//!
//! No pass/fail gate: the win is machine- and skew-dependent; the
//! artifact records the trajectory instead.

use triadic::bench::Bench;
use triadic::census::{census_parallel_on, merged, ParallelConfig};
use triadic::graph::generators::power_law;
use triadic::graph::relabel;
use triadic::sched::Executor;

const NODES: usize = 100_000;

fn main() {
    let mut b = Bench::from_env(10);
    let threads = 4;
    let exec = Executor::with_workers(threads);

    eprintln!("# generating {NODES}-node power-law graph...");
    let g = power_law(NODES, 2.2, 8.0, 11);
    println!("# graph: n={} arcs={} dyads={}", g.node_count(), g.arc_count(), g.dyad_count());

    let t_prep = std::time::Instant::now();
    let (_relabeling, split) = relabel::degree_split(&g, threads);
    let prep_seconds = t_prep.elapsed().as_secs_f64();
    println!("# degree relabel + direction split: {prep_seconds:.3}s (one-off)");

    // ordering must be census-invariant before any timing means a thing
    let natural_census = merged::census(&g);
    let ordered_census = merged::census(&split);
    assert_eq!(
        natural_census, ordered_census,
        "degree-ordered census diverged from natural order"
    );

    let merged_natural = b.run("merged_natural", || merged::census(&g)).mean_s;
    let merged_degree = b.run("merged_degree", || merged::census(&split)).mean_s;

    let cfg = ParallelConfig {
        threads,
        ..ParallelConfig::default()
    };
    let parallel_natural = b
        .run(&format!("parallel_natural_t{threads}"), || {
            census_parallel_on(&g, &cfg, &exec)
        })
        .mean_s;
    let parallel_degree = b
        .run(&format!("parallel_degree_t{threads}"), || {
            census_parallel_on(&split, &cfg, &exec)
        })
        .mean_s;

    let merged_speedup = merged_natural / merged_degree.max(1e-12);
    let parallel_speedup = parallel_natural / parallel_degree.max(1e-12);
    println!(
        "# merged: natural {:.1} ms vs degree {:.1} ms -> {merged_speedup:.2}x",
        merged_natural * 1e3,
        merged_degree * 1e3
    );
    println!(
        "# parallel(t{threads}): natural {:.1} ms vs degree {:.1} ms -> {parallel_speedup:.2}x",
        parallel_natural * 1e3,
        parallel_degree * 1e3
    );

    let json = format!(
        concat!(
            "{{\"schema_version\":1,\"bench\":\"ordering\",\"nodes\":{},\"arcs\":{},",
            "\"threads\":{},\"prep_seconds\":{:.6},",
            "\"merged_natural_seconds\":{:.6},\"merged_degree_seconds\":{:.6},",
            "\"parallel_natural_seconds\":{:.6},\"parallel_degree_seconds\":{:.6},",
            "\"merged_speedup\":{:.4},\"parallel_speedup\":{:.4},",
            "\"census_identical\":true}}\n"
        ),
        g.node_count(),
        g.arc_count(),
        threads,
        prep_seconds,
        merged_natural,
        merged_degree,
        parallel_natural,
        parallel_degree,
        merged_speedup,
        parallel_speedup,
    );
    std::fs::write("BENCH_ordering.json", &json).expect("writing BENCH_ordering.json");
    println!("# wrote BENCH_ordering.json");
}
