//! Sampled vs exact incremental census maintenance.
//!
//! The sampled census exists so a firehose of edge mutations can be
//! absorbed at a fraction of the exact per-op cost. This bench pins
//! that down on a 100k-node power-law graph: a 64-op mixed
//! insert/delete batch applied through `SampledCensus` at p = 0.05 and
//! p = 0.2 is compared against the same batch through the exact
//! `StreamingCensus`, and each rate's estimate is scored against the
//! exact census of the seed graph (sum of absolute per-class errors
//! over the non-null mass). Acceptance target: p = 0.05 maintenance
//! >= 3x faster than exact.
//!
//! Writes `BENCH_sampled.json` (schema_version 1) for the CI bench
//! trajectory and exits non-zero if the target is missed.

use std::sync::Arc;

use triadic::bench::Bench;
use triadic::census::{merged, SampledCensus, StreamingCensus, TriadType, DEFAULT_SAMPLE_SEED};
use triadic::graph::generators::power_law;
use triadic::graph::EdgeOp;
use triadic::rng::Rng;
use triadic::sched::Executor;

const NODES: usize = 100_000;
const BATCH: usize = 64;

/// Sum of absolute per-class estimate errors over the non-null mass.
fn relative_error(sc: &SampledCensus, truth: &triadic::Census) -> f64 {
    let est = sc.estimate();
    let (mut err, mut mass) = (0.0f64, 0.0f64);
    for t in TriadType::ALL {
        if t == TriadType::T003 {
            continue;
        }
        err += (est.class(t).estimate - truth[t] as f64).abs();
        mass += truth[t] as f64;
    }
    err / mass.max(1.0)
}

fn main() {
    let iters: usize = std::env::var("BENCH_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(30);
    let mut b = Bench::new(iters);
    let threads = 4;
    let exec = Executor::with_workers(threads);

    eprintln!("# generating {NODES}-node power-law graph...");
    let g = power_law(NODES, 2.2, 8.0, 7);
    let arcs: Vec<(u32, u32)> = g.arcs().collect();
    println!("# graph: n={} arcs={}", g.node_count(), g.arc_count());

    // the same pre-generated mixed batches drive every session: 70%
    // inserts of random pairs, 30% deletes of existing arcs
    let mut rng = Rng::new(99);
    let total_batches = 3 * (4 * iters + 8);
    let batches: Vec<Vec<EdgeOp>> = (0..total_batches)
        .map(|_| {
            (0..BATCH)
                .map(|_| {
                    if rng.chance(0.3) {
                        let (u, v) = arcs[rng.below(arcs.len() as u64) as usize];
                        EdgeOp::Delete(u, v)
                    } else {
                        EdgeOp::Insert(rng.node(NODES as u32), rng.node(NODES as u32))
                    }
                })
                .collect()
        })
        .collect();
    let mut next = 0usize;

    let t_truth = std::time::Instant::now();
    let truth = merged::census(&g);
    println!("# exact census of the seed graph: {:.3}s", t_truth.elapsed().as_secs_f64());

    let t_seed = std::time::Instant::now();
    let mut exact = StreamingCensus::new(Arc::new(g.clone()));
    let exact_seed_seconds = t_seed.elapsed().as_secs_f64();
    let exact_batch = b
        .run(&format!("exact_delta_batch{BATCH}"), || {
            let report = exact.apply_batch(&batches[next % batches.len()], &exec, threads);
            next += 1;
            report
        })
        .mean_s;

    let mut rows = Vec::new();
    for p in [0.05f64, 0.2] {
        let t_seed = std::time::Instant::now();
        let mut sc = SampledCensus::new(Arc::new(g.clone()), p, DEFAULT_SAMPLE_SEED);
        let seed_seconds = t_seed.elapsed().as_secs_f64();
        let rel_error = relative_error(&sc, &truth);
        let batch_seconds = b
            .run(&format!("sampled_p{p}_delta_batch{BATCH}"), || {
                let report = sc.apply_batch(&batches[next % batches.len()], &exec, threads);
                next += 1;
                report
            })
            .mean_s;
        let speedup = exact_batch / batch_seconds.max(1e-12);
        println!(
            "# p={p}: seed {seed_seconds:.3}s (exact {exact_seed_seconds:.3}s), batch \
             {:.1} us vs exact {:.1} us -> {speedup:.1}x, rel_error {rel_error:.4}",
            batch_seconds * 1e6,
            exact_batch * 1e6
        );
        rows.push((p, seed_seconds, batch_seconds, speedup, rel_error));
    }

    // acceptance: the aggressive rate must buy at least 3x on the
    // maintenance path
    let pass = rows[0].3 >= 3.0;
    let row_json: Vec<String> = rows
        .iter()
        .map(|(p, seed, batch, speedup, rel)| {
            format!(
                concat!(
                    "{{\"p\":{},\"seed_seconds\":{:.6},\"delta_batch_seconds\":{:.9},",
                    "\"speedup_vs_exact\":{:.2},\"relative_error\":{:.6}}}"
                ),
                p, seed, batch, speedup, rel
            )
        })
        .collect();
    let json = format!(
        concat!(
            "{{\"schema_version\":1,\"bench\":\"sampled_census\",\"nodes\":{},\"arcs\":{},",
            "\"batch\":{},\"exact_seed_seconds\":{:.6},\"exact_delta_batch_seconds\":{:.9},",
            "\"rates\":[{}],\"pass\":{}}}\n"
        ),
        g.node_count(),
        g.arc_count(),
        BATCH,
        exact_seed_seconds,
        exact_batch,
        row_json.join(","),
        pass,
    );
    std::fs::write("BENCH_sampled.json", &json).expect("writing BENCH_sampled.json");
    println!("# wrote BENCH_sampled.json");
    if !pass {
        eprintln!(
            "FAIL: p=0.05 maintenance only {:.1}x faster than exact (need 3x)",
            rows[0].3
        );
        std::process::exit(1);
    }
}
