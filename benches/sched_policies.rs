//! SCHED bench: the scheduling-policy study (static/dynamic/guided) —
//! measured on this host over the persistent executor, and simulated on
//! the paper's machines.

use triadic::bench::Bench;
use triadic::census::{census_parallel_on, Accumulation, ParallelConfig};
use triadic::figures::{fig_sched, Scale};
use triadic::graph::generators::power_law;
use triadic::sched::{Executor, Policy};

fn main() {
    let mut b = Bench::from_env(2);

    // measured: each policy schedules the same power-law census over
    // the shared pool; dynamic should win, guided underperform
    let exec = Executor::with_workers(4);
    let g = power_law(20_000, 2.2, 10.0, 42);
    for policy in [
        Policy::static_default(),
        Policy::dynamic_default(),
        Policy::guided_default(),
    ] {
        let cfg = ParallelConfig {
            threads: 4,
            policy,
            accumulation: Accumulation::PerThread,
        };
        let name = format!("census_20k_{}_t4_executor", policy.name());
        b.run(&name, || census_parallel_on(&g, &cfg, &exec));
    }
    println!("# executor: {:?}", exec.stats());

    // simulated: the paper's three machines
    b.run("sched_policies_small", || fig_sched(Scale::Small));
    println!("\n{}", fig_sched(Scale::Small));
}
