//! SCHED bench: the scheduling-policy study (static/dynamic/guided),
//! simulated on the paper's machines and measured on this host.

use triadic::bench::Bench;
use triadic::figures::{fig_sched, Scale};

fn main() {
    let mut b = Bench::from_env(2);
    b.run("sched_policies_small", || fig_sched(Scale::Small));
    println!("\n{}", fig_sched(Scale::Small));
}
