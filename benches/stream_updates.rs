//! Streaming delta updates vs full census recompute.
//!
//! The streaming census exists because a small batch of edge mutations
//! must not cost a full recompute on a serving graph. This bench pins
//! that down on a 100k-node power-law graph: a 64-op mixed
//! insert/delete batch applied through `StreamingCensus` is compared
//! against recomputing the census from scratch (serial merged engine
//! and the parallel engine — the speedup is measured against whichever
//! recompute is *faster*). Acceptance target: >= 10x.
//!
//! Writes `BENCH_stream.json` (schema_version 1) for the CI bench
//! trajectory and exits non-zero if the target is missed.

use std::sync::Arc;

use triadic::bench::Bench;
use triadic::census::{census_parallel_on, merged, ParallelConfig, StreamingCensus};
use triadic::graph::generators::power_law;
use triadic::graph::EdgeOp;
use triadic::rng::Rng;
use triadic::sched::Executor;

const NODES: usize = 100_000;
const BATCH: usize = 64;

fn main() {
    let iters: usize = std::env::var("BENCH_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(30);
    let mut b = Bench::new(iters);
    let threads = 4;
    let exec = Executor::with_workers(threads);

    eprintln!("# generating {NODES}-node power-law graph...");
    let g = power_law(NODES, 2.2, 8.0, 7);
    let arcs: Vec<(u32, u32)> = g.arcs().collect();
    println!("# graph: n={} arcs={}", g.node_count(), g.arc_count());

    // pre-generate enough mixed batches for warmup + iterations: 70%
    // inserts of random pairs, 30% deletes of existing arcs
    let mut rng = Rng::new(99);
    let total_batches = 4 * iters + 8;
    let batches: Vec<Vec<EdgeOp>> = (0..total_batches)
        .map(|_| {
            (0..BATCH)
                .map(|_| {
                    if rng.chance(0.3) {
                        let (u, v) = arcs[rng.below(arcs.len() as u64) as usize];
                        EdgeOp::Delete(u, v)
                    } else {
                        EdgeOp::Insert(rng.node(NODES as u32), rng.node(NODES as u32))
                    }
                })
                .collect()
        })
        .collect();

    let t_seed = std::time::Instant::now();
    let mut sc = StreamingCensus::new(Arc::new(g.clone()));
    let seed_seconds = t_seed.elapsed().as_secs_f64();
    println!("# seed census (merged, one-off): {seed_seconds:.3}s");

    let mut next = 0usize;
    let delta = b
        .run(&format!("stream_delta_batch{BATCH}"), || {
            let report = sc.apply_batch(&batches[next % batches.len()], &exec, threads);
            next += 1;
            report
        })
        .mean_s;

    let full_merged = b.run("full_recompute_merged", || merged::census(&g)).mean_s;
    let cfg = ParallelConfig {
        threads,
        ..ParallelConfig::default()
    };
    let full_parallel = b
        .run(&format!("full_recompute_parallel_t{threads}"), || {
            census_parallel_on(&g, &cfg, &exec)
        })
        .mean_s;

    // measure against the *faster* recompute — the honest baseline
    let full = full_merged.min(full_parallel);
    let speedup = full / delta.max(1e-12);
    let pass = speedup >= 10.0;
    println!(
        "# {BATCH}-op delta batch: {:.1} us vs full recompute {:.1} ms -> {speedup:.1}x \
         (target >= 10x)",
        delta * 1e6,
        full * 1e3
    );

    let json = format!(
        concat!(
            "{{\"schema_version\":1,\"bench\":\"stream_updates\",\"nodes\":{},\"arcs\":{},",
            "\"batch\":{},\"seed_seconds\":{:.6},\"delta_batch_seconds\":{:.9},",
            "\"full_recompute_merged_seconds\":{:.6},\"full_recompute_parallel_seconds\":{:.6},",
            "\"speedup_vs_recompute\":{:.2},\"pass\":{}}}\n"
        ),
        g.node_count(),
        g.arc_count(),
        BATCH,
        seed_seconds,
        delta,
        full_merged,
        full_parallel,
        speedup,
        pass,
    );
    std::fs::write("BENCH_stream.json", &json).expect("writing BENCH_stream.json");
    println!("# wrote BENCH_stream.json");
    if !pass {
        eprintln!("FAIL: delta batch only {speedup:.1}x faster than full recompute (need 10x)");
        std::process::exit(1);
    }
}
