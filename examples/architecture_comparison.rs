//! The paper's §7 study as a runnable example: characterize the three
//! workloads, sweep the three machine models, and print the crossover
//! analysis the paper's Figs 10–13 describe.
//!
//! ```sh
//! cargo run --release --example architecture_comparison [-- full]
//! ```

use triadic::graph::GraphSpec;
use triadic::sched::Policy;
use triadic::simulator::{
    simulate, Machine, NumaMachine, SuperdomeMachine, WorkloadProfile, XmtMachine,
};

fn main() {
    let full = std::env::args().any(|a| a == "full");
    let (np, no, nw) = if full {
        (200_000, 50_000, 400_000)
    } else {
        (60_000, 12_000, 80_000)
    };

    let workloads = [
        GraphSpec::patents(np),
        GraphSpec::orkut(no),
        GraphSpec::webgraph(nw),
    ];
    let xmt = XmtMachine::pnnl();
    let numa = NumaMachine::magny_cours();
    let sd = SuperdomeMachine::sd64();
    let machines: [&dyn Machine; 3] = [&xmt, &numa, &sd];
    let pol = Policy::dynamic_default();

    for spec in &workloads {
        eprintln!("generating {} (n={})...", spec.name, spec.n);
        let g = spec.generate();
        let prof = WorkloadProfile::from_graph(spec.name, &g);
        println!(
            "\n=== {} === n={} arcs={} slots={} slot-imbalance={:.0}x random_fraction={:.2}",
            spec.name,
            g.node_count(),
            g.arc_count(),
            prof.len(),
            prof.imbalance(),
            prof.random_fraction
        );
        println!("{:>6} {:>14} {:>14} {:>14}", "procs", "XMT", "NUMA", "Superdome");
        let procs = [1usize, 2, 4, 8, 16, 32, 36, 40, 48, 64, 96, 128];
        let mut series: Vec<Vec<Option<f64>>> = vec![Vec::new(); 3];
        for &p in &procs {
            let mut row = format!("{p:>6}");
            for (i, m) in machines.iter().enumerate() {
                if p <= m.max_procs() {
                    let t = simulate(*m, &prof, p, pol).makespan;
                    series[i].push(Some(t));
                    row += &format!(" {:>12.3}ms", t * 1e3);
                } else {
                    series[i].push(None);
                    row += &format!(" {:>14}", "-");
                }
            }
            println!("{row}");
        }

        // crossover analysis: first p where XMT beats NUMA / Superdome
        for (other_idx, other_name) in [(1usize, "NUMA"), (2, "Superdome")] {
            let cross = procs.iter().enumerate().find_map(|(i, &p)| {
                match (series[0][i], series[other_idx][i]) {
                    (Some(x), Some(o)) if x < o => Some(p),
                    _ => None,
                }
            });
            match cross {
                Some(p) => println!("  XMT overtakes {other_name} at ~{p} procs"),
                None => println!("  XMT never overtakes {other_name} in this sweep"),
            }
        }
    }

    // Fig 13: the big-machine run
    println!("\n=== webgraph on the 512-processor XMT (Fig 13) ===");
    let spec = GraphSpec::webgraph(nw);
    let g = spec.generate();
    let prof = WorkloadProfile::from_graph(spec.name, &g);
    let m512 = XmtMachine::cray512();
    let t64 = simulate(&m512, &prof, 64, pol).makespan;
    println!("{:>6} {:>14} {:>10}", "procs", "time", "speedup");
    for p in [64usize, 128, 256, 512] {
        let t = simulate(&m512, &prof, p, pol).makespan;
        println!("{p:>6} {:>12.3}ms {:>9.1}x", t * 1e3, t64 / t * 64.0);
    }
    println!("\narchitecture_comparison OK");
}
