//! Dense-backend serving demo: batched census requests through the
//! PJRT AOT path, with latency/throughput reporting — the
//! "coordinator as a serving router" view of the system.
//!
//! ```sh
//! make artifacts && cargo run --release --example dense_service
//! ```
//!
//! Submits a mixed stream of window-sized graphs, reports per-size
//! latency percentiles and overall throughput, and cross-checks a
//! sample of responses against the sparse engine.

use std::path::PathBuf;

use triadic::census::merged;
use triadic::coordinator::{Coordinator, CoordinatorConfig, Route};
use triadic::graph::generators::erdos_renyi;

fn main() -> triadic::error::Result<()> {
    let artifacts = ["artifacts", "../artifacts"]
        .iter()
        .map(PathBuf::from)
        .find(|p| p.join("manifest.tsv").exists());
    if artifacts.is_none() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }
    let coord = Coordinator::start(CoordinatorConfig {
        artifacts_dir: artifacts,
        ..CoordinatorConfig::default()
    })?;
    triadic::ensure!(coord.dense_enabled(), "dense backend failed to start");

    // a mixed request stream: three window sizes, dense-routable
    let mut requests = Vec::new();
    for seed in 0..60u64 {
        let (n, m) = match seed % 3 {
            0 => (48, 400),
            1 => (100, 1500),
            _ => (220, 5000),
        };
        requests.push(erdos_renyi(n, m, seed));
    }

    let t0 = std::time::Instant::now();
    let mut latencies: Vec<(usize, f64)> = Vec::new();
    for (i, g) in requests.iter().enumerate() {
        let out = coord.census(g)?;
        let Route::Dense { size } = out.route else {
            triadic::bail!("request {i} unexpectedly routed sparse");
        };
        latencies.push((size, out.seconds));
        // spot-check exactness on every 10th request
        if i % 10 == 0 {
            triadic::ensure!(
                out.census == merged::census(g),
                "dense result mismatch on request {i}"
            );
        }
    }
    let wall = t0.elapsed().as_secs_f64();

    println!("served {} dense census requests in {wall:.3}s", requests.len());
    println!("throughput: {:.1} req/s\n", requests.len() as f64 / wall);
    for size in [64usize, 128, 256] {
        let mut ls: Vec<f64> = latencies
            .iter()
            .filter(|(s, _)| *s == size)
            .map(|&(_, l)| l)
            .collect();
        if ls.is_empty() {
            continue;
        }
        ls.sort_by(f64::total_cmp);
        let p = |q: f64| ls[((ls.len() - 1) as f64 * q) as usize];
        println!(
            "artifact {size:>3}: {:>2} reqs  p50 {:>8.3}ms  p90 {:>8.3}ms  max {:>8.3}ms",
            ls.len(),
            p(0.5) * 1e3,
            p(0.9) * 1e3,
            ls.last().unwrap() * 1e3
        );
    }
    println!("\nmetrics:\n{}", coord.metrics().render());
    println!("dense_service OK");
    Ok(())
}
