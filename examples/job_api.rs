//! The job-oriented census API, in-process and over the wire.
//!
//! ```sh
//! cargo run --release --example job_api
//! ```
//!
//! Starts a coordinator, submits a batch of census jobs (generator,
//! inline and per-request-tuned sources), polls the handles while they
//! run, then does the same round trip through a loopback TCP server
//! with the `TriadicClient` — the exact path `repro serve` / `repro
//! client` use.

use std::sync::Arc;

use triadic::census::TriadType;
use triadic::coordinator::{
    CensusRequest, CensusServer, Coordinator, CoordinatorConfig, JobStatus, TriadicClient,
};
use triadic::sched::Policy;

fn main() {
    // 1. A sparse-only coordinator: 4 executor workers shared by every
    //    job, 2 job runners draining the submit queue.
    let coord = Arc::new(
        Coordinator::start(CoordinatorConfig {
            artifacts_dir: None,
            pool_threads: 4,
            job_workers: 2,
            ..CoordinatorConfig::default()
        })
        .expect("coordinator starts"),
    );

    // 2. Submit a batch: three sources, three engines, one request with
    //    its own thread count and schedule policy.
    let handles = coord.submit_batch(vec![
        CensusRequest::generator("patents", 20_000).seed(7),
        CensusRequest::inline(4, vec![(0, 1), (1, 2), (2, 0), (2, 3)]).engine("merged"),
        CensusRequest::generator("orkut", 5_000)
            .seed(9)
            .engine("parallel")
            .threads(2)
            .policy(Policy::Dynamic { chunk: 128 })
            .classes(vec![TriadType::T030T, TriadType::T030C]),
    ]);

    // 3. Poll the handles like a dashboard would (non-blocking)...
    loop {
        let states: Vec<_> = handles.iter().map(|h| h.poll().kind().as_str()).collect();
        println!("jobs: {states:?}");
        if handles.iter().all(|h| h.poll().is_terminal()) {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(50));
    }

    // 4. ...then collect the typed responses.
    for handle in &handles {
        match handle.poll() {
            JobStatus::Done(resp) => println!(
                "job {}: engine={} route={} nodes={} {:.3}s, {} classes returned",
                resp.job,
                resp.provenance.engine,
                resp.provenance.route,
                resp.provenance.nodes,
                resp.seconds,
                resp.selected_counts().len(),
            ),
            other => println!("job {}: {:?}", handle.id(), other.kind()),
        }
    }

    // 5. The same API over TCP: serve on a loopback port, drive it with
    //    the library client, shut it down over the protocol.
    let server = CensusServer::bind(coord.clone(), "127.0.0.1:0").expect("bind");
    let addr = server.local_addr();
    let server_thread = std::thread::spawn(move || server.run().expect("serve"));

    let mut client = TriadicClient::connect(addr).expect("connect");
    let response = client
        .census(&CensusRequest::generator("web", 10_000).seed(3))
        .expect("remote census");
    println!(
        "over the wire: job {} census total {} ({} nodes) in {:.3}s",
        response.job,
        response.census.total(),
        response.provenance.nodes,
        response.seconds
    );
    println!("server status: {}", client.status().expect("status"));
    client.shutdown().expect("shutdown");
    server_thread.join().expect("server thread");
}
