//! Quickstart: the five-minute tour of the public API.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```
//!
//! Generates a scale-free graph, computes its triad census four ways
//! (naive oracle, Batagelj–Mrvar, merged-traversal, parallel), verifies
//! they agree, and prints the census with degree statistics.

use triadic::census::{batagelj_mrvar, census_parallel, merged, naive, ParallelConfig, TriadType};
use triadic::graph::degree::{fit_out_degree_exponent, out_degrees, DegreeStats};
use triadic::graph::generators;

fn main() {
    // 1. Generate a directed scale-free graph (deterministic by seed).
    let n = 2_000;
    let g = generators::power_law(n, 2.2, 8.0, 42);
    println!(
        "graph: {} nodes, {} arcs, {} connected dyads",
        g.node_count(),
        g.arc_count(),
        g.dyad_count()
    );

    // 2. Degree analysis (the paper's Fig 6 characterization).
    let degs = out_degrees(&g);
    let stats = DegreeStats::from_sequence(&degs);
    println!(
        "outdegree: max={} mean={:.2} imbalance={:.1}x fitted_gamma={:.2}",
        stats.max,
        stats.mean,
        stats.imbalance,
        fit_out_degree_exponent(&g).unwrap_or(f64::NAN)
    );

    // 3. Triad census, four ways.
    let t0 = std::time::Instant::now();
    let c_naive = naive::census(&g);
    let t_naive = t0.elapsed();

    let t0 = std::time::Instant::now();
    let c_bm = batagelj_mrvar::census(&g);
    let t_bm = t0.elapsed();

    let t0 = std::time::Instant::now();
    let c_merged = merged::census(&g);
    let t_merged = t0.elapsed();

    let t0 = std::time::Instant::now();
    let run = census_parallel(&g, &ParallelConfig::default());
    let t_par = t0.elapsed();

    assert_eq!(c_naive, c_bm, "BM must match the oracle");
    assert_eq!(c_naive, c_merged, "merged traversal must match the oracle");
    assert_eq!(c_naive, run.census, "parallel engine must match the oracle");

    println!("\ncensus (all four implementations agree):");
    print!("{}", run.census.table());
    println!(
        "totals: {} triads = C({n},3); {} transitive vs {} cyclic",
        run.census.total(),
        run.census[TriadType::T030T],
        run.census[TriadType::T030C],
    );
    println!(
        "\ntimings: naive O(n^3) {:?} | batagelj-mrvar {:?} | merged {:?} | parallel {:?}",
        t_naive, t_bm, t_merged, t_par
    );
    println!(
        "merged-traversal speedup over naive: {:.0}x",
        t_naive.as_secs_f64() / t_merged.as_secs_f64()
    );
}
