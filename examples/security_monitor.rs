//! End-to-end driver (the paper's application, Figs 3–4): synthesize
//! network traffic with four injected attacks, stream it through the
//! windowed census pipeline with the **full coordinator stack** — dense
//! AOT (JAX/Pallas via PJRT) backend for the small window graphs when
//! artifacts are present, sparse parallel engine otherwise — and run the
//! triadic anomaly monitor over the census series.
//!
//! ```sh
//! make artifacts && cargo run --release --example security_monitor
//! ```
//!
//! This is the workload that proves all three layers compose: Python
//! authored the dense census at build time; at run time Rust windows the
//! traffic, routes each window's graph to PJRT, and alerts on the
//! result. Exits non-zero if any layer disagrees or any attack is missed.

use std::path::PathBuf;

use triadic::analysis::{
    builtin_patterns, census_series, MonitorConfig, TrafficGenerator, TrafficScenario,
    TriadMonitor,
};
use triadic::census::merged;
use triadic::coordinator::{Coordinator, CoordinatorConfig, Route};

fn main() -> triadic::error::Result<()> {
    // --- 1. Traffic: 90 s of background + the four Fig 3 activities ---
    let duration = 90.0;
    let gen = TrafficGenerator::background(400, 120.0, 2012)
        .with(TrafficScenario::PortScan {
            start: 25.2,
            end: 25.9,
            attacker: 5,
            targets: 60,
        })
        .with(TrafficScenario::Ddos {
            start: 45.1,
            end: 45.8,
            victim: 2,
            sources: 60,
        })
        .with(TrafficScenario::Relay {
            start: 60.1,
            end: 60.9,
            first_hop: 4_000_000,
            length: 16,
            chains: 12,
        })
        .with(TrafficScenario::BotnetSync {
            start: 75.1,
            end: 75.9,
            first_peer: 3_000_000,
            peers: 12,
        });
    let events = gen.generate(duration);
    println!("traffic: {} events over {duration}s", events.len());

    // --- 2. Coordinator: dense AOT backend if artifacts exist ---------
    let artifacts = ["artifacts", "../artifacts"]
        .iter()
        .map(PathBuf::from)
        .find(|p| p.join("manifest.tsv").exists());
    let coord = Coordinator::start(CoordinatorConfig {
        artifacts_dir: artifacts.clone(),
        // Window graphs are sparse; drop the density gate so every
        // window that fits an artifact exercises the dense PJRT path.
        routing: triadic::coordinator::RoutingPolicy {
            min_dense_density: 0.0,
            ..Default::default()
        },
        ..CoordinatorConfig::default()
    })?;
    println!(
        "coordinator: dense backend {}",
        if coord.dense_enabled() {
            "ENABLED (PJRT artifacts loaded)"
        } else {
            "disabled (run `make artifacts` for the full three-layer path)"
        }
    );

    // --- 3. Windowed census via the coordinator ----------------------
    let mut dense_windows = 0usize;
    let mut sparse_windows = 0usize;
    let series = census_series(&events, 1.0, |g| {
        let out = coord.census(g).expect("census request failed");
        match out.route {
            Route::Dense { .. } => dense_windows += 1,
            Route::Sparse => sparse_windows += 1,
        }
        // cross-check every window against the sparse reference engine:
        // the AOT path must be *exact*
        assert_eq!(out.census, merged::census(g), "dense/sparse mismatch!");
        out.census
    });
    println!(
        "windows: {} total ({} dense-routed, {} sparse-routed), all cross-checked exact",
        series.len(),
        dense_windows,
        sparse_windows
    );

    // --- 4. Monitor + alerts -----------------------------------------
    let mut mon = TriadMonitor::new(MonitorConfig::default(), builtin_patterns());
    let mut alerts = Vec::new();
    for w in &series {
        alerts.extend(mon.observe(w));
    }
    for a in &alerts {
        println!(
            "ALERT t={:>3.0}s {:<12} score={:>6.1}  top classes: {} {} {}",
            a.window_start,
            a.pattern,
            a.score,
            a.top_classes[0],
            a.top_classes[1],
            a.top_classes[2]
        );
    }

    // --- 5. Verify every injected attack was caught -------------------
    let caught = |pattern: &str, t: f64| {
        alerts
            .iter()
            .any(|a| a.pattern == pattern && (a.window_start - t).abs() < 1.5)
    };
    let expectations = [
        ("port-scan", 25.0),
        ("ddos", 45.0),
        ("relay", 60.0),
        ("botnet-sync", 75.0),
    ];
    let mut missed = 0;
    for (p, t) in expectations {
        if caught(p, t) {
            println!("detected: {p} at t={t}s");
        } else {
            println!("MISSED:   {p} at t={t}s");
            missed += 1;
        }
    }
    println!("\nmetrics:\n{}", coord.metrics().render());
    if missed > 0 {
        triadic::bail!("{missed} attacks missed");
    }
    println!("security_monitor OK: all 4 attacks detected, dense path exact");
    Ok(())
}
