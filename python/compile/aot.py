"""AOT lowering: JAX census model -> HLO text artifacts for the Rust
PJRT runtime.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the image's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage:  python -m compile.aot --out-dir ../artifacts [--sizes 64,128,256]

Writes one ``census_dense_<n>.hlo.txt`` per size plus a ``manifest.tsv``
the Rust artifact cache reads at startup.
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import census_dense_tuple

DEFAULT_SIZES = (64, 128, 256)


def to_hlo_text(lowered) -> str:
    """stablehlo MLIR -> XlaComputation -> HLO text (tuple return)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_census(n: int) -> str:
    """Lower the dense census for a fixed n×n adjacency to HLO text."""
    spec = jax.ShapeDtypeStruct((n, n), jnp.float32)
    lowered = jax.jit(census_dense_tuple).lower(spec)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--sizes",
        default=",".join(str(s) for s in DEFAULT_SIZES),
        help="comma-separated dense census sizes to lower",
    )
    args = ap.parse_args()

    sizes = [int(s) for s in args.sizes.split(",") if s]
    os.makedirs(args.out_dir, exist_ok=True)
    manifest_rows = []
    for n in sizes:
        if n & (n - 1) or n < 8:
            raise SystemExit(f"size {n} must be a power of two >= 8 (BlockSpec tiling)")
        text = lower_census(n)
        name = f"census_dense_{n}.hlo.txt"
        path = os.path.join(args.out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        manifest_rows.append(f"census_dense\t{n}\t{name}")
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.tsv"), "w") as f:
        f.write("# kind\tsize\tfile\n")
        f.write("\n".join(manifest_rows) + "\n")
    print(f"wrote {os.path.join(args.out_dir, 'manifest.tsv')}")


if __name__ == "__main__":
    main()
