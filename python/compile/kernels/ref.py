"""Pure-jnp oracle for the Pallas kernels and the dense census.

Everything here is deliberately naive: materialized matmuls, no tiling,
no fusion. ``pytest`` pins the Pallas kernel and the AOT model against
these references; the Rust side independently pins the same arithmetic
against the sparse algorithms.
"""

import jax.numpy as jnp


def triple_product_ref(x, y, z):
    """Unfused ``sum((x @ y) * z)``."""
    return jnp.sum((x @ y) * z)


def dyad_decompose_ref(a):
    """(M, As, N) indicator matrices from adjacency ``a`` (0/1 f32)."""
    at = a.T
    m = a * at
    asym = a - m
    n = a.shape[0]
    eye = jnp.eye(n, dtype=a.dtype)
    nul = jnp.ones_like(a) - eye - m - asym - asym.T
    return m, asym, nul


def census_ref(a):
    """Dense 16-class triad census from adjacency ``a``, as an f32
    vector indexed 0..15 in Batagelj–Mrvar census order
    (003, 012, 102, 021D, 021U, 021C, 111D, 111U, 030T, 030C, 201,
    120D, 120U, 120C, 210, 300).

    This is the reference formulation of Moody's matrix method; the
    L2 model computes the same 15 triple products through the Pallas
    kernel.
    """
    m, asym, nul = dyad_decompose_ref(a)
    at = asym.T
    s = asym + at
    t = triple_product_ref

    n = a.shape[0]
    counts = [
        t(nul, nul, s) / 2.0,      # 012
        t(nul, nul, m) / 2.0,      # 102
        t(at, asym, nul) / 2.0,    # 021D
        t(asym, at, nul) / 2.0,    # 021U
        t(asym, asym, nul),        # 021C
        t(m, at, nul),             # 111D
        t(m, asym, nul),           # 111U
        t(asym, asym, asym),       # 030T
        t(asym, asym, at) / 3.0,   # 030C
        t(m, m, nul) / 2.0,        # 201
        t(at, asym, m) / 2.0,      # 120D
        t(asym, at, m) / 2.0,      # 120U
        t(asym, asym, m),          # 120C
        t(m, m, s) / 2.0,          # 210
        t(m, m, m) / 6.0,          # 300
    ]
    nonnull = jnp.stack(counts)
    total = n * (n - 1) * (n - 2) / 6.0
    null = total - jnp.sum(nonnull)
    return jnp.concatenate([jnp.array([null], dtype=nonnull.dtype), nonnull])


def naive_census_ref(a):
    """Brute-force triple-enumeration census — the ground truth for the
    python test suite, independent of the matrix formulas. O(n^3)."""
    import numpy as np

    a = np.asarray(a).astype(np.int64)
    n = a.shape[0]
    counts = np.zeros(16, dtype=np.int64)
    for u in range(n):
        for v in range(u + 1, n):
            for w in range(v + 1, n):
                code = (
                    a[u, v]
                    | a[v, u] << 1
                    | a[u, w] << 2
                    | a[w, u] << 3
                    | a[v, w] << 4
                    | a[w, v] << 5
                )
                counts[_TRICODE_TABLE[code]] += 1
    return counts


def _classify(code: int) -> int:
    """First-principles tricode classifier (mirror of the Rust
    ``classify_tricode``), returning the 0-based census index."""
    uv, vu = code & 1, (code >> 1) & 1
    uw, wu = (code >> 2) & 1, (code >> 3) & 1
    vw, wv = (code >> 4) & 1, (code >> 5) & 1

    def dyad(x, y):
        return 2 if (x and y) else (1 if (x or y) else 0)

    d = [dyad(uv, vu), dyad(uw, wu), dyad(vw, wv)]
    m, a_cnt = d.count(2), d.count(1)
    n_cnt = d.count(0)
    out = [uv + uw, vu + vw, wu + wv]
    inn = [vu + wu, uv + wv, uw + vw]
    mut = [d[0] == 2 or d[1] == 2, d[0] == 2 or d[2] == 2, d[1] == 2 or d[2] == 2]

    key = (m, a_cnt, n_cnt)
    if key == (0, 0, 3):
        return 0
    if key == (0, 1, 2):
        return 1
    if key == (1, 0, 2):
        return 2
    if key == (0, 2, 1):
        if 2 in out:
            return 3  # 021D
        if 2 in inn:
            return 4  # 021U
        return 5  # 021C
    if key == (1, 1, 1):
        # head of the asym arc inside the mutual dyad => 111D
        if d[0] == 1:
            head_in = mut[1] if uv else mut[0]
        elif d[1] == 1:
            head_in = mut[2] if uw else mut[0]
        else:
            head_in = mut[2] if vw else mut[1]
        return 6 if head_in else 7  # 111D / 111U
    if key == (0, 3, 0):
        return 9 if out == [1, 1, 1] else 8  # 030C else 030T
    if key == (2, 0, 1):
        return 10  # 201
    if key == (1, 2, 0):
        z = mut.index(False)
        if out[z] == 2:
            return 11  # 120D
        if inn[z] == 2:
            return 12  # 120U
        return 13  # 120C
    if key == (2, 1, 0):
        return 14  # 210
    return 15  # 300


_TRICODE_TABLE = [_classify(c) for c in range(64)]
