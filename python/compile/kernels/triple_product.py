"""Layer-1 Pallas kernel: fused blocked triple-product reduction.

The dense (Moody matrix-method) triad census is 15 reductions of the form

    T(X, Y, Z) = sum_{i,k} (X @ Y)[i, k] * Z[i, k]

over dyad-indicator matrices. Materializing ``X @ Y`` costs an extra
``n^2`` HBM round-trip per term; this kernel fuses the matmul, the mask
and the reduction so each ``(i, k)`` tile of the product lives only in
VMEM and only the scalar partial sum leaves the core.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid tiles the output
space ``(i, k)``; each grid cell loops over ``j`` tiles, accumulating
``X[i_tile, j_tile] @ Y[j_tile, k_tile]`` on the MXU into an f32 VMEM
accumulator, then masks by ``Z[i_tile, k_tile]`` (VPU elementwise) and
reduces to one scalar per cell. Partial sums land in a per-cell output
vector summed by the caller — the same contention-avoidance shape as the
paper's 64 local census vectors (no cross-cell atomics).

VMEM footprint per cell at BLOCK=128, f32:
    X tile + Y tile + Z tile + acc = 4 * 128*128*4 B = 256 KiB  « 16 MiB.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; the interpret path lowers to plain HLO so the AOT artifact
runs on the Rust CPU client (and, on a real TPU toolchain, the same
``pallas_call`` recompiles to Mosaic).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile edge. 128 matches the MXU systolic array; shrunk for
# smaller inputs by `_block_for`.
BLOCK = 128


def _block_for(n: int) -> int:
    """Largest power-of-two tile <= BLOCK that divides n (n is padded to
    a power of two >= 8 by the caller)."""
    b = min(BLOCK, n)
    while n % b != 0:
        b //= 2
    return max(b, 1)


def _triple_product_kernel(x_ref, y_ref, z_ref, o_ref, *, nj: int):
    """One (i, k) grid cell: accumulate over the j loop, mask, reduce.

    BlockSpec hands us X[i, j], Y[j, k], Z[i, k] tiles with the j grid
    axis innermost, so the f32 accumulator in o_ref is revisited across
    j steps (standard Pallas reduction idiom: init at j==0, flush at
    j==nj-1).
    """
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # MXU: f32 matmul of the current tiles, accumulated in the output
    # block which stays resident in VMEM across the j loop.
    acc = jnp.dot(x_ref[...], y_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] += acc

    @pl.when(j == nj - 1)
    def _mask():
        # mask by Z and leave the masked tile for the caller's reduction
        o_ref[...] *= z_ref[...]


@functools.partial(jax.jit, static_argnames=("block",))
def triple_product(x, y, z, *, block: int | None = None):
    """Fused ``sum((x @ y) * z)`` via the Pallas kernel.

    All three inputs must be square ``(n, n)`` f32 with ``n`` divisible
    by the chosen block size.
    """
    n = x.shape[0]
    assert x.shape == y.shape == z.shape == (n, n), "square matrices required"
    b = block or _block_for(n)
    nj = n // b
    grid = (n // b, n // b, nj)
    masked = pl.pallas_call(
        functools.partial(_triple_product_kernel, nj=nj),
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, b), lambda i, k, j: (i, j)),  # X[i, j]
            pl.BlockSpec((b, b), lambda i, k, j: (j, k)),  # Y[j, k]
            pl.BlockSpec((b, b), lambda i, k, j: (i, k)),  # Z[i, k]
        ],
        out_specs=pl.BlockSpec((b, b), lambda i, k, j: (i, k)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=True,
    )(x, y, z)
    return jnp.sum(masked)


def _dyad_decompose_kernel(a_ref, at_ref, m_ref, asym_ref, nul_ref):
    """Elementwise dyad decomposition of one (i, k) tile pair:
    M = A ∘ Aᵀ, As = A − M, N = 1 − diag − M − As − Asᵀ (VPU work)."""
    a = a_ref[...]
    at = at_ref[...]
    m = a * at
    asym = a - m
    asym_t = at - m
    ones = jnp.ones_like(a)
    # the caller zeroes the diagonal of `nul` (diagonal detection needs
    # global indices; cheaper to fix up outside than to thread iota in)
    nul = ones - m - asym - asym_t
    m_ref[...] = m
    asym_ref[...] = asym
    nul_ref[...] = nul


@functools.partial(jax.jit, static_argnames=("block",))
def dyad_decompose(a, *, block: int | None = None):
    """Split adjacency ``a`` into (mutual, asymmetric, null) indicator
    matrices with a tiled Pallas elementwise kernel."""
    n = a.shape[0]
    b = block or _block_for(n)
    grid = (n // b, n // b)
    spec = pl.BlockSpec((b, b), lambda i, k: (i, k))
    m, asym, nul = pl.pallas_call(
        _dyad_decompose_kernel,
        grid=grid,
        in_specs=[spec, spec],
        out_specs=[spec, spec, spec],
        out_shape=[jax.ShapeDtypeStruct((n, n), jnp.float32)] * 3,
        interpret=True,
    )(a, a.T)
    # zero the diagonal of the null matrix (self-pairs are not dyads)
    eye = jnp.eye(n, dtype=jnp.float32)
    return m, asym, nul - eye * nul
