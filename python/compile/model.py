"""Layer-2 JAX model: Moody's matrix-method dense triad census.

The compute graph takes a padded ``(n, n)`` f32 adjacency matrix and
produces the 16-element census vector (census order 003..300). The dyad
decomposition and all 15 triple-product reductions run through the
Layer-1 Pallas kernels so the whole census lowers into one HLO module
that the Rust runtime executes via PJRT.

Numerics: counts are exact in f32 while every individual product stays
below 2^24; with the AOT sizes n <= 256 the largest single term is
C(256,3) ≈ 2.8M, well inside the exact range. The Rust caller still
recomputes the null slot in u128 when applying padding corrections.

Build-time only — never imported on the request path.
"""

import jax.numpy as jnp

from .kernels.triple_product import dyad_decompose, triple_product


def census_dense(a, block: int | None = None):
    """Full 16-class census of adjacency ``a`` through the Pallas path.

    Returns an f32 vector in census order (003 first).

    ``block`` selects the Pallas tile edge. Default (None) picks the
    MXU-shaped schedule (128, see kernels.triple_product._block_for);
    the CPU-PJRT AOT path passes ``block = n`` because interpret-mode
    grid cells are pure emulation overhead there (§Perf: 4x at n=256).
    """
    import functools

    n = a.shape[0]
    m, asym, nul = dyad_decompose(a, block=block)
    at = jnp.transpose(asym)
    s = asym + at
    t = functools.partial(triple_product, block=block)

    counts = [
        t(nul, nul, s) / 2.0,      # 012
        t(nul, nul, m) / 2.0,      # 102
        t(at, asym, nul) / 2.0,    # 021D
        t(asym, at, nul) / 2.0,    # 021U
        t(asym, asym, nul),        # 021C
        t(m, at, nul),             # 111D
        t(m, asym, nul),           # 111U
        t(asym, asym, asym),       # 030T
        t(asym, asym, at) / 3.0,   # 030C
        t(m, m, nul) / 2.0,        # 201
        t(at, asym, m) / 2.0,      # 120D
        t(asym, at, m) / 2.0,      # 120U
        t(asym, asym, m),          # 120C
        t(m, m, s) / 2.0,          # 210
        t(m, m, m) / 6.0,          # 300
    ]
    nonnull = jnp.stack(counts)
    total = n * (n - 1) * (n - 2) / 6.0
    null = total - jnp.sum(nonnull)
    return jnp.concatenate([jnp.array([null], dtype=nonnull.dtype), nonnull])


def census_dense_tuple(a):
    """AOT entrypoint: 1-tuple result (the HLO-text interchange lowers
    with ``return_tuple=True`` and the Rust side unwraps ``to_tuple1``).

    Uses the CPU-PJRT schedule (single grid cell): the artifact targets
    the Rust CPU client; on a real TPU toolchain lower with the default
    ``block`` instead."""
    return (census_dense(a, block=a.shape[0]),)
