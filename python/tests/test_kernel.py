"""L1 correctness: Pallas kernels vs pure-jnp references.

Hypothesis sweeps shapes, densities and dtypescales; assert_allclose
against ref.py is THE core correctness signal for the kernel layer.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import (
    dyad_decompose_ref,
    triple_product_ref,
)
from compile.kernels.triple_product import (
    _block_for,
    dyad_decompose,
    triple_product,
)

SIZES = [8, 16, 32, 64, 128]


def rand_matrix(rng, n, density=0.2, binary=True):
    x = (rng.random((n, n)) < density).astype(np.float32)
    if not binary:
        x *= rng.random((n, n)).astype(np.float32) * 4.0 - 2.0
    return jnp.asarray(x)


class TestBlockFor:
    def test_divides(self):
        for n in [8, 16, 24, 48, 64, 128, 256, 512]:
            b = _block_for(n)
            assert n % b == 0
            assert b <= 128

    def test_caps_at_mxu_edge(self):
        assert _block_for(256) == 128
        assert _block_for(128) == 128
        assert _block_for(64) == 64


class TestTripleProduct:
    @pytest.mark.parametrize("n", SIZES)
    def test_binary_matrices(self, n):
        rng = np.random.default_rng(n)
        x, y, z = (rand_matrix(rng, n) for _ in range(3))
        got = triple_product(x, y, z)
        want = triple_product_ref(x, y, z)
        np.testing.assert_allclose(got, want, rtol=1e-6)

    @pytest.mark.parametrize("n", SIZES)
    def test_real_valued_matrices(self, n):
        rng = np.random.default_rng(100 + n)
        x, y, z = (rand_matrix(rng, n, density=0.5, binary=False) for _ in range(3))
        got = triple_product(x, y, z)
        want = triple_product_ref(x, y, z)
        np.testing.assert_allclose(got, want, rtol=1e-4)

    def test_explicit_block_override(self):
        rng = np.random.default_rng(7)
        x, y, z = (rand_matrix(rng, 64, 0.3) for _ in range(3))
        want = triple_product_ref(x, y, z)
        for block in [8, 16, 32, 64]:
            got = triple_product(x, y, z, block=block)
            np.testing.assert_allclose(got, want, rtol=1e-6)

    def test_zero_and_identity(self):
        n = 16
        zero = jnp.zeros((n, n), jnp.float32)
        eye = jnp.eye(n, dtype=jnp.float32)
        ones = jnp.ones((n, n), jnp.float32)
        assert float(triple_product(zero, ones, ones)) == 0.0
        # (I @ ones) * ones sums to n*n
        assert float(triple_product(eye, ones, ones)) == n * n
        # trace-like: (I @ I) * I = I
        assert float(triple_product(eye, eye, eye)) == n

    @settings(max_examples=25, deadline=None)
    @given(
        n_pow=st.integers(min_value=3, max_value=6),
        seed=st.integers(min_value=0, max_value=2**31),
        density=st.floats(min_value=0.0, max_value=1.0),
    )
    def test_hypothesis_sweep(self, n_pow, seed, density):
        n = 2**n_pow
        rng = np.random.default_rng(seed)
        x = rand_matrix(rng, n, density)
        y = rand_matrix(rng, n, density)
        z = rand_matrix(rng, n, density)
        got = triple_product(x, y, z)
        want = triple_product_ref(x, y, z)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


class TestDyadDecompose:
    @pytest.mark.parametrize("n", SIZES)
    def test_matches_ref(self, n):
        rng = np.random.default_rng(n * 3 + 1)
        a = rand_matrix(rng, n, 0.3)
        a = a * (1.0 - jnp.eye(n))  # no self-loops
        got = dyad_decompose(a)
        want = dyad_decompose_ref(a)
        for g, w, name in zip(got, want, ["M", "As", "N"]):
            np.testing.assert_allclose(g, w, rtol=1e-6, err_msg=name)

    def test_partition_property(self):
        # M + As + As^T + N + I must be the all-ones matrix
        rng = np.random.default_rng(5)
        n = 32
        a = rand_matrix(rng, n, 0.4) * (1.0 - jnp.eye(n))
        m, asym, nul = dyad_decompose(a)
        total = m + asym + asym.T + nul + jnp.eye(n)
        np.testing.assert_allclose(total, jnp.ones((n, n)), rtol=1e-6)

    def test_m_symmetric_as_antisupported(self):
        rng = np.random.default_rng(9)
        n = 16
        a = rand_matrix(rng, n, 0.5) * (1.0 - jnp.eye(n))
        m, asym, _ = dyad_decompose(a)
        np.testing.assert_allclose(m, m.T)
        # As and As^T never overlap
        assert float(jnp.max(asym * asym.T)) == 0.0


class TestJitAndGrid:
    def test_jit_cache_stable(self):
        # second call must reuse the compiled function (no retrace error)
        rng = np.random.default_rng(2)
        a = rand_matrix(rng, 16, 0.3)
        b = rand_matrix(rng, 16, 0.3)
        c = rand_matrix(rng, 16, 0.3)
        r1 = triple_product(a, b, c)
        r2 = triple_product(a, b, c)
        assert float(r1) == float(r2)

    def test_grid_multiblock_consistency(self):
        # n=128 with block 32 exercises a 4x4x4 grid with j-accumulation
        rng = np.random.default_rng(11)
        x, y, z = (rand_matrix(rng, 128, 0.1) for _ in range(3))
        got = triple_product(x, y, z, block=32)
        want = triple_product_ref(x, y, z)
        np.testing.assert_allclose(got, want, rtol=1e-6)
