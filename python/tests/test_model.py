"""L2 correctness: the dense census model vs the brute-force oracle.

``census_dense`` (Pallas path) and ``census_ref`` (pure-jnp matrix
formulas) must both equal ``naive_census_ref`` (triple enumeration with
the first-principles tricode classifier) exactly after rounding.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import census_ref, naive_census_ref, _TRICODE_TABLE
from compile.model import census_dense


def rand_digraph(rng, n, density):
    a = (rng.random((n, n)) < density).astype(np.float32)
    np.fill_diagonal(a, 0.0)
    return a


def as_int(v):
    return np.asarray(jnp.round(v)).astype(np.int64)


class TestTricodeTable:
    def test_multiplicities(self):
        # Holland–Leinhardt labeled-triad counts per class
        expected = [1, 6, 3, 3, 3, 6, 6, 6, 6, 2, 3, 3, 3, 6, 6, 1]
        for idx, want in enumerate(expected):
            assert _TRICODE_TABLE.count(idx) == want, f"class {idx}"

    def test_arc_conservation(self):
        arcs_per_class = [0, 1, 2, 2, 2, 2, 3, 3, 3, 3, 4, 4, 4, 4, 5, 6]
        for code in range(64):
            assert bin(code).count("1") == arcs_per_class[_TRICODE_TABLE[code]]


class TestFixtures:
    def test_cycle3(self):
        a = np.zeros((8, 8), np.float32)
        a[0, 1] = a[1, 2] = a[2, 0] = 1.0
        want = naive_census_ref(a)
        np.testing.assert_array_equal(as_int(census_dense(jnp.asarray(a))), want)
        assert want[9] == 1  # one 030C

    def test_complete_mutual(self):
        n = 8
        a = np.ones((n, n), np.float32)
        np.fill_diagonal(a, 0.0)
        got = as_int(census_dense(jnp.asarray(a)))
        want = np.zeros(16, np.int64)
        want[15] = n * (n - 1) * (n - 2) // 6
        np.testing.assert_array_equal(got, want)

    def test_empty(self):
        n = 16
        a = np.zeros((n, n), np.float32)
        got = as_int(census_dense(jnp.asarray(a)))
        assert got[0] == n * (n - 1) * (n - 2) // 6
        assert got[1:].sum() == 0

    def test_out_star(self):
        a = np.zeros((8, 8), np.float32)
        a[0, 1] = a[0, 2] = a[0, 3] = 1.0
        got = as_int(census_dense(jnp.asarray(a)))
        np.testing.assert_array_equal(got, naive_census_ref(a))
        assert got[3] == 3  # 021D


class TestAgainstOracle:
    @pytest.mark.parametrize("n", [8, 16])
    @pytest.mark.parametrize("density", [0.05, 0.2, 0.5, 0.9])
    def test_census_dense_exact(self, n, density):
        rng = np.random.default_rng(int(n * 100 + density * 10))
        a = rand_digraph(rng, n, density)
        want = naive_census_ref(a)
        got = as_int(census_dense(jnp.asarray(a)))
        np.testing.assert_array_equal(got, want)

    @pytest.mark.parametrize("n", [8, 16])
    def test_ref_formulas_exact(self, n):
        rng = np.random.default_rng(n)
        a = rand_digraph(rng, n, 0.3)
        want = naive_census_ref(a)
        got = as_int(census_ref(jnp.asarray(a)))
        np.testing.assert_array_equal(got, want)

    @settings(max_examples=15, deadline=None)
    @given(
        seed=st.integers(min_value=0, max_value=2**31),
        density=st.floats(min_value=0.0, max_value=1.0),
    )
    def test_hypothesis_small_graphs(self, seed, density):
        rng = np.random.default_rng(seed)
        a = rand_digraph(rng, 8, density)
        want = naive_census_ref(a)
        got = as_int(census_dense(jnp.asarray(a)))
        np.testing.assert_array_equal(got, want)

    def test_census_totals(self):
        rng = np.random.default_rng(42)
        n = 32
        a = rand_digraph(rng, n, 0.15)
        got = as_int(census_dense(jnp.asarray(a)))
        assert got.sum() == n * (n - 1) * (n - 2) // 6

    def test_padding_adds_only_null_and_dyadic(self):
        # zero-padding a graph must keep all connected-triad classes
        # fixed — the property the Rust runtime's padding correction
        # relies on.
        rng = np.random.default_rng(3)
        a = rand_digraph(rng, 12, 0.3)
        pad = np.zeros((16, 16), np.float32)
        pad[:12, :12] = a
        small = as_int(census_dense(jnp.asarray(a)))
        big = as_int(census_dense(jnp.asarray(pad)))
        # classes with >= 2 connected dyads are untouched by padding
        np.testing.assert_array_equal(small[3:], big[3:])
        # 012/102 grow by (#extra nodes) * (#asym / #mutual dyads)
        extra = 4
        n_asym = int((a * (1 - a.T)).sum())
        n_mut = int((a * a.T).sum() // 2)
        assert big[1] - small[1] == extra * n_asym
        assert big[2] - small[2] == extra * n_mut
