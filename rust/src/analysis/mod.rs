//! Triadic security analysis — the paper's application layer
//! (Figs 3–4): computing the triad census of computer-network traffic at
//! fixed time intervals, tracking the proportions of triad types over
//! time, and alerting when combinations of triads characteristic of
//! threats depart from their baseline behaviour.

pub mod monitor;
pub mod patterns;
pub mod traffic;
pub mod window;

pub use monitor::{Alert, MonitorConfig, TriadMonitor};
pub use patterns::{builtin_patterns, ThreatPattern};
pub use traffic::{TrafficEvent, TrafficGenerator, TrafficScenario};
pub use window::{census_series, WindowCensus, Windower};
