//! The Fig 4 monitoring tool: track triad-class proportions over time,
//! maintain a rolling baseline, and raise alerts when the deviations
//! match a threat pattern "outside its normal behavior".

use super::patterns::ThreatPattern;
use super::window::WindowCensus;
use crate::census::{Census, TriadType};

/// Volume-independent per-class signature of a window census.
///
/// Raw proportions over `C(n,3)` are useless for alerting: the null
/// class absorbs ~100% of mass and every extra active host dilutes all
/// other classes cubically. Instead, the standard conditional
/// normalization of triadic analysis:
///
/// * `003` → 0 (never informative for the Fig 3 patterns);
/// * dyadic classes (`012`, `102`) → share of all *dyadic* triads
///   (mutual-vs-asymmetric dyad balance);
/// * connected classes (`021D`..`300`) → share of all *connected*
///   triads ("proportions of triad types relative to one another", as
///   the paper puts it).
pub fn signature(census: &Census) -> [f64; 16] {
    let mut s = [0f64; 16];
    let dyadic = (census[TriadType::T012] + census[TriadType::T102]).max(1) as f64;
    let connected: u64 = TriadType::ALL
        .iter()
        .filter(|t| t.is_connected_triad())
        .map(|&t| census[t])
        .sum();
    let connected = connected.max(1) as f64;
    for t in TriadType::ALL {
        let i = t.index() - 1;
        s[i] = match t {
            TriadType::T003 => 0.0,
            TriadType::T012 | TriadType::T102 => census[t] as f64 / dyadic,
            _ => census[t] as f64 / connected,
        };
    }
    s
}

/// Monitor configuration.
#[derive(Debug, Clone)]
pub struct MonitorConfig {
    /// Windows used to warm the baseline before alerting begins.
    pub warmup_windows: usize,
    /// EWMA smoothing factor for the per-class baseline (0..1, smaller
    /// = slower adaptation).
    pub alpha: f64,
    /// Pattern score (in baseline σ units) at which an alert fires.
    pub threshold: f64,
}

impl Default for MonitorConfig {
    fn default() -> Self {
        MonitorConfig {
            warmup_windows: 8,
            alpha: 0.15,
            threshold: 6.0,
        }
    }
}

/// A raised alert.
#[derive(Debug, Clone)]
pub struct Alert {
    /// Window start time.
    pub window_start: f64,
    /// Matching pattern name.
    pub pattern: &'static str,
    /// Pattern score (σ units).
    pub score: f64,
    /// The three most-deviating triad classes driving the score.
    pub top_classes: [TriadType; 3],
}

/// Per-class EWMA mean/variance baseline state.
#[derive(Debug, Clone, Default)]
struct Baseline {
    mean: [f64; 16],
    var: [f64; 16],
    windows: usize,
}

impl Baseline {
    fn update(&mut self, props: &[f64; 16], alpha: f64) {
        if self.windows == 0 {
            self.mean = *props;
            self.var = [1e-6; 16];
        } else {
            for i in 0..16 {
                let d = props[i] - self.mean[i];
                self.mean[i] += alpha * d;
                self.var[i] = (1.0 - alpha) * (self.var[i] + alpha * d * d);
            }
        }
        self.windows += 1;
    }

    fn z_scores(&self, props: &[f64; 16]) -> [f64; 16] {
        let mut z = [0f64; 16];
        for i in 0..16 {
            // floor sigma at 3% of share scale: rare classes (201, 030C,
            // 300) otherwise alert on a single random triad
            let sigma = self.var[i].sqrt().max(0.03);
            z[i] = (props[i] - self.mean[i]) / sigma;
        }
        z
    }
}

/// The monitoring tool: feed window censuses, collect alerts.
#[derive(Debug)]
pub struct TriadMonitor {
    cfg: MonitorConfig,
    patterns: Vec<ThreatPattern>,
    baseline: Baseline,
    history: Vec<(f64, [f64; 16])>,
}

impl TriadMonitor {
    /// Create a monitor with the given patterns (see
    /// [`super::patterns::builtin_patterns`]).
    pub fn new(cfg: MonitorConfig, patterns: Vec<ThreatPattern>) -> TriadMonitor {
        TriadMonitor {
            cfg,
            patterns,
            baseline: Baseline::default(),
            history: Vec::new(),
        }
    }

    /// Number of windows observed so far.
    pub fn windows_seen(&self) -> usize {
        self.baseline.windows
    }

    /// The proportion history (for plotting Fig 4-style timelines).
    pub fn history(&self) -> &[(f64, [f64; 16])] {
        &self.history
    }

    /// Observe one window census; returns any alerts it triggers.
    pub fn observe(&mut self, w: &WindowCensus) -> Vec<Alert> {
        let props = signature(&w.census);
        self.history.push((w.start, props));

        let mut alerts = Vec::new();
        if self.baseline.windows >= self.cfg.warmup_windows {
            let z = self.baseline.z_scores(&props);
            for p in &self.patterns {
                let score = p.score(&z);
                if score > self.cfg.threshold {
                    alerts.push(Alert {
                        window_start: w.start,
                        pattern: p.name,
                        score,
                        top_classes: top3(&z, &p.weights),
                    });
                }
            }
        }
        // Alerted windows are anomalies: keep them out of the baseline
        // so a sustained attack cannot normalize itself.
        if alerts.is_empty() {
            self.baseline.update(&props, self.cfg.alpha);
        }
        alerts
    }
}

/// The three classes with the largest weighted deviation.
fn top3(z: &[f64; 16], weights: &[f64; 16]) -> [TriadType; 3] {
    let mut idx: Vec<usize> = (0..16).collect();
    idx.sort_by(|&a, &b| (weights[b] * z[b]).total_cmp(&(weights[a] * z[a])));
    [
        TriadType::from_index(idx[0] + 1),
        TriadType::from_index(idx[1] + 1),
        TriadType::from_index(idx[2] + 1),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::patterns::builtin_patterns;
    use crate::analysis::traffic::{TrafficGenerator, TrafficScenario};
    use crate::analysis::window::census_series;
    use crate::census::merged;

    fn run_monitor(gen: TrafficGenerator, duration: f64) -> (Vec<Alert>, usize) {
        let events = gen.generate(duration);
        let series = census_series(&events, 1.0, merged::census);
        let n = series.len();
        let mut mon = TriadMonitor::new(MonitorConfig::default(), builtin_patterns());
        let mut alerts = Vec::new();
        for w in &series {
            alerts.extend(mon.observe(w));
        }
        (alerts, n)
    }

    #[test]
    fn quiet_traffic_raises_no_alarms() {
        let gen = TrafficGenerator::background(400, 120.0, 11);
        let (alerts, n) = run_monitor(gen, 40.0);
        assert!(n >= 35);
        assert!(
            alerts.len() <= 1,
            "false alarms on quiet traffic: {:?}",
            alerts
        );
    }

    #[test]
    fn port_scan_detected_as_scan() {
        let gen = TrafficGenerator::background(400, 120.0, 11).with(TrafficScenario::PortScan {
            start: 30.2,
            end: 30.9,
            attacker: 5,
            targets: 60,
        });
        let (alerts, _) = run_monitor(gen, 40.0);
        assert!(!alerts.is_empty(), "scan not detected");
        let a = alerts
            .iter()
            .max_by(|x, y| x.score.total_cmp(&y.score))
            .unwrap();
        assert_eq!(a.pattern, "port-scan", "strongest alert: {a:?}");
        assert!((a.window_start - 30.0).abs() < 1e-9);
        assert_eq!(a.top_classes[0], crate::census::TriadType::T021D);
    }

    #[test]
    fn ddos_detected_as_ddos() {
        let gen = TrafficGenerator::background(400, 120.0, 7).with(TrafficScenario::Ddos {
            start: 25.1,
            end: 25.8,
            victim: 2,
            sources: 60,
        });
        let (alerts, _) = run_monitor(gen, 40.0);
        let a = alerts
            .iter()
            .max_by(|x, y| x.score.total_cmp(&y.score))
            .expect("ddos not detected");
        assert_eq!(a.pattern, "ddos");
    }

    #[test]
    fn botnet_detected() {
        let gen =
            TrafficGenerator::background(400, 120.0, 3).with(TrafficScenario::BotnetSync {
                start: 22.1,
                end: 22.9,
                first_peer: 3_000_000,
                peers: 12,
            });
        let (alerts, _) = run_monitor(gen, 40.0);
        let a = alerts
            .iter()
            .max_by(|x, y| x.score.total_cmp(&y.score))
            .expect("botnet not detected");
        assert_eq!(a.pattern, "botnet-sync");
    }

    #[test]
    fn relay_detected() {
        let gen = TrafficGenerator::background(400, 120.0, 5).with(TrafficScenario::Relay {
            start: 28.1,
            end: 28.9,
            first_hop: 4_000_000,
            length: 16,
            chains: 12,
        });
        let (alerts, _) = run_monitor(gen, 40.0);
        let a = alerts
            .iter()
            .max_by(|x, y| x.score.total_cmp(&y.score))
            .expect("relay not detected");
        assert_eq!(a.pattern, "relay");
    }

    #[test]
    fn signature_is_volume_invariant() {
        use crate::census::Census;
        // same structure at 2x the node count -> same signature for the
        // connected classes
        let mut a = Census::zero();
        a.add_count(TriadType::T021C, 50);
        a.add_count(TriadType::T021D, 25);
        a.add_count(TriadType::T012, 1000);
        a.close_with_null(100);
        let mut b = Census::zero();
        b.add_count(TriadType::T021C, 50);
        b.add_count(TriadType::T021D, 25);
        b.add_count(TriadType::T012, 4000); // dyadic scales with n
        b.close_with_null(400);
        let sa = signature(&a);
        let sb = signature(&b);
        for t in [TriadType::T021C, TriadType::T021D] {
            assert!((sa[t.index() - 1] - sb[t.index() - 1]).abs() < 1e-12);
        }
        assert_eq!(sa[0], 0.0);
    }

    #[test]
    fn warmup_suppresses_early_alerts() {
        let gen = TrafficGenerator::background(400, 120.0, 9).with(TrafficScenario::PortScan {
            start: 2.0,
            end: 2.5,
            attacker: 5,
            targets: 80,
        });
        let (alerts, _) = run_monitor(gen, 12.0);
        // scan happens inside the warmup window: nothing may fire there
        assert!(alerts.iter().all(|a| a.window_start > 8.0), "{alerts:?}");
    }
}
