//! The paper's Fig 3: computer-network activities a security analyst
//! monitors, and the triad classes relevant to each.
//!
//! Each pattern weights the 16 census classes; a window's *pattern
//! score* is the weighted sum of its per-class deviations from baseline
//! (see [`super::monitor`]). Weights are positive for classes the
//! activity inflates.

use crate::census::TriadType;

/// A named threat/anomaly triad pattern.
#[derive(Debug, Clone)]
pub struct ThreatPattern {
    /// Short name ("port-scan", ...).
    pub name: &'static str,
    /// Analyst-facing description of the activity.
    pub description: &'static str,
    /// Per-class weights (census-index order).
    pub weights: [f64; 16],
}

impl ThreatPattern {
    /// Build a pattern from `(class, weight)` pairs.
    pub fn new(
        name: &'static str,
        description: &'static str,
        weights: &[(TriadType, f64)],
    ) -> ThreatPattern {
        let mut w = [0f64; 16];
        for &(t, v) in weights {
            w[t.index() - 1] = v;
        }
        ThreatPattern {
            name,
            description,
            weights: w,
        }
    }

    /// Score a per-class deviation vector (e.g. z-scores) against this
    /// pattern.
    pub fn score(&self, deviations: &[f64; 16]) -> f64 {
        self.weights
            .iter()
            .zip(deviations)
            .map(|(w, d)| w * d)
            .sum()
    }
}

/// The four Fig 3 activities.
///
/// * **port-scan** — one source probing many targets: out-stars (`021D`)
///   and, as targets answer, out-star + chain mixes (`111U`).
/// * **ddos** — many sources converging on one victim: in-stars
///   (`021U`, `111D`).
/// * **relay** — stepping-stone/exfiltration chains: paths (`021C`) and
///   transitive closures (`030T`).
/// * **botnet-sync** — peer coordination: reciprocated and cyclic
///   structure (`102`, `030C`, `201`, `300`).
pub fn builtin_patterns() -> Vec<ThreatPattern> {
    vec![
        ThreatPattern::new(
            "port-scan",
            "single source fanning out to many destinations (reconnaissance)",
            &[
                (TriadType::T021D, 1.0),
                (TriadType::T111U, 0.3),
                (TriadType::T012, 0.1),
            ],
        ),
        ThreatPattern::new(
            "ddos",
            "many sources converging on a single destination (flooding)",
            &[
                (TriadType::T021U, 1.0),
                (TriadType::T111D, 0.3),
                (TriadType::T012, 0.1),
            ],
        ),
        ThreatPattern::new(
            "relay",
            "multi-hop relay chains (stepping stones / exfiltration)",
            &[
                // chains rise while the star classes sink (shares are
                // conditional, so a chain surge *displaces* D/U mass);
                // the negative weights double as specificity against
                // scan/ddos windows, whose D/U z-scores explode
                (TriadType::T021C, 1.5),
                (TriadType::T030T, 0.6),
                (TriadType::T021D, -0.4),
                (TriadType::T021U, -0.4),
            ],
        ),
        ThreatPattern::new(
            "botnet-sync",
            "reciprocated peer-to-peer coordination (command & control)",
            &[
                (TriadType::T102, 0.5),
                (TriadType::T030C, 1.0),
                (TriadType::T201, 0.7),
                (TriadType::T300, 1.0),
            ],
        ),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn four_patterns_with_distinct_signatures() {
        let pats = builtin_patterns();
        assert_eq!(pats.len(), 4);
        for (i, a) in pats.iter().enumerate() {
            for b in pats.iter().skip(i + 1) {
                assert_ne!(a.weights, b.weights, "{} vs {}", a.name, b.name);
            }
        }
    }

    #[test]
    fn score_is_weighted_dot() {
        let p = ThreatPattern::new("t", "", &[(TriadType::T021D, 2.0)]);
        let mut dev = [0f64; 16];
        dev[TriadType::T021D.index() - 1] = 3.0;
        dev[TriadType::T300.index() - 1] = 100.0; // unweighted, ignored
        assert_eq!(p.score(&dev), 6.0);
    }

    #[test]
    fn scan_and_ddos_are_duals() {
        // reversing all arcs should map scan deviations onto ddos's
        let pats = builtin_patterns();
        let scan = &pats[0];
        let ddos = &pats[1];
        for t in TriadType::ALL {
            let w_scan = scan.weights[t.index() - 1];
            let w_ddos = ddos.weights[t.reversed().index() - 1];
            assert_eq!(w_scan, w_ddos, "{t}");
        }
    }
}
