//! Synthetic network-traffic generation: a deterministic stand-in for
//! the computer-network flow logs of the paper's monitoring application
//! (which are not redistributable), with injectable attack scenarios
//! matching the Fig 3 patterns.

use crate::rng::Rng;

/// One directed communication event (flow record).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TrafficEvent {
    /// Seconds since stream epoch. Events are generated time-ordered.
    pub time: f64,
    /// Source host id.
    pub src: u64,
    /// Destination host id.
    pub dst: u64,
}

/// An attack scenario injected on top of background traffic.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TrafficScenario {
    /// `attacker` probes `targets` distinct hosts between `start..end`.
    PortScan {
        start: f64,
        end: f64,
        attacker: u64,
        targets: usize,
    },
    /// `sources` hosts flood `victim` between `start..end`.
    Ddos {
        start: f64,
        end: f64,
        victim: u64,
        sources: usize,
    },
    /// `chains` parallel relay chains `h0 -> h1 -> ... -> h_len`
    /// (stepping-stone exfiltration through disjoint hop sets).
    Relay {
        start: f64,
        end: f64,
        first_hop: u64,
        length: usize,
        chains: usize,
    },
    /// A clique of `peers` exchanging reciprocated traffic.
    BotnetSync {
        start: f64,
        end: f64,
        first_peer: u64,
        peers: usize,
    },
}

/// Deterministic traffic generator: Zipf-ish background communication
/// over a host population plus injected scenarios.
#[derive(Debug, Clone)]
pub struct TrafficGenerator {
    /// Host population for background traffic.
    pub hosts: u64,
    /// Background events per second.
    pub rate: f64,
    /// RNG seed.
    pub seed: u64,
    /// Injected scenarios.
    pub scenarios: Vec<TrafficScenario>,
}

impl TrafficGenerator {
    /// A quiet office network.
    pub fn background(hosts: u64, rate: f64, seed: u64) -> TrafficGenerator {
        TrafficGenerator {
            hosts,
            rate,
            seed,
            scenarios: Vec::new(),
        }
    }

    /// Add a scenario (builder style).
    pub fn with(mut self, s: TrafficScenario) -> TrafficGenerator {
        self.scenarios.push(s);
        self
    }

    /// Zipf-like host pick: low ids are popular (servers).
    fn pick_host(rng: &mut Rng, hosts: u64) -> u64 {
        let u = rng.next_f64();
        // mixture: 30% hit the top sqrt(hosts) "servers", 70% uniform
        if rng.chance(0.3) {
            let top = (hosts as f64).sqrt().max(1.0) as u64;
            (u * top as f64) as u64
        } else {
            (u * hosts as f64) as u64
        }
    }

    /// Generate the time-ordered event stream for `duration` seconds.
    pub fn generate(&self, duration: f64) -> Vec<TrafficEvent> {
        let mut rng = Rng::new(self.seed);
        let mut events = Vec::new();

        // background: Poisson-ish arrivals at self.rate
        let n_bg = (self.rate * duration) as usize;
        for _ in 0..n_bg {
            let time = rng.next_f64() * duration;
            let src = Self::pick_host(&mut rng, self.hosts);
            let mut dst = Self::pick_host(&mut rng, self.hosts);
            if dst == src {
                dst = (dst + 1) % self.hosts;
            }
            events.push(TrafficEvent { time, src, dst });
        }

        // scenarios
        for s in &self.scenarios {
            match *s {
                TrafficScenario::PortScan {
                    start,
                    end,
                    attacker,
                    targets,
                } => {
                    for i in 0..targets {
                        let time = start + (end - start) * (i as f64 + 0.5) / targets as f64;
                        events.push(TrafficEvent {
                            time,
                            src: attacker,
                            dst: 1_000_000 + i as u64, // unused address space
                        });
                    }
                }
                TrafficScenario::Ddos {
                    start,
                    end,
                    victim,
                    sources,
                } => {
                    for i in 0..sources {
                        let time = start + (end - start) * (i as f64 + 0.5) / sources as f64;
                        events.push(TrafficEvent {
                            time,
                            src: 2_000_000 + i as u64,
                            dst: victim,
                        });
                    }
                }
                TrafficScenario::Relay {
                    start,
                    end,
                    first_hop,
                    length,
                    chains,
                } => {
                    for c in 0..chains {
                        let base = first_hop + (c * (length + 1)) as u64;
                        for i in 0..length {
                            let frac = (c * length + i) as f64 / (chains * length) as f64;
                            events.push(TrafficEvent {
                                time: start + (end - start) * frac,
                                src: base + i as u64,
                                dst: base + i as u64 + 1,
                            });
                        }
                    }
                }
                TrafficScenario::BotnetSync {
                    start,
                    end,
                    first_peer,
                    peers,
                } => {
                    let mut k = 0usize;
                    let total = peers * (peers - 1);
                    for i in 0..peers as u64 {
                        for j in 0..peers as u64 {
                            if i != j {
                                let frac = k as f64 / total as f64;
                                events.push(TrafficEvent {
                                    time: start + (end - start) * frac,
                                    src: first_peer + i,
                                    dst: first_peer + j,
                                });
                                k += 1;
                            }
                        }
                    }
                }
            }
        }

        events.sort_by(|a, b| a.time.total_cmp(&b.time));
        events
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_time_ordered() {
        let g = TrafficGenerator::background(500, 100.0, 42);
        let a = g.generate(10.0);
        let b = g.generate(10.0);
        assert_eq!(a, b);
        assert!(a.windows(2).all(|w| w[0].time <= w[1].time));
        assert!(a.len() >= 900);
    }

    #[test]
    fn scan_injects_fan_out() {
        let g = TrafficGenerator::background(100, 10.0, 1).with(TrafficScenario::PortScan {
            start: 5.0,
            end: 6.0,
            attacker: 3,
            targets: 40,
        });
        let evs = g.generate(10.0);
        let scans = evs
            .iter()
            .filter(|e| e.src == 3 && e.dst >= 1_000_000)
            .count();
        assert_eq!(scans, 40);
    }

    #[test]
    fn botnet_generates_mutual_pairs() {
        let g = TrafficGenerator::background(10, 1.0, 2).with(TrafficScenario::BotnetSync {
            start: 0.0,
            end: 1.0,
            first_peer: 3_000_000,
            peers: 4,
        });
        let evs = g.generate(2.0);
        let bot: Vec<_> = evs.iter().filter(|e| e.src >= 3_000_000).collect();
        assert_eq!(bot.len(), 12); // 4*3 ordered pairs
    }

    #[test]
    fn no_self_loops_in_background() {
        let g = TrafficGenerator::background(5, 200.0, 3);
        assert!(g.generate(5.0).iter().all(|e| e.src != e.dst));
    }
}
