//! Time-windowed census streams: partition a traffic event stream into
//! fixed intervals, build the per-window communication graph, and
//! compute its census (paper: "computing the triad census of a computer
//! network at fixed time intervals").

use std::collections::HashMap;

use super::traffic::TrafficEvent;
use crate::census::Census;
use crate::graph::{CsrGraph, GraphBuilder};

/// The census of one time window plus its graph statistics.
#[derive(Debug, Clone)]
pub struct WindowCensus {
    /// Window start (seconds since stream epoch).
    pub start: f64,
    /// Window length (seconds).
    pub length: f64,
    /// Distinct hosts active in the window.
    pub hosts: usize,
    /// Distinct directed communication arcs.
    pub arcs: u64,
    /// The triad census of the window graph.
    pub census: Census,
}

/// Partitions events into fixed windows and builds per-window graphs.
///
/// Host ids are arbitrary `u64`s (IP-like); each window remaps the
/// active hosts to a dense `0..n` id space before building the CSR.
#[derive(Debug)]
pub struct Windower {
    window_seconds: f64,
    current_start: f64,
    events: Vec<(u64, u64)>,
    started: bool,
}

impl Windower {
    /// Create a windower with the given interval.
    pub fn new(window_seconds: f64) -> Windower {
        assert!(window_seconds > 0.0);
        Windower {
            window_seconds,
            current_start: 0.0,
            events: Vec::new(),
            started: false,
        }
    }

    /// Window length.
    pub fn window_seconds(&self) -> f64 {
        self.window_seconds
    }

    /// Feed one event (events must be time-ordered). Returns the closed
    /// window's graph when `ev` falls past the current window boundary.
    pub fn push(&mut self, ev: &TrafficEvent) -> Option<(f64, CsrGraph)> {
        if !self.started {
            self.started = true;
            self.current_start = (ev.time / self.window_seconds).floor() * self.window_seconds;
        }
        debug_assert!(
            ev.time >= self.current_start,
            "events must be time-ordered"
        );
        let mut closed = None;
        if ev.time >= self.current_start + self.window_seconds {
            closed = Some((self.current_start, self.flush_graph()));
            self.current_start =
                (ev.time / self.window_seconds).floor() * self.window_seconds;
        }
        if ev.src != ev.dst {
            self.events.push((ev.src, ev.dst));
        }
        closed
    }

    /// Close the stream, returning the final partial window (if any).
    pub fn finish(&mut self) -> Option<(f64, CsrGraph)> {
        if self.events.is_empty() {
            None
        } else {
            Some((self.current_start, self.flush_graph()))
        }
    }

    /// Build and clear the pending window graph.
    fn flush_graph(&mut self) -> CsrGraph {
        let mut ids: HashMap<u64, u32> = HashMap::new();
        let mut arcs = Vec::with_capacity(self.events.len());
        for &(s, d) in &self.events {
            let next = ids.len() as u32;
            let si = *ids.entry(s).or_insert(next);
            let next = ids.len() as u32;
            let di = *ids.entry(d).or_insert(next);
            arcs.push((si, di));
        }
        self.events.clear();
        let mut b = GraphBuilder::new(ids.len());
        b.extend(arcs);
        b.build()
    }
}

/// Convenience: window a whole event slice, producing a census series
/// computed by `census_fn` (the coordinator, or a direct engine).
pub fn census_series<F>(
    events: &[TrafficEvent],
    window_seconds: f64,
    mut census_fn: F,
) -> Vec<WindowCensus>
where
    F: FnMut(&CsrGraph) -> Census,
{
    let mut w = Windower::new(window_seconds);
    let mut out = Vec::new();
    let mut emit = |start: f64, g: CsrGraph, out: &mut Vec<WindowCensus>| {
        let census = census_fn(&g);
        out.push(WindowCensus {
            start,
            length: window_seconds,
            hosts: g.node_count(),
            arcs: g.arc_count(),
            census,
        });
    };
    for ev in events {
        if let Some((start, g)) = w.push(ev) {
            emit(start, g, &mut out);
        }
    }
    if let Some((start, g)) = w.finish() {
        emit(start, g, &mut out);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::census::merged;

    fn ev(t: f64, s: u64, d: u64) -> TrafficEvent {
        TrafficEvent {
            time: t,
            src: s,
            dst: d,
        }
    }

    #[test]
    fn windows_split_at_boundaries() {
        let events = vec![
            ev(0.1, 10, 20),
            ev(0.5, 20, 30),
            ev(1.2, 10, 20), // new window
            ev(2.5, 40, 50), // another
        ];
        let series = census_series(&events, 1.0, merged::census);
        assert_eq!(series.len(), 3);
        assert_eq!(series[0].hosts, 3);
        assert_eq!(series[0].arcs, 2);
        assert_eq!(series[1].hosts, 2);
        assert!((series[0].start - 0.0).abs() < 1e-9);
        assert!((series[1].start - 1.0).abs() < 1e-9);
        assert!((series[2].start - 2.0).abs() < 1e-9);
    }

    #[test]
    fn census_of_window_matches_direct_graph() {
        use crate::census::TriadType;
        // scan pattern: host 1 probes 5 targets in one window
        let events: Vec<_> = (0..5).map(|i| ev(0.2 + i as f64 * 0.1, 1, 100 + i)).collect();
        let series = census_series(&events, 1.0, merged::census);
        assert_eq!(series.len(), 1);
        assert_eq!(series[0].census[TriadType::T021D], 10); // C(5,2) out-star pairs
    }

    #[test]
    fn self_loops_dropped_and_empty_stream() {
        let events = vec![ev(0.0, 7, 7)];
        let series = census_series(&events, 1.0, merged::census);
        assert!(series.is_empty());
        let series = census_series(&[], 1.0, merged::census);
        assert!(series.is_empty());
    }

    #[test]
    fn gap_between_events_skips_empty_windows() {
        let events = vec![ev(0.0, 1, 2), ev(10.0, 3, 4)];
        let series = census_series(&events, 1.0, merged::census);
        assert_eq!(series.len(), 2);
        assert!((series[1].start - 10.0).abs() < 1e-9);
    }
}
