//! Minimal benchmarking harness (criterion is not in the offline vendor
//! set): warmup + timed iterations with mean / stddev / min reporting,
//! used by every target under `benches/`.

use std::time::Instant;

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub stddev_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl BenchStats {
    /// Render one aligned report line.
    pub fn line(&self) -> String {
        format!(
            "{:<44} {:>4} it  mean {:>12}  sd {:>10}  min {:>12}",
            self.name,
            self.iters,
            human_time(self.mean_s),
            human_time(self.stddev_s),
            human_time(self.min_s),
        )
    }
}

/// Pretty-print seconds.
pub fn human_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} us", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Benchmark runner: fixed iteration count with one warmup run.
pub struct Bench {
    iters: usize,
    results: Vec<BenchStats>,
}

impl Bench {
    /// `iters` timed iterations per case (after 1 warmup).
    pub fn new(iters: usize) -> Bench {
        Bench {
            iters: iters.max(1),
            results: Vec::new(),
        }
    }

    /// Honors `BENCH_ITERS` env override (CI dials it down).
    pub fn from_env(default_iters: usize) -> Bench {
        let iters = std::env::var("BENCH_ITERS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(default_iters);
        Bench::new(iters)
    }

    /// Time `f`, preventing the result from being optimized out.
    pub fn run<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &BenchStats {
        let _warm = std::hint::black_box(f());
        let mut times = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t = Instant::now();
            std::hint::black_box(f());
            times.push(t.elapsed().as_secs_f64());
        }
        let mean = times.iter().sum::<f64>() / times.len() as f64;
        let var = times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>() / times.len() as f64;
        let stats = BenchStats {
            name: name.to_string(),
            iters: self.iters,
            mean_s: mean,
            stddev_s: var.sqrt(),
            min_s: times.iter().cloned().fold(f64::INFINITY, f64::min),
            max_s: times.iter().cloned().fold(0.0, f64::max),
        };
        println!("{}", stats.line());
        self.results.push(stats);
        self.results.last().unwrap()
    }

    /// All results so far.
    pub fn results(&self) -> &[BenchStats] {
        &self.results
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_are_sane() {
        let mut b = Bench::new(5);
        let s = b.run("noop-ish", || {
            std::hint::black_box((0..1000u64).sum::<u64>())
        });
        assert_eq!(s.iters, 5);
        assert!(s.min_s <= s.mean_s && s.mean_s <= s.max_s.max(s.mean_s));
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn human_time_units() {
        assert!(human_time(2.0).ends_with(" s"));
        assert!(human_time(2e-3).ends_with(" ms"));
        assert!(human_time(2e-6).ends_with(" us"));
        assert!(human_time(2e-9).ends_with(" ns"));
    }
}
