//! Batagelj & Mrvar's subquadratic triad census — a literal
//! transcription of the paper's Fig 5 pseudocode.
//!
//! The algorithm follows existing edges: for every connected pair
//! `u < v` it materializes the union set `S = N(u) ∪ N(v) \ {u, v}`,
//! credits `n - |S| - 2` *dyadic* triads (third node unconnected), and
//! classifies each `w ∈ S` (under the canonical-selection guard of step
//! 2.1.4) as a *connected* triad. Null triads are closed out at the end
//! as `C(n,3) - Σ`. Complexity `O(m)` for bounded-degree sparse graphs.
//!
//! This version is kept deliberately close to the pseudocode (explicit
//! `S`, graph queries for the tricode) — it is the paper's *starting
//! point*; the optimized merged-traversal variant lives in
//! [`super::merged`].

use super::isotricode::{tricode_of, TRICODE_TABLE};
use super::types::{Census, TriadType};
use crate::graph::GraphView;

/// Compute the full census with the Fig 5 algorithm, over any
/// [`GraphView`].
pub fn census<G: GraphView>(g: &G) -> Census {
    let n = g.node_count();
    let mut c = Census::zero();

    // step 2: for each u ∈ V
    for u in 0..n as u32 {
        // step 2.1: for each v ∈ N(u) with u < v
        for (v, uv_bits) in g.neighbors(u) {
            if u >= v {
                continue;
            }
            // step 2.1.1: S := N(u) ∪ N(v) \ {u, v} (explicitly materialized)
            let s = union_of_neighbors(g, u, v);

            // step 2.1.2: dyadic triad type for the (u,v) dyad
            let tritype = if uv_bits == 0b11 {
                TriadType::T102
            } else {
                TriadType::T012
            };
            // step 2.1.3: third node not adjacent to either
            c.add_count(tritype, (n - s.len() - 2) as u64);

            // step 2.1.4: connected triads with canonical-selection guard
            for &w in &s {
                if v < w || (u < w && w < v && !g.is_neighbor(u, w)) {
                    // steps 2.1.4.1–2: classify and count
                    let code = tricode_of(g, u, v, w);
                    c.bump(TRICODE_TABLE[code as usize]);
                }
            }
        }
    }

    // steps 3–5: close the null count from the total
    c.close_with_null(n);
    c
}

/// `N(u) ∪ N(v) \ {u, v}` via the shared merged walk of the two
/// ascending neighborhoods (the pseudocode's explicit `S`).
fn union_of_neighbors<G: GraphView>(g: &G, u: u32, v: u32) -> Vec<u32> {
    let mut out = Vec::with_capacity(g.degree(u) + g.degree(v));
    super::merged::merged_union_walk(g, u, v, |w, _, _, _| out.push(w));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::census::naive;
    use crate::graph::builder::from_arcs;
    use crate::graph::generators::{self, named};
    use crate::graph::CsrGraph;

    #[test]
    fn union_excludes_endpoints_and_is_sorted() {
        let g = from_arcs(6, &[(0, 1), (0, 2), (0, 3), (1, 3), (1, 4), (5, 1)]);
        let s = union_of_neighbors(&g, 0, 1);
        assert_eq!(s, vec![2, 3, 4, 5]);
    }

    #[test]
    fn matches_naive_on_fixtures() {
        for g in [
            named::cycle3(),
            named::transitive3(),
            named::mutual3(),
            named::out_star4(),
            named::in_star4(),
            named::cycle5(),
            named::complete_mutual(6),
            named::fig1(),
        ] {
            assert_eq!(census(&g), naive::census(&g));
        }
    }

    #[test]
    fn matches_naive_on_random_graphs() {
        for seed in 0..8 {
            let g = generators::power_law(60, 2.2, 4.0, seed);
            assert_eq!(census(&g), naive::census(&g), "seed {seed}");
        }
        for seed in 0..4 {
            let g = generators::erdos_renyi(50, 300, seed);
            assert_eq!(census(&g), naive::census(&g), "er seed {seed}");
        }
    }

    #[test]
    fn empty_graph_is_all_null() {
        let g = CsrGraph::empty(10);
        let c = census(&g);
        assert_eq!(c[TriadType::T003] as u128, Census::expected_total(10));
        assert_eq!(c.nonnull_total(), 0);
    }

    #[test]
    fn dense_mutual_graph() {
        let g = named::complete_mutual(8);
        let c = census(&g);
        assert_eq!(c[TriadType::T300] as u128, Census::expected_total(8));
    }
}
