//! Unified census engine interface and registry.
//!
//! Every census implementation in the crate — the `O(n^3)` naive
//! oracle, Batagelj–Mrvar, the merged-traversal serial variant, the
//! scheduled parallel engine and Moody's dense matrix method — is
//! reachable behind one [`CensusEngine`] trait, so the coordinator, the
//! CLI (`--engine <name>`) and the benches select implementations by
//! name instead of hard-wiring call sites. Engines receive the shared
//! [`Executor`] and must schedule any parallel work on it; serial
//! engines simply ignore it. Results come back as a [`ParallelRun`]
//! (census + per-seat telemetry) regardless of engine, so callers get
//! uniform per-job stats.
//!
//! The trait (and the registry) is parameterized over the
//! [`GraphView`] it censuses — `CsrGraph` by default for the serving
//! path, but the same five engines instantiate over the delta overlay
//! or the direction-split form: `EngineRegistry::<DirSplit>::default()`
//! is the degree-ordered sparse path, and the golden tests run every
//! engine over every view.

use std::time::Instant;

use super::parallel::{
    census_parallel_cancellable, census_parallel_on, ParallelConfig, ParallelRun,
};
use super::types::Census;
use super::{batagelj_mrvar, merged, moody, naive};
use crate::graph::{CsrGraph, GraphView};
use crate::sched::{CancelToken, Executor, ThreadPoolStats};

/// A named triad-census implementation over view type `G`.
pub trait CensusEngine<G: GraphView = CsrGraph>: Send + Sync {
    /// Registry key and display name.
    fn name(&self) -> &str;

    /// Compute the triad census of `g`, scheduling any parallel work on
    /// `exec`.
    fn census(&self, g: &G, exec: &Executor) -> ParallelRun;

    /// [`CensusEngine::census`] with a cooperative cancellation hook:
    /// returns `None` when the job was cancelled before completing.
    /// Serial engines only honor pre-run cancellation (their sweep is
    /// one uninterruptible call); the parallel engine checks the token
    /// between scheduler chunks.
    fn census_cancellable(
        &self,
        g: &G,
        exec: &Executor,
        cancel: &CancelToken,
    ) -> Option<ParallelRun> {
        if cancel.is_cancelled() {
            return None;
        }
        Some(self.census(g, exec))
    }

    /// A copy of this engine re-parameterized with one request's
    /// thread/policy overrides, when the engine is configurable (the
    /// parallel and hybrid engines). Serial engines return `None`: they
    /// have no scheduling knobs, and callers fall back to the engine as
    /// registered.
    fn with_config(&self, _cfg: ParallelConfig) -> Option<Box<dyn CensusEngine<G>>> {
        None
    }
}

/// Wrap a serial engine's result in the uniform telemetry shape: one
/// seat, busy == wall, `items` = the collapsed slot count walked.
fn serial_run<F: FnOnce() -> Census>(items: usize, f: F) -> ParallelRun {
    let t0 = Instant::now();
    let census = f();
    let wall = t0.elapsed().as_secs_f64();
    ParallelRun {
        census,
        stats: ThreadPoolStats {
            chunks: vec![1],
            items: vec![items],
            busy: vec![wall],
            wall,
            seat_sockets: vec![0],
            local_steals: 0,
            remote_steals: 0,
            pinned_workers: 0,
        },
        bank: None,
    }
}

/// The `O(n^3)` all-triples oracle (tiny graphs only).
pub struct NaiveEngine;

impl<G: GraphView> CensusEngine<G> for NaiveEngine {
    fn name(&self) -> &str {
        "naive"
    }
    fn census(&self, g: &G, _exec: &Executor) -> ParallelRun {
        serial_run(g.entry_count(), || naive::census(g))
    }
}

/// The literal Batagelj–Mrvar subquadratic census (paper Fig 5).
pub struct BatageljMrvarEngine;

impl<G: GraphView> CensusEngine<G> for BatageljMrvarEngine {
    fn name(&self) -> &str {
        "batagelj-mrvar"
    }
    fn census(&self, g: &G, _exec: &Executor) -> ParallelRun {
        serial_run(g.entry_count(), || batagelj_mrvar::census(g))
    }
}

/// The optimized serial merged-traversal census (paper Fig 8).
pub struct MergedEngine;

impl<G: GraphView> CensusEngine<G> for MergedEngine {
    fn name(&self) -> &str {
        "merged"
    }
    fn census(&self, g: &G, _exec: &Executor) -> ParallelRun {
        serial_run(g.entry_count(), || merged::census(g))
    }
}

/// Moody's dense matrix-method census (`O(n^2)` memory — small graphs).
pub struct MoodyEngine;

impl<G: GraphView> CensusEngine<G> for MoodyEngine {
    fn name(&self) -> &str {
        "moody"
    }
    fn census(&self, g: &G, _exec: &Executor) -> ParallelRun {
        serial_run(g.entry_count(), || moody::census(g))
    }
}

/// The paper's parallel engine, scheduled on the shared executor.
pub struct ParallelEngine {
    pub cfg: ParallelConfig,
}

impl<G: GraphView> CensusEngine<G> for ParallelEngine {
    fn name(&self) -> &str {
        "parallel"
    }
    fn census(&self, g: &G, exec: &Executor) -> ParallelRun {
        census_parallel_on(g, &self.cfg, exec)
    }
    fn census_cancellable(
        &self,
        g: &G,
        exec: &Executor,
        cancel: &CancelToken,
    ) -> Option<ParallelRun> {
        census_parallel_cancellable(g, &self.cfg, exec, cancel)
    }

    fn with_config(&self, cfg: ParallelConfig) -> Option<Box<dyn CensusEngine<G>>> {
        Some(Box::new(ParallelEngine { cfg }))
    }
}

/// Name-indexed set of engines over view type `G`.
pub struct EngineRegistry<G: GraphView = CsrGraph> {
    engines: Vec<Box<dyn CensusEngine<G>>>,
}

impl<G: GraphView> EngineRegistry<G> {
    /// An empty registry.
    pub fn new() -> EngineRegistry<G> {
        EngineRegistry {
            engines: Vec::new(),
        }
    }

    /// All five built-in engines; `cfg` parameterizes the parallel one.
    pub fn builtin(cfg: ParallelConfig) -> EngineRegistry<G> {
        let mut r = EngineRegistry::new();
        r.register(Box::new(NaiveEngine));
        r.register(Box::new(BatageljMrvarEngine));
        r.register(Box::new(MergedEngine));
        r.register(Box::new(ParallelEngine { cfg }));
        r.register(Box::new(MoodyEngine));
        r
    }

    /// Add an engine, replacing any existing engine of the same name.
    pub fn register(&mut self, engine: Box<dyn CensusEngine<G>>) {
        self.engines.retain(|e| e.name() != engine.name());
        self.engines.push(engine);
    }

    /// Look up an engine by name (`bm` / `batagelj_mrvar` alias the
    /// Batagelj–Mrvar engine).
    pub fn get(&self, name: &str) -> Option<&dyn CensusEngine<G>> {
        let canonical = match name {
            "bm" | "batagelj_mrvar" => "batagelj-mrvar",
            other => other,
        };
        self.engines
            .iter()
            .find(|e| e.name() == canonical)
            .map(|e| e.as_ref())
    }

    /// Registered engine names, in registration order.
    pub fn names(&self) -> Vec<&str> {
        self.engines.iter().map(|e| e.name()).collect()
    }

    /// [`EngineRegistry::get`] with a caller-ready error message listing
    /// the available engines — the single source of the "unknown engine"
    /// wording used by the coordinator and the CLI.
    pub fn get_or_err(&self, name: &str) -> Result<&dyn CensusEngine<G>, String> {
        self.get(name).ok_or_else(|| {
            format!(
                "unknown census engine {name:?} (available: {})",
                self.names().join(", ")
            )
        })
    }
}

impl<G: GraphView> Default for EngineRegistry<G> {
    fn default() -> Self {
        EngineRegistry::builtin(ParallelConfig::default())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::graph::relabel::DirSplit;
    use crate::graph::DeltaOverlay;

    #[test]
    fn all_five_builtin_engines_are_registered() {
        // bare `EngineRegistry` in type position picks up the CsrGraph
        // default parameter
        let r: EngineRegistry = EngineRegistry::default();
        assert_eq!(
            r.names(),
            vec!["naive", "batagelj-mrvar", "merged", "parallel", "moody"]
        );
        for name in ["naive", "bm", "batagelj_mrvar", "merged", "parallel", "moody"] {
            assert!(r.get(name).is_some(), "{name} missing");
        }
        assert!(r.get("fancy").is_none());
    }

    #[test]
    fn engines_agree_through_the_registry() {
        let exec = Executor::with_workers(2);
        let r = EngineRegistry::builtin(ParallelConfig {
            threads: 3,
            ..ParallelConfig::default()
        });
        let g = generators::power_law(70, 2.2, 5.0, 11);
        let want = naive::census(&g);
        for name in r.names() {
            let run = r.get(name).unwrap().census(&g, &exec);
            assert_eq!(run.census, want, "{name}");
            assert_eq!(run.stats.busy.len(), run.stats.chunks.len(), "{name}");
        }
    }

    #[test]
    fn every_engine_instantiates_over_every_view() {
        // the acceptance bar of the GraphView refactor: one registry per
        // representation, identical censuses from all of them
        let exec = Executor::with_workers(2);
        let g = generators::power_law(90, 2.2, 5.0, 17);
        let want = naive::census(&g);

        let overlay = DeltaOverlay::new(std::sync::Arc::new(g.clone()));
        let split = DirSplit::build(&g);

        let csr_reg = EngineRegistry::<crate::graph::CsrGraph>::default();
        let overlay_reg = EngineRegistry::<DeltaOverlay>::default();
        let split_reg = EngineRegistry::<DirSplit>::default();
        for name in csr_reg.names() {
            let a = csr_reg.get(name).unwrap().census(&g, &exec).census;
            let b = overlay_reg.get(name).unwrap().census(&overlay, &exec).census;
            let c = split_reg.get(name).unwrap().census(&split, &exec).census;
            assert_eq!(a, want, "{name} csr");
            assert_eq!(b, want, "{name} overlay");
            assert_eq!(c, want, "{name} dir-split");
        }
    }

    #[test]
    fn cancellation_discards_the_run() {
        let exec = Executor::with_workers(2);
        let r = EngineRegistry::default();
        let g = generators::power_law(60, 2.2, 5.0, 3);
        let cancelled = CancelToken::new();
        cancelled.cancel();
        for name in r.names() {
            let engine = r.get(name).unwrap();
            assert!(
                engine.census_cancellable(&g, &exec, &cancelled).is_none(),
                "{name}: pre-cancelled job must not return a census"
            );
            let live = CancelToken::new();
            let run = engine
                .census_cancellable(&g, &exec, &live)
                .expect("un-cancelled job completes");
            assert_eq!(run.census, naive::census(&g), "{name}");
        }
    }

    #[test]
    fn register_replaces_by_name() {
        let mut r = EngineRegistry::<crate::graph::CsrGraph>::default();
        let before = r.names().len();
        r.register(Box::new(MergedEngine));
        assert_eq!(r.names().len(), before, "same-name registration replaces");
    }
}
