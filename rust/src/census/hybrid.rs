//! Hub-bitmap hybrid census kernel over [`HubSplit`].
//!
//! The merged union walk costs O(deg(u) + deg(v)) per canonical dyad,
//! and under degree ordering `u < v` means `u` is the *heavier*
//! endpoint — so on power-law graphs the hub rows dominate the whole
//! sweep. The hybrid kernel classifies hub-anchored dyads from the
//! hub's bitmap row instead:
//!
//! * **sparse path** (any `v`): walk only `N(v)` (the short side),
//!   answering every `(u, w)` dyad with an O(1) bitmap probe; the
//!   untouched remainder of `N(u)` above `v` is bulk-counted per
//!   direction class with the hub's rank arrays. O(deg(v)) total —
//!   the hub's own degree drops out of the per-dyad cost entirely.
//! * **dense path** (`v` also a bitmap hub with degree ≥ n/16): no
//!   walk at all — intersect the two rows' direction planes word by
//!   word and popcount each of the 15 `(uw, vw)` state combinations
//!   over range masks, bulk-adding whole tricode classes at a time.
//!
//! Both produce the exact increment multiset of
//! [`dyad_task`](super::merged::dyad_task) — same canonical guard,
//! same union accounting — so the hybrid census is byte-identical to
//! every other engine (enforced by golden fixtures and prop sweeps).
//! Non-hub dyads fall through to the merged walk unchanged.

use super::engine::{CensusEngine, EngineRegistry};
use super::isotricode::{tricode_from_dyads, TRICODE_TABLE};
use super::merged::dyad_task;
use super::parallel::{census_kernel_cancellable, DyadKernel, ParallelConfig, ParallelRun};
use super::types::{Census, CensusSink, TriadType};
use crate::graph::{GraphView, HubSplit};
use crate::sched::{CancelToken, Executor};

/// A hub–hub dyad takes the dense word-intersection path when the
/// lighter row still covers ≥ 1/16 of all nodes: below that, walking
/// `N(v)` beats scanning `n/64` words per plane.
const DENSE_DEGREE_DIVISOR: usize = 16;

/// Dense-path word-loop selection. The scalar loop is the tested
/// baseline; the wide loop splits the word range at `v`'s word so the
/// unmasked bulk (every word strictly above it — nearly the whole row,
/// since hubs sit at small ids after degree ordering) runs in explicit
/// 4-wide u64 AND/popcount blocks the compiler can vectorize.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum HubKernelMode {
    /// Reference word-at-a-time loop with per-word range masks.
    Scalar,
    /// Masked prefix handled scalar, unmasked tail in 4-wide blocks.
    #[default]
    Wide,
}

/// Classify one canonical hub-anchored dyad (`u < v`, `u` a bitmap
/// hub) with the default kernel mode, accumulating exactly the
/// increments `dyad_task` would.
#[inline]
pub fn hub_dyad_task<S: CensusSink>(h: &HubSplit, u: u32, v: u32, uv_bits: u8, c: &mut S) {
    hub_dyad_task_with(h, u, v, uv_bits, HubKernelMode::default(), c);
}

/// [`hub_dyad_task`] with an explicit dense-path kernel selection.
#[inline]
pub fn hub_dyad_task_with<S: CensusSink>(
    h: &HubSplit,
    u: u32,
    v: u32,
    uv_bits: u8,
    mode: HubKernelMode,
    c: &mut S,
) {
    debug_assert!(u < v && h.is_hub(u));
    debug_assert!(uv_bits != 0 && uv_bits < 4);
    if h.is_hub(v) && h.degree(v) * DENSE_DEGREE_DIVISOR >= h.node_count() {
        match mode {
            HubKernelMode::Scalar => hub_dense_dyad_task(h, u, v, uv_bits, c),
            HubKernelMode::Wide => hub_dense_dyad_task_wide(h, u, v, uv_bits, c),
        }
    } else {
        hub_sparse_dyad_task(h, u, v, uv_bits, c);
    }
}

/// Sparse path: one walk of `N(v)` with O(1) bitmap probes for the
/// `(u, w)` dyads, then O(1) rank arithmetic for the hub-only tail.
fn hub_sparse_dyad_task<S: CensusSink>(h: &HubSplit, u: u32, v: u32, uv_bits: u8, c: &mut S) {
    let n = h.node_count();
    let dyadic = if uv_bits == 0b11 {
        TriadType::T102
    } else {
        TriadType::T012
    };
    let mut inter = 0u64;
    // walked N(v) members above v, split by their (u, w) class — these
    // are already emitted, so the bulk tail below must exclude them
    let mut above = [0u64; 4];
    for (w, vw) in h.neighbors(v) {
        // w == u probes bit u of u's own row, which is 0 (no self
        // loops), so the guard below skips it without a branch
        let uw = h.hub_dyad_bits(u, w);
        if uw != 0 {
            inter += 1;
        }
        if w > v {
            above[uw as usize] += 1;
            c.bump(TRICODE_TABLE[tricode_from_dyads(uv_bits, uw, vw) as usize]);
        } else if u < w && uw == 0 {
            // canonical guard: u < w < v counts only when ¬uÂw
            c.bump(TRICODE_TABLE[tricode_from_dyads(uv_bits, 0, vw) as usize]);
        }
    }
    // w ∈ N(u) \ N(v), w > v: the (v, w) dyad is null and the guard
    // always passes — whole classes at a time from the rank arrays
    let totals = h.counts_above(u, v);
    for cls in 1..4u8 {
        let extra = totals[cls as usize] - above[cls as usize];
        if extra > 0 {
            c.add(TRICODE_TABLE[tricode_from_dyads(uv_bits, cls, 0) as usize], extra);
        }
    }
    // |N(u) ∪ N(v) \ {u, v}|: u ∈ N(v) and v ∈ N(u) are the only
    // members the union walk would drop
    let union_size = h.degree(u) as u64 + h.degree(v) as u64 - inter - 2;
    c.add(dyadic, n as u64 - union_size - 2);
}

/// Bits of word `wi` whose global id is `>= t`.
#[inline]
fn bits_ge(wi: usize, t: u32) -> u64 {
    let lo = (wi * 64) as u64;
    let t = t as u64;
    if t <= lo {
        u64::MAX
    } else if t >= lo + 64 {
        0
    } else {
        !0u64 << (t - lo)
    }
}

/// The four direction-state planes (null / out-only / in-only /
/// reciprocal) of one row word, indexed by 2-bit dyad code.
#[inline]
fn state_planes(o: u64, i: u64) -> [u64; 4] {
    [!(o | i), o & !i, i & !o, o & i]
}

/// State planes of four consecutive row words, laid out `[state][lane]`
/// — the wide kernel's register block.
#[inline]
fn state_lanes4(o: &[u64], i: &[u64], wi: usize) -> [[u64; 4]; 4] {
    let mut s = [[0u64; 4]; 4];
    for l in 0..4 {
        let (ow, iw) = (o[wi + l], i[wi + l]);
        s[0][l] = !(ow | iw);
        s[1][l] = ow & !iw;
        s[2][l] = iw & !ow;
        s[3][l] = ow & iw;
    }
    s
}

/// Four-lane AND + popcount reduction (the wide kernel's inner op).
#[inline]
fn and_count4(a: &[u64; 4], b: &[u64; 4]) -> u64 {
    ((a[0] & b[0]).count_ones()
        + (a[1] & b[1]).count_ones()
        + (a[2] & b[2]).count_ones()
        + (a[3] & b[3]).count_ones()) as u64
}

/// Emit the dense path's accumulated tallies. Shared by the scalar and
/// wide word loops, which must hand over identical `counts`/`mid`/
/// `union_bits` for any input.
fn emit_dense_counts<S: CensusSink>(
    n: usize,
    uv_bits: u8,
    counts: &[[u64; 4]; 4],
    mid: &[u64; 4],
    union_bits: u64,
    c: &mut S,
) {
    let dyadic = if uv_bits == 0b11 {
        TriadType::T102
    } else {
        TriadType::T012
    };
    for (a, row) in counts.iter().enumerate() {
        for (b, &k) in row.iter().enumerate() {
            if k > 0 {
                let code = tricode_from_dyads(uv_bits, a as u8, b as u8);
                c.add(TRICODE_TABLE[code as usize], k);
            }
        }
    }
    for (b, &k) in mid.iter().enumerate() {
        if k > 0 {
            let code = tricode_from_dyads(uv_bits, 0, b as u8);
            c.add(TRICODE_TABLE[code as usize], k);
        }
    }
    // the union planes carry bit v (in u's row) and bit u (in v's row)
    // and nothing past n, so |S| is the popcount minus the endpoints
    let union_size = union_bits - 2;
    c.add(dyadic, n as u64 - union_size - 2);
}

/// Dense path, scalar kernel: popcount the 15 non-null `(uw, vw)`
/// state intersections over the canonical-guard range masks, one word
/// at a time. The tested baseline the wide kernel is checked against.
fn hub_dense_dyad_task<S: CensusSink>(h: &HubSplit, u: u32, v: u32, uv_bits: u8, c: &mut S) {
    let n = h.node_count();
    let words = h.words();
    let (uo, ui) = h.planes(u);
    let (vo, vi) = h.planes(v);
    // counts[a][b]: members of the w > v region in u-state a, v-state b;
    // mid[b]: u < w < v members with null (u, w) (the ¬uÂw guard)
    let mut counts = [[0u64; 4]; 4];
    let mut mid = [0u64; 4];
    let mut union_bits = 0u64;
    for wi in 0..words {
        let (o1, i1) = (uo[wi], ui[wi]);
        let (o2, i2) = (vo[wi], vi[wi]);
        // state planes by 2-bit dyad code; null includes padding bits
        // past n, but those are null in *both* rows and the (0, 0)
        // combination is never counted
        let ua = state_planes(o1, i1);
        let va = state_planes(o2, i2);
        let hi = bits_ge(wi, v + 1);
        let mid_mask = bits_ge(wi, u + 1) & !bits_ge(wi, v);
        union_bits += (o1 | i1 | o2 | i2).count_ones() as u64;
        for (a, &uw) in ua.iter().enumerate() {
            for (b, &vw) in va.iter().enumerate() {
                if a == 0 && b == 0 {
                    continue;
                }
                let m = uw & vw;
                counts[a][b] += (m & hi).count_ones() as u64;
                if a == 0 {
                    mid[b] += (m & mid_mask).count_ones() as u64;
                }
            }
        }
    }
    emit_dense_counts(n, uv_bits, &counts, &mid, union_bits, c);
}

/// Dense path, wide kernel. Every word strictly above `v`'s needs no
/// range masks at all (`hi` saturates, `mid` vanishes), and after
/// degree-descending relabeling both hubs sit at small ids — so the
/// masked prefix is typically a single word and the whole remaining
/// row runs as unmasked 4-wide u64 AND/popcount blocks.
fn hub_dense_dyad_task_wide<S: CensusSink>(h: &HubSplit, u: u32, v: u32, uv_bits: u8, c: &mut S) {
    let n = h.node_count();
    let words = h.words();
    let (uo, ui) = h.planes(u);
    let (vo, vi) = h.planes(v);
    let mut counts = [[0u64; 4]; 4];
    let mut mid = [0u64; 4];
    let mut union_bits = 0u64;
    // masked prefix: words holding ids <= v keep the scalar handling
    let masked = (v as usize / 64 + 1).min(words);
    for wi in 0..masked {
        let (o1, i1) = (uo[wi], ui[wi]);
        let (o2, i2) = (vo[wi], vi[wi]);
        let ua = state_planes(o1, i1);
        let va = state_planes(o2, i2);
        let hi = bits_ge(wi, v + 1);
        let mid_mask = bits_ge(wi, u + 1) & !bits_ge(wi, v);
        union_bits += (o1 | i1 | o2 | i2).count_ones() as u64;
        for (a, &uw) in ua.iter().enumerate() {
            for (b, &vw) in va.iter().enumerate() {
                if a == 0 && b == 0 {
                    continue;
                }
                let m = uw & vw;
                counts[a][b] += (m & hi).count_ones() as u64;
                if a == 0 {
                    mid[b] += (m & mid_mask).count_ones() as u64;
                }
            }
        }
    }
    // unmasked bulk: 4-wide blocks, no hi/mid masking
    let mut wi = masked;
    while wi + 4 <= words {
        let ua = state_lanes4(uo, ui, wi);
        let va = state_lanes4(vo, vi, wi);
        for l in 0..4 {
            let w = wi + l;
            union_bits += (uo[w] | ui[w] | vo[w] | vi[w]).count_ones() as u64;
        }
        for (a, ul) in ua.iter().enumerate() {
            for (b, vl) in va.iter().enumerate() {
                if a == 0 && b == 0 {
                    continue;
                }
                counts[a][b] += and_count4(ul, vl);
            }
        }
        wi += 4;
    }
    // unmasked remainder (< 4 words)
    while wi < words {
        let (o1, i1) = (uo[wi], ui[wi]);
        let (o2, i2) = (vo[wi], vi[wi]);
        let ua = state_planes(o1, i1);
        let va = state_planes(o2, i2);
        union_bits += (o1 | i1 | o2 | i2).count_ones() as u64;
        for (a, &uw) in ua.iter().enumerate() {
            for (b, &vw) in va.iter().enumerate() {
                if a == 0 && b == 0 {
                    continue;
                }
                counts[a][b] += (uw & vw).count_ones() as u64;
            }
        }
        wi += 1;
    }
    emit_dense_counts(n, uv_bits, &counts, &mid, union_bits, c);
}

/// The hybrid sweep's per-dyad kernel: hub rows take the bitmap path,
/// the sparse tail keeps the merged walk. Every dyad task is tallied
/// into the split's hit/miss counters, which feed the adaptive-`k`
/// retune ([`HubSplit::retune_k`](crate::graph::HubSplit::retune_k)).
pub(crate) struct HubKernel {
    /// Dense-path word-loop selection.
    pub mode: HubKernelMode,
}

impl DyadKernel<HubSplit> for HubKernel {
    #[inline]
    fn dyad<S: CensusSink>(&self, g: &HubSplit, u: u32, v: u32, bits: u8, sink: &mut S) {
        if g.is_hub(u) {
            g.record_hub_hit(u);
            hub_dyad_task_with(g, u, v, bits, self.mode, sink);
        } else {
            g.record_hub_miss(u);
            dyad_task(g, u, v, bits, sink);
        }
    }
}

/// Hybrid parallel census on an explicit executor (the serving path
/// for `--order degree`), with the default kernel mode.
pub fn census_hybrid_on(h: &HubSplit, cfg: &ParallelConfig, exec: &Executor) -> ParallelRun {
    census_hybrid_with(h, cfg, exec, &CancelToken::new(), HubKernelMode::default())
        .expect("fresh token never cancels")
}

/// [`census_hybrid_on`] with a cooperative cancellation hook.
pub fn census_hybrid_cancellable(
    h: &HubSplit,
    cfg: &ParallelConfig,
    exec: &Executor,
    cancel: &CancelToken,
) -> Option<ParallelRun> {
    census_hybrid_with(h, cfg, exec, cancel, HubKernelMode::default())
}

/// Fully explicit hybrid census: cancellation hook plus dense-path
/// kernel selection (the scalar/wide ablation entry point).
pub fn census_hybrid_with(
    h: &HubSplit,
    cfg: &ParallelConfig,
    exec: &Executor,
    cancel: &CancelToken,
    mode: HubKernelMode,
) -> Option<ParallelRun> {
    census_kernel_cancellable(h, cfg, exec, cancel, &HubKernel { mode })
}

/// Serial hybrid census (tests and the differential oracle harness).
pub fn census_hybrid_serial(h: &HubSplit) -> Census {
    census_hybrid_serial_with(h, HubKernelMode::default())
}

/// [`census_hybrid_serial`] with an explicit kernel selection.
pub fn census_hybrid_serial_with(h: &HubSplit, mode: HubKernelMode) -> Census {
    let kernel = HubKernel { mode };
    let mut c = Census::zero();
    for u in 0..h.node_count() as u32 {
        for (v, bits) in h.neighbors(u) {
            if u < v {
                kernel.dyad(h, u, v, bits, &mut c);
            }
        }
    }
    c.close_with_null(h.node_count());
    c
}

/// The hybrid engine: registered as `"parallel"` over [`HubSplit`], so
/// the degree-ordered sparse serving path upgrades transparently — same
/// engine name, same telemetry shape, byte-identical census.
pub struct HybridEngine {
    pub cfg: ParallelConfig,
    /// Dense-path kernel selection (wide unless ablating).
    pub kernel: HubKernelMode,
}

impl CensusEngine<HubSplit> for HybridEngine {
    fn name(&self) -> &str {
        "parallel"
    }

    fn census(&self, g: &HubSplit, exec: &Executor) -> ParallelRun {
        census_hybrid_with(g, &self.cfg, exec, &CancelToken::new(), self.kernel)
            .expect("fresh token never cancels")
    }

    fn census_cancellable(
        &self,
        g: &HubSplit,
        exec: &Executor,
        cancel: &CancelToken,
    ) -> Option<ParallelRun> {
        census_hybrid_with(g, &self.cfg, exec, cancel, self.kernel)
    }

    fn with_config(&self, cfg: ParallelConfig) -> Option<Box<dyn CensusEngine<HubSplit>>> {
        Some(Box::new(HybridEngine {
            cfg,
            kernel: self.kernel,
        }))
    }
}

/// The five built-in engines over [`HubSplit`] with `"parallel"`
/// replaced by the hybrid kernel — the registry `Core` serves degree-
/// ordered requests from.
pub fn hybrid_registry(cfg: ParallelConfig) -> EngineRegistry<HubSplit> {
    let mut r = EngineRegistry::builtin(cfg);
    r.register(Box::new(HybridEngine {
        cfg,
        kernel: HubKernelMode::default(),
    }));
    r
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::census::{merged, naive};
    use crate::graph::builder::from_arcs;
    use crate::graph::generators::{self, named};
    use crate::graph::relabel::{degree_split, DirSplit};
    use crate::graph::CsrGraph;

    fn hub_of(g: &CsrGraph, k: Option<usize>) -> HubSplit {
        let (_, split) = degree_split(g, 2);
        match k {
            Some(k) => HubSplit::with_hub_count(split, k),
            None => HubSplit::build(split),
        }
    }

    #[test]
    fn serial_hybrid_matches_merged_at_every_hub_count() {
        for seed in 0..6 {
            let g = generators::power_law(160, 2.2, 6.0, seed);
            let want = merged::census(&g);
            let n = g.node_count();
            for k in [0, 1, 3, n / 2, n] {
                let h = hub_of(&g, Some(k));
                assert_eq!(census_hybrid_serial(&h), want, "seed {seed} k {k}");
            }
            let h = hub_of(&g, None);
            assert_eq!(census_hybrid_serial(&h), want, "seed {seed} adaptive");
        }
    }

    #[test]
    fn dense_path_matches_on_mutual_cliques() {
        // complete mutual graphs push every hub–hub dyad down the dense
        // word-intersection path (degree = n - 1 ≫ n/16)
        for n in [4, 6, 9, 65, 130] {
            let g = named::complete_mutual(n);
            let h = hub_of(&g, Some(n));
            assert_eq!(census_hybrid_serial(&h), merged::census(&g), "K{n}");
        }
    }

    #[test]
    fn mega_hub_star_is_exact() {
        // one hub of degree n-1 over degree-1 tails: the sparse hub path
        // with maximal rank-tail bulk counts
        let arcs: Vec<(u32, u32)> = (1..300u32)
            .map(|v| if v % 3 == 0 { (v, 0) } else { (0, v) })
            .collect();
        let g = from_arcs(300, &arcs);
        let want = merged::census(&g);
        for k in [0, 1, 300] {
            let h = hub_of(&g, Some(k));
            assert_eq!(census_hybrid_serial(&h), want, "k {k}");
        }
        let h = hub_of(&g, None);
        assert_eq!(h.hub_count(), 1, "adaptive k takes exactly the star center");
        assert_eq!(census_hybrid_serial(&h), want);
    }

    #[test]
    fn empty_graph_and_no_edges() {
        for n in [0, 1, 7] {
            let g = CsrGraph::empty(n);
            let h = HubSplit::build(DirSplit::build(&g));
            assert_eq!(census_hybrid_serial(&h), merged::census(&g), "n {n}");
        }
    }

    #[test]
    fn parallel_hybrid_matches_and_covers_all_entries() {
        let exec = Executor::with_workers(2);
        let g = generators::power_law(400, 2.1, 7.0, 23);
        let want = merged::census(&g);
        let h = hub_of(&g, Some(40));
        let cfg = ParallelConfig {
            threads: 3,
            ..ParallelConfig::default()
        };
        let run = census_hybrid_on(&h, &cfg, &exec);
        assert_eq!(run.census, want);
        assert_eq!(run.stats.items.iter().sum::<usize>(), h.entry_count());
    }

    #[test]
    fn wide_and_scalar_kernels_are_byte_identical() {
        // dense-heavy inputs: mutual cliques (every dyad dense) at word
        // boundaries, and power-law graphs with every row a bitmap
        for n in [4, 63, 64, 65, 127, 128, 130, 257, 320] {
            let g = named::complete_mutual(n);
            let h = hub_of(&g, Some(n));
            let scalar = census_hybrid_serial_with(&h, HubKernelMode::Scalar);
            let wide = census_hybrid_serial_with(&h, HubKernelMode::Wide);
            assert_eq!(scalar, wide, "K{n}");
            assert_eq!(scalar, merged::census(&g), "K{n} vs merged");
        }
        for seed in 0..4 {
            let g = generators::power_law(300, 2.0, 8.0, seed);
            let n = g.node_count();
            for k in [n / 4, n] {
                let h = hub_of(&g, Some(k));
                assert_eq!(
                    census_hybrid_serial_with(&h, HubKernelMode::Scalar),
                    census_hybrid_serial_with(&h, HubKernelMode::Wide),
                    "seed {seed} k {k}"
                );
            }
        }
    }

    #[test]
    fn parallel_wide_and_scalar_agree_with_merged() {
        let exec = Executor::with_workers(2);
        let g = generators::power_law(400, 2.1, 7.0, 29);
        let want = merged::census(&g);
        let h = hub_of(&g, Some(400));
        let cfg = ParallelConfig {
            threads: 3,
            ..ParallelConfig::default()
        };
        for mode in [HubKernelMode::Scalar, HubKernelMode::Wide] {
            let run = census_hybrid_with(&h, &cfg, &exec, &CancelToken::new(), mode)
                .expect("fresh token never cancels");
            assert_eq!(run.census, want, "{mode:?}");
        }
    }

    #[test]
    fn census_records_hub_traffic_for_retuning() {
        let g = generators::power_law(200, 2.2, 6.0, 13);
        let h = hub_of(&g, Some(20));
        assert_eq!(h.hub_stats().total(), 0);
        census_hybrid_serial(&h);
        let s = h.hub_stats();
        assert!(s.hits > 0, "hub-anchored dyads must be recorded as hits");
        assert!(s.misses > 0, "tail dyads must be recorded as misses");
        assert_eq!(s.total(), g.dyad_count(), "one tally per canonical dyad");
        // a second census doubles the window; reset clears it
        census_hybrid_serial(&h);
        assert_eq!(h.hub_stats().total(), 2 * g.dyad_count());
        h.reset_hub_stats();
        assert_eq!(h.hub_stats().total(), 0);
    }

    #[test]
    fn hybrid_registry_replaces_parallel_only() {
        let reg = hybrid_registry(ParallelConfig::default());
        let mut names = reg.names();
        names.sort_unstable();
        assert_eq!(
            names,
            vec!["batagelj-mrvar", "merged", "moody", "naive", "parallel"]
        );
        let exec = Executor::with_workers(2);
        let g = generators::power_law(90, 2.2, 5.0, 17);
        let want = naive::census(&g);
        let h = hub_of(&g, Some(10));
        for name in reg.names() {
            let run = reg.get(name).unwrap().census(&h, &exec);
            assert_eq!(run.census, want, "{name}");
        }
    }

    #[test]
    fn cancellation_and_config_override() {
        let exec = Executor::with_workers(2);
        let g = generators::power_law(80, 2.2, 5.0, 3);
        let h = hub_of(&g, Some(8));
        let engine = HybridEngine {
            cfg: ParallelConfig::default(),
            kernel: HubKernelMode::default(),
        };
        let cancelled = CancelToken::new();
        cancelled.cancel();
        assert!(engine.census_cancellable(&h, &exec, &cancelled).is_none());
        let over = engine
            .with_config(ParallelConfig {
                threads: 2,
                ..ParallelConfig::default()
            })
            .expect("hybrid engine is configurable");
        assert_eq!(over.name(), "parallel");
        assert_eq!(over.census(&h, &exec).census, naive::census(&g));
    }
}
