//! Tricode computation and the 64 → 16 isomorphism lookup table
//! (the paper's `IsoTricode` function, Fig 5 step 2.1.4.1).
//!
//! A *tricode* encodes the 6 possible arcs among an ordered node triple
//! `(u, v, w)` as a 6-bit integer:
//!
//! ```text
//! bit 0: u -> v      bit 1: v -> u
//! bit 2: u -> w      bit 3: w -> u
//! bit 4: v -> w      bit 5: w -> v
//! ```
//!
//! Rather than transcribing the published 64-entry table (easy to typo,
//! hard to audit), [`classify_tricode`] derives each code's class from
//! first principles — dyad composition plus orientation analysis — and
//! [`TRICODE_TABLE`] is generated from it at compile time. The table is
//! validated in tests against the known Holland–Leinhardt labeled-triad
//! multiplicities (1, 6, 3, 3, 3, 6, 6, 6, 6, 2, 3, 3, 3, 6, 6, 1).

use super::types::TriadType;
use crate::graph::GraphView;

/// Classify a 6-bit tricode into its triad isomorphism class.
///
/// `const`-evaluable so the lookup table is built at compile time.
pub const fn classify_tricode(code: u8) -> TriadType {
    // arc indicator bits
    let uv = (code & 1) != 0;
    let vu = (code & 2) != 0;
    let uw = (code & 4) != 0;
    let wu = (code & 8) != 0;
    let vw = (code & 16) != 0;
    let wv = (code & 32) != 0;

    // dyad composition: 0 = null, 1 = asym, 2 = mutual
    const fn dyad(a: bool, b: bool) -> u8 {
        match (a, b) {
            (false, false) => 0,
            (true, true) => 2,
            _ => 1,
        }
    }
    let d_uv = dyad(uv, vu);
    let d_uw = dyad(uw, wu);
    let d_vw = dyad(vw, wv);

    let m = (d_uv == 2) as u8 + (d_uw == 2) as u8 + (d_vw == 2) as u8;
    let a = (d_uv == 1) as u8 + (d_uw == 1) as u8 + (d_vw == 1) as u8;
    let n = (d_uv == 0) as u8 + (d_uw == 0) as u8 + (d_vw == 0) as u8;

    // per-node out/in degrees within the triad (u=0, v=1, w=2)
    let out = [
        uv as u8 + uw as u8,
        vu as u8 + vw as u8,
        wu as u8 + wv as u8,
    ];
    let inn = [
        vu as u8 + wu as u8,
        uv as u8 + wv as u8,
        uw as u8 + vw as u8,
    ];
    // per-node "participates in a mutual dyad" flag
    let mut_flag = [
        d_uv == 2 || d_uw == 2,
        d_uv == 2 || d_vw == 2,
        d_uw == 2 || d_vw == 2,
    ];

    match (m, a, n) {
        (0, 0, 3) => TriadType::T003,
        (0, 1, 2) => TriadType::T012,
        (1, 0, 2) => TriadType::T102,
        (0, 2, 1) => {
            // two asymmetric arcs: diverge (D), converge (U) or chain (C)
            if out[0] == 2 || out[1] == 2 || out[2] == 2 {
                TriadType::T021D
            } else if inn[0] == 2 || inn[1] == 2 || inn[2] == 2 {
                TriadType::T021U
            } else {
                TriadType::T021C
            }
        }
        (1, 1, 1) => {
            // one mutual dyad, one asym arc touching it through the shared
            // node: arc INTO the dyad => 111D, arc OUT of the dyad => 111U.
            // Find the asym arc (p -> q); q in the mutual dyad => D.
            let into_dyad = if d_uv == 1 {
                if uv {
                    mut_flag[1] // arc u->v, head v
                } else {
                    mut_flag[0] // arc v->u, head u
                }
            } else if d_uw == 1 {
                if uw {
                    mut_flag[2]
                } else {
                    mut_flag[0]
                }
            } else {
                // d_vw == 1
                if vw {
                    mut_flag[2]
                } else {
                    mut_flag[1]
                }
            };
            if into_dyad {
                TriadType::T111D
            } else {
                TriadType::T111U
            }
        }
        (0, 3, 0) => {
            // all asymmetric: 3-cycle iff every node has out-degree 1
            if out[0] == 1 && out[1] == 1 && out[2] == 1 {
                TriadType::T030C
            } else {
                TriadType::T030T
            }
        }
        (2, 0, 1) => TriadType::T201,
        (1, 2, 0) => {
            // mutual dyad {x,y}; z (no mutual flag) holds both asym arcs
            let z = if !mut_flag[0] {
                0
            } else if !mut_flag[1] {
                1
            } else {
                2
            };
            if out[z] == 2 {
                TriadType::T120D
            } else if inn[z] == 2 {
                TriadType::T120U
            } else {
                TriadType::T120C
            }
        }
        (2, 1, 0) => TriadType::T210,
        _ => TriadType::T300, // (3,0,0)
    }
}

/// The compile-time generated 64-entry lookup table.
pub const TRICODE_TABLE: [TriadType; 64] = {
    let mut table = [TriadType::T003; 64];
    let mut code = 0usize;
    while code < 64 {
        table[code] = classify_tricode(code as u8);
        code += 1;
    }
    table
};

/// Compute the tricode of `(u, v, w)` by querying the view (three
/// dyad lookups — each a pair of direction bits already laid out in
/// tricode order). The merged-traversal census builds tricodes from
/// in-flight neighborhood walks instead; this query path serves the
/// naive oracle and ad-hoc inspection, over any [`GraphView`].
#[inline]
pub fn tricode_of<G: GraphView>(g: &G, u: u32, v: u32, w: u32) -> u8 {
    tricode_from_dyads(g.dyad_bits(u, v), g.dyad_bits(u, w), g.dyad_bits(v, w))
}

/// Classify a triple directly.
#[inline]
pub fn triad_type_of<G: GraphView>(g: &G, u: u32, v: u32, w: u32) -> TriadType {
    TRICODE_TABLE[tricode_of(g, u, v, w) as usize]
}

/// Assemble a tricode from the three dyad direction-bit pairs, as the
/// merged traversal decodes them *in situ* from packed edges:
/// `uv`, `uw`, `vw` are 2-bit values `(a->b) | (b->a) << 1`.
#[inline]
pub fn tricode_from_dyads(uv: u8, uw: u8, vw: u8) -> u8 {
    debug_assert!(uv < 4 && uw < 4 && vw < 4);
    uv | (uw << 2) | (vw << 4)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::from_arcs;

    /// Apply a permutation of the three slots to a tricode, returning the
    /// code of the same labeled triad read in the new order.
    fn permute_code(code: u8, perm: [usize; 3]) -> u8 {
        // arc matrix among slots 0,1,2
        let mut arc = [[false; 3]; 3];
        arc[0][1] = code & 1 != 0;
        arc[1][0] = code & 2 != 0;
        arc[0][2] = code & 4 != 0;
        arc[2][0] = code & 8 != 0;
        arc[1][2] = code & 16 != 0;
        arc[2][1] = code & 32 != 0;
        let a = |i: usize, j: usize| arc[perm[i]][perm[j]];
        (a(0, 1) as u8)
            | (a(1, 0) as u8) << 1
            | (a(0, 2) as u8) << 2
            | (a(2, 0) as u8) << 3
            | (a(1, 2) as u8) << 4
            | (a(2, 1) as u8) << 5
    }

    #[test]
    fn table_covers_all_16_classes() {
        let mut seen = [false; 16];
        for t in TRICODE_TABLE {
            seen[t.index() - 1] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn labeled_multiplicities_match_holland_leinhardt() {
        // Known counts of labeled triads per class among the 64 codes.
        let expected: [(TriadType, usize); 16] = [
            (TriadType::T003, 1),
            (TriadType::T012, 6),
            (TriadType::T102, 3),
            (TriadType::T021D, 3),
            (TriadType::T021U, 3),
            (TriadType::T021C, 6),
            (TriadType::T111D, 6),
            (TriadType::T111U, 6),
            (TriadType::T030T, 6),
            (TriadType::T030C, 2),
            (TriadType::T201, 3),
            (TriadType::T120D, 3),
            (TriadType::T120U, 3),
            (TriadType::T120C, 6),
            (TriadType::T210, 6),
            (TriadType::T300, 1),
        ];
        for (t, want) in expected {
            let got = TRICODE_TABLE.iter().filter(|&&x| x == t).count();
            assert_eq!(got, want, "class {t}");
        }
    }

    #[test]
    fn classification_is_permutation_invariant() {
        let perms = [
            [0, 1, 2],
            [0, 2, 1],
            [1, 0, 2],
            [1, 2, 0],
            [2, 0, 1],
            [2, 1, 0],
        ];
        for code in 0u8..64 {
            let class = TRICODE_TABLE[code as usize];
            for p in perms {
                let pc = permute_code(code, p);
                assert_eq!(
                    TRICODE_TABLE[pc as usize], class,
                    "code {code} perm {p:?} -> {pc}"
                );
            }
        }
    }

    #[test]
    fn man_counts_consistent_with_bits() {
        for code in 0u8..64 {
            let t = TRICODE_TABLE[code as usize];
            let (m, a, _) = t.man();
            let arcs = code.count_ones() as u8;
            assert_eq!(2 * m + a, arcs, "code {code} class {t}");
        }
    }

    #[test]
    fn reversal_symmetry_of_table() {
        // Reversing every arc of a code maps its class to class.reversed().
        for code in 0u8..64 {
            let rev = ((code & 0b010101) << 1) | ((code & 0b101010) >> 1);
            assert_eq!(
                TRICODE_TABLE[rev as usize],
                TRICODE_TABLE[code as usize].reversed(),
                "code {code}"
            );
        }
    }

    #[test]
    fn canonical_examples() {
        assert_eq!(classify_tricode(0b000000), TriadType::T003);
        assert_eq!(classify_tricode(0b000001), TriadType::T012); // u->v
        assert_eq!(classify_tricode(0b000011), TriadType::T102); // u<->v
        assert_eq!(classify_tricode(0b000101), TriadType::T021D); // u->v, u->w
        assert_eq!(classify_tricode(0b001010), TriadType::T021U); // v->u, w->u
        assert_eq!(classify_tricode(0b010001), TriadType::T021C); // u->v->w
        assert_eq!(classify_tricode(0b010101), TriadType::T030T); // u->v->w, u->w
        assert_eq!(classify_tricode(0b011001), TriadType::T030C); // u->v->w->u
        assert_eq!(classify_tricode(0b001111), TriadType::T201); // u<->v, u<->w
        assert_eq!(classify_tricode(0b111111), TriadType::T300);
        // u<->v plus w->u: arc into the dyad => 111D
        assert_eq!(classify_tricode(0b001011), TriadType::T111D);
        // u<->v plus u->w: arc out of the dyad => 111U
        assert_eq!(classify_tricode(0b000111), TriadType::T111U);
        // u<->v plus w->u, w->v: diverging from w => 120D
        assert_eq!(classify_tricode(0b101011), TriadType::T120D);
        // u<->v plus u->w, v->w: converging into w => 120U
        assert_eq!(classify_tricode(0b010111), TriadType::T120U);
        // u<->v plus u->w, w->v: chain through w => 120C
        assert_eq!(classify_tricode(0b100111), TriadType::T120C);
        // u<->v, u<->w, v->w
        assert_eq!(classify_tricode(0b011111), TriadType::T210);
    }

    #[test]
    fn graph_query_tricode_matches_direct_bits() {
        let g = from_arcs(3, &[(0, 1), (1, 2), (2, 0)]);
        let code = tricode_of(&g, 0, 1, 2);
        assert_eq!(TRICODE_TABLE[code as usize], TriadType::T030C);
    }

    #[test]
    fn tricode_from_dyads_layout() {
        // uv=Out(01), uw=In(10), vw=Both(11) -> bits 0b11_10_01
        assert_eq!(tricode_from_dyads(0b01, 0b10, 0b11), 0b111001);
    }
}
