//! The paper's optimized serial census: merged two-pointer traversal
//! (Fig 8) with *in situ* tricode construction.
//!
//! Improvements over the literal Batagelj–Mrvar transcription:
//!
//! * the union set `S` is never materialized — two pointers walk the
//!   sorted neighbor rows of `u` and `v` in numeric order;
//! * the `w` dyad directions are decoded from the 2 packed bits of the
//!   row entries themselves: `w` found only in `u`'s row ⇒ the `(v,w)`
//!   dyad is null; only in `v`'s row ⇒ `(u,w)` null; in both ⇒ both
//!   known. No binary searches in the inner loop at all;
//! * the canonical-selection test `¬uÂw` of Fig 5 is likewise free: it
//!   is exactly "`w` did not come from `u`'s row".
//!
//! The same kernel, exposed as [`dyad_task`], is what the parallel
//! engine schedules over the collapsed `(u,v)` iteration space.

use super::isotricode::{tricode_from_dyads, TRICODE_TABLE};
use super::types::{Census, CensusSink, TriadType};
use crate::graph::csr::{CsrGraph, Dir};

/// Process one connected dyad `(u, v)` (`u < v`, `dir` = direction bits
/// of the `(u,v)` entry in `u`'s row), accumulating into `c`.
///
/// This is steps 2.1.1–2.1.4 of Fig 5 with the Fig 8 merged traversal.
/// Generic over the sink so the parallel engine can route the increments
/// either to a private census or to a hash-selected shared bank slot.
#[inline]
pub fn dyad_task<S: CensusSink>(g: &CsrGraph, u: u32, v: u32, dir: Dir, c: &mut S) {
    debug_assert!(u < v);
    let n = g.node_count();
    let uv_bits = dir as u32 as u8;

    // dyadic triads: third node adjacent to neither u nor v
    let dyadic = if dir == Dir::Both {
        TriadType::T102
    } else {
        TriadType::T012
    };

    let ru = g.row(u);
    let rv = g.row(v);
    let (mut i, mut j) = (0usize, 0usize);
    let mut union_size = 0usize; // |S| = |N(u) ∪ N(v) \ {u,v}|

    // Merged two-pointer traversal in numeric order (Fig 8), split into
    // a two-sided phase and two straight-line drain loops (§Perf: ~15%
    // over the Option-matching formulation — no per-step branching on
    // slice ends inside the hot loop).
    //
    // Canonical-selection guard (Fig 5 step 2.1.4): count (u,v,w) iff
    //   v < w  ∨  (u < w < v ∧ ¬uÂw)
    // where ¬uÂw ⇔ w was not found in u's row — free in this traversal.
    while i < ru.len() && j < rv.len() {
        let ea = ru[i];
        let eb = rv[j];
        let (wa, wb) = (ea.nbr(), eb.nbr());
        let (w, uw, vw, from_u) = if wa < wb {
            i += 1;
            (wa, (ea.0 & 0b11) as u8, 0u8, true)
        } else if wb < wa {
            j += 1;
            (wb, 0, (eb.0 & 0b11) as u8, false)
        } else {
            i += 1;
            j += 1;
            (wa, (ea.0 & 0b11) as u8, (eb.0 & 0b11) as u8, true)
        };
        if w == u || w == v {
            continue;
        }
        union_size += 1;
        if v < w || (u < w && w < v && !from_u) {
            let code = tricode_from_dyads(uv_bits, uw, vw);
            c.bump(TRICODE_TABLE[code as usize]);
        }
    }
    // drain u's tail: w only in N(u) ⇒ (v,w) null, ¬uÂw false ⇒ count
    // only when v < w
    while i < ru.len() {
        let ea = ru[i];
        i += 1;
        let w = ea.nbr();
        if w == v {
            continue;
        }
        union_size += 1;
        if v < w {
            let code = tricode_from_dyads(uv_bits, (ea.0 & 0b11) as u8, 0);
            c.bump(TRICODE_TABLE[code as usize]);
        }
    }
    // drain v's tail: w only in N(v) ⇒ (u,w) null, ¬uÂw true
    while j < rv.len() {
        let eb = rv[j];
        j += 1;
        let w = eb.nbr();
        if w == u {
            continue;
        }
        union_size += 1;
        if v < w || (u < w && w < v) {
            let code = tricode_from_dyads(uv_bits, 0, (eb.0 & 0b11) as u8);
            c.bump(TRICODE_TABLE[code as usize]);
        }
    }

    c.add(dyadic, (n - union_size - 2) as u64);
}

/// Full serial census with the merged-traversal kernel.
pub fn census(g: &CsrGraph) -> Census {
    let mut c = Census::zero();
    for u in 0..g.node_count() as u32 {
        for e in g.row(u) {
            let v = e.nbr();
            if u < v {
                dyad_task(g, u, v, e.dir(), &mut c);
            }
        }
    }
    c.close_with_null(g.node_count());
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::census::{batagelj_mrvar, naive};
    use crate::graph::generators::{self, named};

    #[test]
    fn matches_naive_on_fixtures() {
        for g in [
            named::cycle3(),
            named::transitive3(),
            named::mutual3(),
            named::out_star4(),
            named::in_star4(),
            named::cycle5(),
            named::complete_mutual(6),
            named::fig1(),
        ] {
            assert_eq!(census(&g), naive::census(&g));
        }
    }

    #[test]
    fn matches_naive_on_random_graphs() {
        for seed in 0..10 {
            let g = generators::power_law(70, 2.1, 5.0, seed);
            assert_eq!(census(&g), naive::census(&g), "seed {seed}");
        }
    }

    #[test]
    fn matches_bm_on_larger_graphs() {
        // BM itself is validated against naive on small graphs; use it as
        // the oracle at sizes where naive would be slow.
        for seed in [3, 11] {
            let g = generators::power_law(1500, 2.3, 8.0, seed);
            assert_eq!(census(&g), batagelj_mrvar::census(&g), "seed {seed}");
        }
        let g = generators::barabasi_albert(1200, 4, 9);
        assert_eq!(census(&g), batagelj_mrvar::census(&g));
    }

    #[test]
    fn handles_disconnected_and_empty() {
        let g = CsrGraph::empty(12);
        assert_eq!(census(&g), naive::census(&g));
        let g = generators::erdos_renyi(30, 10, 2);
        assert_eq!(census(&g), naive::census(&g));
    }

    #[test]
    fn dyad_task_counts_each_triad_once() {
        // On a complete mutual K6 every dyad task contributes; the guard
        // must still yield exactly C(6,3) triads of type 300.
        let g = named::complete_mutual(6);
        let c = census(&g);
        assert_eq!(c[TriadType::T300], 20);
        assert_eq!(c.total(), Census::expected_total(6));
    }
}
