//! The paper's optimized serial census: merged two-pointer traversal
//! (Fig 8) with *in situ* tricode construction — generic over every
//! [`GraphView`] (owned CSR, mmap CSR, delta overlay, direction-split).
//!
//! Improvements over the literal Batagelj–Mrvar transcription:
//!
//! * the union set `S` is never materialized — two pointers walk the
//!   ascending neighbor iterators of `u` and `v` in numeric order;
//! * the `w` dyad directions come from the iterators themselves: `w`
//!   found only in `u`'s walk ⇒ the `(v,w)` dyad is null; only in
//!   `v`'s ⇒ `(u,w)` null; in both ⇒ both known. No dyad lookups in
//!   the inner loop at all;
//! * the canonical-selection test `¬uÂw` of Fig 5 is likewise free: it
//!   is exactly "`w` did not come from `u`'s walk".
//!
//! The union walk is exposed as [`merged_union_walk`] — the one merged
//! neighborhood traversal in the crate. [`dyad_task`] (the kernel the
//! parallel engine schedules over the collapsed `(u,v)` space) and the
//! streaming census's per-mutation rescan are both thin closures over
//! it, which is what deleted the bespoke overlay-scan duplication that
//! used to live in `census/stream.rs`.

use super::isotricode::{tricode_from_dyads, TRICODE_TABLE};
use super::types::{Census, CensusSink, TriadType};
use crate::graph::GraphView;

/// Walk `S = N(u) ∪ N(v) \ {u, v}` in ascending order, invoking
/// `f(w, uw_bits, vw_bits, from_u)` for every `w` — `uw_bits` /
/// `vw_bits` are the 2-bit dyad directions (`0` = null) and `from_u`
/// is true iff `w` appeared in `u`'s neighborhood (the free `uÂw`
/// test). Returns `|S|`. O(deg(u) + deg(v)).
///
/// Structured as a two-sided phase plus two straight-line drain loops
/// (§Perf: ~15% over a peekable/Option-matching formulation — the hot
/// loop's only branches are the ones that also advance the walk).
#[inline]
pub fn merged_union_walk<G, F>(g: &G, u: u32, v: u32, mut f: F) -> usize
where
    G: GraphView,
    F: FnMut(u32, u8, u8, bool),
{
    let mut ru = g.neighbors(u);
    let mut rv = g.neighbors(v);
    let mut union_size = 0usize;
    let mut a = ru.next();
    let mut b = rv.next();
    while let (Some((wa, ub)), Some((wb, vb))) = (a, b) {
        let (w, uw, vw, from_u) = if wa < wb {
            a = ru.next();
            (wa, ub, 0, true)
        } else if wb < wa {
            b = rv.next();
            (wb, 0, vb, false)
        } else {
            a = ru.next();
            b = rv.next();
            (wa, ub, vb, true)
        };
        if w == u || w == v {
            continue;
        }
        union_size += 1;
        f(w, uw, vw, from_u);
    }
    // drain u's tail: w only in N(u) — (v,w) is null (w == u impossible
    // in a simple graph, but the endpoint guard stays uniform)
    while let Some((w, bits)) = a {
        a = ru.next();
        if w == v {
            continue;
        }
        union_size += 1;
        f(w, bits, 0, true);
    }
    // drain v's tail: w only in N(v) — (u,w) null
    while let Some((w, bits)) = b {
        b = rv.next();
        if w == u {
            continue;
        }
        union_size += 1;
        f(w, 0, bits, false);
    }
    union_size
}

/// Process one connected dyad `(u, v)` (`u < v`, `uv_bits` = the 2-bit
/// direction of the dyad seen from `u`), accumulating into `c`.
///
/// This is steps 2.1.1–2.1.4 of Fig 5 with the Fig 8 merged traversal.
/// Generic over the sink so the parallel engine can route increments
/// either to a private census or to a hash-selected shared bank slot,
/// and over the view so every representation shares one kernel.
///
/// Canonical-selection guard (Fig 5 step 2.1.4): count `(u,v,w)` iff
/// `v < w ∨ (u < w < v ∧ ¬uÂw)` — each connected triad is classified
/// exactly once, from its lowest-ordered vertex's dyads (under degree
/// ordering that vertex is the triad's highest-degree one).
#[inline]
pub fn dyad_task<G: GraphView, S: CensusSink>(g: &G, u: u32, v: u32, uv_bits: u8, c: &mut S) {
    debug_assert!(u < v);
    debug_assert!(uv_bits != 0 && uv_bits < 4);
    let n = g.node_count();

    // dyadic triads: third node adjacent to neither u nor v
    let dyadic = if uv_bits == 0b11 {
        TriadType::T102
    } else {
        TriadType::T012
    };

    let union_size = merged_union_walk(g, u, v, |w, uw, vw, from_u| {
        if v < w || (u < w && w < v && !from_u) {
            let code = tricode_from_dyads(uv_bits, uw, vw);
            c.bump(TRICODE_TABLE[code as usize]);
        }
    });

    c.add(dyadic, (n - union_size - 2) as u64);
}

/// Full serial census with the merged-traversal kernel, over any view.
pub fn census<G: GraphView>(g: &G) -> Census {
    let mut c = Census::zero();
    for u in 0..g.node_count() as u32 {
        for (v, bits) in g.neighbors(u) {
            if u < v {
                dyad_task(g, u, v, bits, &mut c);
            }
        }
    }
    c.close_with_null(g.node_count());
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::census::{batagelj_mrvar, naive};
    use crate::graph::generators::{self, named};
    use crate::graph::relabel::DirSplit;
    use crate::graph::{CsrGraph, DeltaOverlay};
    use std::sync::Arc;

    #[test]
    fn matches_naive_on_fixtures() {
        for g in [
            named::cycle3(),
            named::transitive3(),
            named::mutual3(),
            named::out_star4(),
            named::in_star4(),
            named::cycle5(),
            named::complete_mutual(6),
            named::fig1(),
        ] {
            assert_eq!(census(&g), naive::census(&g));
        }
    }

    #[test]
    fn matches_naive_on_random_graphs() {
        for seed in 0..10 {
            let g = generators::power_law(70, 2.1, 5.0, seed);
            assert_eq!(census(&g), naive::census(&g), "seed {seed}");
        }
    }

    #[test]
    fn matches_bm_on_larger_graphs() {
        // BM itself is validated against naive on small graphs; use it as
        // the oracle at sizes where naive would be slow.
        for seed in [3, 11] {
            let g = generators::power_law(1500, 2.3, 8.0, seed);
            assert_eq!(census(&g), batagelj_mrvar::census(&g), "seed {seed}");
        }
        let g = generators::barabasi_albert(1200, 4, 9);
        assert_eq!(census(&g), batagelj_mrvar::census(&g));
    }

    #[test]
    fn handles_disconnected_and_empty() {
        let g = CsrGraph::empty(12);
        assert_eq!(census(&g), naive::census(&g));
        let g = generators::erdos_renyi(30, 10, 2);
        assert_eq!(census(&g), naive::census(&g));
    }

    #[test]
    fn dyad_task_counts_each_triad_once() {
        // On a complete mutual K6 every dyad task contributes; the guard
        // must still yield exactly C(6,3) triads of type 300.
        let g = named::complete_mutual(6);
        let c = census(&g);
        assert_eq!(c[TriadType::T300], 20);
        assert_eq!(c.total(), Census::expected_total(6));
    }

    #[test]
    fn union_walk_reports_bits_and_provenance() {
        // 0-1 dyad; 2 in N(0) only, 3 in N(1) only, 4 in both
        let g = crate::graph::builder::from_arcs(
            5,
            &[(0, 1), (0, 2), (3, 1), (0, 4), (4, 0), (1, 4)],
        );
        let mut seen = Vec::new();
        let n = merged_union_walk(&g, 0, 1, |w, uw, vw, from_u| {
            seen.push((w, uw, vw, from_u));
        });
        assert_eq!(n, 3);
        let want: Vec<(u32, u8, u8, bool)> =
            vec![(2, 0b01, 0, true), (3, 0, 0b10, false), (4, 0b11, 0b01, true)];
        assert_eq!(seen, want);
    }

    #[test]
    fn one_kernel_every_view() {
        // the same generic census over CSR, overlay and direction-split
        // views of one graph must agree bit for bit
        let g = generators::power_law(150, 2.2, 6.0, 31);
        let want = census(&g);
        let overlay = DeltaOverlay::new(Arc::new(g.clone()));
        assert_eq!(census(&overlay), want);
        let split = DirSplit::build(&g);
        assert_eq!(census(&split), want);
    }
}
