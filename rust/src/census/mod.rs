//! Triad census algorithms.
//!
//! A *triad* is a subgraph of three nodes of a directed graph; it has 64
//! possible edge configurations that collapse into 16 isomorphism
//! classes (the Holland–Leinhardt M-A-N taxonomy). The *triad census*
//! counts the triads of a graph in each class and is the computational
//! core of triadic analysis (paper §3–4).
//!
//! Implementations, in increasing sophistication:
//!
//! * [`naive::census`] — `O(n^3)` enumeration of all triples; the test
//!   oracle.
//! * [`batagelj_mrvar::census`] — the `O(m)` subquadratic algorithm of
//!   Batagelj & Mrvar (paper Fig 5), transcribed literally.
//! * [`merged::census`] — the paper's optimized serial variant: merged
//!   two-pointer traversal of the sorted neighbor arrays (Fig 8) with
//!   *in situ* tricode construction from the direction bits.
//! * [`parallel::census`] — the paper's contribution: the merged variant
//!   over a manhattan-collapsed iteration space with OpenMP-style
//!   scheduling and hash-distributed local census vectors.
//! * [`moody::census`] — Moody's dense matrix-method census, the
//!   baseline the dense (JAX/Pallas AOT) path mirrors.
//!
//! All five are generic over [`crate::graph::GraphView`] — owned CSR,
//! mmap-backed CSR, the streaming
//! [`DeltaOverlay`](crate::graph::overlay::DeltaOverlay) and the
//! direction-split form census identically through one monomorphized
//! kernel per engine — and reachable behind the
//! [`engine::CensusEngine`] trait via [`engine::EngineRegistry`], the
//! by-name selection surface of the coordinator and the `--engine` CLI
//! flag. [`crate::graph::relabel`] supplies the census-invariant
//! degree-descending reordering the `--order degree` /
//! `ordering:"degree"` knobs apply before the sparse engines run.
//!
//! For graphs that change between requests, [`stream::StreamingCensus`]
//! maintains a live census over a
//! [`DeltaOverlay`](crate::graph::overlay::DeltaOverlay) by
//! reclassifying only the O(deg(u) + deg(v)) triads touched by each
//! edge mutation — no full recompute on the serving path. When even
//! that is too much, [`sampled::SampledCensus`] trades exactness for
//! throughput: exact maintenance restricted to a deterministically
//! hash-sampled fraction `p` of the dyads, unbiased per class with
//! variance-derived confidence intervals (the `sampled{p}` fidelity
//! of the wire protocol and the `--sample-p` CLI flag).

pub mod batagelj_mrvar;
pub mod engine;
pub mod hybrid;
pub mod isotricode;
pub mod merged;
pub mod moody;
pub mod naive;
pub mod parallel;
pub mod sampled;
pub mod stream;
pub mod types;

pub use engine::{CensusEngine, EngineRegistry};
pub use hybrid::{
    census_hybrid_cancellable, census_hybrid_on, census_hybrid_serial, census_hybrid_serial_with,
    census_hybrid_with, hybrid_registry, HubKernelMode, HybridEngine,
};
pub use isotricode::{classify_tricode, tricode_of, TRICODE_TABLE};
pub use parallel::{
    auto_bank_slots, census_parallel, census_parallel_cancellable, census_parallel_on,
    census_parallel_range, census_parallel_scoped, Accumulation, BankTelemetry, ParallelConfig,
    ParallelRun,
};
pub use sampled::{
    estimate_sampled, keep_dyad, sample_base, ClassEstimate, SampledCensus, SampledEstimate,
    DEFAULT_CONFIDENCE_Z, DEFAULT_SAMPLE_SEED,
};
pub use stream::{BatchReport, StreamStats, StreamingCensus};
pub use types::{Census, TriadType};
