//! Moody's matrix-method triad census (the paper's `O(n^2)` baseline,
//! ref [12]) over dense adjacency algebra.
//!
//! Every one of the 15 non-null class counts reduces to a fused
//! *triple-product sum* `T(X,Y,Z) = Σ_{i,k} (X·Y)_{ik} · Z_{ik}` over the
//! dyad-indicator matrices
//!
//! * `M`  — mutual (`A ∘ Aᵀ`),
//! * `As` — asymmetric (`A − M`),
//! * `S`  — any one-way connection (`As + Asᵀ`),
//! * `N`  — null (`J − I − M − S`),
//!
//! with a small symmetry divisor. This Rust implementation is the exact
//! arithmetic mirror of the JAX/Pallas dense path
//! (`python/compile/model.py`), so the AOT artifact can be cross-checked
//! against it bit-for-bit after integer rounding; both are validated
//! against the sparse algorithms in tests.
//!
//! Complexity `Θ(n^3)` (inside the matmuls) — intended for the dense
//! windowed workloads of the monitoring application, not for the
//! large sparse graphs (those go through [`super::merged`] /
//! [`super::parallel`]).

use super::types::{Census, TriadType};
use crate::graph::GraphView;

/// Dense dyad-indicator matrices of a digraph.
#[derive(Debug, Clone)]
pub struct DyadMatrices {
    pub n: usize,
    /// mutual: `M[i,j] = 1` iff arcs both ways.
    pub m: Vec<f64>,
    /// asymmetric: `As[i,j] = 1` iff `i->j` and not `j->i`.
    pub a: Vec<f64>,
    /// null: `N[i,j] = 1` iff `i != j` and no arc either way.
    pub nul: Vec<f64>,
}

impl DyadMatrices {
    /// Decompose any view's adjacency into `M`, `As`, `N`.
    pub fn new<G: GraphView>(g: &G) -> DyadMatrices {
        let n = g.node_count();
        let mut m = vec![0f64; n * n];
        let mut a = vec![0f64; n * n];
        let mut nul = vec![0f64; n * n];
        for i in 0..n {
            for j in 0..n {
                if i != j {
                    nul[i * n + j] = 1.0;
                }
            }
        }
        for u in 0..n as u32 {
            for (v, bits) in g.neighbors(u) {
                let v = v as usize;
                let u = u as usize;
                nul[u * n + v] = 0.0;
                match bits {
                    0b11 => m[u * n + v] = 1.0,
                    0b01 => a[u * n + v] = 1.0,
                    _ => {} // in-arc: recorded from the other side
                }
            }
        }
        DyadMatrices { n, m, a, nul }
    }

    /// Transpose of an `n×n` row-major matrix.
    fn transpose(x: &[f64], n: usize) -> Vec<f64> {
        let mut t = vec![0f64; n * n];
        for i in 0..n {
            for j in 0..n {
                t[j * n + i] = x[i * n + j];
            }
        }
        t
    }
}

/// Fused triple-product sum `Σ_{i,k} (X·Y)_{ik} Z_{ik}` without
/// materializing `X·Y`: per output row, accumulate `x[i,:]·Y` into a
/// scratch row (ikj order — streams `Y` rows), then dot with `z[i,:]`.
/// This is the Rust mirror of the Pallas kernel's blocked reduction.
pub fn triple_product_sum(x: &[f64], y: &[f64], z: &[f64], n: usize) -> f64 {
    debug_assert_eq!(x.len(), n * n);
    debug_assert_eq!(y.len(), n * n);
    debug_assert_eq!(z.len(), n * n);
    let mut total = 0f64;
    let mut row = vec![0f64; n];
    for i in 0..n {
        row.iter_mut().for_each(|r| *r = 0.0);
        for j in 0..n {
            let xij = x[i * n + j];
            if xij != 0.0 {
                let yrow = &y[j * n..j * n + n];
                for (r, &yv) in row.iter_mut().zip(yrow) {
                    *r += xij * yv;
                }
            }
        }
        let zrow = &z[i * n..i * n + n];
        for (r, &zv) in row.iter().zip(zrow) {
            total += r * zv;
        }
    }
    total
}

/// The 15 Moody triple-product formulas. Returns the census (null class
/// closed from `C(n,3)`).
pub fn census_from_matrices(d: &DyadMatrices) -> Census {
    let n = d.n;
    let m = &d.m;
    let a = &d.a;
    let nul = &d.nul;
    let at = DyadMatrices::transpose(a, n);
    let s: Vec<f64> = a.iter().zip(&at).map(|(x, y)| x + y).collect();

    let t = |x: &[f64], y: &[f64], z: &[f64]| triple_product_sum(x, y, z, n);

    let mut c = Census::zero();
    let put = |c: &mut Census, ty: TriadType, v: f64| {
        debug_assert!(
            (v - v.round()).abs() < 1e-6 && v >= -1e-6,
            "non-integral count {v} for {ty}"
        );
        c.add_count(ty, v.round() as u64);
    };

    put(&mut c, TriadType::T300, t(m, m, m) / 6.0);
    put(&mut c, TriadType::T210, t(m, m, &s) / 2.0);
    put(&mut c, TriadType::T201, t(m, m, nul) / 2.0);
    put(&mut c, TriadType::T120D, t(&at, a, m) / 2.0);
    put(&mut c, TriadType::T120U, t(a, &at, m) / 2.0);
    put(&mut c, TriadType::T120C, t(a, a, m));
    put(&mut c, TriadType::T111D, t(m, &at, nul));
    put(&mut c, TriadType::T111U, t(m, a, nul));
    put(&mut c, TriadType::T030T, t(a, a, a));
    put(&mut c, TriadType::T030C, t(a, a, &at) / 3.0);
    put(&mut c, TriadType::T021D, t(&at, a, nul) / 2.0);
    put(&mut c, TriadType::T021U, t(a, &at, nul) / 2.0);
    put(&mut c, TriadType::T021C, t(a, a, nul));
    put(&mut c, TriadType::T102, t(nul, nul, m) / 2.0);
    put(&mut c, TriadType::T012, t(nul, nul, &s) / 2.0);
    c.close_with_null(n);
    c
}

/// Full dense census of any view.
pub fn census<G: GraphView>(g: &G) -> Census {
    census_from_matrices(&DyadMatrices::new(g))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::census::naive;
    use crate::graph::generators::{self, named};

    #[test]
    fn dyad_matrices_partition_pairs() {
        let g = generators::power_law(50, 2.2, 4.0, 3);
        let d = DyadMatrices::new(&g);
        let n = d.n;
        for i in 0..n {
            for j in 0..n {
                let idx = i * n + j;
                let at = d.a[j * n + i];
                let total = d.m[idx] + d.a[idx] + at + d.nul[idx];
                if i == j {
                    assert_eq!(total, 0.0);
                } else {
                    assert_eq!(total, 1.0, "pair ({i},{j}) not exactly one dyad state");
                }
            }
        }
    }

    #[test]
    fn triple_product_small() {
        // X = Y = Z = all-ones 2x2 (with diagonal): (XY) = 2*ones, sum(∘Z) = 8
        let ones = vec![1f64; 4];
        assert_eq!(triple_product_sum(&ones, &ones, &ones, 2), 8.0);
    }

    #[test]
    fn matches_naive_on_fixtures() {
        for g in [
            named::cycle3(),
            named::transitive3(),
            named::mutual3(),
            named::out_star4(),
            named::in_star4(),
            named::cycle5(),
            named::complete_mutual(6),
            named::fig1(),
        ] {
            assert_eq!(census(&g), naive::census(&g));
        }
    }

    #[test]
    fn matches_naive_on_random_graphs() {
        for seed in 0..10 {
            let g = generators::power_law(48, 2.0, 5.0, seed);
            assert_eq!(census(&g), naive::census(&g), "seed {seed}");
        }
        for seed in 0..4 {
            let g = generators::erdos_renyi(40, 250, seed + 100);
            assert_eq!(census(&g), naive::census(&g), "er seed {seed}");
        }
    }

    #[test]
    fn matches_merged_on_medium_graph() {
        let g = generators::power_law(300, 2.4, 6.0, 77);
        assert_eq!(census(&g), crate::census::merged::census(&g));
    }
}
