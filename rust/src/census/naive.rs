//! Naive `O(n^3)` triad census: enumerate every node triple and classify
//! it. Exponentially slower than the `O(m)` algorithms on sparse graphs
//! but trivially correct — this is the oracle every other implementation
//! is validated against (paper §4's "simple, naive algorithm").

use super::isotricode::{tricode_of, TRICODE_TABLE};
use super::types::Census;
use crate::graph::GraphView;

/// Compute the full 16-class census by triple enumeration, over any
/// [`GraphView`].
pub fn census<G: GraphView>(g: &G) -> Census {
    let n = g.node_count() as u32;
    let mut c = Census::zero();
    for u in 0..n {
        for v in (u + 1)..n {
            for w in (v + 1)..n {
                let code = tricode_of(g, u, v, w);
                c.bump(TRICODE_TABLE[code as usize]);
            }
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::census::types::TriadType;
    use crate::graph::generators::named;

    #[test]
    fn cycle3_is_one_030c() {
        let c = census(&named::cycle3());
        assert_eq!(c[TriadType::T030C], 1);
        assert_eq!(c.total(), 1);
    }

    #[test]
    fn transitive3_is_one_030t() {
        let c = census(&named::transitive3());
        assert_eq!(c[TriadType::T030T], 1);
        assert_eq!(c.total(), 1);
    }

    #[test]
    fn mutual3_is_one_300() {
        let c = census(&named::mutual3());
        assert_eq!(c[TriadType::T300], 1);
    }

    #[test]
    fn out_star4() {
        let c = census(&named::out_star4());
        assert_eq!(c[TriadType::T021D], 3);
        assert_eq!(c[TriadType::T012], 0);
        // triads {1,2,3} have no arcs
        assert_eq!(c[TriadType::T003], 1);
        assert_eq!(c.total(), 4);
    }

    #[test]
    fn in_star4() {
        let c = census(&named::in_star4());
        assert_eq!(c[TriadType::T021U], 3);
        assert_eq!(c[TriadType::T003], 1);
    }

    #[test]
    fn complete_mutual_5_all_300() {
        let c = census(&named::complete_mutual(5));
        assert_eq!(c[TriadType::T300], 10);
        assert_eq!(c.total(), 10);
    }

    #[test]
    fn cycle5_census() {
        // 5-cycle: C(5,3)=10 triads. Each triple of consecutive nodes
        // (5 of them) is a chain 021C; the other 5 triples have exactly
        // 2 non-adjacent arcs? Enumerate: nodes {i, i+1, i+3}: arcs
        // i->i+1 only plus (i+3 -> i+4 not in set)... trust the oracle's
        // own arithmetic here and check invariants instead.
        let c = census(&named::cycle5());
        assert_eq!(c.total(), 10);
        // every arc appears in n-2 = 3 triads; 5 arcs -> 15 arc-slots
        assert_eq!(c.implied_arc_triples(), 15);
        assert_eq!(c[TriadType::T021C], 5);
    }

    #[test]
    fn total_always_choose_3() {
        let g = crate::graph::generators::power_law(40, 2.0, 4.0, 1);
        let c = census(&g);
        assert_eq!(c.total(), Census::expected_total(40));
    }
}
