//! The paper's parallel triad census engine.
//!
//! Combines every optimization of §6–7:
//!
//! * **Manhattan collapse** — the imperfectly nested `(u ∈ V, v ∈ N(u))`
//!   loops of Fig 5 are flattened into the CSR entry index space
//!   `0..entry_count`, so scheduler chunks see uniform-cost *slots*
//!   rather than whole (wildly imbalanced, power-law) vertex rows. A
//!   worker seats itself with one `O(log n)` offset search per chunk and
//!   walks linearly from there.
//! * **OpenMP-style policies** — static / dynamic / guided, from
//!   [`crate::sched`]. The paper's finding (dynamic best, guided
//!   severely underperforming) is reproduced by `benches/sched_policies`.
//! * **Local census vectors** — instead of hammering one shared
//!   16-element vector, increments go to one of `B` (default 64) atomic
//!   census vectors selected by a hash of `(u, v)`, exactly the paper's
//!   hot-spot mitigation; the bank is summed once at the end. Three
//!   accumulation modes exist: the paper's single *global* bank
//!   (`Bank`), the NUMA-hardened *per-socket* banks (`Banked` — each
//!   socket's seats fetch-add only into a bank sized for that socket,
//!   so no census increment ever crosses a socket boundary before the
//!   final reduce), and fully private `PerThread` vectors (no atomics)
//!   for the ablation bench.

use std::sync::atomic::{AtomicU64, Ordering};

use super::merged::dyad_task;
use super::types::{Census, CensusSink, TriadType};
use crate::graph::GraphView;
use crate::rng::splitmix64;
use crate::sched::{
    run_partitioned_scoped, CancelToken, Executor, Policy, ThreadPoolStats, Topology,
};

/// How triad increments are accumulated across threads.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Accumulation {
    /// The paper's scheme: `B` shared atomic census vectors, selected per
    /// dyad by `hash(u, v) % B` — one *global* bank, so on a NUMA host
    /// the hash scatters increments across sockets.
    Bank { slots: usize },
    /// Socket-local banks: one bank per socket, each sized from the
    /// [`Topology`] and the seats the socket owns
    /// ([`auto_bank_slots`]), with the `(u, v)` hash picking a slot
    /// *within* the writer's own socket bank. A 1-thread run allocates
    /// a few slots, not the paper's full 64, and no increment crosses a
    /// socket until the single final reduce.
    Banked,
    /// Fully private per-thread vectors (no shared writes at all).
    PerThread,
}

/// Slots for one socket's census bank, derived from the seats the
/// socket actually runs: 8 slots per seat (enough spread that two seats
/// rarely collide on a slot) clamped to the paper's 64-vector bank, and
/// at least 1 so an unseated socket still has a valid (empty) bank.
pub fn auto_bank_slots(socket_seats: usize) -> usize {
    (socket_seats * 8).max(1).next_power_of_two().min(64)
}

/// Configuration of a parallel census run.
#[derive(Debug, Clone, Copy)]
pub struct ParallelConfig {
    pub threads: usize,
    pub policy: Policy,
    pub accumulation: Accumulation,
}

impl Default for ParallelConfig {
    fn default() -> Self {
        ParallelConfig {
            threads: std::thread::available_parallelism()
                .map(|p| p.get())
                .unwrap_or(1),
            policy: Policy::dynamic_default(),
            // The paper's 64 local census vectors target the XMT's
            // word-level synchronization; on cache-coherent hosts the
            // §Perf ablation (benches/census_core.rs) measures the
            // atomic bank at ~2x the cost of fully private vectors, so
            // private accumulation is the default here. Pass
            // `Accumulation::Bank { slots: 64 }` to reproduce the
            // paper's scheme exactly.
            accumulation: Accumulation::PerThread,
        }
    }
}

/// A bank of `B` atomic 16-element census vectors (the paper's "64 local
/// triad census vectors"), padded to cache lines to avoid false sharing.
pub struct CensusBank {
    // 16 counters per slot; slot stride padded to 2 cache lines (16*8B).
    slots: Vec<[AtomicU64; 16]>,
}

impl CensusBank {
    /// Create a bank with `slots` vectors.
    pub fn new(slots: usize) -> CensusBank {
        assert!(slots > 0);
        CensusBank {
            slots: (0..slots)
                .map(|_| std::array::from_fn(|_| AtomicU64::new(0)))
                .collect(),
        }
    }

    /// Number of slots.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    /// True if no slots (never: constructor asserts).
    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// The paper's uniform hash of the `(u, v)` pair onto a slot.
    #[inline]
    pub fn slot_of(&self, u: u32, v: u32) -> usize {
        let mut key = ((u as u64) << 32) | v as u64;
        (splitmix64(&mut key) % self.slots.len() as u64) as usize
    }

    /// Reduce the bank into a single census (Fig 5 steps 3–4 analogue).
    pub fn reduce(&self) -> Census {
        let mut total = Census::zero();
        for slot in &self.slots {
            for (i, c) in slot.iter().enumerate() {
                total.add_count(
                    TriadType::from_index(i + 1),
                    c.load(Ordering::Relaxed),
                );
            }
        }
        total
    }
}

/// Sink view of one bank slot: all increments are atomic fetch-adds,
/// mirroring the XMT's word-level `int_fetch_add` synchronization.
pub struct BankSlot<'a> {
    slot: &'a [AtomicU64; 16],
}

impl CensusSink for BankSlot<'_> {
    #[inline]
    fn bump(&mut self, t: TriadType) {
        self.slot[t.index() - 1].fetch_add(1, Ordering::Relaxed);
    }
    #[inline]
    fn add(&mut self, t: TriadType, k: u64) {
        self.slot[t.index() - 1].fetch_add(k, Ordering::Relaxed);
    }
}

/// Telemetry of one banked accumulation: how the bank was sized and
/// how its write traffic split across sockets. "Writes" are counted
/// per routed dyad task (each task then issues its class increments
/// into the chosen slot), which is the unit the hash distributes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BankTelemetry {
    /// Banks allocated (1 for the global `Bank`, one per socket for
    /// `Banked`).
    pub banks: usize,
    /// Total slots across all banks.
    pub slots: usize,
    /// Per socket: dyads routed into the writer socket's own bank (or
    /// its proportional share of the global bank).
    pub local_writes: Vec<u64>,
    /// Per socket: dyads whose global-bank slot fell in another
    /// socket's share — the cross-socket hot-spot traffic the paper's
    /// Fig 5 mitigation trades for hash spreading, and that `Banked`
    /// eliminates by construction (always 0 there).
    pub remote_writes: Vec<u64>,
}

/// Result of a parallel census run: the census plus scheduler telemetry
/// (consumed by the workload characterizer and the figures harness).
#[derive(Debug, Clone)]
pub struct ParallelRun {
    pub census: Census,
    pub stats: ThreadPoolStats,
    /// Bank sizing and write-split telemetry; `None` under `PerThread`
    /// accumulation and for serial engines.
    pub bank: Option<BankTelemetry>,
}

/// Per-dyad classification kernel the collapsed sweep dispatches to.
/// [`MergedKernel`] (the merged union walk) is the default; the
/// hub-bitmap hybrid (`census/hybrid.rs`) substitutes a kernel that
/// answers hub rows from bitmap planes. The sweep is monomorphized per
/// kernel, so the tail path pays no dispatch cost.
pub(crate) trait DyadKernel<G: GraphView>: Sync {
    fn dyad<S: CensusSink>(&self, g: &G, u: u32, v: u32, bits: u8, sink: &mut S);
}

/// The default kernel: [`dyad_task`]'s merged two-pointer walk.
pub(crate) struct MergedKernel;

impl<G: GraphView> DyadKernel<G> for MergedKernel {
    #[inline]
    fn dyad<S: CensusSink>(&self, g: &G, u: u32, v: u32, bits: u8, sink: &mut S) {
        dyad_task(g, u, v, bits, sink);
    }
}

/// Which driver executes the collapsed iteration space.
enum LoopRunner<'e> {
    /// A persistent shared executor (the serving path).
    Pool(&'e Executor),
    /// Per-call scoped thread spawn (the pre-executor behavior; kept as
    /// the pool-reuse ablation baseline).
    Scoped,
}

impl LoopRunner<'_> {
    /// The socket inventory banked accumulation sizes itself against.
    /// The scoped baseline is topology-blind by design, so it banks as
    /// a single socket.
    fn topology(&self) -> Topology {
        match self {
            LoopRunner::Pool(exec) => exec.topology().clone(),
            LoopRunner::Scoped => Topology::single_socket(),
        }
    }

    fn run<A, I, W>(
        &self,
        len: usize,
        nthreads: usize,
        policy: Policy,
        cancel: &CancelToken,
        init: I,
        work: W,
    ) -> (Vec<A>, ThreadPoolStats, bool)
    where
        A: Send,
        I: Fn(usize) -> A + Sync,
        W: Fn(&mut A, usize, usize, usize) + Sync,
    {
        match self {
            LoopRunner::Pool(exec) => {
                exec.run_cancellable(len, nthreads, policy, cancel, init, work)
            }
            LoopRunner::Scoped => {
                // The scoped ablation baseline predates the executor's
                // cancellation hook; it only honors pre-run cancellation.
                if cancel.is_cancelled() {
                    let accs = (0..nthreads.max(1)).map(&init).collect();
                    return (accs, ThreadPoolStats::default(), true);
                }
                let (accs, stats) = run_partitioned_scoped(len, nthreads, policy, init, work);
                (accs, stats, false)
            }
        }
    }
}

fn census_with<G: GraphView, K: DyadKernel<G>>(
    g: &G,
    cfg: &ParallelConfig,
    runner: LoopRunner<'_>,
    cancel: &CancelToken,
    kernel: &K,
) -> Option<ParallelRun> {
    let n = g.node_count();
    let mut run = census_entries_with(g, cfg, runner, cancel, 0, g.entry_count(), kernel)?;
    run.census.close_with_null(n);
    Some(run)
}

/// Kernel-parameterized cancellable census on an explicit executor —
/// the hybrid engine's entry point (`census/hybrid.rs` supplies the
/// hub-aware kernel; scheduling and accumulation stay shared here).
pub(crate) fn census_kernel_cancellable<G: GraphView, K: DyadKernel<G>>(
    g: &G,
    cfg: &ParallelConfig,
    exec: &Executor,
    cancel: &CancelToken,
    kernel: &K,
) -> Option<ParallelRun> {
    census_with(g, cfg, LoopRunner::Pool(exec), cancel, kernel)
}

/// Sweep the collapsed entry subrange `[base, end)` and return the raw
/// non-null tallies — null closure is the caller's job, which is what
/// lets shard partials sum exactly before closing once.
fn census_entries_with<G: GraphView, K: DyadKernel<G>>(
    g: &G,
    cfg: &ParallelConfig,
    runner: LoopRunner<'_>,
    cancel: &CancelToken,
    base: usize,
    end: usize,
    kernel: &K,
) -> Option<ParallelRun> {
    debug_assert!(base <= end && end <= g.entry_count());
    let len = end - base;
    // fetched once per census: borrowed straight from CSR-shaped views,
    // an O(n) prefix sum over effective degrees for the overlay
    let offsets = g.flat_offsets();
    let offsets: &[usize] = &offsets;

    let (census, stats, cancelled, bank) = match cfg.accumulation {
        Accumulation::Bank { slots } => {
            let topo = runner.topology();
            let nseats = cfg.threads.max(1);
            let nsockets = topo.nsockets();
            let bank = CensusBank::new(slots.max(1));
            // Per-seat (local, remote) routed-dyad counters: a slot in
            // the writer socket's proportional share of the global bank
            // counts as local, everything else as the cross-socket
            // scatter the per-socket banks exist to eliminate.
            let (parts, stats, cancelled) = runner.run(
                len,
                cfg.threads,
                cfg.policy,
                cancel,
                |_tid| (0u64, 0u64),
                |acc: &mut (u64, u64), seat, s, e| {
                    let socket = topo.socket_of(seat, nseats);
                    walk_chunk(g, offsets, base + s, base + e, |u, v, bits| {
                        let slot = bank.slot_of(u, v);
                        let mut sink = BankSlot {
                            slot: &bank.slots[slot],
                        };
                        kernel.dyad(g, u, v, bits, &mut sink);
                        if nsockets > 1 && topo.socket_of(slot, bank.len()) != socket {
                            acc.1 += 1;
                        } else {
                            acc.0 += 1;
                        }
                    });
                },
            );
            let (local, remote) = split_writes(&topo, nseats, &parts);
            let telemetry = BankTelemetry {
                banks: 1,
                slots: bank.len(),
                local_writes: local,
                remote_writes: remote,
            };
            (bank.reduce(), stats, cancelled, Some(telemetry))
        }
        Accumulation::Banked => {
            let topo = runner.topology();
            let nseats = cfg.threads.max(1);
            // One bank per socket, sized from the seats the socket owns
            // — a 1-thread run gets auto_bank_slots(1) slots, not the
            // paper's full 64-vector bank.
            let banks: Vec<CensusBank> = (0..topo.nsockets())
                .map(|s| {
                    let (gs, ge) = topo.group(s, nseats);
                    CensusBank::new(auto_bank_slots(ge - gs))
                })
                .collect();
            let (parts, stats, cancelled) = runner.run(
                len,
                cfg.threads,
                cfg.policy,
                cancel,
                |_tid| (0u64, 0u64),
                |acc: &mut (u64, u64), seat, s, e| {
                    let bank = &banks[topo.socket_of(seat, nseats)];
                    walk_chunk(g, offsets, base + s, base + e, |u, v, bits| {
                        let mut sink = BankSlot {
                            slot: &bank.slots[bank.slot_of(u, v)],
                        };
                        kernel.dyad(g, u, v, bits, &mut sink);
                        acc.0 += 1;
                    });
                },
            );
            let (local, remote) = split_writes(&topo, nseats, &parts);
            let telemetry = BankTelemetry {
                banks: banks.len(),
                slots: banks.iter().map(CensusBank::len).sum(),
                local_writes: local,
                remote_writes: remote,
            };
            let census = banks.iter().fold(Census::zero(), |acc, b| acc + b.reduce());
            (census, stats, cancelled, Some(telemetry))
        }
        Accumulation::PerThread => {
            let (parts, stats, cancelled) = runner.run(
                len,
                cfg.threads,
                cfg.policy,
                cancel,
                |_tid| Census::zero(),
                |acc, _tid, s, e| {
                    walk_chunk(g, offsets, base + s, base + e, |u, v, bits| {
                        kernel.dyad(g, u, v, bits, acc);
                    });
                },
            );
            (
                parts.into_iter().fold(Census::zero(), |a, b| a + b),
                stats,
                cancelled,
                None,
            )
        }
    };
    if cancelled {
        // a partially swept census is a wrong census — discard it
        return None;
    }
    if let (LoopRunner::Pool(exec), Some(b)) = (&runner, &bank) {
        exec.record_bank_writes(&b.local_writes, &b.remote_writes);
    }
    Some(ParallelRun {
        census,
        stats,
        bank,
    })
}

/// Fold per-seat `(local, remote)` routed-dyad counts into per-socket
/// totals, attributing each seat to the socket that owns it in the
/// proportional layout.
fn split_writes(topo: &Topology, nseats: usize, parts: &[(u64, u64)]) -> (Vec<u64>, Vec<u64>) {
    let mut local = vec![0u64; topo.nsockets()];
    let mut remote = vec![0u64; topo.nsockets()];
    for (seat, &(l, r)) in parts.iter().enumerate() {
        let s = topo.socket_of(seat, nseats);
        local[s] += l;
        remote[s] += r;
    }
    (local, remote)
}

/// Parallel triad census over the collapsed entry space, on the shared
/// process-wide executor. Generic over any [`GraphView`].
pub fn census_parallel<G: GraphView>(g: &G, cfg: &ParallelConfig) -> ParallelRun {
    census_with(
        g,
        cfg,
        LoopRunner::Pool(Executor::global()),
        &CancelToken::new(),
        &MergedKernel,
    )
    .expect("fresh token never cancels")
}

/// Parallel triad census on an explicit [`Executor`] — the coordinator's
/// serving path: every request interleaves chunks on the same pool.
pub fn census_parallel_on<G: GraphView>(
    g: &G,
    cfg: &ParallelConfig,
    exec: &Executor,
) -> ParallelRun {
    census_with(g, cfg, LoopRunner::Pool(exec), &CancelToken::new(), &MergedKernel)
        .expect("fresh token never cancels")
}

/// [`census_parallel_on`] with a cooperative cancellation hook: returns
/// `None` (discarding the partial sweep) when `cancel` fires before the
/// census covers the whole entry space. This is the coordinator's
/// job-cancellation path — a `JobHandle::cancel` on a running sparse job
/// trips the token and the seats stop claiming chunks.
pub fn census_parallel_cancellable<G: GraphView>(
    g: &G,
    cfg: &ParallelConfig,
    exec: &Executor,
    cancel: &CancelToken,
) -> Option<ParallelRun> {
    census_with(g, cfg, LoopRunner::Pool(exec), cancel, &MergedKernel)
}

/// Partial parallel census of the contiguous vertex range `lo..hi`: the
/// sweep covers exactly the collapsed entries `[offsets[lo], offsets[hi])`,
/// so a set of ranges partitioning `0..n` yields partial tables that sum
/// — class by class — to the whole-graph non-null tallies. The returned
/// counts are **raw**: [`Census::close_with_null`] is *not* applied (the
/// `003` slot stays zero), because the null count is a property of the
/// whole graph and must be closed exactly once by whoever merges the
/// shards. This is the worker-side entry of the distributed planner.
/// Returns `None` if `cancel` fires mid-sweep.
///
/// Panics if the range is inverted or `hi` exceeds the node count —
/// wire-facing callers validate first and answer `bad_request`.
pub fn census_parallel_range<G: GraphView>(
    g: &G,
    cfg: &ParallelConfig,
    exec: &Executor,
    cancel: &CancelToken,
    lo: usize,
    hi: usize,
) -> Option<ParallelRun> {
    let n = g.node_count();
    assert!(
        lo <= hi && hi <= n,
        "shard {lo}..{hi} out of bounds for {n} nodes"
    );
    let (base, end) = {
        let offsets = g.flat_offsets();
        (offsets[lo], offsets[hi])
    };
    census_entries_with(g, cfg, LoopRunner::Pool(exec), cancel, base, end, &MergedKernel)
}

/// Parallel triad census spawning scoped threads for this one call (the
/// pre-executor behavior). Baseline of `benches/executor_reuse.rs`; not
/// for new code.
pub fn census_parallel_scoped<G: GraphView>(g: &G, cfg: &ParallelConfig) -> ParallelRun {
    census_with(g, cfg, LoopRunner::Scoped, &CancelToken::new(), &MergedKernel)
        .expect("fresh token never cancels")
}

/// Walk the collapsed entry range `[s, e)` of `offsets` (the view's
/// flat offsets), invoking `f(u, v, bits)` for every entry that is the
/// canonical (`u < v`) side of a dyad. One offset binary search seats
/// the walk; rows are then consumed linearly — the mid-row seek is
/// O(1) for CSR-shaped views (their neighbor iterators implement
/// positional `nth`) and O(skipped) for merged-iterator views.
#[inline]
fn walk_chunk<G: GraphView, F: FnMut(u32, u32, u8)>(
    g: &G,
    offsets: &[usize],
    s: usize,
    e: usize,
    mut f: F,
) {
    if s >= e {
        return;
    }
    debug_assert!(e <= *offsets.last().unwrap());
    // partition_point: first u with offsets[u+1] > s
    let mut u = (offsets.partition_point(|&o| o <= s) - 1) as u32;
    let mut idx = s;
    while idx < e {
        // advance u past empty rows until idx is inside u's row
        while idx >= offsets[u as usize + 1] {
            u += 1;
        }
        let row_end = offsets[u as usize + 1].min(e);
        let skip = idx - offsets[u as usize];
        for (v, bits) in g.neighbors(u).skip(skip).take(row_end - idx) {
            if u < v {
                f(u, v, bits);
            }
        }
        idx = row_end;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::census::naive;
    use crate::graph::generators::{self, named};
    use crate::graph::CsrGraph;

    fn cfg(threads: usize, policy: Policy, acc: Accumulation) -> ParallelConfig {
        ParallelConfig {
            threads,
            policy,
            accumulation: acc,
        }
    }

    #[test]
    fn matches_naive_all_policies_and_accumulations() {
        let g = generators::power_law(80, 2.2, 5.0, 13);
        let want = naive::census(&g);
        for policy in [
            Policy::Static { chunk: 7 },
            Policy::Dynamic { chunk: 16 },
            Policy::Guided { min_chunk: 4 },
        ] {
            for acc in [
                Accumulation::Bank { slots: 64 },
                Accumulation::Banked,
                Accumulation::PerThread,
            ] {
                for threads in [1, 2, 4] {
                    let run = census_parallel(&g, &cfg(threads, policy, acc));
                    assert_eq!(run.census, want, "{policy:?} {acc:?} x{threads}");
                }
            }
        }
    }

    #[test]
    fn matches_merged_on_larger_graph() {
        let g = generators::power_law(3000, 2.1, 10.0, 5);
        let want = crate::census::merged::census(&g);
        let run = census_parallel(&g, &ParallelConfig::default());
        assert_eq!(run.census, want);
    }

    #[test]
    fn auto_bank_slots_scale_with_seats() {
        assert_eq!(auto_bank_slots(0), 1, "seatless sockets keep a valid bank");
        assert_eq!(auto_bank_slots(1), 8);
        assert_eq!(auto_bank_slots(3), 32);
        assert_eq!(auto_bank_slots(8), 64);
        assert_eq!(auto_bank_slots(100), 64, "clamped at the paper's bank");
    }

    #[test]
    fn banked_single_thread_allocates_a_small_bank() {
        // regression: `Bank { slots: 64 }` allocated the full bank even
        // for a 1-thread run; `Banked` derives its size from the
        // topology and the seat count instead
        let g = generators::power_law(120, 2.2, 5.0, 9);
        let want = naive::census(&g);
        let c = cfg(1, Policy::dynamic_default(), Accumulation::Banked);
        let run = census_parallel(&g, &c);
        assert_eq!(run.census, want);
        let bank = run.bank.expect("banked runs report telemetry");
        assert!(
            bank.slots < 64,
            "1 seat must not allocate the full 64-slot bank (got {})",
            bank.slots
        );
        // one socket carries the seat (8 slots); any others idle at 1
        assert_eq!(bank.slots, auto_bank_slots(1) + (bank.banks - 1));
    }

    #[test]
    fn banked_on_two_sockets_keeps_writes_local() {
        use crate::sched::{ExecutorConfig, PinMode, Topology};
        let g = generators::power_law(300, 2.2, 6.0, 17);
        let want = naive::census(&g);
        let exec = Executor::with_topology(
            ExecutorConfig {
                workers: 2,
                max_concurrent_jobs: 0,
                pin: PinMode::None,
            },
            Topology::synthetic(vec![1, 1]),
        );
        let run = census_parallel_on(
            &g,
            &cfg(4, Policy::Dynamic { chunk: 16 }, Accumulation::Banked),
            &exec,
        );
        assert_eq!(run.census, want);
        let bank = run.bank.expect("banked runs report telemetry");
        assert_eq!(bank.banks, 2);
        assert_eq!(bank.remote_writes, vec![0, 0], "socket banks never cross");
        assert_eq!(bank.local_writes.iter().sum::<u64>(), g.dyad_count());
        let es = exec.stats();
        assert_eq!(es.bank_local_writes.iter().sum::<u64>(), g.dyad_count());
        assert_eq!(es.bank_remote_writes.iter().sum::<u64>(), 0);

        // the global bank on the same pool scatters a share of the
        // writes into the other socket's slots
        let run = census_parallel_on(
            &g,
            &cfg(
                4,
                Policy::Dynamic { chunk: 16 },
                Accumulation::Bank { slots: 64 },
            ),
            &exec,
        );
        assert_eq!(run.census, want);
        let bank = run.bank.expect("bank runs report telemetry");
        assert_eq!(bank.banks, 1);
        assert_eq!(bank.slots, 64);
        let local: u64 = bank.local_writes.iter().sum();
        let remote: u64 = bank.remote_writes.iter().sum();
        assert_eq!(local + remote, g.dyad_count());
        assert!(remote > 0, "a global bank on two sockets must scatter");
    }

    #[test]
    fn bank_slot_hash_is_uniformish() {
        let bank = CensusBank::new(64);
        let mut counts = vec![0usize; 64];
        for u in 0..200u32 {
            for v in (u + 1)..200u32 {
                counts[bank.slot_of(u, v)] += 1;
            }
        }
        let total: usize = counts.iter().sum();
        let mean = total as f64 / 64.0;
        for (i, &c) in counts.iter().enumerate() {
            assert!(
                (c as f64) > mean * 0.5 && (c as f64) < mean * 1.5,
                "slot {i} count {c} vs mean {mean}"
            );
        }
    }

    #[test]
    fn bank_reduce_sums_slots() {
        let bank = CensusBank::new(4);
        let mut s0 = BankSlot {
            slot: &bank.slots[0],
        };
        s0.bump(TriadType::T300);
        s0.add(TriadType::T012, 3);
        let mut s3 = BankSlot {
            slot: &bank.slots[3],
        };
        s3.add(TriadType::T012, 2);
        let c = bank.reduce();
        assert_eq!(c[TriadType::T300], 1);
        assert_eq!(c[TriadType::T012], 5);
    }

    #[test]
    fn walk_chunk_covers_every_canonical_dyad_once() {
        let g = generators::power_law(200, 2.3, 6.0, 21);
        let offsets = g.flat_offsets();
        let mut seen = std::collections::HashSet::new();
        // split the space into odd-sized chunks
        let len = GraphView::entry_count(&g);
        let mut s = 0;
        while s < len {
            let e = (s + 17).min(len);
            walk_chunk(&g, &offsets, s, e, |u, v, _| {
                assert!(seen.insert((u, v)), "dyad ({u},{v}) seen twice");
            });
            s = e;
        }
        assert_eq!(seen.len() as u64, g.dyad_count());
    }

    #[test]
    fn walk_chunk_agrees_across_views() {
        // the overlay's computed flat offsets must chunk to the same
        // canonical dyad set as the CSR's stored offsets
        let g = generators::power_law(150, 2.2, 5.0, 8);
        let overlay = crate::graph::DeltaOverlay::new(std::sync::Arc::new(g.clone()));
        let collect = |dyads: &mut Vec<(u32, u32, u8)>, chunk: usize| {
            let offsets = GraphView::flat_offsets(&overlay);
            let len = GraphView::entry_count(&overlay);
            let mut s = 0;
            while s < len {
                let e = s.saturating_add(chunk).min(len);
                walk_chunk(&overlay, &offsets, s, e, |u, v, b| dyads.push((u, v, b)));
                s = e;
            }
        };
        let mut whole = Vec::new();
        collect(&mut whole, usize::MAX);
        let mut chunked = Vec::new();
        collect(&mut chunked, 13);
        assert_eq!(whole, chunked);
        let mut csr = Vec::new();
        let offsets = g.flat_offsets();
        let len = GraphView::entry_count(&g);
        walk_chunk(&g, &offsets, 0, len, |u, v, b| csr.push((u, v, b)));
        assert_eq!(whole, csr);
    }

    #[test]
    fn range_shards_sum_to_the_closed_census() {
        let g = generators::power_law(300, 2.2, 6.0, 41);
        let n = GraphView::node_count(&g);
        let want = naive::census(&g);
        let exec = Executor::with_workers(2);
        let c = cfg(2, Policy::Dynamic { chunk: 16 }, Accumulation::PerThread);
        // uneven cuts, including an empty shard and a single-node shard
        for cuts in [
            vec![0, n],
            vec![0, 1, 1, 2, n / 3, n],
            vec![0, n / 4, n / 2, 3 * n / 4, n],
        ] {
            let mut sum = Census::zero();
            for w in cuts.windows(2) {
                let part = census_parallel_range(&g, &c, &exec, &CancelToken::new(), w[0], w[1])
                    .expect("fresh token never cancels");
                assert_eq!(part.census[TriadType::T003], 0, "shards carry raw tallies");
                sum += part.census;
            }
            sum.close_with_null(n);
            assert_eq!(sum, want, "cuts {cuts:?}");
        }
    }

    #[test]
    fn empty_and_tiny_graphs() {
        for g in [CsrGraph::empty(5), named::cycle3()] {
            let want = naive::census(&g);
            let run = census_parallel(&g, &ParallelConfig::default());
            assert_eq!(run.census, want);
        }
    }

    #[test]
    fn mapped_and_owned_storage_yield_identical_census() {
        // the engine walks storage-agnostic slice accessors: a graph
        // served zero-copy from a mapped v2 file must census identically
        let g = generators::power_law(700, 2.2, 7.0, 57);
        let path = std::env::temp_dir().join("triadic_parallel_mmap.csr");
        crate::graph::io::write_binary_v2_file(&g, &path).unwrap();
        let mapped = crate::graph::io::load_mmap_file(&path).unwrap();
        let want = census_parallel(&g, &ParallelConfig::default()).census;
        let got = census_parallel(&mapped, &ParallelConfig::default()).census;
        assert_eq!(got, want);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn scoped_and_executor_paths_agree() {
        let g = generators::power_law(400, 2.2, 6.0, 33);
        let exec = Executor::with_workers(2);
        for acc in [
            Accumulation::Bank { slots: 16 },
            Accumulation::Banked,
            Accumulation::PerThread,
        ] {
            let c = cfg(3, Policy::Dynamic { chunk: 32 }, acc);
            let on_pool = census_parallel_on(&g, &c, &exec);
            let scoped = census_parallel_scoped(&g, &c);
            let global = census_parallel(&g, &c);
            assert_eq!(on_pool.census, scoped.census, "{acc:?}");
            assert_eq!(on_pool.census, global.census, "{acc:?}");
        }
        assert!(exec.stats().jobs >= 2);
    }

    #[test]
    fn stats_cover_all_entries() {
        let g = generators::power_law(500, 2.2, 8.0, 2);
        let run = census_parallel(
            &g,
            &cfg(3, Policy::Dynamic { chunk: 64 }, Accumulation::PerThread),
        );
        assert_eq!(run.stats.items.iter().sum::<usize>(), g.entry_count());
    }
}
