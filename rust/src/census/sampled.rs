//! Approximate triad census over a p-sampled edge overlay.
//!
//! Exact streaming maintenance pays O(deg(u) + deg(v)) per mutation;
//! on a firehose that is still too much. Following the coordinated
//! edge-sampling line of Tangwongsan, Pavan & Tirthapura (arXiv
//! 1308.2166), [`SampledCensus`] keeps the full 16-class table only
//! over the *sampled subgraph*: an unordered dyad `{u, v}` is in the
//! sample iff a deterministic hash of `(seed, u, v)` falls below `p`,
//! so an insert and a later delete of the same dyad always agree, the
//! decision is free of coordination state, and replaying the same
//! stream under the same seed is bit-reproducible.
//!
//! Because sampling can only *null* dyads — never invent arcs — a
//! triad observed with `k` connected dyads arose from a true triad of
//! some class with `≥ k` connected dyads. That makes the expected
//! observed counts an upper-triangular linear system over the true
//! counts, inverted exactly by [`estimate_sampled`]: closed-triad
//! classes (three connected dyads) unbias by `1/p³` with no
//! correction, dyadic-pair classes by `1/p²` minus the expected
//! spill-down from degraded closed triads, single-dyad classes by
//! `1/p` minus both spill terms, and the null class closes against
//! the invariant `C(n, 3)` total. At `p = 1` every factor collapses
//! to 1 and the table is byte-identical to the exact census.
//!
//! Interval semantics: each class carries a variance-derived
//! `estimate ± z·std_err` interval. The variance model is per-triad
//! Bernoulli sampling inflated by the mean number of observed triads
//! per kept dyad — triads sharing a sampled dyad rise and fall
//! together, so the plain binomial term is a floor, not the truth —
//! plus the propagated variance of the spill-down corrections. The
//! claimed coverage is enforced empirically by the seeded
//! differential harness in `rust/tests/sampled_diff.rs`.

use std::sync::{Arc, OnceLock};

use super::isotricode::{tricode_from_dyads, TRICODE_TABLE};
use super::merged;
use super::stream::{BatchReport, StreamStats, StreamingCensus};
use super::types::{Census, TriadType};
use crate::graph::overlay::{ApplyOutcome, DeltaOverlay, EdgeOp};
use crate::graph::{CsrGraph, GraphBuilder};
use crate::rng::splitmix64;
use crate::sched::Executor;

/// Default dyad-hash seed for sessions that do not pick their own —
/// a nod to arXiv 1308.2166.
pub const DEFAULT_SAMPLE_SEED: u64 = 0x1308_2166;

/// Default interval half-width in standard errors (two-sided 99%).
pub const DEFAULT_CONFIDENCE_Z: f64 = 2.576;

/// Deterministic dyad-sampling decision: keep the unordered dyad
/// `{u, v}` iff `splitmix64(seed, min, max)` lands below `p`. The
/// same `(seed, p)` always answers the same for a dyad, in either
/// endpoint order, so inserts and deletes agree; `p ≥ 1` keeps all.
#[inline]
pub fn keep_dyad(seed: u64, u: u32, v: u32, p: f64) -> bool {
    if p >= 1.0 {
        return true;
    }
    let (a, b) = if u <= v { (u, v) } else { (v, u) };
    let mut x = seed ^ (((a as u64) << 32) | (b as u64));
    let h = splitmix64(&mut x);
    ((h >> 11) as f64) * (1.0 / (1u64 << 53) as f64) < p
}

/// Filter `g` down to the arcs whose dyad survives [`keep_dyad`] under
/// `(seed, p)` — the sampled base a [`SampledCensus`] session layers
/// its overlay on.
pub fn sample_base(g: &CsrGraph, p: f64, seed: u64) -> CsrGraph {
    let mut b = GraphBuilder::new(g.node_count());
    for (u, v) in g.arcs() {
        if keep_dyad(seed, u, v, p) {
            b.arc(u, v);
        }
    }
    b.build()
}

/// One class of a [`SampledEstimate`]: the raw sampled-subgraph count
/// beside the unbiased point estimate and its confidence interval.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ClassEstimate {
    /// Count of this class in the sampled subgraph (no unbiasing).
    pub observed: u64,
    /// Unbiased point estimate of the true count (may be fractional;
    /// slightly negative values are sampling noise around zero).
    pub estimate: f64,
    /// Standard error of the estimate under the variance model.
    pub std_err: f64,
    /// `max(0, estimate - z·std_err)`.
    pub lo: f64,
    /// `max(lo, estimate + z·std_err)`.
    pub hi: f64,
}

/// The 16 per-class estimates of one sampled census, plus the sampling
/// parameters they were derived under.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SampledEstimate {
    /// Dyad sampling rate the estimates unbias.
    pub p: f64,
    /// Interval half-width in standard errors.
    pub z: f64,
    /// Node count of the full graph (fixes the `C(n, 3)` closure).
    pub nodes: usize,
    /// Estimates in census-index order.
    pub classes: [ClassEstimate; 16],
}

impl SampledEstimate {
    /// The estimate for one class.
    #[inline]
    pub fn class(&self, t: TriadType) -> &ClassEstimate {
        &self.classes[t.index() - 1]
    }

    /// Sum of the point estimates — identically `C(n, 3)` because the
    /// null class is closed against the invariant total.
    pub fn total(&self) -> f64 {
        self.classes.iter().map(|c| c.estimate).sum()
    }

    /// Round the point estimates to an integer [`Census`], re-closing
    /// the null class so the total stays exactly `C(n, 3)`. At
    /// `p = 1.0` this is byte-identical to the exact census.
    pub fn census(&self) -> Census {
        let mut c = Census::zero();
        for t in TriadType::ALL {
            if t != TriadType::T003 {
                c.add_count(t, self.class(t).estimate.round().max(0.0) as u64);
            }
        }
        let total = Census::expected_total(self.nodes);
        let null = total.saturating_sub(c.nonnull_total());
        let mut counts = *c.counts();
        counts[0] = null.min(u64::MAX as u128) as u64;
        Census::from_counts(counts)
    }

    /// Single-realization gate for the CLI `--oracle-interval` check:
    /// `exact` within `estimate ± band·std_err ± slack`. One sample is
    /// not an ensemble — statistical coverage of the nominal `z`
    /// interval is asserted over many seeds in `sampled_diff.rs`; the
    /// CLI gate widens to `band` standard errors plus an absolute
    /// `slack` so a deterministic smoke run is not a coin flip.
    pub fn covers(&self, t: TriadType, exact: u64, band: f64, slack: f64) -> bool {
        let c = self.class(t);
        (exact as f64 - c.estimate).abs() <= band * c.std_err + slack
    }
}

/// Degradation table: for each class `s`, `ways[s][d][t]` counts the
/// subsets of `s`'s connected dyads whose removal (exactly `d` dyads)
/// leaves a triad of class `t`. Derived at first use from the tricode
/// machinery itself — one representative dyad triple per class — so it
/// can never drift from the classifier.
struct DegradeTable {
    ways: [[[u8; 16]; 4]; 16],
    /// Connected dyads per class (`M + A`).
    k: [u8; 16],
}

fn degrade_table() -> &'static DegradeTable {
    static TABLE: OnceLock<DegradeTable> = OnceLock::new();
    TABLE.get_or_init(|| {
        let mut rep: [Option<[u8; 3]>; 16] = [None; 16];
        for uv in 0..4u8 {
            for uw in 0..4u8 {
                for vw in 0..4u8 {
                    let t = TRICODE_TABLE[tricode_from_dyads(uv, uw, vw) as usize];
                    rep[t.index() - 1].get_or_insert([uv, uw, vw]);
                }
            }
        }
        let mut ways = [[[0u8; 16]; 4]; 16];
        let mut k = [0u8; 16];
        for s in 0..16 {
            let dyads = rep[s].expect("every class has a representative dyad triple");
            let connected: Vec<usize> = (0..3).filter(|&i| dyads[i] != 0).collect();
            k[s] = connected.len() as u8;
            for mask in 0..(1u32 << connected.len()) {
                let mut left = dyads;
                let mut dropped = 0usize;
                for (bit, &pos) in connected.iter().enumerate() {
                    if mask & (1 << bit) == 0 {
                        left[pos] = 0;
                        dropped += 1;
                    }
                }
                let t = TRICODE_TABLE[tricode_from_dyads(left[0], left[1], left[2]) as usize];
                ways[s][dropped][t.index() - 1] += 1;
            }
        }
        DegradeTable { ways, k }
    })
}

/// Unbias the census of a p-sampled subgraph into per-class estimates
/// of the true census. `observed` is the exact census of the sampled
/// subgraph (any engine), `nodes` the full node count, `kept_dyads`
/// the connected dyads surviving in the sample (the variance model's
/// sharing denominator), `z` the interval half-width in standard
/// errors.
///
/// Classes resolve in decreasing connected-dyad order: a class only
/// ever degrades into classes with strictly fewer connected dyads, so
/// the spill-down corrections always reference already-unbiased
/// estimates, and the whole system inverts in one pass.
pub fn estimate_sampled(
    observed: &Census,
    nodes: usize,
    kept_dyads: u64,
    p: f64,
    z: f64,
) -> SampledEstimate {
    assert!(p > 0.0 && p <= 1.0, "sample rate out of range: {p}");
    let tab = degrade_table();
    let q = 1.0 - p;
    let denom = kept_dyads.max(1) as f64;
    let mut est = [0f64; 16];
    let mut var = [0f64; 16];
    let mut order: Vec<usize> = (1..16).collect();
    order.sort_by_key(|&i| std::cmp::Reverse(tab.k[i]));
    for &t in &order {
        let pk = p.powi(tab.k[t] as i32);
        let o = observed.counts()[t] as f64;
        let mut e = o / pk;
        // triads observed in one class share kept dyads and rise and
        // fall together; widen the per-triad Bernoulli term by the
        // mean observed triads per kept dyad (the +1 keeps an empty
        // observation from claiming certainty)
        let width = 1.0 + tab.k[t] as f64 * o / denom;
        let mut v = (1.0 - pk) * (o + 1.0) * width / (pk * pk);
        for &s in &order {
            if tab.k[s] <= tab.k[t] {
                continue;
            }
            let d = (tab.k[s] - tab.k[t]) as usize;
            let w = tab.ways[s][d][t] as f64;
            if w > 0.0 {
                let coeff = w * q.powi(d as i32);
                e -= coeff * est[s];
                v += coeff * coeff * var[s];
            }
        }
        est[t] = e;
        var[t] = v;
    }
    est[0] = Census::expected_total(nodes) as f64 - est[1..].iter().sum::<f64>();
    var[0] = var[1..].iter().sum();
    let mut classes = [ClassEstimate::default(); 16];
    for i in 0..16 {
        let se = var[i].sqrt();
        let lo = (est[i] - z * se).max(0.0);
        classes[i] = ClassEstimate {
            observed: observed.counts()[i],
            estimate: est[i],
            std_err: se,
            lo,
            hi: (est[i] + z * se).max(lo),
        };
    }
    SampledEstimate {
        p,
        z,
        nodes,
        classes,
    }
}

/// A live approximate census: exact streaming maintenance restricted
/// to the p-sampled dyads, unbiased on demand by [`estimate_sampled`].
///
/// Ops whose dyad hashes out of the sample are counted (`skipped`) and
/// dropped in O(1); sampled ops pay the usual O(deg) delta scan — but
/// against the sampled overlay, whose degrees are themselves a `p`
/// fraction of the full graph's. Invalid ops (self-loops, range) fall
/// through to the overlay so rejection semantics match exact mode
/// byte for byte, as does everything else at `p = 1.0`.
pub struct SampledCensus {
    inner: StreamingCensus,
    p: f64,
    seed: u64,
    z: f64,
    seen: u64,
    skipped: u64,
}

impl SampledCensus {
    /// Open a sampled session over `base`: filter it by [`keep_dyad`],
    /// seed with a merged-engine recompute of the sampled subgraph.
    pub fn new(base: Arc<CsrGraph>, p: f64, seed: u64) -> SampledCensus {
        let sampled = if p >= 1.0 {
            base
        } else {
            Arc::new(sample_base(&base, p, seed))
        };
        let census = merged::census(sampled.as_ref());
        SampledCensus::with_initial(sampled, census, p, seed)
    }

    /// Open over a caller-prepared sampled base (already filtered by
    /// [`keep_dyad`] under the same `(p, seed)`, or the full graph at
    /// `p = 1.0`) with its caller-computed exact census — the
    /// coordinator seeds large graphs on its configured engine.
    pub fn with_initial(base: Arc<CsrGraph>, census: Census, p: f64, seed: u64) -> SampledCensus {
        assert!(p > 0.0 && p <= 1.0, "sample rate out of range: {p}");
        SampledCensus {
            inner: StreamingCensus::with_initial(base, census),
            p,
            seed,
            z: DEFAULT_CONFIDENCE_Z,
            seen: 0,
            skipped: 0,
        }
    }

    /// Override the interval half-width (standard errors).
    pub fn with_z(mut self, z: f64) -> SampledCensus {
        self.z = z;
        self
    }

    /// True when `op` is valid but its dyad is not in the sample.
    fn samples_out(&self, op: EdgeOp) -> bool {
        let (u, v) = op.endpoints();
        let n = self.inner.overlay().node_count();
        let valid = u != v && (u as usize) < n && (v as usize) < n;
        valid && !keep_dyad(self.seed, u, v, self.p)
    }

    /// Apply one mutation. Sampled-out ops return
    /// [`ApplyOutcome::NoChange`] in O(1).
    pub fn apply(&mut self, op: EdgeOp) -> ApplyOutcome {
        self.seen += 1;
        if self.samples_out(op) {
            self.skipped += 1;
            return ApplyOutcome::NoChange;
        }
        self.inner.apply(op)
    }

    /// Apply a batch, parallelizing the surviving ops' delta scans as
    /// in [`StreamingCensus::apply_batch`]. Sampled-out ops count as
    /// `no_ops` in the report (they are no-ops of the sampled
    /// overlay by construction).
    pub fn apply_batch(&mut self, ops: &[EdgeOp], exec: &Executor, seats: usize) -> BatchReport {
        self.seen += ops.len() as u64;
        let mut kept = Vec::with_capacity(ops.len());
        for &op in ops {
            if !self.samples_out(op) {
                kept.push(op);
            }
        }
        let dropped = (ops.len() - kept.len()) as u64;
        self.skipped += dropped;
        let mut report = self.inner.apply_batch(&kept, exec, seats);
        report.no_ops += dropped;
        report
    }

    /// The unbiased per-class estimates with intervals.
    pub fn estimate(&self) -> SampledEstimate {
        estimate_sampled(
            &self.inner.census(),
            self.inner.overlay().node_count(),
            self.inner.overlay().dyad_count(),
            self.p,
            self.z,
        )
    }

    /// The rounded estimate as an integer census — byte-identical to
    /// exact maintenance at `p = 1.0`.
    pub fn census(&self) -> Census {
        self.estimate().census()
    }

    /// The raw census of the sampled subgraph (no unbiasing).
    pub fn sampled_census(&self) -> Census {
        self.inner.census()
    }

    /// The overlay holding the sampled effective graph.
    pub fn overlay(&self) -> &DeltaOverlay {
        self.inner.overlay()
    }

    /// Counters of the inner exact maintenance over the sample.
    pub fn stats(&self) -> StreamStats {
        self.inner.stats()
    }

    /// Valid ops dropped because their dyad hashed out of the sample.
    pub fn skipped(&self) -> u64 {
        self.skipped
    }

    /// Total ops offered to the session.
    pub fn seen(&self) -> u64 {
        self.seen
    }

    /// The sampling rate.
    pub fn sample_rate(&self) -> f64 {
        self.p
    }

    /// The dyad-hash seed.
    pub fn sample_seed(&self) -> u64 {
        self.seed
    }

    /// Rebuild the sampled base from the effective sample and reset
    /// the overlay; estimates are invariant under compaction.
    pub fn compact(&mut self) {
        self.inner.compact();
    }

    /// [`SampledCensus::compact`] with a parallel ingest sort.
    pub fn compact_with(&mut self, threads: usize) {
        self.inner.compact_with(threads);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::from_arcs;
    use crate::graph::generators;

    #[test]
    fn degrade_table_matches_hand_counts() {
        let tab = degrade_table();
        for t in TriadType::ALL {
            let (m, a, _) = t.man();
            assert_eq!(tab.k[t.index() - 1], m + a, "{t}");
            // dropping zero dyads is the identity
            assert_eq!(tab.ways[t.index() - 1][0][t.index() - 1], 1, "{t}");
        }
        let s300 = TriadType::T300.index() - 1;
        assert_eq!(tab.ways[s300][1][TriadType::T201.index() - 1], 3);
        assert_eq!(tab.ways[s300][2][TriadType::T102.index() - 1], 3);
        assert_eq!(tab.ways[s300][3][TriadType::T003.index() - 1], 1);
        let s030t = TriadType::T030T.index() - 1;
        for t in [TriadType::T021D, TriadType::T021U, TriadType::T021C] {
            assert_eq!(tab.ways[s030t][1][t.index() - 1], 1, "030T minus one arc");
        }
        let s030c = TriadType::T030C.index() - 1;
        assert_eq!(tab.ways[s030c][1][TriadType::T021C.index() - 1], 3);
    }

    #[test]
    fn keep_dyad_is_symmetric_and_seeded() {
        let mut kept = 0u32;
        for u in 0..200u32 {
            for v in (u + 1)..200u32 {
                let k = keep_dyad(7, u, v, 0.3);
                assert_eq!(k, keep_dyad(7, v, u, 0.3), "order-independent");
                assert!(keep_dyad(7, u, v, 1.0), "p=1 keeps everything");
                kept += k as u32;
            }
        }
        let rate = kept as f64 / (200.0 * 199.0 / 2.0);
        assert!((rate - 0.3).abs() < 0.03, "empirical rate {rate}");
    }

    #[test]
    fn p_one_is_byte_identical_to_exact() {
        let exec = Executor::with_workers(2);
        let base = generators::erdos_renyi(40, 120, 11);
        let mut exact = StreamingCensus::new(Arc::new(base.clone()));
        let mut sampled = SampledCensus::new(Arc::new(base), 1.0, 99);
        let mut rng = crate::rng::Rng::new(5);
        let ops: Vec<EdgeOp> = (0..300)
            .map(|_| {
                let (u, v) = (rng.node(40), rng.node(40));
                if rng.chance(0.4) {
                    EdgeOp::Delete(u, v)
                } else {
                    EdgeOp::Insert(u, v)
                }
            })
            .collect();
        for chunk in ops.chunks(50) {
            let a = exact.apply_batch(chunk, &exec, 2);
            let b = sampled.apply_batch(chunk, &exec, 2);
            assert_eq!(a, b, "p=1 batch reports agree");
            assert_eq!(exact.census(), sampled.census());
            assert_eq!(exact.census(), sampled.sampled_census());
        }
        assert_eq!(sampled.skipped(), 0);
        let est = sampled.estimate();
        for t in TriadType::ALL {
            let c = est.class(t);
            assert_eq!(c.std_err, 0.0, "{t}: no sampling noise at p=1");
            assert_eq!(c.lo, c.hi, "{t}");
            assert_eq!(c.estimate, exact.census()[t] as f64, "{t}");
        }
    }

    #[test]
    fn estimates_close_the_triad_total() {
        let g = generators::power_law(120, 2.2, 5.0, 3);
        for &p in &[0.2, 0.5, 0.8] {
            let sc = SampledCensus::new(Arc::new(g.clone()), p, 17);
            let est = sc.estimate();
            let want = Census::expected_total(120) as f64;
            let drift = (est.total() - want).abs();
            assert!(drift < 1e-6 * want, "p={p}: total {} vs {want}", est.total());
            for t in TriadType::ALL {
                let c = est.class(t);
                assert!(c.lo <= c.hi, "{t}");
                assert!(c.std_err >= 0.0, "{t}");
            }
        }
    }

    #[test]
    fn dyadic_pair_classes_scale_by_inverse_p_squared_without_spill() {
        // a bipartite digraph has no triad with three connected dyads,
        // so the 1/p² unbiasing of the two-dyad classes has no
        // spill-down correction and must equal the raw scaled count
        let g = from_arcs(8, &[(0, 4), (4, 1), (1, 5), (5, 1), (2, 6), (6, 3), (3, 7), (7, 0)]);
        let p = 0.6;
        let sc = SampledCensus::new(Arc::new(g), p, 23);
        let est = sc.estimate();
        let obs = sc.sampled_census();
        for t in [
            TriadType::T021D,
            TriadType::T021U,
            TriadType::T021C,
            TriadType::T111D,
            TriadType::T111U,
            TriadType::T201,
        ] {
            let want = obs[t] as f64 / (p * p);
            let got = est.class(t).estimate;
            assert!((got - want).abs() < 1e-9, "{t}: {got} vs {want}");
        }
    }

    #[test]
    fn sampled_out_ops_are_constant_time_no_change() {
        let mut sc = SampledCensus::new(Arc::new(CsrGraph::empty(50)), 0.3, 41);
        let mut dropped = 0u64;
        for u in 0..50u32 {
            for v in 0..50u32 {
                if u == v {
                    continue;
                }
                match sc.apply(EdgeOp::Insert(u, v)) {
                    ApplyOutcome::NoChange if !keep_dyad(41, u, v, 0.3) => dropped += 1,
                    ApplyOutcome::Rejected(_) => panic!("valid op rejected"),
                    _ => {}
                }
            }
        }
        assert_eq!(sc.skipped(), dropped);
        assert!(dropped > 0, "p=0.3 drops some dyads");
        // invalid ops still reject exactly as in exact mode
        assert!(matches!(
            sc.apply(EdgeOp::Insert(3, 3)),
            ApplyOutcome::Rejected(_)
        ));
        assert!(matches!(
            sc.apply(EdgeOp::Insert(0, 99)),
            ApplyOutcome::Rejected(_)
        ));
        assert_eq!(sc.stats().rejected, 2);
    }

    #[test]
    fn estimate_is_a_pure_function_of_the_final_state() {
        // two different interleavings over disjoint dyads must land on
        // bit-identical estimates under a fixed seed
        let exec = Executor::with_workers(2);
        let ops: Vec<EdgeOp> = (0..60u32)
            .map(|k| EdgeOp::Insert(2 * k, 2 * k + 1))
            .collect();
        let mut fwd = SampledCensus::new(Arc::new(CsrGraph::empty(120)), 0.5, 77);
        let mut rev = SampledCensus::new(Arc::new(CsrGraph::empty(120)), 0.5, 77);
        fwd.apply_batch(&ops, &exec, 2);
        let flipped: Vec<EdgeOp> = ops.iter().rev().copied().collect();
        rev.apply_batch(&flipped, &exec, 2);
        let (a, b) = (fwd.estimate(), rev.estimate());
        for t in TriadType::ALL {
            let (ca, cb) = (a.class(t), b.class(t));
            assert_eq!(ca.estimate.to_bits(), cb.estimate.to_bits(), "{t}");
            assert_eq!(ca.std_err.to_bits(), cb.std_err.to_bits(), "{t}");
        }
    }

    #[test]
    fn compaction_preserves_the_estimate() {
        let base = generators::erdos_renyi(30, 80, 9);
        let mut sc = SampledCensus::new(Arc::new(base), 0.7, 13);
        for k in 0..40u32 {
            sc.apply(EdgeOp::Insert((k * 7) % 30, (k * 11 + 1) % 30));
        }
        let before = sc.estimate();
        sc.compact();
        assert_eq!(before, sc.estimate());
        assert_eq!(sc.stats().compactions, 1);
    }
}
