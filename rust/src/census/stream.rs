//! Incrementally maintained triad census over a mutable edge stream.
//!
//! A full census recompute touches every connected dyad of the graph;
//! an edge mutation `(u, v)`, however, can only change the class of the
//! `n - 2` triads that contain *both* `u` and `v` — every other triad
//! keeps all three of its dyads. [`StreamingCensus`] exploits this: each
//! applied [`EdgeOp`] walks the merged effective neighborhoods of its
//! endpoints once (O(deg(u) + deg(v))), moving each touched triad from
//! its old class to its new one, and rebalances the remaining
//! `n - 2 - |N(u) ∪ N(v)|` dyadic/null triads in O(1) bulk — the same
//! per-edge delta structure that Tangwongsan et al. use for streaming
//! triangle counts, generalized to all 16 classes via the tricode
//! table.
//!
//! Batches are partitioned into contiguous *node-disjoint rounds*: no
//! triad contains two dyads mutated in the same round, so the per-op
//! census deltas are independent and a round's scans parallelize on the
//! shared [`Executor`] with exact, order-insensitive results.
//!
//! Correctness is enforced adversarially by the differential harness in
//! `rust/tests/stream_diff.rs`: after every randomized batch the live
//! census must equal a fresh full recompute by the merged oracle.

use std::sync::Arc;

use super::isotricode::{tricode_from_dyads, TRICODE_TABLE};
use super::merged::{self, merged_union_walk};
use super::types::Census;
use crate::graph::overlay::{ApplyOutcome, DeltaOverlay, EdgeOp};
use crate::graph::CsrGraph;
use crate::sched::{Executor, Policy};

/// Below this many changed ops a round's delta scans run inline — the
/// executor dispatch costs more than the scans save.
const PAR_MIN_OPS: usize = 32;

/// Lifetime counters of one streaming session.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StreamStats {
    /// Ops that changed the graph (and census).
    pub applied: u64,
    /// Duplicate inserts / deletes of absent arcs.
    pub no_ops: u64,
    /// Self-loop or out-of-range ops.
    pub rejected: u64,
    /// Triads individually reclassified by neighborhood scans (the
    /// O(deg) work; bulk dyadic/null rebalancing is O(1) and uncounted).
    pub reclassified: u64,
    /// Batches applied via [`StreamingCensus::apply_batch`].
    pub batches: u64,
    /// Node-disjoint parallel rounds those batches split into.
    pub rounds: u64,
    /// [`StreamingCensus::compact`] calls.
    pub compactions: u64,
}

/// Outcome of one applied batch.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct BatchReport {
    pub applied: u64,
    pub no_ops: u64,
    pub rejected: u64,
    pub reclassified: u64,
    pub rounds: u64,
}

/// A live triad census over a [`DeltaOverlay`], updated per edge
/// mutation instead of recomputed.
pub struct StreamingCensus {
    overlay: DeltaOverlay,
    /// Live counts per class (census-index order), including `003`.
    counts: [u64; 16],
    stats: StreamStats,
}

impl StreamingCensus {
    /// Open a stream over `base`, seeding the live census with a full
    /// merged-engine recompute.
    pub fn new(base: Arc<CsrGraph>) -> StreamingCensus {
        let census = merged::census(base.as_ref());
        StreamingCensus::with_initial(base, census)
    }

    /// Open a stream over `base` with a caller-computed initial census
    /// (any engine; the coordinator seeds large graphs on its configured
    /// engine). The census must be exact for `base` — every later delta
    /// builds on it.
    pub fn with_initial(base: Arc<CsrGraph>, census: Census) -> StreamingCensus {
        StreamingCensus {
            overlay: DeltaOverlay::new(base),
            counts: *census.counts(),
            stats: StreamStats::default(),
        }
    }

    /// The current census.
    #[inline]
    pub fn census(&self) -> Census {
        Census::from_counts(self.counts)
    }

    /// The overlay holding the effective graph.
    #[inline]
    pub fn overlay(&self) -> &DeltaOverlay {
        &self.overlay
    }

    /// Session counters.
    #[inline]
    pub fn stats(&self) -> StreamStats {
        self.stats
    }

    /// Apply one mutation, updating the census in O(deg(u) + deg(v)).
    pub fn apply(&mut self, op: EdgeOp) -> ApplyOutcome {
        let outcome = self.overlay.apply(op);
        match outcome {
            ApplyOutcome::Changed { old, new } => {
                let (u, v) = op.endpoints();
                let mut delta = [0i64; 16];
                let scanned = scan_dyad_change(&self.overlay, u, v, old, new, &mut delta);
                apply_delta(&mut self.counts, &delta);
                self.stats.applied += 1;
                self.stats.reclassified += scanned;
            }
            ApplyOutcome::NoChange => self.stats.no_ops += 1,
            ApplyOutcome::Rejected(_) => self.stats.rejected += 1,
        }
        outcome
    }

    /// Apply a batch of mutations in order, parallelizing the
    /// neighborhood scans of node-disjoint runs on `exec` with `seats`
    /// virtual seats. Exactly equivalent to applying the ops one by one.
    pub fn apply_batch(&mut self, ops: &[EdgeOp], exec: &Executor, seats: usize) -> BatchReport {
        let mut report = BatchReport::default();
        let mut i = 0;
        while i < ops.len() {
            // maximal contiguous node-disjoint run: no triad sees two of
            // its dyads change in one round, so per-op deltas compose
            let mut used = std::collections::HashSet::new();
            let mut j = i;
            while j < ops.len() {
                let (u, v) = ops[j].endpoints();
                if used.contains(&u) || used.contains(&v) {
                    break;
                }
                used.insert(u);
                used.insert(v);
                j += 1;
            }
            // mutate first (cheap, inherently serial), recording the
            // dyad transitions the scans must account for
            let mut changed: Vec<(u32, u32, u8, u8)> = Vec::with_capacity(j - i);
            for &op in &ops[i..j] {
                match self.overlay.apply(op) {
                    ApplyOutcome::Changed { old, new } => {
                        let (u, v) = op.endpoints();
                        changed.push((u, v, old, new));
                    }
                    ApplyOutcome::NoChange => report.no_ops += 1,
                    ApplyOutcome::Rejected(_) => report.rejected += 1,
                }
            }
            report.applied += changed.len() as u64;
            report.rounds += 1;
            // scan phase: reads only dyads incident to this round's own
            // endpoints, all settled above — safe to fan out
            let overlay = &self.overlay;
            let mut delta = [0i64; 16];
            if changed.len() >= PAR_MIN_OPS && seats > 1 && exec.worker_count() > 1 {
                let (parts, _stats) = exec.run(
                    changed.len(),
                    seats,
                    Policy::Dynamic { chunk: 4 },
                    |_seat| ([0i64; 16], 0u64),
                    |acc, _seat, s, e| {
                        for &(u, v, old, new) in &changed[s..e] {
                            acc.1 += scan_dyad_change(overlay, u, v, old, new, &mut acc.0);
                        }
                    },
                );
                for (part, scanned) in parts {
                    for k in 0..16 {
                        delta[k] += part[k];
                    }
                    report.reclassified += scanned;
                }
            } else {
                for &(u, v, old, new) in &changed {
                    report.reclassified += scan_dyad_change(overlay, u, v, old, new, &mut delta);
                }
            }
            apply_delta(&mut self.counts, &delta);
            i = j;
        }
        self.stats.applied += report.applied;
        self.stats.no_ops += report.no_ops;
        self.stats.rejected += report.rejected;
        self.stats.reclassified += report.reclassified;
        self.stats.rounds += report.rounds;
        self.stats.batches += 1;
        report
    }

    /// Rebuild the base CSR from the effective graph and reset the
    /// overlay. The census is invariant under compaction (it describes
    /// the effective graph, which does not change).
    pub fn compact(&mut self) {
        self.compact_with(1);
    }

    /// [`StreamingCensus::compact`] with a parallel ingest sort.
    pub fn compact_with(&mut self, threads: usize) {
        let fresh = self.overlay.compact_with(threads);
        debug_assert_eq!(fresh.arc_count(), self.overlay.arc_count());
        self.overlay = DeltaOverlay::new(Arc::new(fresh));
        self.stats.compactions += 1;
    }
}

/// Fold a signed per-class delta into the live counts. Underflow means
/// the delta logic lost track of a triad — fail loudly, never wrap.
fn apply_delta(counts: &mut [u64; 16], delta: &[i64; 16]) {
    for i in 0..16 {
        let d = delta[i];
        if d >= 0 {
            counts[i] += d as u64;
        } else {
            counts[i] = counts[i]
                .checked_sub(d.unsigned_abs())
                .expect("streaming census underflow (delta accounting bug)");
        }
    }
}

/// Account one dyad transition `(u, v): old → new` into `delta`: every
/// triad `{u, v, w}` moves from its class under `old` to its class
/// under `new`. Third nodes adjacent to `u` or `v` are visited by the
/// same [`merged_union_walk`] every census engine uses (their `(u, w)`
/// / `(v, w)` dyads decide the class); the rest move between the
/// null/dyadic classes in bulk. Returns the number of individually
/// scanned third nodes.
fn scan_dyad_change(
    overlay: &DeltaOverlay,
    u: u32,
    v: u32,
    old: u8,
    new: u8,
    delta: &mut [i64; 16],
) -> u64 {
    let union_size = merged_union_walk(overlay, u, v, |_w, uw, vw, _from_u| {
        let from = TRICODE_TABLE[tricode_from_dyads(old, uw, vw) as usize];
        let to = TRICODE_TABLE[tricode_from_dyads(new, uw, vw) as usize];
        if from != to {
            delta[from.index() - 1] -= 1;
            delta[to.index() - 1] += 1;
        }
    });
    // third nodes adjacent to neither endpoint: null/dyadic bulk move
    let rest = (overlay.node_count() - 2 - union_size) as i64;
    if rest > 0 {
        let from = TRICODE_TABLE[tricode_from_dyads(old, 0, 0) as usize];
        let to = TRICODE_TABLE[tricode_from_dyads(new, 0, 0) as usize];
        if from != to {
            delta[from.index() - 1] -= rest;
            delta[to.index() - 1] += rest;
        }
    }
    union_size as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::census::types::TriadType;
    use crate::graph::builder::from_arcs;
    use crate::graph::generators;

    fn oracle(sc: &StreamingCensus) -> Census {
        // the merged engine runs straight over the overlay view — no
        // compaction needed for a full-recompute cross-check anymore
        merged::census(sc.overlay())
    }

    #[test]
    fn single_inserts_track_the_oracle() {
        let mut sc = StreamingCensus::new(Arc::new(CsrGraph::empty(5)));
        assert_eq!(sc.census()[TriadType::T003], 10);
        for op in [
            EdgeOp::Insert(0, 1),
            EdgeOp::Insert(1, 0),
            EdgeOp::Insert(1, 2),
            EdgeOp::Insert(2, 0),
            EdgeOp::Insert(3, 4),
        ] {
            assert!(matches!(sc.apply(op), ApplyOutcome::Changed { .. }));
            assert_eq!(sc.census(), oracle(&sc));
        }
        assert_eq!(sc.stats().applied, 5);
    }

    #[test]
    fn deletes_track_the_oracle() {
        let base = from_arcs(6, &[(0, 1), (1, 0), (1, 2), (2, 3), (3, 1), (4, 5)]);
        let mut sc = StreamingCensus::new(Arc::new(base));
        for op in [
            EdgeOp::Delete(1, 0),
            EdgeOp::Delete(2, 3),
            EdgeOp::Delete(4, 5),
            EdgeOp::Delete(0, 1),
        ] {
            assert!(matches!(sc.apply(op), ApplyOutcome::Changed { .. }));
            assert_eq!(sc.census(), oracle(&sc));
        }
        assert_eq!(sc.overlay().arc_count(), 2);
    }

    #[test]
    fn noops_and_rejects_leave_the_census_alone() {
        let mut sc = StreamingCensus::new(Arc::new(from_arcs(4, &[(0, 1)])));
        let before = sc.census();
        assert_eq!(sc.apply(EdgeOp::Insert(0, 1)), ApplyOutcome::NoChange);
        assert_eq!(sc.apply(EdgeOp::Delete(2, 3)), ApplyOutcome::NoChange);
        assert!(matches!(
            sc.apply(EdgeOp::Insert(2, 2)),
            ApplyOutcome::Rejected(_)
        ));
        assert!(matches!(
            sc.apply(EdgeOp::Insert(0, 9)),
            ApplyOutcome::Rejected(_)
        ));
        assert_eq!(sc.census(), before);
        let s = sc.stats();
        assert_eq!((s.applied, s.no_ops, s.rejected), (0, 2, 2));
    }

    #[test]
    fn census_total_is_invariant() {
        let mut sc = StreamingCensus::new(Arc::new(generators::erdos_renyi(30, 60, 4)));
        let want = Census::expected_total(30);
        assert_eq!(sc.census().total(), want);
        for k in 0..40u32 {
            sc.apply(EdgeOp::Insert(k % 30, (k * 7 + 1) % 30));
            sc.apply(EdgeOp::Delete((k * 3) % 30, (k * 5 + 2) % 30));
            assert_eq!(sc.census().total(), want);
        }
        assert_eq!(sc.census(), oracle(&sc));
    }

    #[test]
    fn batch_apply_equals_one_by_one() {
        let exec = Executor::with_workers(3);
        let base = generators::erdos_renyi(40, 100, 9);
        let mut serial = StreamingCensus::new(Arc::new(base.clone()));
        let mut batched = StreamingCensus::new(Arc::new(base));
        let mut rng = crate::rng::Rng::new(17);
        let ops: Vec<EdgeOp> = (0..400)
            .map(|_| {
                let (u, v) = (rng.node(40), rng.node(40));
                if rng.chance(0.35) {
                    EdgeOp::Delete(u, v)
                } else {
                    EdgeOp::Insert(u, v)
                }
            })
            .collect();
        for op in &ops {
            serial.apply(*op);
        }
        for chunk in ops.chunks(64) {
            batched.apply_batch(chunk, &exec, 4);
        }
        assert_eq!(batched.census(), serial.census());
        assert_eq!(batched.census(), oracle(&serial));
        assert_eq!(batched.overlay().compact(), serial.overlay().compact());
        let s = batched.stats();
        assert_eq!(s.applied + s.no_ops + s.rejected, 400);
        assert!(s.rounds >= s.batches);
    }

    #[test]
    fn parallel_round_scans_match_the_oracle() {
        // node-disjoint on a graph big enough that whole batches stay in
        // one round and cross PAR_MIN_OPS — the executor path runs
        let exec = Executor::with_workers(4);
        let base = generators::power_law(600, 2.2, 6.0, 21);
        let mut sc = StreamingCensus::new(Arc::new(base));
        for round in 0..4 {
            let ops: Vec<EdgeOp> = (0..120u32)
                .map(|k| {
                    // distinct endpoint pairs: one long disjoint round
                    let (u, v) = (2 * k, 2 * k + 1);
                    if round % 2 == 0 {
                        EdgeOp::Insert(u, v)
                    } else {
                        EdgeOp::Delete(u, v)
                    }
                })
                .collect();
            let report = sc.apply_batch(&ops, &exec, 4);
            assert_eq!(report.rounds, 1, "disjoint ops stay in one round");
            assert_eq!(sc.census(), oracle(&sc), "round {round}");
        }
    }

    #[test]
    fn compaction_preserves_census_and_resets_overlay() {
        let mut sc = StreamingCensus::new(Arc::new(generators::erdos_renyi(25, 50, 2)));
        for k in 0..30u32 {
            sc.apply(EdgeOp::Insert((k * 3) % 25, (k * 11 + 1) % 25));
        }
        let before = sc.census();
        let arcs = sc.overlay().arc_count();
        assert!(sc.overlay().is_dirty());
        sc.compact();
        assert_eq!(sc.census(), before);
        assert_eq!(sc.overlay().arc_count(), arcs);
        assert!(!sc.overlay().is_dirty());
        assert_eq!(sc.stats().compactions, 1);
        // mutations keep tracking after the rebase
        sc.apply(EdgeOp::Insert(0, 24));
        sc.apply(EdgeOp::Delete(3, 1));
        assert_eq!(sc.census(), oracle(&sc));
    }

    #[test]
    fn streams_over_named_fixtures() {
        // grow an empty 7-node graph into fig1, then tear it back down
        let fig1 = generators::named::fig1();
        let mut sc = StreamingCensus::new(Arc::new(CsrGraph::empty(7)));
        let arcs: Vec<(u32, u32)> = fig1.arcs().collect();
        for &(u, v) in &arcs {
            sc.apply(EdgeOp::Insert(u, v));
        }
        assert_eq!(sc.census(), merged::census(&fig1));
        for &(u, v) in &arcs {
            sc.apply(EdgeOp::Delete(u, v));
        }
        assert_eq!(sc.census(), merged::census(&CsrGraph::empty(7)));
    }
}
