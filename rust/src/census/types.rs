//! The 16-class triad taxonomy and the census accumulator.
//!
//! Classes follow the standard Holland–Leinhardt M-A-N naming, indexed
//! 1..=16 exactly as in Batagelj–Mrvar (and the paper's Fig 5, where
//! `TriType` 1 = null `003`, 2 = `012`, 3 = `102`).

use std::fmt;
use std::ops::{Add, AddAssign, Index, IndexMut};

/// The 16 triad isomorphism classes. The `M-A-N` digits give the counts
/// of Mutual, Asymmetric and Null dyads; the letter distinguishes
/// orientation (Down = diverging from a source, Up = converging into a
/// sink, Cyclic / Transitive).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
#[repr(u8)]
pub enum TriadType {
    /// Empty triad (three null dyads).
    T003 = 1,
    /// Single arc.
    T012 = 2,
    /// Single mutual dyad.
    T102 = 3,
    /// `A <- B -> C` — out-star.
    T021D = 4,
    /// `A -> B <- C` — in-star.
    T021U = 5,
    /// `A -> B -> C` — chain.
    T021C = 6,
    /// `A <-> B <- C` — arc into a mutual dyad.
    T111D = 7,
    /// `A <-> B -> C` — arc out of a mutual dyad.
    T111U = 8,
    /// Transitive triple.
    T030T = 9,
    /// 3-cycle.
    T030C = 10,
    /// Two mutual dyads, third pair null.
    T201 = 11,
    /// Mutual dyad + out-star arcs.
    T120D = 12,
    /// Mutual dyad + in-star arcs.
    T120U = 13,
    /// Mutual dyad + chain.
    T120C = 14,
    /// Two mutual dyads + one asymmetric.
    T210 = 15,
    /// Complete: three mutual dyads.
    T300 = 16,
}

impl TriadType {
    /// All 16 types in census-index order.
    pub const ALL: [TriadType; 16] = [
        TriadType::T003,
        TriadType::T012,
        TriadType::T102,
        TriadType::T021D,
        TriadType::T021U,
        TriadType::T021C,
        TriadType::T111D,
        TriadType::T111U,
        TriadType::T030T,
        TriadType::T030C,
        TriadType::T201,
        TriadType::T120D,
        TriadType::T120U,
        TriadType::T120C,
        TriadType::T210,
        TriadType::T300,
    ];

    /// 1-based census index (matches Batagelj–Mrvar / Fig 5).
    #[inline]
    pub fn index(self) -> usize {
        self as usize
    }

    /// From a 1-based census index.
    #[inline]
    pub fn from_index(i: usize) -> TriadType {
        assert!((1..=16).contains(&i), "triad index out of range: {i}");
        TriadType::ALL[i - 1]
    }

    /// Standard M-A-N label.
    pub fn label(self) -> &'static str {
        match self {
            TriadType::T003 => "003",
            TriadType::T012 => "012",
            TriadType::T102 => "102",
            TriadType::T021D => "021D",
            TriadType::T021U => "021U",
            TriadType::T021C => "021C",
            TriadType::T111D => "111D",
            TriadType::T111U => "111U",
            TriadType::T030T => "030T",
            TriadType::T030C => "030C",
            TriadType::T201 => "201",
            TriadType::T120D => "120D",
            TriadType::T120U => "120U",
            TriadType::T120C => "120C",
            TriadType::T210 => "210",
            TriadType::T300 => "300",
        }
    }

    /// From the standard M-A-N label (`"021D"`, `"300"`, …) — the
    /// inverse of [`TriadType::label`], used by the wire protocol's
    /// triad-class subset selection. Case-sensitive.
    pub fn from_label(label: &str) -> Option<TriadType> {
        TriadType::ALL.iter().copied().find(|t| t.label() == label)
    }

    /// Counts of (mutual, asymmetric, null) dyads in this class.
    pub fn man(self) -> (u8, u8, u8) {
        match self {
            TriadType::T003 => (0, 0, 3),
            TriadType::T012 => (0, 1, 2),
            TriadType::T102 => (1, 0, 2),
            TriadType::T021D | TriadType::T021U | TriadType::T021C => (0, 2, 1),
            TriadType::T111D | TriadType::T111U => (1, 1, 1),
            TriadType::T030T | TriadType::T030C => (0, 3, 0),
            TriadType::T201 => (2, 0, 1),
            TriadType::T120D | TriadType::T120U | TriadType::T120C => (1, 2, 0),
            TriadType::T210 => (2, 1, 0),
            TriadType::T300 => (3, 0, 0),
        }
    }

    /// Number of arcs in the class.
    pub fn arc_count(self) -> u8 {
        let (m, a, _) = self.man();
        2 * m + a
    }

    /// The class of the arc-reversed (transpose) triad: `D` and `U`
    /// variants swap, everything else is self-dual.
    pub fn reversed(self) -> TriadType {
        match self {
            TriadType::T021D => TriadType::T021U,
            TriadType::T021U => TriadType::T021D,
            TriadType::T111D => TriadType::T111U,
            TriadType::T111U => TriadType::T111D,
            TriadType::T120D => TriadType::T120U,
            TriadType::T120U => TriadType::T120D,
            t => t,
        }
    }

    /// True if at least one dyad is connected (i.e. the triad is dyadic
    /// or connected in the paper's terms — not null).
    pub fn is_nonnull(self) -> bool {
        self != TriadType::T003
    }

    /// True if every node touches at least one arc within the triad (the
    /// paper's *connected* triads — those counted by the inner loop).
    pub fn is_connected_triad(self) -> bool {
        let (m, a, n) = self.man();
        // with at most one null dyad, a triad of 3 nodes can only strand
        // a node if two dyads are null
        let _ = (m, a);
        n < 2
    }
}

impl fmt::Display for TriadType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.label())
    }
}

/// A 16-element triad census (counts per class, u64).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Census {
    counts: [u64; 16],
}

impl Census {
    /// All-zero census.
    pub fn zero() -> Census {
        Census::default()
    }

    /// Build from counts in census-index order.
    pub fn from_counts(counts: [u64; 16]) -> Census {
        Census { counts }
    }

    /// The raw counts in census-index order.
    pub fn counts(&self) -> &[u64; 16] {
        &self.counts
    }

    /// Increment one class.
    #[inline]
    pub fn bump(&mut self, t: TriadType) {
        self.counts[t.index() - 1] += 1;
    }

    /// Add `k` to one class.
    #[inline]
    pub fn add_count(&mut self, t: TriadType, k: u64) {
        self.counts[t.index() - 1] += k;
    }

    /// Total triads counted.
    pub fn total(&self) -> u128 {
        self.counts.iter().map(|&c| c as u128).sum()
    }

    /// Sum of non-null classes (indices 2..=16) — the `sum` of Fig 5
    /// step 3-4.
    pub fn nonnull_total(&self) -> u128 {
        self.counts[1..].iter().map(|&c| c as u128).sum()
    }

    /// Number of triads a graph of `n` nodes has: `C(n,3)`.
    pub fn expected_total(n: usize) -> u128 {
        let n = n as u128;
        if n < 3 {
            0
        } else {
            n * (n - 1) * (n - 2) / 6
        }
    }

    /// Fill the null-class slot from `C(n,3) - Σ non-null` (Fig 5 step 5).
    pub fn close_with_null(&mut self, n: usize) {
        let total = Census::expected_total(n);
        let nonnull = self.nonnull_total();
        assert!(
            nonnull <= total,
            "census overflow: nonnull {nonnull} > C(n,3) {total}"
        );
        self.counts[0] = (total - nonnull) as u64;
    }

    /// The census of the transpose graph: D/U classes swap.
    pub fn reversed(&self) -> Census {
        let mut out = Census::zero();
        for t in TriadType::ALL {
            // fully qualified: `std::ops::Add` is in scope here and would
            // otherwise shadow the inherent two-argument `add`
            out.add_count(t.reversed(), self[t]);
        }
        out
    }

    /// Proportion vector (sums to 1 unless empty).
    pub fn proportions(&self) -> [f64; 16] {
        let tot = self.total() as f64;
        let mut p = [0f64; 16];
        if tot > 0.0 {
            for i in 0..16 {
                p[i] = self.counts[i] as f64 / tot;
            }
        }
        p
    }

    /// Number of arcs implied by the census (consistency invariant:
    /// each arc is in exactly `n - 2` triads).
    pub fn implied_arc_triples(&self) -> u128 {
        TriadType::ALL
            .iter()
            .map(|&t| t.arc_count() as u128 * self[t] as u128)
            .sum()
    }

    /// Render as a compact labeled table row set.
    pub fn table(&self) -> String {
        let mut s = String::new();
        for t in TriadType::ALL {
            s.push_str(&format!("{:>5}  {:>16}\n", t.label(), self[t]));
        }
        s
    }
}

/// Abstraction over census accumulation targets, letting the same
/// triad-enumeration kernel feed either a private per-thread [`Census`]
/// or a shared atomic census bank (the paper's 64 local vectors).
pub trait CensusSink {
    /// Count one triad of class `t`.
    fn bump(&mut self, t: TriadType);
    /// Count `k` triads of class `t`.
    fn add(&mut self, t: TriadType, k: u64);
}

impl CensusSink for Census {
    #[inline]
    fn bump(&mut self, t: TriadType) {
        Census::bump(self, t);
    }
    #[inline]
    fn add(&mut self, t: TriadType, k: u64) {
        Census::add_count(self, t, k);
    }
}

impl Index<TriadType> for Census {
    type Output = u64;
    #[inline]
    fn index(&self, t: TriadType) -> &u64 {
        &self.counts[t.index() - 1]
    }
}

impl IndexMut<TriadType> for Census {
    #[inline]
    fn index_mut(&mut self, t: TriadType) -> &mut u64 {
        &mut self.counts[t.index() - 1]
    }
}

impl Add for Census {
    type Output = Census;
    fn add(mut self, rhs: Census) -> Census {
        self += rhs;
        self
    }
}

impl AddAssign for Census {
    fn add_assign(&mut self, rhs: Census) {
        for i in 0..16 {
            self.counts[i] += rhs.counts[i];
        }
    }
}

impl fmt::Display for Census {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (i, t) in TriadType::ALL.iter().enumerate() {
            if i > 0 {
                write!(f, " ")?;
            }
            write!(f, "{}={}", t.label(), self[*t])?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indices_round_trip() {
        for t in TriadType::ALL {
            assert_eq!(TriadType::from_index(t.index()), t);
        }
        assert_eq!(TriadType::T003.index(), 1);
        assert_eq!(TriadType::T300.index(), 16);
    }

    #[test]
    fn labels_round_trip() {
        for t in TriadType::ALL {
            assert_eq!(TriadType::from_label(t.label()), Some(t));
        }
        assert_eq!(TriadType::from_label("nope"), None);
        assert_eq!(TriadType::from_label("021d"), None, "case-sensitive");
    }

    #[test]
    fn man_digits_match_labels() {
        for t in TriadType::ALL {
            let (m, a, n) = t.man();
            assert_eq!(m + a + n, 3, "{t}");
            let lbl = t.label().as_bytes();
            assert_eq!(lbl[0] - b'0', m, "{t}");
            assert_eq!(lbl[1] - b'0', a, "{t}");
            assert_eq!(lbl[2] - b'0', n, "{t}");
        }
    }

    #[test]
    fn reversal_is_involution() {
        for t in TriadType::ALL {
            assert_eq!(t.reversed().reversed(), t);
            // M-A-N counts invariant under reversal
            assert_eq!(t.reversed().man(), t.man());
        }
    }

    #[test]
    fn census_arithmetic() {
        let mut a = Census::zero();
        a.bump(TriadType::T300);
        a.add_count(TriadType::T012, 5);
        let mut b = Census::zero();
        b.add_count(TriadType::T012, 2);
        let c = a + b;
        assert_eq!(c[TriadType::T012], 7);
        assert_eq!(c[TriadType::T300], 1);
        assert_eq!(c.total(), 8);
    }

    #[test]
    fn close_with_null() {
        let mut c = Census::zero();
        c.add_count(TriadType::T030C, 1); // e.g. the 3-cycle on n=5
        c.close_with_null(5);
        assert_eq!(c[TriadType::T003], Census::expected_total(5) as u64 - 1);
        assert_eq!(c.total(), Census::expected_total(5));
    }

    #[test]
    fn expected_total_small() {
        assert_eq!(Census::expected_total(0), 0);
        assert_eq!(Census::expected_total(2), 0);
        assert_eq!(Census::expected_total(3), 1);
        assert_eq!(Census::expected_total(4), 4);
        assert_eq!(Census::expected_total(6), 20);
    }

    #[test]
    fn proportions_sum_to_one() {
        let mut c = Census::zero();
        c.add_count(TriadType::T003, 10);
        c.add_count(TriadType::T012, 30);
        let p = c.proportions();
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn arc_counts_per_class() {
        assert_eq!(TriadType::T003.arc_count(), 0);
        assert_eq!(TriadType::T012.arc_count(), 1);
        assert_eq!(TriadType::T102.arc_count(), 2);
        assert_eq!(TriadType::T030T.arc_count(), 3);
        assert_eq!(TriadType::T300.arc_count(), 6);
    }
}
