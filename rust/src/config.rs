//! CLI argument parsing and run configuration.
//!
//! The offline environment vendors no argument-parsing crate, so this is
//! a small, strict flag parser: `--key value` / `--key=value` / bare
//! `--flag` booleans, with typed accessors and unknown-flag rejection.

use std::collections::BTreeMap;

/// Parsed command line: a subcommand plus flags.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First positional token (subcommand).
    pub command: Option<String>,
    flags: BTreeMap<String, String>,
    /// Flags consumed so far (for unknown-flag detection).
    seen: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse from an iterator of tokens (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Result<Args, String> {
        let mut args = Args::default();
        let mut it = tokens.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                args.command = it.next();
            }
        }
        while let Some(tok) = it.next() {
            let Some(stripped) = tok.strip_prefix("--") else {
                return Err(format!("unexpected positional argument {tok:?}"));
            };
            if let Some((k, v)) = stripped.split_once('=') {
                args.flags.insert(k.to_string(), v.to_string());
            } else if it.peek().is_some_and(|n| !n.starts_with("--")) {
                args.flags.insert(stripped.to_string(), it.next().unwrap());
            } else {
                args.flags.insert(stripped.to_string(), "true".to_string());
            }
        }
        Ok(args)
    }

    /// Parse from the process environment.
    pub fn from_env() -> Result<Args, String> {
        Args::parse(std::env::args().skip(1))
    }

    fn mark(&self, key: &str) {
        self.seen.borrow_mut().push(key.to_string());
    }

    /// String flag with default.
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.mark(key);
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Optional string flag.
    pub fn opt_str(&self, key: &str) -> Option<String> {
        self.mark(key);
        self.flags.get(key).cloned()
    }

    /// Typed flag with default.
    pub fn get_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T, String>
    where
        T::Err: std::fmt::Display,
    {
        self.mark(key);
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse::<T>()
                .map_err(|e| format!("bad value for --{key}: {v:?} ({e})")),
        }
    }

    /// Boolean flag (present or `--key true/false`).
    pub fn flag(&self, key: &str) -> bool {
        self.mark(key);
        matches!(self.flags.get(key).map(String::as_str), Some("true") | Some("1"))
    }

    /// Comma-separated list of a parseable type.
    pub fn list_or<T: std::str::FromStr>(&self, key: &str, default: &[T]) -> Result<Vec<T>, String>
    where
        T: Clone,
        T::Err: std::fmt::Display,
    {
        self.mark(key);
        match self.flags.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| {
                    s.parse::<T>()
                        .map_err(|e| format!("bad element in --{key}: {s:?} ({e})"))
                })
                .collect(),
        }
    }

    /// Error if any provided flag was never consumed (catches typos).
    pub fn reject_unknown(&self) -> Result<(), String> {
        let seen = self.seen.borrow();
        for k in self.flags.keys() {
            if !seen.iter().any(|s| s == k) {
                return Err(format!("unknown flag --{k}"));
            }
        }
        Ok(())
    }
}

/// Resolve a workload spec from CLI flags (`--graph patents|orkut|web`,
/// `--nodes N`, `--seed S`).
pub fn graph_spec_from(args: &Args) -> Result<crate::graph::GraphSpec, String> {
    let name = args.str_or("graph", "patents");
    let default_nodes = match name.as_str() {
        "patents" => 200_000,
        "orkut" => 50_000,
        "web" | "webgraph" => 400_000,
        _ => 0,
    };
    let nodes = args.get_or("nodes", default_nodes)?;
    let seed = match args.opt_str("seed") {
        Some(s) => Some(s.parse().map_err(|e| format!("bad --seed: {e}"))?),
        None => None,
    };
    crate::graph::generators::spec_by_name(&name, nodes, seed)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("census --graph orkut --nodes 1000 --verbose");
        assert_eq!(a.command.as_deref(), Some("census"));
        assert_eq!(a.str_or("graph", "x"), "orkut");
        assert_eq!(a.get_or("nodes", 0usize).unwrap(), 1000);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn equals_syntax() {
        let a = parse("run --policy=dynamic:64");
        assert_eq!(a.str_or("policy", ""), "dynamic:64");
    }

    #[test]
    fn lists() {
        let a = parse("x --procs 1,2,4,8");
        assert_eq!(a.list_or("procs", &[0usize]).unwrap(), vec![1, 2, 4, 8]);
        assert_eq!(a.list_or("missing", &[3usize]).unwrap(), vec![3]);
    }

    #[test]
    fn bad_values_error() {
        let a = parse("x --nodes abc");
        assert!(a.get_or("nodes", 0usize).is_err());
        assert!(Args::parse(vec!["x".into(), "stray".into()]).is_err());
    }

    #[test]
    fn unknown_flag_rejection() {
        let a = parse("x --known 1 --typo 2");
        let _ = a.get_or("known", 0usize);
        assert!(a.reject_unknown().is_err());
        let _ = a.get_or("typo", 0usize);
        assert!(a.reject_unknown().is_ok());
    }

    #[test]
    fn graph_specs() {
        let a = parse("x --graph web --nodes 5000 --seed 9");
        let spec = graph_spec_from(&a).unwrap();
        assert_eq!(spec.name, "webgraph");
        assert_eq!(spec.n, 5000);
        assert_eq!(spec.seed, 9);
        let a = parse("x --graph nope");
        assert!(graph_spec_from(&a).is_err());
    }
}
