//! `TriadicClient` — the library client for the census wire protocol.
//!
//! A thin, synchronous transport over one TCP connection: every method
//! writes one request frame, reads one response frame and decodes it
//! through [`super::protocol`]. Transport failures and server-side
//! errors both surface as structured [`WireError`]s, so callers switch
//! on [`ErrorCode`] regardless of where the failure happened.
//!
//! ```ignore
//! let mut client = TriadicClient::connect("127.0.0.1:7333")?;
//! let job = client.submit(&CensusRequest::generator("patents", 10_000))?.job;
//! loop {
//!     let report = client.poll(job)?;
//!     if report.state.is_terminal() {
//!         break;
//!     }
//!     std::thread::sleep(std::time::Duration::from_millis(20));
//! }
//! let response = client.wait(job)?; // terminal: returns immediately
//! ```

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

use super::protocol::{
    CensusRequest, CensusResponse, ErrorCode, Json, JobReport, JobStateKind, RequestFrame,
    ResponseFrame, StreamApplyReport, StreamOpened, StreamSnapshot, Verb, WireError,
};
use crate::graph::EdgeOp;

/// Transport deadlines for a [`TriadicClient`]. `None` fields block
/// forever (the pre-timeout behavior). Build with the chained setters:
///
/// ```ignore
/// let t = ClientTimeouts::default()
///     .connect(Duration::from_secs(5))
///     .read(Duration::from_secs(30))
///     .write(Duration::from_secs(30));
/// let mut client = TriadicClient::connect_with_timeouts(addr, t)?;
/// ```
///
/// Mind the read deadline on [`TriadicClient::wait`] /
/// [`TriadicClient::census`]: the server answers a `wait` only once
/// the job is terminal, so the deadline must cover the whole census,
/// not one network round trip. Poll loops can run much tighter.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ClientTimeouts {
    pub connect: Option<Duration>,
    pub read: Option<Duration>,
    pub write: Option<Duration>,
}

impl ClientTimeouts {
    /// Deadline for establishing the TCP connection.
    pub fn connect(mut self, d: Duration) -> ClientTimeouts {
        self.connect = Some(d);
        self
    }

    /// Deadline for each blocking read of a response frame.
    pub fn read(mut self, d: Duration) -> ClientTimeouts {
        self.read = Some(d);
        self
    }

    /// Deadline for each blocking write of a request frame.
    pub fn write(mut self, d: Duration) -> ClientTimeouts {
        self.write = Some(d);
        self
    }
}

/// Synchronous client for one server connection.
pub struct TriadicClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    next_id: u64,
}

/// Map an I/O failure to the structured `transport` error code, naming
/// a deadline expiry explicitly (read timeouts surface as
/// `WouldBlock` on some platforms, `TimedOut` on others).
fn transport_error(e: std::io::Error) -> WireError {
    let detail = match e.kind() {
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut => {
            format!("timed out: {e}")
        }
        _ => e.to_string(),
    };
    WireError::new(ErrorCode::Transport, format!("transport: {detail}"))
}

impl TriadicClient {
    /// Connect to a running `repro serve --listen` endpoint.
    pub fn connect<A: ToSocketAddrs>(addr: A) -> Result<TriadicClient, WireError> {
        TriadicClient::connect_with_timeouts(addr, ClientTimeouts::default())
    }

    /// Connect with transport deadlines, so a stalled or black-holed
    /// server surfaces as a structured [`ErrorCode::Transport`] error
    /// instead of hanging this thread forever.
    pub fn connect_with_timeouts<A: ToSocketAddrs>(
        addr: A,
        timeouts: ClientTimeouts,
    ) -> Result<TriadicClient, WireError> {
        let stream = match timeouts.connect {
            None => TcpStream::connect(&addr).map_err(transport_error)?,
            Some(deadline) => {
                // `connect_timeout` wants resolved addresses: try each,
                // keeping the last failure for the error message
                let addrs: Vec<_> = addr
                    .to_socket_addrs()
                    .map_err(transport_error)?
                    .collect();
                let mut last = None;
                let mut stream = None;
                for a in &addrs {
                    match TcpStream::connect_timeout(a, deadline) {
                        Ok(s) => {
                            stream = Some(s);
                            break;
                        }
                        Err(e) => last = Some(e),
                    }
                }
                stream.ok_or_else(|| match last {
                    Some(e) => transport_error(e),
                    None => WireError::new(
                        ErrorCode::Transport,
                        "transport: address resolved to nothing",
                    ),
                })?
            }
        };
        let reader = BufReader::new(stream.try_clone().map_err(transport_error)?);
        let client = TriadicClient {
            reader,
            writer: stream,
            next_id: 0,
        };
        client.with_timeouts(timeouts)
    }

    /// Apply (or clear) read/write deadlines on the live connection.
    /// The `connect` field is ignored here — the connection exists.
    pub fn with_timeouts(self, timeouts: ClientTimeouts) -> Result<TriadicClient, WireError> {
        self.writer
            .set_read_timeout(timeouts.read)
            .and_then(|_| self.writer.set_write_timeout(timeouts.write))
            .map_err(transport_error)?;
        Ok(self)
    }

    /// One request/response round trip; returns the `result` payload.
    fn call(&mut self, mut frame: RequestFrame) -> Result<Json, WireError> {
        self.next_id += 1;
        frame.id = self.next_id;
        let mut line = frame.encode();
        line.push('\n');
        self.writer
            .write_all(line.as_bytes())
            .and_then(|_| self.writer.flush())
            .map_err(transport_error)?;
        let mut reply = String::new();
        let n = self.reader.read_line(&mut reply).map_err(transport_error)?;
        if n == 0 {
            return Err(WireError::new(
                ErrorCode::Transport,
                "transport: server closed the connection",
            ));
        }
        let response = ResponseFrame::decode(reply.trim_end())?;
        // id 0 marks an unkeyed server-side error (the frame was too
        // broken to echo an id) — surface the structured error itself
        // rather than a misleading mismatch report
        if response.id != frame.id && !(response.id == 0 && response.result.is_err()) {
            return Err(WireError::new(
                ErrorCode::BadFrame,
                format!("correlation id mismatch: sent {} got {}", frame.id, response.id),
            ));
        }
        response.result
    }

    /// Submit a census request; the returned report is the job's intake
    /// state (`queued`, or already `failed` for a rejected request).
    pub fn submit(&mut self, request: &CensusRequest) -> Result<JobReport, WireError> {
        let mut frame = RequestFrame::new(0, Verb::Submit);
        frame.request = Some(request.clone());
        JobReport::from_json(&self.call(frame)?)
    }

    /// Non-blocking job status.
    pub fn poll(&mut self, job: u64) -> Result<JobReport, WireError> {
        let mut frame = RequestFrame::new(0, Verb::Poll);
        frame.job = Some(job);
        JobReport::from_json(&self.call(frame)?)
    }

    /// Block until the job is terminal and return its census; a failed
    /// or cancelled job comes back as its structured error.
    pub fn wait(&mut self, job: u64) -> Result<CensusResponse, WireError> {
        let mut frame = RequestFrame::new(0, Verb::Wait);
        frame.job = Some(job);
        let report = JobReport::from_json(&self.call(frame)?)?;
        report_into_response(report)
    }

    /// Request cancellation; `true` when the job was still cancellable.
    pub fn cancel(&mut self, job: u64) -> Result<bool, WireError> {
        let mut frame = RequestFrame::new(0, Verb::Cancel);
        frame.job = Some(job);
        let result = self.call(frame)?;
        Ok(result.get("cancelled").and_then(Json::as_bool).unwrap_or(false))
    }

    /// Convenience: submit and block until done.
    pub fn census(&mut self, request: &CensusRequest) -> Result<CensusResponse, WireError> {
        let report = self.submit(request)?;
        if report.state.is_terminal() {
            return report_into_response(report);
        }
        self.wait(report.job)
    }

    /// Server identity and job counters (the `status` verb payload).
    pub fn status(&mut self) -> Result<Json, WireError> {
        self.call(RequestFrame::new(0, Verb::Status))
    }

    /// Metrics text exposition of the server's coordinator.
    pub fn metrics_text(&mut self) -> Result<String, WireError> {
        let result = self.call(RequestFrame::new(0, Verb::Metrics))?;
        Ok(result
            .get("text")
            .and_then(Json::as_str)
            .unwrap_or_default()
            .to_string())
    }

    /// Ask the server to stop accepting connections and exit its accept
    /// loop. The ack is written before the server begins stopping;
    /// already-admitted jobs are drained by the serving process before
    /// it exits (`repro serve` waits on the in-flight gauge).
    pub fn shutdown(&mut self) -> Result<(), WireError> {
        self.call(RequestFrame::new(0, Verb::Shutdown)).map(|_| ())
    }

    /// Open a streaming census session over the request's graph source
    /// (the request's `engine` picks the seed-census engine; `threads`,
    /// `policy` and `classes` are ignored). A `sampled:P` `fidelity`
    /// on the request opens the session over the p-filtered base —
    /// snapshots then carry rounded estimates plus a `sampling`
    /// interval report. The session lives server-side until
    /// [`TriadicClient::stream_close`] and is shared across
    /// connections by its id.
    pub fn stream_open(&mut self, request: &CensusRequest) -> Result<StreamOpened, WireError> {
        let mut frame = RequestFrame::new(0, Verb::StreamOpen);
        frame.request = Some(request.clone());
        StreamOpened::from_json(&self.call(frame)?)
    }

    /// Apply a batch of edge mutations to a session, in order. Invalid
    /// ops (self-loops, out-of-range ids) are counted in `rejected`
    /// rather than failing the batch.
    pub fn stream_apply(
        &mut self,
        stream: u64,
        ops: &[EdgeOp],
    ) -> Result<StreamApplyReport, WireError> {
        let mut frame = RequestFrame::new(0, Verb::StreamApply);
        frame.stream = Some(stream);
        frame.ops = Some(ops.to_vec());
        StreamApplyReport::from_json(&self.call(frame)?)
    }

    /// Read a session's live census and counters.
    pub fn stream_query(&mut self, stream: u64) -> Result<StreamSnapshot, WireError> {
        let mut frame = RequestFrame::new(0, Verb::StreamQuery);
        frame.stream = Some(stream);
        StreamSnapshot::from_json(&self.call(frame)?)
    }

    /// Ask the server to rebuild the session's base CSR from its
    /// overlay. The census is unchanged; the overlay resets to empty.
    pub fn stream_compact(&mut self, stream: u64) -> Result<(), WireError> {
        let mut frame = RequestFrame::new(0, Verb::StreamCompact);
        frame.stream = Some(stream);
        self.call(frame).map(|_| ())
    }

    /// Close a session. Closing an unknown (or already-closed) session
    /// is an [`ErrorCode::UnknownStream`] error.
    pub fn stream_close(&mut self, stream: u64) -> Result<(), WireError> {
        let mut frame = RequestFrame::new(0, Verb::StreamClose);
        frame.stream = Some(stream);
        self.call(frame).map(|_| ())
    }
}

/// Collapse a terminal report into the response / structured error the
/// blocking client methods return.
fn report_into_response(report: JobReport) -> Result<CensusResponse, WireError> {
    match report.state {
        JobStateKind::Done => report.response.ok_or_else(|| {
            WireError::new(ErrorCode::BadFrame, "done report without a response body")
        }),
        JobStateKind::Failed => Err(report
            .error
            .unwrap_or_else(|| WireError::new(ErrorCode::Internal, "job failed"))),
        JobStateKind::Cancelled => Err(WireError::new(ErrorCode::Cancelled, "job cancelled")),
        state => Err(WireError::new(
            ErrorCode::Internal,
            format!("job still {} after wait", state.as_str()),
        )),
    }
}
