//! The coordinator: the job-oriented service layer that owns both
//! census backends, routes work between them, and speaks a versioned
//! wire protocol to remote clients.
//!
//! Architecture (Python never appears at runtime):
//!
//! ```text
//!  repro client / TriadicClient           in-process callers
//!        │  newline-delimited JSON              │ census() / census_path()
//!        ▼  (v1 frames, TCP)                    │ (compatibility shims)
//!  ┌───────────────┐  submit/poll/wait/cancel   │
//!  │ CensusServer  │────────────┐               │
//!  └───────────────┘            ▼               ▼
//!                      ┌──────────────────────────────┐
//!                      │ Coordinator                  │
//!                      │  submit(CensusRequest)       │
//!                      │    → JobHandle               │
//!                      │  job queue + runner threads  │
//!                      └───────┬──────────────────────┘
//!              resolve source  │  (path cache / inline / generator)
//!                              ▼
//!                           Router ──────────┬───────────────┐
//!                              │ sparse      │ dense         │
//!                              ▼             ▼               │
//!              ┌────────────────────┐  ┌──────────────────┐  │
//!              │ EngineRegistry     │  │ dense service    │  │
//!              │ (naive/bm/merged/  │  │ thread (PJRT,    │  │
//!              │  parallel/moody)   │  │ request queue)   │  │
//!              └─────────┬──────────┘  └──────────────────┘  │
//!                        ▼                                   │
//!              shared Executor (persistent work-stealing     │
//!              pool; CancelToken checked between chunks) ◀───┘
//! ```
//!
//! * **Protocol** ([`protocol`]): the versioned request/response model —
//!   [`CensusRequest`] (graph source = path | inline edges | generator;
//!   per-request engine / threads / policy / triad-class subset),
//!   [`CensusResponse`] (census + provenance + scheduler stats +
//!   timing), structured [`ErrorCode`]s, and the newline-delimited JSON
//!   frames both sides exchange.
//! * **Jobs** ([`service`]): [`Coordinator::submit`] returns a
//!   [`JobHandle`] with non-blocking `poll()`, blocking `wait()` and
//!   cooperative `cancel()`; a bounded pool of job-runner threads drains
//!   the queue. The blocking `census`/`census_path` calls are shims over
//!   the same pipeline.
//! * **Routing** ([`router`]): small graphs that fit an AOT artifact go
//!   to the dense PJRT backend (one matmul-census execution, ideal for
//!   the monitoring application's windowed subgraphs); everything else
//!   runs on the sparse engines. Naming an engine in a request forces
//!   the sparse path.
//! * **Transport** ([`server`], [`client`]): `repro serve --listen`
//!   fronts the coordinator with the nonblocking multi-tenant gateway
//!   ([`crate::net`]) by default, or the legacy thread-per-connection
//!   accept loop behind `--legacy-accept`; both share one dispatch
//!   core and job table. [`TriadicClient`] is the library-side
//!   counterpart the `repro client` subcommand wraps.
//! * **Distribution**: `repro worker` runs a sparse-only coordinator
//!   behind the same server and honors the request-level `shard` field
//!   (raw partial tallies over one vertex range); `repro serve
//!   --workers a,b,c` makes the coordinator a planner that partitions
//!   the collapsed triad space over `flat_offsets`, scatters shard
//!   sub-jobs to the pool (retrying a shard on the next worker when one
//!   disconnects), and merges the partials by exact summation —
//!   byte-identical to a single-process run.
//! * **Streams**: `stream_open` / `stream_apply` / `stream_query` /
//!   `stream_compact` / `stream_close` maintain live incremental
//!   censuses ([`crate::census::StreamingCensus`]) in a cross-connection
//!   session table — edge mutations between requests cost
//!   O(deg(u) + deg(v)) instead of a full recompute. A request-level
//!   `fidelity` knob (`exact` | `sampled:P`) downgrades a session (or a
//!   one-shot census) to maintenance over a deterministically p-sampled
//!   dyad overlay ([`crate::census::SampledCensus`]), with unbiased
//!   per-class estimates and confidence intervals ([`SampleReport`])
//!   beside the rounded table.
//! * **Metrics**: counters + gauges + latency histograms per backend,
//!   job lifecycle counters, served by the `metrics` verb.

pub mod client;
pub mod protocol;
pub mod router;
pub mod server;
pub mod service;

pub use client::{ClientTimeouts, TriadicClient};
pub use protocol::{
    CensusRequest, CensusResponse, ErrorCode, Fidelity, GraphSource, JobReport, JobStateKind,
    Provenance, SampleReport, SchedStats, Shard, StreamApplyReport, StreamOpened, StreamSnapshot,
    WireError, DEFAULT_PRIORITY, MAX_PRIORITY, PROTOCOL_VERSION,
};
pub use router::{Route, Router, RoutingPolicy};
pub use server::CensusServer;
pub use service::{CensusOutcome, Coordinator, CoordinatorConfig, JobHandle, JobStatus};
