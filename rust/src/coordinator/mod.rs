//! The coordinator: the service layer that owns both census backends
//! and routes work between them.
//!
//! Architecture (Python never appears at runtime):
//!
//! ```text
//!            submit(graph)                 ┌──────────────────────┐
//!  client ────────────────▶  Router ─────▶ │ sparse engine        │
//!                              │           │ (parallel BM census) │
//!                              │           └──────────────────────┘
//!                              │   dense   ┌──────────────────────┐
//!                              └─────────▶ │ dense service thread │
//!                                          │ owns PJRT runtime,   │
//!                                          │ drains request queue │
//!                                          └──────────────────────┘
//! ```
//!
//! * **Routing** ([`router`]): small graphs that fit an AOT artifact go
//!   to the dense PJRT backend (one matmul-census execution, ideal for
//!   the monitoring application's windowed subgraphs); everything else
//!   runs on the sparse parallel engine.
//! * **Dense service** ([`service`]): `PjRtLoadedExecutable` is not
//!   `Send`, so a dedicated thread owns the [`DenseCensusRuntime`]
//!   (compile-once) and serves a bounded request queue — the same
//!   confine-and-batch pattern a GPU serving router uses.
//! * **Metrics**: counters + latency histograms per backend.

pub mod router;
pub mod service;

pub use router::{Route, Router, RoutingPolicy};
pub use service::{Coordinator, CoordinatorConfig, CensusOutcome};
