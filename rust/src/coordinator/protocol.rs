//! The versioned census wire protocol: request/response model types and
//! their newline-delimited JSON encoding.
//!
//! Every frame on the wire is one JSON object on one line and carries a
//! `"v"` protocol-version field; peers reject frames whose version they
//! do not speak with a structured [`ErrorCode::BadVersion`] error
//! instead of guessing. The offline vendor set has no serde, so this
//! module also carries a small, strict JSON value type ([`Json`]) with a
//! recursive-descent parser and serializer — integers are kept exact in
//! `i128` (census counts are `u64` and `C(n,3)` totals can exceed the
//! `f64` integer range), floats stay `f64`.
//!
//! Layering: this module owns *all* encode/decode; the TCP server
//! ([`super::server`]) and the client ([`super::client`]) are pure
//! transports moving encoded lines.
//!
//! ## Frames
//!
//! Request (client → server), one per line:
//!
//! ```json
//! {"v":1,"id":7,"verb":"submit","request":{"source":{"kind":"path","path":"g.csr"}}}
//! {"v":1,"id":8,"verb":"poll","job":3}
//! {"v":1,"id":9,"verb":"status"}
//! {"v":1,"id":10,"verb":"stream_open","request":{"source":{"kind":"path","path":"g.csr"}}}
//! {"v":1,"id":11,"verb":"stream_apply","stream":1,"ops":[["+",0,1],["-",2,3]]}
//! ```
//!
//! Response (server → client), one per request, echoing `id`:
//!
//! ```json
//! {"v":1,"id":7,"ok":true,"result":{"job":3,"state":"queued"}}
//! {"v":1,"id":8,"ok":false,"error":{"code":"unknown_job","message":"no job 99"}}
//! ```

use std::fmt;

use crate::census::{Census, SampledEstimate, TriadType};
use crate::graph::{EdgeOp, VertexOrdering};
use crate::sched::{Policy, ThreadPoolStats};

/// The wire protocol version spoken by this build. Bumped on any
/// incompatible frame change; every frame carries it.
pub const PROTOCOL_VERSION: u64 = 1;

// ---------------------------------------------------------------------------
// JSON value
// ---------------------------------------------------------------------------

/// A parsed JSON value. Integers are kept exact (`i128` covers the full
/// `u64` census-count range); anything with a fraction or exponent
/// becomes `Num`.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Int(i128),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Insertion-ordered key/value pairs (duplicate keys: first wins on
    /// lookup).
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object member lookup (first match).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Int(i) => u64::try_from(*i).ok(),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Int(i) => usize::try_from(*i).ok(),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Int(i) => Some(*i as f64),
            Json::Num(f) => Some(*f),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Parse one JSON document (surrounding whitespace allowed, nothing
    /// after it).
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = JsonParser {
            b: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(format!("trailing garbage at byte {}", p.pos));
        }
        Ok(v)
    }
}

impl From<u64> for Json {
    fn from(v: u64) -> Json {
        Json::Int(v as i128)
    }
}

impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Int(v as i128)
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}

impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}

impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => f.write_str("null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Int(i) => write!(f, "{i}"),
            Json::Num(n) if n.is_finite() => write!(f, "{n}"),
            Json::Num(_) => f.write_str("null"), // NaN / inf have no JSON form
            Json::Str(s) => write_json_string(f, s),
            Json::Arr(items) => {
                f.write_str("[")?;
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write!(f, "{v}")?;
                }
                f.write_str("]")
            }
            Json::Obj(pairs) => {
                f.write_str("{")?;
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write_json_string(f, k)?;
                    write!(f, ":{v}")?;
                }
                f.write_str("}")
            }
        }
    }
}

fn write_json_string(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    f.write_str("\"")?;
    for c in s.chars() {
        match c {
            '"' => f.write_str("\\\"")?,
            '\\' => f.write_str("\\\\")?,
            '\n' => f.write_str("\\n")?,
            '\r' => f.write_str("\\r")?,
            '\t' => f.write_str("\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    f.write_str("\"")
}

struct JsonParser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl JsonParser<'_> {
    fn skip_ws(&mut self) {
        while let Some(&c) = self.b.get(self.pos) {
            if c == b' ' || c == b'\t' || c == b'\n' || c == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!(
                "expected {:?} at byte {}, found {:?}",
                c as char,
                self.pos,
                self.peek().map(|b| b as char)
            ))
        }
    }

    fn eat_keyword(&mut self, kw: &str) -> Result<(), String> {
        if self.b[self.pos..].starts_with(kw.as_bytes()) {
            self.pos += kw.len();
            Ok(())
        } else {
            Err(format!("expected {kw:?} at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'n') => self.eat_keyword("null").map(|_| Json::Null),
            Some(b't') => self.eat_keyword("true").map(|_| Json::Bool(true)),
            Some(b'f') => self.eat_keyword("false").map(|_| Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!(
                "unexpected {:?} at byte {}",
                other.map(|b| b as char),
                self.pos
            )),
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            pairs.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(pairs));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".to_string()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or("unterminated escape")?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let cp = if (0xd800..0xdc00).contains(&hi) {
                                // surrogate pair: expect \uDC00..\uDFFF next
                                self.eat(b'\\')?;
                                self.eat(b'u')?;
                                let lo = self.hex4()?;
                                if !(0xdc00..0xe000).contains(&lo) {
                                    return Err("invalid low surrogate".to_string());
                                }
                                0x10000 + ((hi - 0xd800) << 10) + (lo - 0xdc00)
                            } else {
                                hi
                            };
                            out.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| format!("invalid code point {cp:#x}"))?,
                            );
                        }
                        other => return Err(format!("bad escape \\{}", other as char)),
                    }
                }
                Some(_) => {
                    // consume one UTF-8 scalar (input is &str, so valid)
                    let rest = &self.b[self.pos..];
                    let s = std::str::from_utf8(rest).map_err(|e| e.to_string())?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, String> {
        if self.pos + 4 > self.b.len() {
            return Err("truncated \\u escape".to_string());
        }
        let hex = std::str::from_utf8(&self.b[self.pos..self.pos + 4])
            .map_err(|e| e.to_string())?;
        self.pos += 4;
        u32::from_str_radix(hex, 16).map_err(|e| format!("bad \\u escape {hex:?}: {e}"))
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        let mut is_float = false;
        while let Some(c) = self.peek() {
            match c {
                b'0'..=b'9' => self.pos += 1,
                b'.' | b'e' | b'E' | b'+' | b'-' => {
                    is_float = true;
                    self.pos += 1;
                }
                _ => break,
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).map_err(|e| e.to_string())?;
        if is_float {
            text.parse::<f64>()
                .map(Json::Num)
                .map_err(|e| format!("bad number {text:?}: {e}"))
        } else {
            text.parse::<i128>()
                .map(Json::Int)
                .map_err(|e| format!("bad integer {text:?}: {e}"))
        }
    }
}

// ---------------------------------------------------------------------------
// Structured errors
// ---------------------------------------------------------------------------

/// Structured error codes carried in every error frame. Stable strings —
/// clients switch on the code, not the message.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorCode {
    /// Frame carried a missing or unsupported protocol version.
    BadVersion,
    /// Frame was not a parseable protocol frame.
    BadFrame,
    /// Request was structurally valid but semantically broken
    /// (unknown generator, inline arc out of range, bad policy…).
    BadRequest,
    /// Verb not recognized by this server.
    UnknownVerb,
    /// Engine name not in the registry.
    UnknownEngine,
    /// Job id not known to this server.
    UnknownJob,
    /// Stream session id not known to this server (never opened, or
    /// already closed — a double `stream_close` lands here).
    UnknownStream,
    /// Graph source could not be loaded.
    GraphLoad,
    /// The job was cancelled before completing.
    Cancelled,
    /// Server is shutting down and not accepting work.
    ShuttingDown,
    /// A distributed shard could not be placed: every worker in the
    /// pool failed or disconnected while holding it.
    WorkerUnavailable,
    /// The tenant's token bucket is empty: the request was shed by the
    /// admission gate. Retry after backing off; the connection stays
    /// open and usable.
    RateLimited,
    /// The server (or this tenant's inflight quota) is at capacity:
    /// connection cap reached, write buffers backed up, or too many
    /// jobs already running. Retry against a less loaded endpoint.
    Overloaded,
    /// Client-side transport failure: connect/read/write failed or
    /// timed out before a response frame arrived. Produced by
    /// [`TriadicClient`](super::client::TriadicClient), never sent by
    /// a server.
    Transport,
    /// Anything else.
    Internal,
}

impl ErrorCode {
    pub fn as_str(self) -> &'static str {
        match self {
            ErrorCode::BadVersion => "bad_version",
            ErrorCode::BadFrame => "bad_frame",
            ErrorCode::BadRequest => "bad_request",
            ErrorCode::UnknownVerb => "unknown_verb",
            ErrorCode::UnknownEngine => "unknown_engine",
            ErrorCode::UnknownJob => "unknown_job",
            ErrorCode::UnknownStream => "unknown_stream",
            ErrorCode::GraphLoad => "graph_load",
            ErrorCode::Cancelled => "cancelled",
            ErrorCode::ShuttingDown => "shutting_down",
            ErrorCode::WorkerUnavailable => "worker_unavailable",
            ErrorCode::RateLimited => "rate_limited",
            ErrorCode::Overloaded => "overloaded",
            ErrorCode::Transport => "transport",
            ErrorCode::Internal => "internal",
        }
    }

    /// Inverse of [`ErrorCode::as_str`]; unknown codes (from a newer
    /// peer) collapse to [`ErrorCode::Internal`].
    pub fn parse(s: &str) -> ErrorCode {
        match s {
            "bad_version" => ErrorCode::BadVersion,
            "bad_frame" => ErrorCode::BadFrame,
            "bad_request" => ErrorCode::BadRequest,
            "unknown_verb" => ErrorCode::UnknownVerb,
            "unknown_engine" => ErrorCode::UnknownEngine,
            "unknown_job" => ErrorCode::UnknownJob,
            "unknown_stream" => ErrorCode::UnknownStream,
            "graph_load" => ErrorCode::GraphLoad,
            "cancelled" => ErrorCode::Cancelled,
            "shutting_down" => ErrorCode::ShuttingDown,
            "worker_unavailable" => ErrorCode::WorkerUnavailable,
            "rate_limited" => ErrorCode::RateLimited,
            "overloaded" => ErrorCode::Overloaded,
            "transport" => ErrorCode::Transport,
            _ => ErrorCode::Internal,
        }
    }
}

/// A structured protocol error: stable code + human message.
#[derive(Debug, Clone, PartialEq)]
pub struct WireError {
    pub code: ErrorCode,
    pub message: String,
}

impl WireError {
    pub fn new<M: fmt::Display>(code: ErrorCode, message: M) -> WireError {
        WireError {
            code,
            message: message.to_string(),
        }
    }

    /// The `{"code":...,"message":...}` object embedded in error frames
    /// (and reusable by anything logging structured errors).
    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("code".into(), Json::from(self.code.as_str())),
            ("message".into(), Json::from(self.message.clone())),
        ])
    }

    fn from_json(v: &Json) -> WireError {
        WireError {
            code: ErrorCode::parse(v.get("code").and_then(Json::as_str).unwrap_or("")),
            message: v
                .get("message")
                .and_then(Json::as_str)
                .unwrap_or("")
                .to_string(),
        }
    }
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[{}] {}", self.code.as_str(), self.message)
    }
}

// ---------------------------------------------------------------------------
// Requests
// ---------------------------------------------------------------------------

/// Where the graph of a census request comes from.
#[derive(Debug, Clone, PartialEq)]
pub enum GraphSource {
    /// A file path readable by the *server* (edge list, `TRIADIC1` or
    /// mmap-served `TRIADIC2`), cached across requests.
    Path(String),
    /// An inline directed edge list over nodes `0..nodes` — the
    /// monitoring application's windowed subgraphs travel this way.
    Inline { nodes: usize, arcs: Vec<(u32, u32)> },
    /// A named synthetic workload (`patents`, `orkut`, `web`), generated
    /// server-side at the given node count.
    Generator {
        name: String,
        nodes: usize,
        seed: Option<u64>,
    },
}

impl GraphSource {
    /// Short provenance string recorded in responses.
    pub fn describe(&self) -> String {
        match self {
            GraphSource::Path(p) => format!("path:{p}"),
            GraphSource::Inline { nodes, arcs } => {
                format!("inline:n={nodes},arcs={}", arcs.len())
            }
            GraphSource::Generator { name, nodes, seed } => match seed {
                Some(s) => format!("generator:{name},n={nodes},seed={s}"),
                None => format!("generator:{name},n={nodes}"),
            },
        }
    }

    fn to_json(&self) -> Json {
        match self {
            GraphSource::Path(p) => Json::Obj(vec![
                ("kind".into(), Json::from("path")),
                ("path".into(), Json::from(p.clone())),
            ]),
            GraphSource::Inline { nodes, arcs } => Json::Obj(vec![
                ("kind".into(), Json::from("inline")),
                ("nodes".into(), Json::from(*nodes)),
                (
                    "arcs".into(),
                    Json::Arr(
                        arcs.iter()
                            .map(|&(u, v)| {
                                Json::Arr(vec![Json::from(u as u64), Json::from(v as u64)])
                            })
                            .collect(),
                    ),
                ),
            ]),
            GraphSource::Generator { name, nodes, seed } => {
                let mut pairs = vec![
                    ("kind".into(), Json::from("generator")),
                    ("name".into(), Json::from(name.clone())),
                    ("nodes".into(), Json::from(*nodes)),
                ];
                if let Some(s) = seed {
                    pairs.push(("seed".into(), Json::from(*s)));
                }
                Json::Obj(pairs)
            }
        }
    }

    fn from_json(v: &Json) -> Result<GraphSource, WireError> {
        let bad = |m: String| WireError::new(ErrorCode::BadRequest, m);
        let kind = v
            .get("kind")
            .and_then(Json::as_str)
            .ok_or_else(|| bad("source.kind missing".into()))?;
        match kind {
            "path" => {
                let p = v
                    .get("path")
                    .and_then(Json::as_str)
                    .ok_or_else(|| bad("source.path missing".into()))?;
                Ok(GraphSource::Path(p.to_string()))
            }
            "inline" => {
                let nodes = v
                    .get("nodes")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| bad("source.nodes missing".into()))?;
                let arcs_json = v
                    .get("arcs")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| bad("source.arcs missing".into()))?;
                let mut arcs = Vec::with_capacity(arcs_json.len());
                for a in arcs_json {
                    let pair = a.as_arr().filter(|p| p.len() == 2);
                    let (u, v) = match pair {
                        Some(p) => (p[0].as_u64(), p[1].as_u64()),
                        None => (None, None),
                    };
                    match (u, v) {
                        (Some(u), Some(v)) if u < nodes as u64 && v < nodes as u64 => {
                            arcs.push((u as u32, v as u32));
                        }
                        _ => {
                            return Err(bad(format!(
                                "inline arc {a} is not a [u, v] pair inside 0..{nodes}"
                            )))
                        }
                    }
                }
                Ok(GraphSource::Inline { nodes, arcs })
            }
            "generator" => {
                let name = v
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| bad("source.name missing".into()))?;
                let nodes = v
                    .get("nodes")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| bad("source.nodes missing".into()))?;
                let seed = v.get("seed").and_then(Json::as_u64);
                Ok(GraphSource::Generator {
                    name: name.to_string(),
                    nodes,
                    seed,
                })
            }
            other => Err(bad(format!(
                "unknown source kind {other:?} (path|inline|generator)"
            ))),
        }
    }
}

/// A contiguous vertex range `lo..hi` of the collapsed triad space —
/// the unit the distributed planner ships to one worker. A shard
/// request censuses only the entries `[offsets[lo], offsets[hi])` and
/// returns **raw non-null tallies** (the `003` slot stays zero): the
/// null count is a whole-graph property the merging coordinator closes
/// exactly once. Decode rejects inverted ranges; the upper bound is
/// validated against the node count where the graph is resolved.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Shard {
    pub lo: usize,
    pub hi: usize,
}

impl Shard {
    pub fn new(lo: usize, hi: usize) -> Shard {
        Shard { lo, hi }
    }

    /// Vertices covered (`hi - lo`; empty shards are legal).
    pub fn len(&self) -> usize {
        self.hi - self.lo
    }

    pub fn is_empty(&self) -> bool {
        self.lo == self.hi
    }

    fn to_json(self) -> Json {
        Json::Obj(vec![
            ("lo".into(), Json::from(self.lo)),
            ("hi".into(), Json::from(self.hi)),
        ])
    }

    fn from_json(v: &Json) -> Result<Shard, WireError> {
        let bad = |m: String| WireError::new(ErrorCode::BadRequest, m);
        let lo = v
            .get("lo")
            .and_then(Json::as_usize)
            .ok_or_else(|| bad("shard.lo missing or not a non-negative integer".into()))?;
        let hi = v
            .get("hi")
            .and_then(Json::as_usize)
            .ok_or_else(|| bad("shard.hi missing or not a non-negative integer".into()))?;
        if lo > hi {
            return Err(bad(format!(
                "shard range inverted: lo {lo} > hi {hi} (valid: 0 <= lo <= hi <= node count)"
            )));
        }
        Ok(Shard { lo, hi })
    }
}

impl fmt::Display for Shard {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}..{}", self.lo, self.hi)
    }
}

/// A census request: graph source plus per-request execution options.
/// Build with the constructors + chained setters:
///
/// ```ignore
/// let req = CensusRequest::generator("patents", 50_000)
///     .seed(7)
///     .engine("parallel")
///     .threads(8)
///     .policy(Policy::Dynamic { chunk: 256 })
///     .classes(vec![TriadType::T030T, TriadType::T030C]);
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct CensusRequest {
    pub source: GraphSource,
    /// Engine override. `None` routes normally (dense backend eligible);
    /// naming an engine forces the sparse path through that engine.
    pub engine: Option<String>,
    /// Seat count override for the parallel engine.
    pub threads: Option<usize>,
    /// Schedule-policy override for the parallel engine.
    pub policy: Option<Policy>,
    /// Vertex ordering the sparse path preprocesses with (`None` =
    /// natural). Census-invariant: only timing changes.
    pub ordering: Option<VertexOrdering>,
    /// Triad-class subset to return; `None` = the full 16-class census.
    pub classes: Option<Vec<TriadType>>,
    /// Vertex-range restriction: census only the shard's slice of the
    /// collapsed triad space and return raw (unclosed) tallies. Set by
    /// the distributed planner on the sub-requests it ships to workers;
    /// `None` = the whole graph, closed as usual.
    pub shard: Option<Shard>,
    /// Tenant this request bills against at the gateway's admission
    /// gate (token bucket + inflight quota). `None` = the default
    /// bucket. Servers without a gateway ignore the field.
    pub tenant: Option<String>,
    /// Submit-queue priority, `0..=`[`MAX_PRIORITY`] (higher runs
    /// sooner; FIFO within a level). `None` = the tenant's configured
    /// priority, or [`DEFAULT_PRIORITY`].
    pub priority: Option<u8>,
    /// Census fidelity. `None` / `Exact` computes the exact table;
    /// `Sampled{p}` estimates it from a deterministic dyad sample,
    /// attaching per-class intervals to the response. Distributed
    /// planning and shard sub-requests are exact-only — the planner
    /// strips this field from the sub-jobs it ships.
    pub fidelity: Option<Fidelity>,
}

/// Default submit-queue priority for requests (and tenants) that do
/// not name one.
pub const DEFAULT_PRIORITY: u8 = 4;

/// Largest submit-queue priority a request may carry.
pub const MAX_PRIORITY: u8 = 9;

impl CensusRequest {
    pub fn from_source(source: GraphSource) -> CensusRequest {
        CensusRequest {
            source,
            engine: None,
            threads: None,
            policy: None,
            ordering: None,
            classes: None,
            shard: None,
            tenant: None,
            priority: None,
            fidelity: None,
        }
    }

    /// Census of a server-side graph file.
    pub fn path<P: Into<String>>(path: P) -> CensusRequest {
        CensusRequest::from_source(GraphSource::Path(path.into()))
    }

    /// Census of an inline edge list over nodes `0..nodes`.
    pub fn inline(nodes: usize, arcs: Vec<(u32, u32)>) -> CensusRequest {
        CensusRequest::from_source(GraphSource::Inline { nodes, arcs })
    }

    /// Census of a named synthetic workload generated server-side.
    pub fn generator<N: Into<String>>(name: N, nodes: usize) -> CensusRequest {
        CensusRequest::from_source(GraphSource::Generator {
            name: name.into(),
            nodes,
            seed: None,
        })
    }

    /// Generator seed (no effect on path / inline sources).
    pub fn seed(mut self, seed: u64) -> CensusRequest {
        if let GraphSource::Generator { seed: s, .. } = &mut self.source {
            *s = Some(seed);
        }
        self
    }

    /// Force a named engine (sparse path).
    pub fn engine<E: Into<String>>(mut self, engine: E) -> CensusRequest {
        self.engine = Some(engine.into());
        self
    }

    /// Seat count for the parallel engine.
    pub fn threads(mut self, threads: usize) -> CensusRequest {
        self.threads = Some(threads);
        self
    }

    /// Schedule policy for the parallel engine.
    pub fn policy(mut self, policy: Policy) -> CensusRequest {
        self.policy = Some(policy);
        self
    }

    /// Vertex ordering preprocessing for the sparse path.
    pub fn ordering(mut self, ordering: VertexOrdering) -> CensusRequest {
        self.ordering = Some(ordering);
        self
    }

    /// Return only these triad classes.
    pub fn classes(mut self, classes: Vec<TriadType>) -> CensusRequest {
        self.classes = Some(classes);
        self
    }

    /// Restrict the census to the vertex-range shard `lo..hi` (raw,
    /// unclosed partial tallies — the distributed planner's sub-job).
    pub fn shard(mut self, lo: usize, hi: usize) -> CensusRequest {
        self.shard = Some(Shard::new(lo, hi));
        self
    }

    /// Bill this request against a named tenant at the gateway.
    pub fn tenant<T: Into<String>>(mut self, tenant: T) -> CensusRequest {
        self.tenant = Some(tenant.into());
        self
    }

    /// Submit-queue priority, `0..=`[`MAX_PRIORITY`] (higher runs
    /// sooner).
    pub fn priority(mut self, priority: u8) -> CensusRequest {
        self.priority = Some(priority);
        self
    }

    /// Set the census fidelity explicitly.
    pub fn fidelity(mut self, fidelity: Fidelity) -> CensusRequest {
        self.fidelity = Some(fidelity);
        self
    }

    /// Request sampled fidelity at dyad rate `p` (`0 < p <= 1`).
    pub fn sampled(self, p: f64) -> CensusRequest {
        self.fidelity(Fidelity::Sampled { p })
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![("source".into(), self.source.to_json())];
        if let Some(e) = &self.engine {
            pairs.push(("engine".into(), Json::from(e.clone())));
        }
        if let Some(t) = self.threads {
            pairs.push(("threads".into(), Json::from(t)));
        }
        if let Some(p) = &self.policy {
            pairs.push(("policy".into(), Json::from(policy_to_wire(p))));
        }
        if let Some(o) = self.ordering {
            pairs.push(("ordering".into(), Json::from(o.name())));
        }
        if let Some(classes) = &self.classes {
            pairs.push((
                "classes".into(),
                Json::Arr(classes.iter().map(|t| Json::from(t.label())).collect()),
            ));
        }
        if let Some(shard) = self.shard {
            pairs.push(("shard".into(), shard.to_json()));
        }
        if let Some(t) = &self.tenant {
            pairs.push(("tenant".into(), Json::from(t.clone())));
        }
        if let Some(p) = self.priority {
            pairs.push(("priority".into(), Json::from(p as u64)));
        }
        if let Some(f) = self.fidelity {
            pairs.push(("fidelity".into(), Json::from(f.wire_name())));
        }
        Json::Obj(pairs)
    }

    pub fn from_json(v: &Json) -> Result<CensusRequest, WireError> {
        let bad = |m: String| WireError::new(ErrorCode::BadRequest, m);
        let source = GraphSource::from_json(
            v.get("source")
                .ok_or_else(|| bad("request.source missing".into()))?,
        )?;
        let engine = v.get("engine").and_then(Json::as_str).map(str::to_string);
        let threads = v.get("threads").and_then(Json::as_usize);
        let policy = match v.get("policy").and_then(Json::as_str) {
            Some(s) => Some(Policy::parse(s).map_err(|e| bad(format!("bad policy: {e}")))?),
            None => None,
        };
        // VertexOrdering::parse's message lists the valid orderings —
        // the protocol-decode side of the "unknown value" contract
        let ordering = match v.get("ordering").and_then(Json::as_str) {
            Some(s) => Some(VertexOrdering::parse(s).map_err(bad)?),
            None => None,
        };
        let classes = match v.get("classes").and_then(Json::as_arr) {
            Some(items) => {
                let mut out = Vec::with_capacity(items.len());
                for item in items {
                    let label = item
                        .as_str()
                        .ok_or_else(|| bad(format!("class {item} is not a label string")))?;
                    out.push(
                        TriadType::from_label(label)
                            .ok_or_else(|| bad(format!("unknown triad class {label:?}")))?,
                    );
                }
                Some(out)
            }
            None => None,
        };
        // inverted ranges are rejected here, at decode time; the upper
        // bound is checked against the node count where the graph is
        // resolved (also a bad_request, listing the valid range)
        let shard = match v.get("shard") {
            Some(s) => Some(Shard::from_json(s)?),
            None => None,
        };
        let tenant = v.get("tenant").and_then(Json::as_str).map(str::to_string);
        let priority = match v.get("priority") {
            Some(p) => {
                let p = p
                    .as_u64()
                    .filter(|&p| p <= MAX_PRIORITY as u64)
                    .ok_or_else(|| {
                        bad(format!("priority {p} out of range 0..={MAX_PRIORITY}"))
                    })?;
                Some(p as u8)
            }
            None => None,
        };
        // strict like ordering/policy: unknown or out-of-range values
        // are structured errors naming the valid forms, not defaults
        let fidelity = match v.get("fidelity") {
            Some(f) => {
                let s = f.as_str().ok_or_else(|| {
                    bad(format!(
                        "fidelity {f} invalid (valid: \"exact\" or \"sampled:P\" with 0 < P <= 1)"
                    ))
                })?;
                Some(Fidelity::parse(s).map_err(bad)?)
            }
            None => None,
        };
        Ok(CensusRequest {
            source,
            engine,
            threads,
            policy,
            ordering,
            classes,
            shard,
            tenant,
            priority,
            fidelity,
        })
    }
}

/// Wire form of a [`Policy`]: the CLI syntax `name:chunk`, accepted back
/// by [`Policy::parse`].
pub fn policy_to_wire(p: &Policy) -> String {
    match p {
        Policy::Static { chunk } => format!("static:{chunk}"),
        Policy::Dynamic { chunk } => format!("dynamic:{chunk}"),
        Policy::Guided { min_chunk } => format!("guided:{min_chunk}"),
    }
}

/// Requested census fidelity: the exact table, or unbiased estimation
/// over a p-sampled dyad overlay
/// ([`SampledCensus`](crate::census::SampledCensus)) with per-class
/// confidence intervals riding beside the counts.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Fidelity {
    /// The exact census (the default when the field is absent).
    Exact,
    /// Estimates unbiased from a deterministic dyad sample of rate `p`.
    Sampled {
        /// Dyad sampling rate, `0 < p <= 1`; `1.0` is byte-identical
        /// to exact.
        p: f64,
    },
}

impl Fidelity {
    /// Wire / CLI form: `"exact"` or `"sampled:P"`.
    pub fn wire_name(self) -> String {
        match self {
            Fidelity::Exact => "exact".to_string(),
            Fidelity::Sampled { p } => format!("sampled:{p}"),
        }
    }

    /// The sampling rate, when sampled.
    pub fn sample_p(self) -> Option<f64> {
        match self {
            Fidelity::Exact => None,
            Fidelity::Sampled { p } => Some(p),
        }
    }

    /// Parse the wire / CLI form. Strict: anything but `"exact"` or
    /// `"sampled:P"` with `0 < P <= 1` errors, naming the valid forms.
    pub fn parse(s: &str) -> Result<Fidelity, String> {
        if s == "exact" {
            return Ok(Fidelity::Exact);
        }
        if let Some(num) = s.strip_prefix("sampled:") {
            if let Ok(p) = num.parse::<f64>() {
                if p > 0.0 && p <= 1.0 {
                    return Ok(Fidelity::Sampled { p });
                }
            }
        }
        Err(format!(
            "fidelity {s:?} invalid (valid: \"exact\" or \"sampled:P\" with 0 < P <= 1)"
        ))
    }
}

impl fmt::Display for Fidelity {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.wire_name())
    }
}

// ---------------------------------------------------------------------------
// Responses
// ---------------------------------------------------------------------------

/// Where a served census came from.
#[derive(Debug, Clone, PartialEq)]
pub struct Provenance {
    /// [`GraphSource::describe`] of the request's source.
    pub source: String,
    /// Engine that computed the census (`dense` for the AOT backend).
    pub engine: String,
    /// `sparse` or `dense:SIZE` (artifact size routed to).
    pub route: String,
    /// Vertex ordering the sparse path ran under (`natural` or
    /// `degree`; dense routes are always `natural`).
    pub ordering: String,
    /// Fidelity actually applied ([`Fidelity::wire_name`]: `exact` or
    /// `sampled:P`). Old peers never send it; decode defaults `exact`.
    pub fidelity: String,
    pub nodes: u64,
    pub arcs: u64,
    /// Hub-bitmap rows (`k`) the degree-ordered hybrid kernel ran
    /// with; `None` off the degree-ordered sparse path. Old peers
    /// never send it; decode defaults `None`.
    pub hub_k: Option<u64>,
    /// Adaptive-`k` retunes the cached split serving this request has
    /// absorbed so far (same presence rules as `hub_k`).
    pub hub_retunes: Option<u64>,
}

/// Flattened per-job scheduler telemetry (from [`ThreadPoolStats`]).
#[derive(Debug, Clone, PartialEq)]
pub struct SchedStats {
    /// Virtual seats the job ran with.
    pub seats: usize,
    /// Chunks claimed across all seats.
    pub chunks: u64,
    /// Iteration slots covered across all seats.
    pub items: u64,
    /// Busy seconds summed over seats.
    pub busy_seconds: f64,
    /// Wall-clock seconds of the parallel region.
    pub wall_seconds: f64,
    /// Max/mean busy ratio (1.0 = perfectly balanced).
    pub imbalance: f64,
    /// Sockets the executor scheduled the job across (1 when the
    /// topology is single-socket or unknown).
    pub sockets: usize,
    /// Dynamic-policy chunk steals that stayed on the thief's socket.
    pub local_steals: u64,
    /// Steals that crossed a socket boundary.
    pub remote_steals: u64,
    /// Max/mean busy ratio across *sockets* (1.0 = balanced).
    pub socket_imbalance: f64,
    /// Pool workers pinned to their socket's CPUs when the job ran
    /// (0 = unpinned: `--pin none`, a fallback platform, or a serial
    /// engine). Old peers never send it; decode defaults 0.
    pub pinned_workers: usize,
}

impl SchedStats {
    pub fn from_pool(stats: &ThreadPoolStats) -> SchedStats {
        SchedStats {
            seats: stats.items.len(),
            chunks: stats.chunks.iter().map(|&c| c as u64).sum(),
            items: stats.items.iter().map(|&i| i as u64).sum(),
            busy_seconds: stats.busy.iter().sum(),
            wall_seconds: stats.wall,
            imbalance: stats.imbalance(),
            sockets: stats.socket_busy().len(),
            local_steals: stats.local_steals,
            remote_steals: stats.remote_steals,
            socket_imbalance: stats.socket_imbalance(),
            pinned_workers: stats.pinned_workers,
        }
    }

    fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("seats".into(), Json::from(self.seats)),
            ("chunks".into(), Json::from(self.chunks)),
            ("items".into(), Json::from(self.items)),
            ("busy_seconds".into(), Json::Num(self.busy_seconds)),
            ("wall_seconds".into(), Json::Num(self.wall_seconds)),
            ("imbalance".into(), Json::Num(self.imbalance)),
            ("sockets".into(), Json::from(self.sockets)),
            ("local_steals".into(), Json::from(self.local_steals)),
            ("remote_steals".into(), Json::from(self.remote_steals)),
            ("socket_imbalance".into(), Json::Num(self.socket_imbalance)),
            ("pinned_workers".into(), Json::from(self.pinned_workers)),
        ])
    }

    fn from_json(v: &Json) -> SchedStats {
        SchedStats {
            seats: v.get("seats").and_then(Json::as_usize).unwrap_or(0),
            chunks: v.get("chunks").and_then(Json::as_u64).unwrap_or(0),
            items: v.get("items").and_then(Json::as_u64).unwrap_or(0),
            busy_seconds: v.get("busy_seconds").and_then(Json::as_f64).unwrap_or(0.0),
            wall_seconds: v.get("wall_seconds").and_then(Json::as_f64).unwrap_or(0.0),
            imbalance: v.get("imbalance").and_then(Json::as_f64).unwrap_or(0.0),
            sockets: v.get("sockets").and_then(Json::as_usize).unwrap_or(1),
            local_steals: v.get("local_steals").and_then(Json::as_u64).unwrap_or(0),
            remote_steals: v.get("remote_steals").and_then(Json::as_u64).unwrap_or(0),
            socket_imbalance: v
                .get("socket_imbalance")
                .and_then(Json::as_f64)
                .unwrap_or(1.0),
            pinned_workers: v
                .get("pinned_workers")
                .and_then(Json::as_usize)
                .unwrap_or_default(),
        }
    }
}

/// A served census with provenance, timing and scheduler telemetry.
///
/// When `classes` is set, only those classes were requested: the wire
/// carries just the selected counts and every other slot of `census` is
/// zero on the receiving side.
#[derive(Debug, Clone, PartialEq)]
pub struct CensusResponse {
    pub protocol_version: u64,
    /// Coordinator-assigned job id.
    pub job: u64,
    pub census: Census,
    pub classes: Option<Vec<TriadType>>,
    pub provenance: Provenance,
    /// `None` for dense routes (no chunk scheduler ran).
    pub stats: Option<SchedStats>,
    /// Per-class interval report; present iff the applied fidelity was
    /// sampled.
    pub sampling: Option<SampleReport>,
    /// End-to-end seconds (load + route + census).
    pub seconds: f64,
}

impl CensusResponse {
    /// The counts this response carries, in census-index order —
    /// the requested subset, or all 16 classes.
    pub fn selected_counts(&self) -> Vec<(TriadType, u64)> {
        match &self.classes {
            Some(classes) => classes.iter().map(|&t| (t, self.census[t])).collect(),
            None => TriadType::ALL.iter().map(|&t| (t, self.census[t])).collect(),
        }
    }

    pub fn to_json(&self) -> Json {
        let counts = Json::Obj(
            self.selected_counts()
                .into_iter()
                .map(|(t, c)| (t.label().to_string(), Json::from(c)))
                .collect(),
        );
        let mut pairs = vec![
            ("v".into(), Json::from(self.protocol_version)),
            ("job".into(), Json::from(self.job)),
            ("counts".into(), counts),
        ];
        if let Some(classes) = &self.classes {
            pairs.push((
                "classes".into(),
                Json::Arr(classes.iter().map(|t| Json::from(t.label())).collect()),
            ));
        }
        let mut prov = vec![
            ("source".into(), Json::from(self.provenance.source.clone())),
            ("engine".into(), Json::from(self.provenance.engine.clone())),
            ("route".into(), Json::from(self.provenance.route.clone())),
            (
                "ordering".into(),
                Json::from(self.provenance.ordering.clone()),
            ),
            (
                "fidelity".into(),
                Json::from(self.provenance.fidelity.clone()),
            ),
            ("nodes".into(), Json::from(self.provenance.nodes)),
            ("arcs".into(), Json::from(self.provenance.arcs)),
        ];
        if let Some(k) = self.provenance.hub_k {
            prov.push(("hub_k".into(), Json::from(k)));
        }
        if let Some(r) = self.provenance.hub_retunes {
            prov.push(("hub_retunes".into(), Json::from(r)));
        }
        pairs.push(("provenance".into(), Json::Obj(prov)));
        if let Some(stats) = &self.stats {
            pairs.push(("stats".into(), stats.to_json()));
        }
        if let Some(sampling) = &self.sampling {
            pairs.push(("sampling".into(), sampling.to_json()));
        }
        pairs.push(("seconds".into(), Json::Num(self.seconds)));
        Json::Obj(pairs)
    }

    pub fn from_json(v: &Json) -> Result<CensusResponse, WireError> {
        let bad = |m: String| WireError::new(ErrorCode::BadFrame, m);
        let counts_json = v
            .get("counts")
            .ok_or_else(|| bad("response.counts missing".into()))?;
        let pairs = match counts_json {
            Json::Obj(pairs) => pairs,
            _ => return Err(bad("response.counts is not an object".into())),
        };
        let mut census = Census::zero();
        for (label, count) in pairs {
            let t = TriadType::from_label(label)
                .ok_or_else(|| bad(format!("unknown triad class {label:?}")))?;
            let c = count
                .as_u64()
                .ok_or_else(|| bad(format!("count for {label} is not a u64")))?;
            census.add_count(t, c);
        }
        let classes = match v.get("classes").and_then(Json::as_arr) {
            Some(items) => Some(
                items
                    .iter()
                    .map(|item| {
                        item.as_str()
                            .and_then(TriadType::from_label)
                            .ok_or_else(|| bad(format!("bad class entry {item}")))
                    })
                    .collect::<Result<Vec<_>, _>>()?,
            ),
            None => None,
        };
        let prov = v
            .get("provenance")
            .ok_or_else(|| bad("response.provenance missing".into()))?;
        let getstr = |obj: &Json, key: &str| {
            obj.get(key)
                .and_then(Json::as_str)
                .unwrap_or_default()
                .to_string()
        };
        Ok(CensusResponse {
            protocol_version: v.get("v").and_then(Json::as_u64).unwrap_or(0),
            job: v.get("job").and_then(Json::as_u64).unwrap_or(0),
            census,
            classes,
            provenance: Provenance {
                source: getstr(prov, "source"),
                engine: getstr(prov, "engine"),
                route: getstr(prov, "route"),
                ordering: match getstr(prov, "ordering") {
                    s if s.is_empty() => VertexOrdering::Natural.name().to_string(),
                    s => s,
                },
                fidelity: match getstr(prov, "fidelity") {
                    s if s.is_empty() => Fidelity::Exact.wire_name(),
                    s => s,
                },
                nodes: prov.get("nodes").and_then(Json::as_u64).unwrap_or(0),
                arcs: prov.get("arcs").and_then(Json::as_u64).unwrap_or(0),
                hub_k: prov.get("hub_k").and_then(Json::as_u64),
                hub_retunes: prov.get("hub_retunes").and_then(Json::as_u64),
            },
            stats: v.get("stats").map(SchedStats::from_json),
            sampling: match v.get("sampling") {
                Some(s) => Some(SampleReport::from_json(s)?),
                None => None,
            },
            seconds: v.get("seconds").and_then(Json::as_f64).unwrap_or(0.0),
        })
    }
}

// ---------------------------------------------------------------------------
// Job reports
// ---------------------------------------------------------------------------

/// Lifecycle states a job can be observed in.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobStateKind {
    Queued,
    Running,
    Done,
    Failed,
    Cancelled,
}

impl JobStateKind {
    pub fn as_str(self) -> &'static str {
        match self {
            JobStateKind::Queued => "queued",
            JobStateKind::Running => "running",
            JobStateKind::Done => "done",
            JobStateKind::Failed => "failed",
            JobStateKind::Cancelled => "cancelled",
        }
    }

    pub fn parse(s: &str) -> Option<JobStateKind> {
        match s {
            "queued" => Some(JobStateKind::Queued),
            "running" => Some(JobStateKind::Running),
            "done" => Some(JobStateKind::Done),
            "failed" => Some(JobStateKind::Failed),
            "cancelled" => Some(JobStateKind::Cancelled),
            _ => None,
        }
    }

    /// Whether this state is terminal (the job will never change again).
    pub fn is_terminal(self) -> bool {
        matches!(
            self,
            JobStateKind::Done | JobStateKind::Failed | JobStateKind::Cancelled
        )
    }
}

/// Point-in-time view of one job, as served by `poll` / `wait`.
#[derive(Debug, Clone, PartialEq)]
pub struct JobReport {
    pub job: u64,
    pub state: JobStateKind,
    /// Present iff `state == Done`.
    pub response: Option<CensusResponse>,
    /// Present iff `state == Failed`.
    pub error: Option<WireError>,
}

impl JobReport {
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("job".into(), Json::from(self.job)),
            ("state".into(), Json::from(self.state.as_str())),
        ];
        if let Some(r) = &self.response {
            pairs.push(("response".into(), r.to_json()));
        }
        if let Some(e) = &self.error {
            pairs.push(("error".into(), e.to_json()));
        }
        Json::Obj(pairs)
    }

    pub fn from_json(v: &Json) -> Result<JobReport, WireError> {
        let bad = |m: String| WireError::new(ErrorCode::BadFrame, m);
        let state = v
            .get("state")
            .and_then(Json::as_str)
            .and_then(JobStateKind::parse)
            .ok_or_else(|| bad("job report state missing or unknown".into()))?;
        Ok(JobReport {
            job: v
                .get("job")
                .and_then(Json::as_u64)
                .ok_or_else(|| bad("job report id missing".into()))?,
            state,
            response: match v.get("response") {
                Some(r) => Some(CensusResponse::from_json(r)?),
                None => None,
            },
            error: v.get("error").map(WireError::from_json),
        })
    }
}

// ---------------------------------------------------------------------------
// Streaming census sessions
// ---------------------------------------------------------------------------

/// Encode a batch of edge ops as `[["+", u, v], ["-", u, v], …]`.
pub fn ops_to_json(ops: &[EdgeOp]) -> Json {
    Json::Arr(
        ops.iter()
            .map(|op| {
                let (u, v) = op.endpoints();
                Json::Arr(vec![
                    Json::from(if op.is_insert() { "+" } else { "-" }),
                    Json::from(u as u64),
                    Json::from(v as u64),
                ])
            })
            .collect(),
    )
}

/// Decode a `stream_apply` op array. Node ids must fit `u32`; range
/// checking against the session's node count happens server-side, where
/// out-of-range ops are counted as rejected rather than failing the
/// whole batch.
pub fn ops_from_json(v: &Json) -> Result<Vec<EdgeOp>, WireError> {
    let bad = |m: String| WireError::new(ErrorCode::BadRequest, m);
    let items = v
        .as_arr()
        .ok_or_else(|| bad("ops is not an array".into()))?;
    let mut out = Vec::with_capacity(items.len());
    for item in items {
        let parts = item.as_arr().filter(|p| p.len() == 3);
        let parsed = parts.and_then(|p| {
            let sign = p[0].as_str()?;
            let u = p[1].as_u64().and_then(|x| u32::try_from(x).ok())?;
            let v = p[2].as_u64().and_then(|x| u32::try_from(x).ok())?;
            match sign {
                "+" => Some(EdgeOp::Insert(u, v)),
                "-" => Some(EdgeOp::Delete(u, v)),
                _ => None,
            }
        });
        match parsed {
            Some(op) => out.push(op),
            None => return Err(bad(format!("op {item} is not [\"+\"|\"-\", u, v]"))),
        }
    }
    Ok(out)
}

/// Encode a full 16-class census as the standard label → count object.
fn census_to_json(census: &Census) -> Json {
    Json::Obj(
        TriadType::ALL
            .iter()
            .map(|&t| (t.label().to_string(), Json::from(census[t])))
            .collect(),
    )
}

/// Decode a label → count object (missing labels read as zero).
fn census_from_json(v: &Json) -> Result<Census, WireError> {
    let bad = |m: String| WireError::new(ErrorCode::BadFrame, m);
    let pairs = match v {
        Json::Obj(pairs) => pairs,
        _ => return Err(bad("counts is not an object".into())),
    };
    let mut census = Census::zero();
    for (label, count) in pairs {
        let t = TriadType::from_label(label)
            .ok_or_else(|| bad(format!("unknown triad class {label:?}")))?;
        let c = count
            .as_u64()
            .ok_or_else(|| bad(format!("count for {label} is not a u64")))?;
        census.add_count(t, c);
    }
    Ok(census)
}

/// Per-class interval report attached to sampled-fidelity responses.
///
/// One row per Holland–Leinhardt class: the unbiased point estimate and
/// the `[lo, hi]` confidence interval at the server's configured `z`.
/// Counts in the sibling census table are these estimates rounded to
/// integers; the report carries the unrounded values so clients can
/// reason about uncertainty without re-deriving the variance model.
#[derive(Debug, Clone, PartialEq)]
pub struct SampleReport {
    /// Dyad keep probability actually applied.
    pub p: f64,
    /// Normal quantile the intervals were derived at.
    pub z: f64,
    /// Unbiased per-class point estimates, [`TriadType::ALL`] order.
    pub estimate: [f64; 16],
    /// Interval lower bounds, same order.
    pub lo: [f64; 16],
    /// Interval upper bounds, same order.
    pub hi: [f64; 16],
}

impl SampleReport {
    pub fn from_estimate(est: &SampledEstimate) -> SampleReport {
        let mut report = SampleReport {
            p: est.p,
            z: est.z,
            estimate: [0.0; 16],
            lo: [0.0; 16],
            hi: [0.0; 16],
        };
        for (i, &t) in TriadType::ALL.iter().enumerate() {
            let c = est.class(t);
            report.estimate[i] = c.estimate;
            report.lo[i] = c.lo;
            report.hi[i] = c.hi;
        }
        report
    }

    pub fn to_json(&self) -> Json {
        let classes = TriadType::ALL
            .iter()
            .enumerate()
            .map(|(i, &t)| {
                let row = vec![
                    Json::Num(self.estimate[i]),
                    Json::Num(self.lo[i]),
                    Json::Num(self.hi[i]),
                ];
                (t.label().to_string(), Json::Arr(row))
            })
            .collect();
        Json::Obj(vec![
            ("p".into(), Json::Num(self.p)),
            ("z".into(), Json::Num(self.z)),
            ("classes".into(), Json::Obj(classes)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<SampleReport, WireError> {
        let bad = |m: String| WireError::new(ErrorCode::BadFrame, m);
        let p = v
            .get("p")
            .and_then(Json::as_f64)
            .ok_or_else(|| bad("sampling report carries no p".into()))?;
        let z = v.get("z").and_then(Json::as_f64).unwrap_or(0.0);
        let mut report = SampleReport {
            p,
            z,
            estimate: [0.0; 16],
            lo: [0.0; 16],
            hi: [0.0; 16],
        };
        let classes = v
            .get("classes")
            .ok_or_else(|| bad("sampling report carries no classes".into()))?;
        for (i, &t) in TriadType::ALL.iter().enumerate() {
            let row = classes
                .get(t.label())
                .and_then(Json::as_arr)
                .filter(|r| r.len() == 3)
                .ok_or_else(|| bad(format!("sampling row for {} malformed", t.label())))?;
            let nums: Vec<f64> = row.iter().filter_map(Json::as_f64).collect();
            if nums.len() != 3 {
                return Err(bad(format!("sampling row for {} non-numeric", t.label())));
            }
            report.estimate[i] = nums[0];
            report.lo[i] = nums[1];
            report.hi[i] = nums[2];
        }
        Ok(report)
    }
}

/// `stream_open` result: the session id plus the opened graph's shape.
#[derive(Debug, Clone, PartialEq)]
pub struct StreamOpened {
    pub stream: u64,
    pub nodes: u64,
    pub arcs: u64,
    /// Engine that computed the seed census.
    pub engine: String,
    /// Fidelity the session runs at (`exact` or `sampled:P`); old
    /// peers never send it and decode defaults to `exact`.
    pub fidelity: String,
}

impl StreamOpened {
    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("stream".into(), Json::from(self.stream)),
            ("nodes".into(), Json::from(self.nodes)),
            ("arcs".into(), Json::from(self.arcs)),
            ("engine".into(), Json::from(self.engine.clone())),
            ("fidelity".into(), Json::from(self.fidelity.clone())),
        ])
    }

    pub fn from_json(v: &Json) -> Result<StreamOpened, WireError> {
        let fidelity = match v.get("fidelity").and_then(Json::as_str) {
            Some(s) if !s.is_empty() => s.to_string(),
            _ => Fidelity::Exact.wire_name(),
        };
        Ok(StreamOpened {
            stream: require_u64(v, "stream")?,
            nodes: require_u64(v, "nodes")?,
            arcs: require_u64(v, "arcs")?,
            engine: v
                .get("engine")
                .and_then(Json::as_str)
                .unwrap_or_default()
                .to_string(),
            fidelity,
        })
    }
}

/// `stream_apply` result: what the batch did to the session.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StreamApplyReport {
    pub stream: u64,
    /// Ops that changed the graph.
    pub applied: u64,
    /// Duplicate inserts / deletes of absent arcs.
    pub no_ops: u64,
    /// Self-loop or out-of-range ops.
    pub rejected: u64,
    /// Triads individually reclassified by the delta scans.
    pub reclassified: u64,
    /// Effective arc count after the batch.
    pub arcs: u64,
}

impl StreamApplyReport {
    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("stream".into(), Json::from(self.stream)),
            ("applied".into(), Json::from(self.applied)),
            ("no_ops".into(), Json::from(self.no_ops)),
            ("rejected".into(), Json::from(self.rejected)),
            ("reclassified".into(), Json::from(self.reclassified)),
            ("arcs".into(), Json::from(self.arcs)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<StreamApplyReport, WireError> {
        Ok(StreamApplyReport {
            stream: require_u64(v, "stream")?,
            applied: require_u64(v, "applied")?,
            no_ops: require_u64(v, "no_ops")?,
            rejected: require_u64(v, "rejected")?,
            reclassified: require_u64(v, "reclassified")?,
            arcs: require_u64(v, "arcs")?,
        })
    }
}

/// `stream_query` result: the live census plus session counters.
#[derive(Debug, Clone, PartialEq)]
pub struct StreamSnapshot {
    pub stream: u64,
    pub census: Census,
    pub nodes: u64,
    pub arcs: u64,
    /// Dyads currently diverging from the session's base CSR.
    pub edits: u64,
    /// Lifetime applied-op count.
    pub applied: u64,
    /// Lifetime reclassified-triad count.
    pub reclassified: u64,
    /// Lifetime compaction count.
    pub compactions: u64,
    /// Interval report; present iff the session runs sampled fidelity
    /// (the census table then holds the rounded estimates).
    pub sampling: Option<SampleReport>,
}

impl StreamSnapshot {
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("stream".into(), Json::from(self.stream)),
            ("counts".into(), census_to_json(&self.census)),
            ("nodes".into(), Json::from(self.nodes)),
            ("arcs".into(), Json::from(self.arcs)),
            ("edits".into(), Json::from(self.edits)),
            ("applied".into(), Json::from(self.applied)),
            ("reclassified".into(), Json::from(self.reclassified)),
            ("compactions".into(), Json::from(self.compactions)),
        ];
        if let Some(sampling) = &self.sampling {
            pairs.push(("sampling".into(), sampling.to_json()));
        }
        Json::Obj(pairs)
    }

    pub fn from_json(v: &Json) -> Result<StreamSnapshot, WireError> {
        let counts = v.get("counts").ok_or_else(|| {
            WireError::new(ErrorCode::BadFrame, "stream snapshot carries no counts")
        })?;
        Ok(StreamSnapshot {
            stream: require_u64(v, "stream")?,
            census: census_from_json(counts)?,
            nodes: require_u64(v, "nodes")?,
            arcs: require_u64(v, "arcs")?,
            edits: require_u64(v, "edits")?,
            applied: require_u64(v, "applied")?,
            reclassified: require_u64(v, "reclassified")?,
            compactions: require_u64(v, "compactions")?,
            sampling: match v.get("sampling") {
                Some(s) => Some(SampleReport::from_json(s)?),
                None => None,
            },
        })
    }
}

/// Required-field u64 accessor shared by the stream payload decoders.
fn require_u64(v: &Json, key: &str) -> Result<u64, WireError> {
    v.get(key)
        .and_then(Json::as_u64)
        .ok_or_else(|| WireError::new(ErrorCode::BadFrame, format!("field {key:?} missing")))
}

// ---------------------------------------------------------------------------
// Frames
// ---------------------------------------------------------------------------

/// Protocol verbs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verb {
    /// Submit a census request; result is a queued [`JobReport`].
    Submit,
    /// Non-blocking job status.
    Poll,
    /// Block until the job is terminal; result is its final report.
    Wait,
    /// Request job cancellation.
    Cancel,
    /// Server health/identity summary.
    Status,
    /// Metrics text exposition.
    Metrics,
    /// Stop accepting connections and exit the serve loop.
    Shutdown,
    /// Open a streaming census session over a graph source; result is a
    /// [`StreamOpened`].
    StreamOpen,
    /// Apply a batch of edge mutations to a session; result is a
    /// [`StreamApplyReport`].
    StreamApply,
    /// Read a session's live census; result is a [`StreamSnapshot`].
    StreamQuery,
    /// Rebuild the session's base CSR from its overlay.
    StreamCompact,
    /// Close a session and free its state.
    StreamClose,
}

impl Verb {
    pub fn as_str(self) -> &'static str {
        match self {
            Verb::Submit => "submit",
            Verb::Poll => "poll",
            Verb::Wait => "wait",
            Verb::Cancel => "cancel",
            Verb::Status => "status",
            Verb::Metrics => "metrics",
            Verb::Shutdown => "shutdown",
            Verb::StreamOpen => "stream_open",
            Verb::StreamApply => "stream_apply",
            Verb::StreamQuery => "stream_query",
            Verb::StreamCompact => "stream_compact",
            Verb::StreamClose => "stream_close",
        }
    }

    pub fn parse(s: &str) -> Option<Verb> {
        match s {
            "submit" => Some(Verb::Submit),
            "poll" => Some(Verb::Poll),
            "wait" => Some(Verb::Wait),
            "cancel" => Some(Verb::Cancel),
            "status" => Some(Verb::Status),
            "metrics" => Some(Verb::Metrics),
            "shutdown" => Some(Verb::Shutdown),
            "stream_open" => Some(Verb::StreamOpen),
            "stream_apply" => Some(Verb::StreamApply),
            "stream_query" => Some(Verb::StreamQuery),
            "stream_compact" => Some(Verb::StreamCompact),
            "stream_close" => Some(Verb::StreamClose),
            _ => None,
        }
    }
}

/// One client → server frame.
#[derive(Debug, Clone, PartialEq)]
pub struct RequestFrame {
    /// Protocol version (always [`PROTOCOL_VERSION`] when built here).
    pub v: u64,
    /// Client correlation id, echoed in the response frame.
    pub id: u64,
    pub verb: Verb,
    /// Payload for [`Verb::Submit`] / [`Verb::StreamOpen`].
    pub request: Option<CensusRequest>,
    /// Target for [`Verb::Poll`] / [`Verb::Wait`] / [`Verb::Cancel`].
    pub job: Option<u64>,
    /// Target session for the `stream_*` verbs (except `stream_open`).
    pub stream: Option<u64>,
    /// Payload for [`Verb::StreamApply`].
    pub ops: Option<Vec<EdgeOp>>,
}

impl RequestFrame {
    pub fn new(id: u64, verb: Verb) -> RequestFrame {
        RequestFrame {
            v: PROTOCOL_VERSION,
            id,
            verb,
            request: None,
            job: None,
            stream: None,
            ops: None,
        }
    }

    /// Serialize to one line (no trailing newline).
    pub fn encode(&self) -> String {
        let mut pairs = vec![
            ("v".into(), Json::from(self.v)),
            ("id".into(), Json::from(self.id)),
            ("verb".into(), Json::from(self.verb.as_str())),
        ];
        if let Some(r) = &self.request {
            pairs.push(("request".into(), r.to_json()));
        }
        if let Some(j) = self.job {
            pairs.push(("job".into(), Json::from(j)));
        }
        if let Some(s) = self.stream {
            pairs.push(("stream".into(), Json::from(s)));
        }
        if let Some(ops) = &self.ops {
            pairs.push(("ops".into(), ops_to_json(ops)));
        }
        Json::Obj(pairs).to_string()
    }

    /// Parse and validate one frame line. Version and verb problems come
    /// back as structured errors so the server can answer them.
    pub fn decode(line: &str) -> Result<RequestFrame, WireError> {
        let v = Json::parse(line)
            .map_err(|e| WireError::new(ErrorCode::BadFrame, format!("unparseable frame: {e}")))?;
        let version = v.get("v").and_then(Json::as_u64).ok_or_else(|| {
            WireError::new(ErrorCode::BadVersion, "frame carries no \"v\" version field")
        })?;
        if version != PROTOCOL_VERSION {
            return Err(WireError::new(
                ErrorCode::BadVersion,
                format!("protocol version {version} unsupported (speaking {PROTOCOL_VERSION})"),
            ));
        }
        let verb_str = v
            .get("verb")
            .and_then(Json::as_str)
            .ok_or_else(|| WireError::new(ErrorCode::BadFrame, "frame carries no verb"))?;
        let verb = Verb::parse(verb_str)
            .ok_or_else(|| WireError::new(ErrorCode::UnknownVerb, format!("verb {verb_str:?}")))?;
        let request = match v.get("request") {
            Some(r) => Some(CensusRequest::from_json(r)?),
            None => None,
        };
        let ops = match v.get("ops") {
            Some(o) => Some(ops_from_json(o)?),
            None => None,
        };
        Ok(RequestFrame {
            v: version,
            id: v.get("id").and_then(Json::as_u64).unwrap_or(0),
            verb,
            request,
            job: v.get("job").and_then(Json::as_u64),
            stream: v.get("stream").and_then(Json::as_u64),
            ops,
        })
    }
}

/// One server → client frame: `Ok` payload or structured error, tagged
/// with the client's correlation id.
#[derive(Debug, Clone, PartialEq)]
pub struct ResponseFrame {
    pub v: u64,
    pub id: u64,
    pub result: Result<Json, WireError>,
}

impl ResponseFrame {
    pub fn ok(id: u64, result: Json) -> ResponseFrame {
        ResponseFrame {
            v: PROTOCOL_VERSION,
            id,
            result: Ok(result),
        }
    }

    pub fn err(id: u64, error: WireError) -> ResponseFrame {
        ResponseFrame {
            v: PROTOCOL_VERSION,
            id,
            result: Err(error),
        }
    }

    /// Serialize to one line (no trailing newline).
    pub fn encode(&self) -> String {
        let mut pairs = vec![
            ("v".into(), Json::from(self.v)),
            ("id".into(), Json::from(self.id)),
        ];
        match &self.result {
            Ok(result) => {
                pairs.push(("ok".into(), Json::Bool(true)));
                pairs.push(("result".into(), result.clone()));
            }
            Err(e) => {
                pairs.push(("ok".into(), Json::Bool(false)));
                pairs.push(("error".into(), e.to_json()));
            }
        }
        Json::Obj(pairs).to_string()
    }

    pub fn decode(line: &str) -> Result<ResponseFrame, WireError> {
        let v = Json::parse(line)
            .map_err(|e| WireError::new(ErrorCode::BadFrame, format!("unparseable frame: {e}")))?;
        let version = v
            .get("v")
            .and_then(Json::as_u64)
            .ok_or_else(|| WireError::new(ErrorCode::BadVersion, "response carries no version"))?;
        if version != PROTOCOL_VERSION {
            return Err(WireError::new(
                ErrorCode::BadVersion,
                format!("protocol version {version} unsupported (speaking {PROTOCOL_VERSION})"),
            ));
        }
        let id = v.get("id").and_then(Json::as_u64).unwrap_or(0);
        let ok = v.get("ok").and_then(Json::as_bool).unwrap_or(false);
        if ok {
            let result = v
                .get("result")
                .cloned()
                .ok_or_else(|| WireError::new(ErrorCode::BadFrame, "ok frame without result"))?;
            Ok(ResponseFrame {
                v: version,
                id,
                result: Ok(result),
            })
        } else {
            let error = v
                .get("error")
                .map(WireError::from_json)
                .unwrap_or_else(|| {
                    WireError::new(ErrorCode::Internal, "error frame without error body")
                });
            Ok(ResponseFrame {
                v: version,
                id,
                result: Err(error),
            })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_round_trips() {
        let cases = [
            r#"null"#,
            r#"true"#,
            r#"[1,2,3]"#,
            r#"{"a":1,"b":[{"c":"d"}],"e":-2.5}"#,
            r#""he said \"hi\"\n""#,
        ];
        for case in cases {
            let v = Json::parse(case).unwrap();
            let reparsed = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, reparsed, "{case}");
        }
    }

    #[test]
    fn json_big_integers_stay_exact() {
        let big = u64::MAX;
        let v = Json::parse(&format!("{{\"c\":{big}}}")).unwrap();
        assert_eq!(v.get("c").and_then(Json::as_u64), Some(big));
        assert_eq!(v.to_string(), format!("{{\"c\":{big}}}"));
    }

    #[test]
    fn json_rejects_garbage() {
        for bad in ["", "{", "[1,]", "{\"a\"}", "tru", "1 2", "\"\\q\""] {
            assert!(Json::parse(bad).is_err(), "{bad:?} should not parse");
        }
    }

    #[test]
    fn json_string_escapes() {
        let v = Json::parse(r#""tab\t nl\n uni\u0041 pair\ud83d\ude00""#).unwrap();
        assert_eq!(v.as_str(), Some("tab\t nl\n uniA pair😀"));
    }

    #[test]
    fn request_round_trips_all_sources() {
        let reqs = [
            CensusRequest::path("/data/g.csr"),
            CensusRequest::inline(4, vec![(0, 1), (1, 2), (3, 0)])
                .engine("merged")
                .classes(vec![TriadType::T030T, TriadType::T030C]),
            CensusRequest::generator("patents", 5_000)
                .seed(7)
                .engine("parallel")
                .threads(8)
                .policy(Policy::Dynamic { chunk: 128 })
                .ordering(VertexOrdering::Degree),
            CensusRequest::path("/data/g.csr").ordering(VertexOrdering::Natural),
            CensusRequest::path("/data/g.csr")
                .engine("parallel")
                .shard(1_000, 2_000),
            CensusRequest::generator("web", 64).shard(0, 0),
            CensusRequest::generator("patents", 256)
                .tenant("acme")
                .priority(7),
            CensusRequest::path("/data/g.csr").priority(0),
        ];
        for req in reqs {
            let line = req.to_json().to_string();
            let back = CensusRequest::from_json(&Json::parse(&line).unwrap()).unwrap();
            assert_eq!(back, req, "{line}");
        }
    }

    #[test]
    fn unknown_ordering_is_rejected_with_the_valid_list() {
        let json = Json::parse(
            r#"{"source":{"kind":"generator","name":"patents","nodes":10},"ordering":"random"}"#,
        )
        .unwrap();
        let err = CensusRequest::from_json(&json).unwrap_err();
        assert_eq!(err.code, ErrorCode::BadRequest);
        assert!(err.message.contains("unknown ordering"), "{err}");
        assert!(
            err.message.contains("natural") && err.message.contains("degree"),
            "decode error must list the valid orderings: {err}"
        );
    }

    #[test]
    fn inverted_or_malformed_shards_are_rejected_at_decode() {
        let inverted = Json::parse(
            r#"{"source":{"kind":"path","path":"g.csr"},"shard":{"lo":10,"hi":3}}"#,
        )
        .unwrap();
        let err = CensusRequest::from_json(&inverted).unwrap_err();
        assert_eq!(err.code, ErrorCode::BadRequest);
        assert!(
            err.message.contains("lo 10 > hi 3") && err.message.contains("node count"),
            "decode error must state the valid range: {err}"
        );
        for bad in [
            r#"{"source":{"kind":"path","path":"g.csr"},"shard":{"hi":3}}"#,
            r#"{"source":{"kind":"path","path":"g.csr"},"shard":{"lo":-1,"hi":3}}"#,
            r#"{"source":{"kind":"path","path":"g.csr"},"shard":{"lo":"a","hi":3}}"#,
        ] {
            let err = CensusRequest::from_json(&Json::parse(bad).unwrap()).unwrap_err();
            assert_eq!(err.code, ErrorCode::BadRequest, "{bad}");
        }
        // equal bounds (an empty shard) are legal
        let empty = Json::parse(
            r#"{"source":{"kind":"path","path":"g.csr"},"shard":{"lo":5,"hi":5}}"#,
        )
        .unwrap();
        let req = CensusRequest::from_json(&empty).unwrap();
        assert_eq!(req.shard, Some(Shard::new(5, 5)));
        assert!(req.shard.unwrap().is_empty());
        assert_eq!(Shard::new(2, 7).len(), 5);
        assert_eq!(Shard::new(2, 7).to_string(), "2..7");
    }

    #[test]
    fn inline_arcs_are_bounds_checked() {
        let json = Json::parse(
            r#"{"source":{"kind":"inline","nodes":3,"arcs":[[0,1],[5,1]]}}"#,
        )
        .unwrap();
        let err = CensusRequest::from_json(&json).unwrap_err();
        assert_eq!(err.code, ErrorCode::BadRequest);
    }

    #[test]
    fn response_round_trips_full_and_subset() {
        let mut census = Census::zero();
        census.add_count(TriadType::T030T, 41);
        census.add_count(TriadType::T003, 1_000_000);
        let full = CensusResponse {
            protocol_version: PROTOCOL_VERSION,
            job: 9,
            census,
            classes: None,
            provenance: Provenance {
                source: "generator:patents,n=100".to_string(),
                engine: "parallel".to_string(),
                route: "sparse".to_string(),
                ordering: "degree".to_string(),
                fidelity: "exact".to_string(),
                nodes: 100,
                arcs: 440,
                hub_k: Some(12),
                hub_retunes: Some(1),
            },
            stats: Some(SchedStats {
                seats: 4,
                chunks: 12,
                items: 900,
                busy_seconds: 0.01,
                wall_seconds: 0.004,
                imbalance: 1.2,
                sockets: 2,
                local_steals: 5,
                remote_steals: 1,
                socket_imbalance: 1.5,
                pinned_workers: 4,
            }),
            sampling: None,
            seconds: 0.005,
        };
        let back =
            CensusResponse::from_json(&Json::parse(&full.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back, full);

        let subset = CensusResponse {
            classes: Some(vec![TriadType::T030T]),
            ..full.clone()
        };
        let line = subset.to_json().to_string();
        let back = CensusResponse::from_json(&Json::parse(&line).unwrap()).unwrap();
        // only the selected class travels: T003 does not survive the wire
        assert_eq!(back.census[TriadType::T030T], 41);
        assert_eq!(back.census[TriadType::T003], 0);
        assert_eq!(back.classes, Some(vec![TriadType::T030T]));
        assert_eq!(back.selected_counts(), vec![(TriadType::T030T, 41)]);
    }

    #[test]
    fn frames_round_trip() {
        let mut f = RequestFrame::new(3, Verb::Submit);
        f.request = Some(CensusRequest::path("x.csr"));
        let back = RequestFrame::decode(&f.encode()).unwrap();
        assert_eq!(back, f);

        let mut p = RequestFrame::new(4, Verb::Poll);
        p.job = Some(17);
        assert_eq!(RequestFrame::decode(&p.encode()).unwrap(), p);

        let ok = ResponseFrame::ok(3, Json::from("fine"));
        assert_eq!(ResponseFrame::decode(&ok.encode()).unwrap(), ok);
        let err = ResponseFrame::err(4, WireError::new(ErrorCode::UnknownJob, "no job 17"));
        let back = ResponseFrame::decode(&err.encode()).unwrap();
        assert_eq!(back.result.unwrap_err().code, ErrorCode::UnknownJob);
    }

    #[test]
    fn version_mismatch_is_a_structured_error() {
        let err = RequestFrame::decode(r#"{"v":99,"id":1,"verb":"status"}"#).unwrap_err();
        assert_eq!(err.code, ErrorCode::BadVersion);
        let err = RequestFrame::decode(r#"{"id":1,"verb":"status"}"#).unwrap_err();
        assert_eq!(err.code, ErrorCode::BadVersion);
        let err = RequestFrame::decode(r#"{"v":1,"id":1,"verb":"dance"}"#).unwrap_err();
        assert_eq!(err.code, ErrorCode::UnknownVerb);
        let err = RequestFrame::decode("not json").unwrap_err();
        assert_eq!(err.code, ErrorCode::BadFrame);
    }

    #[test]
    fn job_reports_round_trip() {
        let report = JobReport {
            job: 5,
            state: JobStateKind::Failed,
            response: None,
            error: Some(WireError::new(ErrorCode::GraphLoad, "no such file")),
        };
        let back = JobReport::from_json(&Json::parse(&report.to_json().to_string()).unwrap());
        assert_eq!(back.unwrap(), report);
        assert!(JobStateKind::Done.is_terminal());
        assert!(!JobStateKind::Running.is_terminal());
    }

    #[test]
    fn error_codes_round_trip() {
        for code in [
            ErrorCode::BadVersion,
            ErrorCode::BadFrame,
            ErrorCode::BadRequest,
            ErrorCode::UnknownVerb,
            ErrorCode::UnknownEngine,
            ErrorCode::UnknownJob,
            ErrorCode::UnknownStream,
            ErrorCode::GraphLoad,
            ErrorCode::Cancelled,
            ErrorCode::ShuttingDown,
            ErrorCode::WorkerUnavailable,
            ErrorCode::RateLimited,
            ErrorCode::Overloaded,
            ErrorCode::Transport,
            ErrorCode::Internal,
        ] {
            assert_eq!(ErrorCode::parse(code.as_str()), code);
        }
        assert_eq!(ErrorCode::parse("novel_code"), ErrorCode::Internal);
    }

    #[test]
    fn out_of_range_priorities_are_rejected_at_decode() {
        let json = Json::parse(
            r#"{"source":{"kind":"generator","name":"patents","nodes":10},"priority":12}"#,
        )
        .unwrap();
        let err = CensusRequest::from_json(&json).unwrap_err();
        assert_eq!(err.code, ErrorCode::BadRequest);
        assert!(err.message.contains("priority"), "{err}");
        for bad in [
            r#"{"source":{"kind":"path","path":"g"},"priority":-1}"#,
            r#"{"source":{"kind":"path","path":"g"},"priority":"high"}"#,
        ] {
            let err = CensusRequest::from_json(&Json::parse(bad).unwrap()).unwrap_err();
            assert_eq!(err.code, ErrorCode::BadRequest, "{bad}");
        }
        // the whole valid range decodes
        for p in 0..=MAX_PRIORITY {
            let line = CensusRequest::path("g").priority(p).to_json().to_string();
            let back = CensusRequest::from_json(&Json::parse(&line).unwrap()).unwrap();
            assert_eq!(back.priority, Some(p));
        }
    }

    #[test]
    fn stream_verbs_parse_and_print() {
        for verb in [
            Verb::StreamOpen,
            Verb::StreamApply,
            Verb::StreamQuery,
            Verb::StreamCompact,
            Verb::StreamClose,
        ] {
            assert_eq!(Verb::parse(verb.as_str()), Some(verb));
        }
    }

    #[test]
    fn stream_ops_round_trip() {
        let ops = vec![
            EdgeOp::Insert(0, 1),
            EdgeOp::Delete(7, 3),
            EdgeOp::Insert(u32::MAX, 0),
        ];
        let back = ops_from_json(&Json::parse(&ops_to_json(&ops).to_string()).unwrap()).unwrap();
        assert_eq!(back, ops);
    }

    #[test]
    fn malformed_stream_ops_are_rejected() {
        for bad in [
            r#"[["*",0,1]]"#,    // unknown sign
            r#"[["+",0]]"#,      // missing endpoint
            r#"[["+","a",1]]"#,  // non-numeric id
            r#"[["+",0,5000000000]]"#, // id over u32
            r#"[1,2]"#,          // not op triples
            r#"{"op":"+"}"#,     // not an array
        ] {
            let err = ops_from_json(&Json::parse(bad).unwrap()).unwrap_err();
            assert_eq!(err.code, ErrorCode::BadRequest, "{bad}");
        }
    }

    #[test]
    fn stream_frames_round_trip() {
        let mut open = RequestFrame::new(1, Verb::StreamOpen);
        open.request = Some(CensusRequest::inline(4, vec![(0, 1), (1, 2)]).engine("merged"));
        assert_eq!(RequestFrame::decode(&open.encode()).unwrap(), open);

        let mut apply = RequestFrame::new(2, Verb::StreamApply);
        apply.stream = Some(9);
        apply.ops = Some(vec![EdgeOp::Insert(0, 3), EdgeOp::Delete(1, 2)]);
        assert_eq!(RequestFrame::decode(&apply.encode()).unwrap(), apply);

        for verb in [Verb::StreamQuery, Verb::StreamCompact, Verb::StreamClose] {
            let mut f = RequestFrame::new(3, verb);
            f.stream = Some(9);
            assert_eq!(RequestFrame::decode(&f.encode()).unwrap(), f);
        }
    }

    #[test]
    fn stream_payloads_round_trip() {
        let opened = StreamOpened {
            stream: 4,
            nodes: 100,
            arcs: 440,
            engine: "merged".to_string(),
            fidelity: "sampled:0.25".to_string(),
        };
        let back =
            StreamOpened::from_json(&Json::parse(&opened.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back, opened);

        let report = StreamApplyReport {
            stream: 4,
            applied: 10,
            no_ops: 2,
            rejected: 1,
            reclassified: 77,
            arcs: 449,
        };
        let back =
            StreamApplyReport::from_json(&Json::parse(&report.to_json().to_string()).unwrap())
                .unwrap();
        assert_eq!(back, report);

        let mut census = Census::zero();
        census.add_count(TriadType::T003, 1_000);
        census.add_count(TriadType::T030C, 3);
        let snapshot = StreamSnapshot {
            stream: 4,
            census,
            nodes: 100,
            arcs: 449,
            edits: 12,
            applied: 10,
            reclassified: 77,
            compactions: 1,
            sampling: None,
        };
        let back =
            StreamSnapshot::from_json(&Json::parse(&snapshot.to_json().to_string()).unwrap())
                .unwrap();
        assert_eq!(back, snapshot);
        // a snapshot with no counts is a broken frame
        let err = StreamSnapshot::from_json(&Json::parse(r#"{"stream":1}"#).unwrap()).unwrap_err();
        assert_eq!(err.code, ErrorCode::BadFrame);
    }

    #[test]
    fn fidelity_parses_and_round_trips() {
        assert_eq!(Fidelity::parse("exact").unwrap(), Fidelity::Exact);
        assert_eq!(
            Fidelity::parse("sampled:0.25").unwrap(),
            Fidelity::Sampled { p: 0.25 }
        );
        assert_eq!(Fidelity::parse("sampled:1").unwrap(), Fidelity::Sampled { p: 1.0 });
        for f in [Fidelity::Exact, Fidelity::Sampled { p: 0.1 }] {
            assert_eq!(Fidelity::parse(&f.wire_name()).unwrap(), f);
        }
        for bad in ["", "sampled", "sampled:", "sampled:0", "sampled:1.5", "sampled:abc", "bogus"] {
            let err = Fidelity::parse(bad).unwrap_err();
            assert!(
                err.contains("valid: \"exact\" or \"sampled:P\""),
                "error for {bad:?} must name the valid forms: {err}"
            );
        }
        assert_eq!(Fidelity::Sampled { p: 0.5 }.sample_p(), Some(0.5));
        assert_eq!(Fidelity::Exact.sample_p(), None);
    }

    #[test]
    fn fidelity_rides_the_request_wire() {
        let req = CensusRequest::path("g.csr").sampled(0.2);
        let line = req.to_json().to_string();
        let back = CensusRequest::from_json(&Json::parse(&line).unwrap()).unwrap();
        assert_eq!(back.fidelity, Some(Fidelity::Sampled { p: 0.2 }));
        // old peers omit the field entirely: decode keeps it None
        let old = Json::parse(r#"{"source":{"kind":"path","path":"g.csr"}}"#).unwrap();
        assert_eq!(CensusRequest::from_json(&old).unwrap().fidelity, None);
        // malformed fidelity is a structured error naming the valid forms
        for bad in [
            r#"{"source":{"kind":"path","path":"g"},"fidelity":"sampled:2"}"#,
            r#"{"source":{"kind":"path","path":"g"},"fidelity":"sampled:0"}"#,
            r#"{"source":{"kind":"path","path":"g"},"fidelity":"fast"}"#,
            r#"{"source":{"kind":"path","path":"g"},"fidelity":7}"#,
        ] {
            let err = CensusRequest::from_json(&Json::parse(bad).unwrap()).unwrap_err();
            assert_eq!(err.code, ErrorCode::BadRequest, "{bad}");
            assert!(err.message.contains("valid: \"exact\" or \"sampled:P\""), "{err}");
        }
    }

    #[test]
    fn sampling_reports_round_trip() {
        let mut report = SampleReport {
            p: 0.2,
            z: 2.576,
            estimate: [0.0; 16],
            lo: [0.0; 16],
            hi: [0.0; 16],
        };
        for i in 0..16 {
            report.estimate[i] = i as f64 * 1.5;
            report.lo[i] = i as f64;
            report.hi[i] = i as f64 * 2.0;
        }
        let back =
            SampleReport::from_json(&Json::parse(&report.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back, report);
        // a report with no p is a broken frame
        let err = SampleReport::from_json(&Json::parse(r#"{"z":2.0}"#).unwrap()).unwrap_err();
        assert_eq!(err.code, ErrorCode::BadFrame);
    }

    #[test]
    fn old_peer_payloads_default_to_exact_fidelity() {
        let opened = StreamOpened::from_json(
            &Json::parse(r#"{"stream":1,"nodes":5,"arcs":4,"engine":"merged"}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(opened.fidelity, "exact");
        let line = r#"{"job":1,"counts":{},"provenance":{"source":"s","engine":"merged",
            "route":"sparse","nodes":5,"arcs":4},"seconds":0.1}"#
            .replace('\n', "");
        let back = CensusResponse::from_json(&Json::parse(&line).unwrap()).unwrap();
        assert_eq!(back.provenance.fidelity, "exact");
        assert_eq!(back.sampling, None);
    }

    #[test]
    fn policy_wire_round_trips() {
        for p in [
            Policy::Static { chunk: 7 },
            Policy::Dynamic { chunk: 256 },
            Policy::Guided { min_chunk: 64 },
        ] {
            assert_eq!(Policy::parse(&policy_to_wire(&p)).unwrap(), p);
        }
    }
}
