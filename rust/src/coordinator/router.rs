//! Routing policy: which backend serves a census request.

use crate::graph::CsrGraph;

/// The backend chosen for a request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Route {
    /// Sparse parallel Batagelj–Mrvar engine (L3).
    Sparse,
    /// Dense AOT (JAX/Pallas via PJRT) backend, with the artifact size
    /// the graph will be padded to.
    Dense { size: usize },
}

/// Tunable routing policy.
#[derive(Debug, Clone)]
pub struct RoutingPolicy {
    /// Dense artifact sizes available (ascending), from the runtime
    /// manifest. Empty ⇒ everything routes sparse.
    pub dense_sizes: Vec<usize>,
    /// Graphs above this node count never go dense even if an artifact
    /// fits (padding waste dominates).
    pub dense_max_nodes: usize,
    /// Minimum dyad density (connected dyads / possible dyads) below
    /// which the sparse engine wins even for tiny graphs: the dense
    /// backend's Θ(n³) matmuls only pay off when the merged traversal
    /// would touch a comparable volume.
    pub min_dense_density: f64,
}

impl Default for RoutingPolicy {
    fn default() -> Self {
        RoutingPolicy {
            dense_sizes: Vec::new(),
            dense_max_nodes: 256,
            min_dense_density: 0.02,
        }
    }
}

/// The router proper.
#[derive(Debug, Clone, Default)]
pub struct Router {
    policy: RoutingPolicy,
}

impl Router {
    pub fn new(policy: RoutingPolicy) -> Router {
        Router { policy }
    }

    /// Current policy.
    pub fn policy(&self) -> &RoutingPolicy {
        &self.policy
    }

    /// Decide the backend for a graph.
    pub fn route(&self, g: &CsrGraph) -> Route {
        let n = g.node_count();
        if n == 0 || n > self.policy.dense_max_nodes {
            return Route::Sparse;
        }
        let Some(&size) = self.policy.dense_sizes.iter().find(|&&s| s >= n) else {
            return Route::Sparse;
        };
        let possible = (n as f64) * (n as f64 - 1.0) / 2.0;
        let density = if possible > 0.0 {
            g.dyad_count() as f64 / possible
        } else {
            0.0
        };
        if density >= self.policy.min_dense_density {
            Route::Dense { size }
        } else {
            Route::Sparse
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{erdos_renyi, named, power_law};

    fn router() -> Router {
        Router::new(RoutingPolicy {
            dense_sizes: vec![64, 128, 256],
            dense_max_nodes: 256,
            min_dense_density: 0.02,
        })
    }

    #[test]
    fn dense_for_small_dense_graphs() {
        let r = router();
        let g = erdos_renyi(50, 400, 1);
        assert_eq!(r.route(&g), Route::Dense { size: 64 });
        let g = erdos_renyi(100, 2000, 1);
        assert_eq!(r.route(&g), Route::Dense { size: 128 });
    }

    #[test]
    fn sparse_for_large_graphs() {
        let r = router();
        let g = power_law(5000, 2.2, 5.0, 1);
        assert_eq!(r.route(&g), Route::Sparse);
    }

    #[test]
    fn sparse_for_sparse_small_graphs() {
        let r = router();
        // 200 nodes, ~20 dyads: density 0.001 « 0.02
        let g = erdos_renyi(200, 20, 1);
        assert_eq!(r.route(&g), Route::Sparse);
    }

    #[test]
    fn sparse_when_no_artifacts() {
        let r = Router::new(RoutingPolicy::default());
        assert_eq!(r.route(&named::mutual3()), Route::Sparse);
    }

    #[test]
    fn empty_graph_routes_sparse() {
        let r = router();
        assert_eq!(r.route(&crate::graph::CsrGraph::empty(0)), Route::Sparse);
        // nodes but no arcs: density 0 < any positive threshold
        assert_eq!(r.route(&crate::graph::CsrGraph::empty(10)), Route::Sparse);
    }

    #[test]
    fn dense_max_nodes_boundary_is_inclusive() {
        // complete mutual graphs (density 1.0) isolate the node bound
        let r = Router::new(RoutingPolicy {
            dense_sizes: vec![64],
            dense_max_nodes: 50,
            min_dense_density: 0.02,
        });
        assert_eq!(
            r.route(&named::complete_mutual(50)),
            Route::Dense { size: 64 },
            "exactly at the bound stays dense"
        );
        assert_eq!(
            r.route(&named::complete_mutual(51)),
            Route::Sparse,
            "one past the bound routes sparse"
        );
    }

    #[test]
    fn graphs_larger_than_every_artifact_route_sparse() {
        // under dense_max_nodes, dense enough, but no artifact fits
        let r = Router::new(RoutingPolicy {
            dense_sizes: vec![16],
            dense_max_nodes: 256,
            min_dense_density: 0.02,
        });
        assert_eq!(r.route(&named::complete_mutual(20)), Route::Sparse);
        // and the smallest artifact >= n is chosen, not the largest
        let r = Router::new(RoutingPolicy {
            dense_sizes: vec![16, 64, 256],
            dense_max_nodes: 256,
            min_dense_density: 0.02,
        });
        assert_eq!(r.route(&named::complete_mutual(20)), Route::Dense { size: 64 });
    }

    #[test]
    fn min_dense_density_threshold_on_either_side() {
        // n = 10 → 45 possible dyads. With the threshold at exactly
        // 5/45, 5 connected dyads are dense (>=) and 4 are sparse.
        let r = Router::new(RoutingPolicy {
            dense_sizes: vec![16],
            dense_max_nodes: 256,
            min_dense_density: 5.0 / 45.0,
        });
        let five = crate::graph::builder::from_arcs(
            10,
            &[(0, 1), (2, 3), (4, 5), (6, 7), (8, 9)],
        );
        assert_eq!(five.dyad_count(), 5);
        assert_eq!(
            r.route(&five),
            Route::Dense { size: 16 },
            "density exactly at the threshold is dense (inclusive)"
        );
        let four = crate::graph::builder::from_arcs(10, &[(0, 1), (2, 3), (4, 5), (6, 7)]);
        assert_eq!(four.dyad_count(), 4);
        assert_eq!(r.route(&four), Route::Sparse, "just under the threshold");
    }

    #[test]
    fn zero_density_threshold_admits_any_connected_graph() {
        let r = Router::new(RoutingPolicy {
            dense_sizes: vec![16],
            dense_max_nodes: 256,
            min_dense_density: 0.0,
        });
        let g = crate::graph::builder::from_arcs(10, &[(0, 1)]);
        assert_eq!(r.route(&g), Route::Dense { size: 16 });
    }
}
