//! Dependency-free TCP census server speaking the newline-delimited
//! JSON protocol of [`super::protocol`].
//!
//! Two transports share one dispatch core:
//!
//! - [`CensusServer`] — the legacy thread-per-connection accept loop
//!   (kept behind `repro serve --legacy-accept` for ablation). One
//!   thread per connection; frames are processed strictly in order.
//! - [`Gateway`](crate::net::Gateway) — the nonblocking reactor that
//!   multiplexes thousands of connections (newline-JSON and HTTP) on a
//!   fixed thread count, with per-tenant admission control.
//!
//! Both paths decode, dispatch to the [`Coordinator`] job API through
//! [`ServiceState`], and encode — all payload shapes live in the
//! protocol module. Job and stream state is shared across connections
//! *and transports*: submit over HTTP, poll over newline-JSON.
//!
//! Control verbs: `status` (identity + job counters), `metrics` (text
//! exposition of the coordinator registry), `shutdown` (stop accepting
//! and return from the serve loop).
//!
//! Streaming census sessions (`stream_open` / `stream_apply` /
//! `stream_query` / `stream_compact` / `stream_close`) live in a
//! cross-connection table like jobs do: open on one connection, feed
//! and query from another. Each session is its own mutex — a batch
//! applying on one session never blocks another session (or any other
//! verb); concurrent applies on the *same* session serialize, which is
//! what keeps the incremental census exact.
//!
//! Slow-client protection (both transports): a per-connection idle
//! timeout and a max buffered-frame size, so a slowloris or a
//! never-reading peer cannot pin a thread or grow a buffer without
//! bound. Oversized frames get a structured `bad_request` before the
//! disconnect; idle connections are closed silently.
//!
//! Completed jobs stay resolvable until the server exits — a polling
//! client may fetch a terminal report any number of times. Bound the
//! process by restarting the server, not by racing clients to observe
//! results exactly once.

use std::collections::HashMap;
use std::io::{BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use super::protocol::{
    ErrorCode, Fidelity, Json, RequestFrame, ResponseFrame, SampleReport, StreamApplyReport,
    StreamOpened, StreamSnapshot, Verb, WireError, PROTOCOL_VERSION,
};
use super::service::{Coordinator, JobHandle};
use crate::census::{
    BatchReport, Census, SampledCensus, StreamStats, StreamingCensus, DEFAULT_SAMPLE_SEED,
};
use crate::error::{Context, Result};
use crate::graph::{DeltaOverlay, EdgeOp};
use crate::net::conn::{read_bounded_line, BoundedLine, ConnLimits};
use crate::sched::Executor;

/// A session's census maintainer: exact incremental maintenance, or
/// sampled maintenance over the p-filtered base (the `fidelity` knob
/// of `stream_open`).
enum SessionCensus {
    Exact(StreamingCensus),
    Sampled(SampledCensus),
}

impl SessionCensus {
    fn apply_batch(&mut self, ops: &[EdgeOp], exec: &Executor, seats: usize) -> BatchReport {
        match self {
            SessionCensus::Exact(c) => c.apply_batch(ops, exec, seats),
            SessionCensus::Sampled(c) => c.apply_batch(ops, exec, seats),
        }
    }

    /// The servable table: exact counts, or rounded unbiased estimates.
    fn census(&self) -> Census {
        match self {
            SessionCensus::Exact(c) => c.census(),
            SessionCensus::Sampled(c) => c.census(),
        }
    }

    /// The interval report beside a sampled session's table.
    fn sampling(&self) -> Option<SampleReport> {
        match self {
            SessionCensus::Exact(_) => None,
            SessionCensus::Sampled(c) => Some(SampleReport::from_estimate(&c.estimate())),
        }
    }

    fn overlay(&self) -> &DeltaOverlay {
        match self {
            SessionCensus::Exact(c) => c.overlay(),
            SessionCensus::Sampled(c) => c.overlay(),
        }
    }

    fn stats(&self) -> StreamStats {
        match self {
            SessionCensus::Exact(c) => c.stats(),
            SessionCensus::Sampled(c) => c.stats(),
        }
    }

    fn compact_with(&mut self, threads: usize) {
        match self {
            SessionCensus::Exact(c) => c.compact_with(threads),
            SessionCensus::Sampled(c) => c.compact_with(threads),
        }
    }
}

/// One live streaming census session.
struct StreamSession {
    census: SessionCensus,
}

/// The transport-independent serving state: the coordinator, the
/// cross-connection job and stream tables, and the shutdown latch.
/// The legacy accept loop and the nonblocking gateway both hold an
/// `Arc<ServiceState>` — which is what makes `--legacy-accept` a pure
/// transport ablation.
pub(crate) struct ServiceState {
    pub(crate) coordinator: Arc<Coordinator>,
    jobs: Mutex<HashMap<u64, JobHandle>>,
    /// Stream sessions, each behind its own mutex so long applies on
    /// one session do not serialize the whole server.
    streams: Mutex<HashMap<u64, Arc<Mutex<StreamSession>>>>,
    stream_seq: AtomicU64,
    shutdown: AtomicBool,
    started: Instant,
    /// A blocking accept loop registers its address here so
    /// [`ServiceState::begin_shutdown`] can poke it awake; the
    /// nonblocking gateway leaves it empty and notices the latch on
    /// its next reactor tick.
    wake_addr: Mutex<Option<SocketAddr>>,
}

impl ServiceState {
    pub(crate) fn new(coordinator: Arc<Coordinator>) -> ServiceState {
        ServiceState {
            coordinator,
            jobs: Mutex::new(HashMap::new()),
            streams: Mutex::new(HashMap::new()),
            stream_seq: AtomicU64::new(0),
            shutdown: AtomicBool::new(false),
            started: Instant::now(),
            wake_addr: Mutex::new(None),
        }
    }

    /// Register the address a blocking accept loop listens on, for the
    /// shutdown wake-up connection.
    pub(crate) fn set_wake_addr(&self, addr: SocketAddr) {
        *self.wake_addr.lock().unwrap() = Some(addr);
    }

    /// Flip the shutdown latch and (for a blocking accept loop) wake it
    /// with a throwaway connection. Called *after* the shutdown ack has
    /// been flushed to the requesting client, so the ack is never raced
    /// by process teardown.
    pub(crate) fn begin_shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(addr) = *self.wake_addr.lock().unwrap() {
            let _ = TcpStream::connect(addr);
        }
    }

    pub(crate) fn is_shutting_down(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }

    /// Look a job up in the cross-connection table (the gateway parks
    /// `wait` verbs on the handle instead of blocking a reactor).
    pub(crate) fn job(&self, id: u64) -> Option<JobHandle> {
        self.jobs.lock().unwrap().get(&id).cloned()
    }

    /// Insert a submitted job into the cross-connection table.
    pub(crate) fn insert_job(&self, handle: JobHandle) {
        self.jobs.lock().unwrap().insert(handle.id(), handle);
    }
}

/// The legacy census TCP server: thread-per-connection, blocking I/O.
/// Bind, read the OS-assigned address, then [`CensusServer::run`] the
/// accept loop (usually on its own thread).
pub struct CensusServer {
    listener: TcpListener,
    state: Arc<ServiceState>,
    limits: ConnLimits,
    addr: SocketAddr,
}

impl CensusServer {
    /// Bind to `addr` (e.g. `127.0.0.1:0` for an OS-assigned port).
    pub fn bind<A: ToSocketAddrs + std::fmt::Debug>(
        coordinator: Arc<Coordinator>,
        addr: A,
    ) -> Result<CensusServer> {
        CensusServer::bind_with_limits(coordinator, addr, ConnLimits::default())
    }

    /// [`CensusServer::bind`] with explicit slow-client limits.
    pub fn bind_with_limits<A: ToSocketAddrs + std::fmt::Debug>(
        coordinator: Arc<Coordinator>,
        addr: A,
        limits: ConnLimits,
    ) -> Result<CensusServer> {
        let listener =
            TcpListener::bind(&addr).with_context(|| format!("binding census server {addr:?}"))?;
        let local = listener.local_addr().context("reading bound address")?;
        let state = Arc::new(ServiceState::new(coordinator));
        state.set_wake_addr(local);
        Ok(CensusServer {
            listener,
            state,
            limits,
            addr: local,
        })
    }

    /// The actually-bound address (resolves `:0` to the assigned port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Accept loop: one handler thread per connection, until a client
    /// sends `shutdown`. Handler threads are detached — in-flight
    /// requests on other connections finish on their own; new frames
    /// after shutdown are answered with `shutting_down`.
    pub fn run(self) -> Result<()> {
        let CensusServer {
            listener,
            state,
            limits,
            addr: _,
        } = self;
        for conn in listener.incoming() {
            if state.is_shutting_down() {
                break;
            }
            match conn {
                Ok(stream) => {
                    let state = state.clone();
                    let spawned = std::thread::Builder::new()
                        .name("census-conn".into())
                        .spawn(move || handle_connection(&state, stream, limits));
                    if let Err(e) = spawned {
                        eprintln!("serve: failed to spawn connection thread: {e}");
                    }
                }
                Err(e) => {
                    if state.is_shutting_down() {
                        break;
                    }
                    eprintln!("serve: accept error: {e}");
                }
            }
        }
        Ok(())
    }
}

/// Serve one connection: read frames line by line, answer each in
/// order, stop on disconnect, idle timeout, an oversized frame, or
/// after shutdown is requested.
fn handle_connection(state: &ServiceState, stream: TcpStream, limits: ConnLimits) {
    let metrics = state.coordinator.metrics();
    metrics.inc("server_connections_total", 1);
    metrics.add_gauge("server_connections_open", 1);
    // the read timeout doubles as the idle timeout: a connection that
    // sends nothing for a whole window is dropped, so a slowloris
    // holds a thread for one window, not forever
    let _ = stream.set_read_timeout(Some(limits.idle_timeout));
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(e) => {
            eprintln!("serve: connection clone failed: {e}");
            metrics.add_gauge("server_connections_open", -1);
            return;
        }
    };
    let mut reader = BufReader::new(stream);
    loop {
        let line = match read_bounded_line(&mut reader, limits.max_frame_bytes) {
            Ok(BoundedLine::Line(l)) => l,
            Ok(BoundedLine::TooLong) => {
                // structured verdict before the disconnect — the peer
                // learns *why* instead of seeing a silent drop
                metrics.inc("server_oversize_disconnects_total", 1);
                let reply = ResponseFrame::err(0, oversize_error(limits.max_frame_bytes));
                let mut out = reply.encode();
                out.push('\n');
                let _ = writer.write_all(out.as_bytes()).and_then(|_| writer.flush());
                break;
            }
            Ok(BoundedLine::Eof) => break,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                metrics.inc("server_idle_disconnects_total", 1);
                break;
            }
            Err(_) => break, // peer vanished mid-frame
        };
        if line.trim().is_empty() {
            continue;
        }
        let (reply, stop_after_reply) = process_frame(state, &line);
        let mut out = reply.encode();
        out.push('\n');
        if writer.write_all(out.as_bytes()).and_then(|_| writer.flush()).is_err() {
            break;
        }
        if stop_after_reply {
            // shutdown verb: the ack is on the wire, now stop accepting
            state.begin_shutdown();
            break;
        }
    }
    metrics.add_gauge("server_connections_open", -1);
}

/// The structured error an oversized frame is answered with, shared by
/// both transports so clients see one shape.
pub(crate) fn oversize_error(limit: usize) -> WireError {
    WireError::new(
        ErrorCode::BadRequest,
        format!("frame exceeds this server's limit of {limit} bytes"),
    )
}

/// Decode, dispatch, encode one frame. Never panics the connection:
/// every failure becomes a structured error frame. The second element
/// is `true` when the server should begin shutdown *after* the reply
/// has been written (the `shutdown` verb's ack-first contract).
pub(crate) fn process_frame(state: &ServiceState, line: &str) -> (ResponseFrame, bool) {
    let metrics = state.coordinator.metrics();
    metrics.inc("server_frames_total", 1);
    let frame = match RequestFrame::decode(line) {
        Ok(f) => f,
        Err(e) => {
            metrics.inc("server_errors_total", 1);
            return (ResponseFrame::err(salvage_id(line), e), false);
        }
    };
    match execute(state, &frame) {
        Ok(result) => {
            let stop = frame.verb == Verb::Shutdown;
            (ResponseFrame::ok(frame.id, result), stop)
        }
        Err(e) => {
            metrics.inc("server_errors_total", 1);
            (ResponseFrame::err(frame.id, e), false)
        }
    }
}

/// A frame failed validation (version, verb, request body) but the
/// correlation id may still be salvageable from the raw JSON so the
/// client can key the error; 0 marks a frame too broken even for that.
pub(crate) fn salvage_id(line: &str) -> u64 {
    Json::parse(line)
        .ok()
        .and_then(|v| v.get("id").and_then(Json::as_u64))
        .unwrap_or(0)
}

/// Look a frame's job up in the cross-connection table.
fn lookup_job(state: &ServiceState, frame: &RequestFrame) -> Result<JobHandle, WireError> {
    let id = frame
        .job
        .ok_or_else(|| WireError::new(ErrorCode::BadRequest, "frame carries no job id"))?;
    state
        .job(id)
        .ok_or_else(|| WireError::new(ErrorCode::UnknownJob, format!("no job {id}")))
}

/// Look a frame's stream session up in the cross-connection table.
fn lookup_stream(
    state: &ServiceState,
    frame: &RequestFrame,
) -> Result<(u64, Arc<Mutex<StreamSession>>), WireError> {
    let id = frame
        .stream
        .ok_or_else(|| WireError::new(ErrorCode::BadRequest, "frame carries no stream id"))?;
    state
        .streams
        .lock()
        .unwrap()
        .get(&id)
        .cloned()
        .map(|s| (id, s))
        .ok_or_else(|| WireError::new(ErrorCode::UnknownStream, format!("no stream session {id}")))
}

/// Dispatch one decoded frame against the shared serving state. The
/// `wait` verb blocks the calling thread until the job is terminal —
/// fine on a thread-per-connection transport; the gateway intercepts
/// `wait` before this point and parks the connection instead.
pub(crate) fn execute(state: &ServiceState, frame: &RequestFrame) -> Result<Json, WireError> {
    let metrics = state.coordinator.metrics();
    match frame.verb {
        Verb::Submit => {
            if state.is_shutting_down() {
                return Err(WireError::new(
                    ErrorCode::ShuttingDown,
                    "server is shutting down",
                ));
            }
            let request = frame.request.clone().ok_or_else(|| {
                WireError::new(ErrorCode::BadRequest, "submit frame carries no request")
            })?;
            let handle = state.coordinator.submit(request);
            let report = handle.report();
            state.insert_job(handle);
            Ok(report.to_json())
        }
        Verb::Poll => Ok(lookup_job(state, frame)?.report().to_json()),
        Verb::Wait => {
            let handle = lookup_job(state, frame)?;
            // block this connection until terminal; job-level failure
            // travels inside the report, not as a frame error
            let _ = handle.wait();
            Ok(handle.report().to_json())
        }
        Verb::Cancel => {
            let handle = lookup_job(state, frame)?;
            let had_effect = handle.cancel();
            Ok(Json::Obj(vec![
                ("job".into(), Json::from(handle.id())),
                ("cancelled".into(), Json::Bool(had_effect)),
            ]))
        }
        Verb::Status => {
            let coord = &state.coordinator;
            Ok(Json::Obj(vec![
                ("protocol".into(), Json::from(PROTOCOL_VERSION)),
                ("engine".into(), Json::from(coord.engine_name())),
                ("pool_workers".into(), Json::from(coord.executor().worker_count())),
                ("job_workers".into(), Json::from(coord.job_worker_count())),
                (
                    "distributed_workers".into(),
                    Json::from(coord.worker_pool().len()),
                ),
                ("dense_enabled".into(), Json::Bool(coord.dense_enabled())),
                (
                    "jobs_submitted".into(),
                    Json::from(metrics.get("jobs_submitted_total")),
                ),
                ("jobs_done".into(), Json::from(metrics.get("jobs_done_total"))),
                (
                    "jobs_inflight".into(),
                    Json::Int(metrics.gauge("jobs_inflight") as i128),
                ),
                (
                    "streams_open".into(),
                    Json::Int(metrics.gauge("stream_sessions_open") as i128),
                ),
                (
                    "uptime_seconds".into(),
                    Json::Num(state.started.elapsed().as_secs_f64()),
                ),
            ]))
        }
        Verb::Metrics => Ok(Json::Obj(vec![(
            "text".into(),
            Json::from(state.coordinator.metrics().render()),
        )])),
        Verb::Shutdown => {
            // side-effect free: the transport flips the latch after the
            // ack is flushed (see process_frame's second element)
            Ok(Json::Obj(vec![("stopping".into(), Json::Bool(true))]))
        }
        Verb::StreamOpen => {
            if state.is_shutting_down() {
                return Err(WireError::new(
                    ErrorCode::ShuttingDown,
                    "server is shutting down",
                ));
            }
            let request = frame.request.clone().ok_or_else(|| {
                WireError::new(ErrorCode::BadRequest, "stream_open frame carries no request")
            })?;
            let coord = &state.coordinator;
            let base = coord.resolve_source(&request.source)?;
            // sampled fidelity: the returned session base is already
            // the p-filtered graph, censused by the seed engine
            let (seed, engine, session_base) = coord.seed_census(
                &base,
                request.engine.as_deref(),
                request.ordering,
                request.fidelity,
            )?;
            let fidelity = request.fidelity.unwrap_or(Fidelity::Exact);
            let opened = StreamOpened {
                stream: state.stream_seq.fetch_add(1, Ordering::Relaxed) + 1,
                nodes: session_base.node_count() as u64,
                arcs: session_base.arc_count(),
                engine,
                fidelity: fidelity.wire_name(),
            };
            let census = match fidelity {
                Fidelity::Sampled { p } => SessionCensus::Sampled(SampledCensus::with_initial(
                    session_base,
                    seed,
                    p,
                    DEFAULT_SAMPLE_SEED,
                )),
                Fidelity::Exact => {
                    SessionCensus::Exact(StreamingCensus::with_initial(session_base, seed))
                }
            };
            let session = StreamSession { census };
            state
                .streams
                .lock()
                .unwrap()
                .insert(opened.stream, Arc::new(Mutex::new(session)));
            metrics.inc("stream_sessions_total", 1);
            metrics.add_gauge("stream_sessions_open", 1);
            Ok(opened.to_json())
        }
        Verb::StreamApply => {
            let (id, session) = lookup_stream(state, frame)?;
            let ops = frame.ops.as_deref().ok_or_else(|| {
                WireError::new(ErrorCode::BadRequest, "stream_apply frame carries no ops")
            })?;
            let exec = state.coordinator.executor().clone();
            let seats = exec.worker_count().max(1);
            let mut s = session.lock().unwrap();
            let report = s.census.apply_batch(ops, &exec, seats);
            metrics.inc("stream_ops_total", ops.len() as u64);
            metrics.inc("stream_ops_applied_total", report.applied);
            metrics.inc("stream_reclassifications_total", report.reclassified);
            Ok(StreamApplyReport {
                stream: id,
                applied: report.applied,
                no_ops: report.no_ops,
                rejected: report.rejected,
                reclassified: report.reclassified,
                arcs: s.census.overlay().arc_count(),
            }
            .to_json())
        }
        Verb::StreamQuery => {
            let (id, session) = lookup_stream(state, frame)?;
            let s = session.lock().unwrap();
            let stats = s.census.stats();
            Ok(StreamSnapshot {
                stream: id,
                census: s.census.census(),
                nodes: s.census.overlay().node_count() as u64,
                arcs: s.census.overlay().arc_count(),
                edits: s.census.overlay().edit_count() as u64,
                applied: stats.applied,
                reclassified: stats.reclassified,
                compactions: stats.compactions,
                sampling: s.census.sampling(),
            }
            .to_json())
        }
        Verb::StreamCompact => {
            let (id, session) = lookup_stream(state, frame)?;
            let mut s = session.lock().unwrap();
            let threads = state.coordinator.executor().worker_count().max(1);
            s.census.compact_with(threads);
            metrics.inc("stream_compactions_total", 1);
            Ok(Json::Obj(vec![
                ("stream".into(), Json::from(id)),
                ("compacted".into(), Json::Bool(true)),
                ("arcs".into(), Json::from(s.census.overlay().arc_count())),
            ]))
        }
        Verb::StreamClose => {
            let id = frame.stream.ok_or_else(|| {
                WireError::new(ErrorCode::BadRequest, "frame carries no stream id")
            })?;
            let removed = state.streams.lock().unwrap().remove(&id);
            match removed {
                Some(_) => {
                    metrics.add_gauge("stream_sessions_open", -1);
                    Ok(Json::Obj(vec![
                        ("stream".into(), Json::from(id)),
                        ("closed".into(), Json::Bool(true)),
                    ]))
                }
                None => Err(WireError::new(
                    ErrorCode::UnknownStream,
                    format!("no stream session {id}"),
                )),
            }
        }
    }
}
