//! Dependency-free TCP census server speaking the newline-delimited
//! JSON protocol of [`super::protocol`].
//!
//! One thread per connection; frames are processed strictly in order
//! per connection, and job state is shared across connections (submit
//! on one, poll on another). The server is a pure transport: every
//! frame decodes, dispatches to the [`Coordinator`] job API, and
//! encodes — all payload shapes live in the protocol module.
//!
//! Control verbs: `status` (identity + job counters), `metrics` (text
//! exposition of the coordinator registry), `shutdown` (stop accepting
//! and return from [`CensusServer::run`]).
//!
//! Completed jobs stay resolvable until the server exits — a polling
//! client may fetch a terminal report any number of times. Bound the
//! process by restarting the server, not by racing clients to observe
//! results exactly once.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use super::protocol::{
    ErrorCode, Json, RequestFrame, ResponseFrame, Verb, WireError, PROTOCOL_VERSION,
};
use super::service::{Coordinator, JobHandle};
use crate::error::{Context, Result};

/// Shared server state: the coordinator, the cross-connection job table
/// and the shutdown latch.
struct ServerState {
    coordinator: Arc<Coordinator>,
    jobs: Mutex<HashMap<u64, JobHandle>>,
    shutdown: AtomicBool,
    started: Instant,
    addr: SocketAddr,
}

impl ServerState {
    /// Flip the shutdown latch and wake the blocking accept loop with a
    /// throwaway connection. Called *after* the shutdown ack has been
    /// flushed to the requesting client, so the ack is never raced by
    /// process teardown.
    fn begin_shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr);
    }
}

/// The census TCP server. Bind, read the OS-assigned address, then
/// [`CensusServer::run`] the accept loop (usually on its own thread).
pub struct CensusServer {
    listener: TcpListener,
    state: Arc<ServerState>,
}

impl CensusServer {
    /// Bind to `addr` (e.g. `127.0.0.1:0` for an OS-assigned port).
    pub fn bind<A: ToSocketAddrs + std::fmt::Debug>(
        coordinator: Arc<Coordinator>,
        addr: A,
    ) -> Result<CensusServer> {
        let listener =
            TcpListener::bind(&addr).with_context(|| format!("binding census server {addr:?}"))?;
        let local = listener.local_addr().context("reading bound address")?;
        Ok(CensusServer {
            listener,
            state: Arc::new(ServerState {
                coordinator,
                jobs: Mutex::new(HashMap::new()),
                shutdown: AtomicBool::new(false),
                started: Instant::now(),
                addr: local,
            }),
        })
    }

    /// The actually-bound address (resolves `:0` to the assigned port).
    pub fn local_addr(&self) -> SocketAddr {
        self.state.addr
    }

    /// Accept loop: one handler thread per connection, until a client
    /// sends `shutdown`. Handler threads are detached — in-flight
    /// requests on other connections finish on their own; new frames
    /// after shutdown are answered with `shutting_down`.
    pub fn run(self) -> Result<()> {
        let CensusServer { listener, state } = self;
        for conn in listener.incoming() {
            if state.shutdown.load(Ordering::SeqCst) {
                break;
            }
            match conn {
                Ok(stream) => {
                    let state = state.clone();
                    let spawned = std::thread::Builder::new()
                        .name("census-conn".into())
                        .spawn(move || handle_connection(&state, stream));
                    if let Err(e) = spawned {
                        eprintln!("serve: failed to spawn connection thread: {e}");
                    }
                }
                Err(e) => {
                    if state.shutdown.load(Ordering::SeqCst) {
                        break;
                    }
                    eprintln!("serve: accept error: {e}");
                }
            }
        }
        Ok(())
    }
}

/// Serve one connection: read frames line by line, answer each in
/// order, stop on disconnect or after shutdown is requested.
fn handle_connection(state: &ServerState, stream: TcpStream) {
    let metrics = state.coordinator.metrics();
    metrics.inc("server_connections_total", 1);
    metrics.add_gauge("server_connections_open", 1);
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(e) => {
            eprintln!("serve: connection clone failed: {e}");
            metrics.add_gauge("server_connections_open", -1);
            return;
        }
    };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break, // peer vanished mid-frame
        };
        if line.trim().is_empty() {
            continue;
        }
        let (reply, stop_after_reply) = process_frame(state, &line);
        let mut out = reply.encode();
        out.push('\n');
        if writer.write_all(out.as_bytes()).and_then(|_| writer.flush()).is_err() {
            break;
        }
        if stop_after_reply {
            // shutdown verb: the ack is on the wire, now stop accepting
            state.begin_shutdown();
            break;
        }
    }
    metrics.add_gauge("server_connections_open", -1);
}

/// Decode, dispatch, encode one frame. Never panics the connection:
/// every failure becomes a structured error frame. The second element
/// is `true` when the server should begin shutdown *after* the reply
/// has been written (the `shutdown` verb's ack-first contract).
fn process_frame(state: &ServerState, line: &str) -> (ResponseFrame, bool) {
    let metrics = state.coordinator.metrics();
    metrics.inc("server_frames_total", 1);
    let frame = match RequestFrame::decode(line) {
        Ok(f) => f,
        Err(e) => {
            // the frame failed validation (version, verb, request body)
            // but the correlation id may still be salvageable from the
            // raw JSON so the client can key the error; 0 marks a frame
            // too broken even for that
            metrics.inc("server_errors_total", 1);
            let id = Json::parse(line)
                .ok()
                .and_then(|v| v.get("id").and_then(Json::as_u64))
                .unwrap_or(0);
            return (ResponseFrame::err(id, e), false);
        }
    };
    match execute(state, &frame) {
        Ok(result) => {
            let stop = frame.verb == Verb::Shutdown;
            (ResponseFrame::ok(frame.id, result), stop)
        }
        Err(e) => {
            metrics.inc("server_errors_total", 1);
            (ResponseFrame::err(frame.id, e), false)
        }
    }
}

/// Look a frame's job up in the cross-connection table.
fn lookup_job(state: &ServerState, frame: &RequestFrame) -> Result<JobHandle, WireError> {
    let id = frame
        .job
        .ok_or_else(|| WireError::new(ErrorCode::BadRequest, "frame carries no job id"))?;
    state
        .jobs
        .lock()
        .unwrap()
        .get(&id)
        .cloned()
        .ok_or_else(|| WireError::new(ErrorCode::UnknownJob, format!("no job {id}")))
}

fn execute(state: &ServerState, frame: &RequestFrame) -> Result<Json, WireError> {
    match frame.verb {
        Verb::Submit => {
            if state.shutdown.load(Ordering::SeqCst) {
                return Err(WireError::new(
                    ErrorCode::ShuttingDown,
                    "server is shutting down",
                ));
            }
            let request = frame.request.clone().ok_or_else(|| {
                WireError::new(ErrorCode::BadRequest, "submit frame carries no request")
            })?;
            let handle = state.coordinator.submit(request);
            let report = handle.report();
            state.jobs.lock().unwrap().insert(handle.id(), handle);
            Ok(report.to_json())
        }
        Verb::Poll => Ok(lookup_job(state, frame)?.report().to_json()),
        Verb::Wait => {
            let handle = lookup_job(state, frame)?;
            // block this connection until terminal; job-level failure
            // travels inside the report, not as a frame error
            let _ = handle.wait();
            Ok(handle.report().to_json())
        }
        Verb::Cancel => {
            let handle = lookup_job(state, frame)?;
            let had_effect = handle.cancel();
            Ok(Json::Obj(vec![
                ("job".into(), Json::from(handle.id())),
                ("cancelled".into(), Json::Bool(had_effect)),
            ]))
        }
        Verb::Status => {
            let coord = &state.coordinator;
            let metrics = coord.metrics();
            Ok(Json::Obj(vec![
                ("protocol".into(), Json::from(PROTOCOL_VERSION)),
                ("engine".into(), Json::from(coord.engine_name())),
                ("pool_workers".into(), Json::from(coord.executor().worker_count())),
                ("job_workers".into(), Json::from(coord.job_worker_count())),
                ("dense_enabled".into(), Json::Bool(coord.dense_enabled())),
                (
                    "jobs_submitted".into(),
                    Json::from(metrics.get("jobs_submitted_total")),
                ),
                ("jobs_done".into(), Json::from(metrics.get("jobs_done_total"))),
                (
                    "jobs_inflight".into(),
                    Json::Int(metrics.gauge("jobs_inflight") as i128),
                ),
                (
                    "uptime_seconds".into(),
                    Json::Num(state.started.elapsed().as_secs_f64()),
                ),
            ]))
        }
        Verb::Metrics => Ok(Json::Obj(vec![(
            "text".into(),
            Json::from(state.coordinator.metrics().render()),
        )])),
        Verb::Shutdown => {
            // side-effect free: handle_connection flips the latch after
            // the ack is flushed (see process_frame's second element)
            Ok(Json::Obj(vec![("stopping".into(), Json::Bool(true))]))
        }
    }
}
