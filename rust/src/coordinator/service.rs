//! The coordinator service: request intake, backend dispatch, dense
//! service thread, metrics.

use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use super::router::{Route, Router, RoutingPolicy};
use crate::census::{census_parallel, Census, ParallelConfig};
use crate::graph::CsrGraph;
use crate::metrics::Metrics;
use crate::runtime::DenseCensusRuntime;

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Artifact directory for the dense backend; `None` disables it.
    pub artifacts_dir: Option<PathBuf>,
    /// Sparse engine configuration.
    pub sparse: ParallelConfig,
    /// Routing overrides (dense sizes are filled from the manifest).
    pub routing: RoutingPolicy,
    /// Dense request queue depth (backpressure bound).
    pub dense_queue: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            artifacts_dir: Some(PathBuf::from("artifacts")),
            sparse: ParallelConfig::default(),
            routing: RoutingPolicy::default(),
            dense_queue: 64,
        }
    }
}

/// A served census with provenance and timing.
#[derive(Debug, Clone)]
pub struct CensusOutcome {
    pub census: Census,
    pub route: Route,
    pub seconds: f64,
}

/// Request envelope for the dense service thread.
struct DenseRequest {
    graph: CsrGraph,
    reply: mpsc::Sender<Result<Census>>,
}

/// The coordinator: owns the router, the sparse engine configuration and
/// (if artifacts are present) the dense service thread.
pub struct Coordinator {
    router: Router,
    sparse: ParallelConfig,
    dense_tx: Option<mpsc::SyncSender<DenseRequest>>,
    dense_thread: Option<std::thread::JoinHandle<()>>,
    metrics: Arc<Metrics>,
}

impl Coordinator {
    /// Start the coordinator. Compiles all dense artifacts up front (on
    /// the service thread) if an artifact directory is configured and
    /// readable; otherwise runs sparse-only.
    pub fn start(cfg: CoordinatorConfig) -> Result<Coordinator> {
        let metrics = Arc::new(Metrics::new());
        let mut routing = cfg.routing.clone();

        let (dense_tx, dense_thread) = match &cfg.artifacts_dir {
            Some(dir) if dir.join("manifest.tsv").exists() => {
                let (tx, rx) = mpsc::sync_channel::<DenseRequest>(cfg.dense_queue);
                let (size_tx, size_rx) = mpsc::channel::<Result<Vec<usize>>>();
                let dir = dir.clone();
                let m = metrics.clone();
                // PjRtLoadedExecutable is not Send: the runtime lives and
                // dies on this thread; requests arrive by channel.
                let handle = std::thread::Builder::new()
                    .name("dense-census".into())
                    .spawn(move || dense_service(dir, rx, size_tx, m))
                    .context("spawning dense service thread")?;
                let sizes = size_rx
                    .recv()
                    .context("dense service thread died during startup")??;
                routing.dense_sizes = sizes;
                (Some(tx), Some(handle))
            }
            _ => (None, None),
        };

        Ok(Coordinator {
            router: Router::new(routing),
            sparse: cfg.sparse,
            dense_tx,
            dense_thread,
            metrics,
        })
    }

    /// Whether the dense backend is live.
    pub fn dense_enabled(&self) -> bool {
        self.dense_tx.is_some()
    }

    /// The routing table in force.
    pub fn router(&self) -> &Router {
        &self.router
    }

    /// Shared metrics registry.
    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Serve one census request synchronously (the monitor and the CLI
    /// drive this; concurrent callers are fine — the sparse engine is
    /// reentrant and the dense service serializes behind its queue).
    pub fn census(&self, g: &CsrGraph) -> Result<CensusOutcome> {
        let t0 = Instant::now();
        let route = self.router.route(g);
        let census = match (route, &self.dense_tx) {
            (Route::Dense { .. }, Some(tx)) => {
                self.metrics.inc("census_dense_total", 1);
                let (reply_tx, reply_rx) = mpsc::channel();
                tx.send(DenseRequest {
                    graph: g.clone(),
                    reply: reply_tx,
                })
                .ok()
                .context("dense service thread gone")?;
                let res = self
                    .metrics
                    .time("dense_census", || reply_rx.recv())
                    .context("dense service dropped the request")??;
                res
            }
            _ => {
                self.metrics.inc("census_sparse_total", 1);
                self.metrics
                    .time("sparse_census", || census_parallel(g, &self.sparse))
                    .census
            }
        };
        Ok(CensusOutcome {
            census,
            route,
            seconds: t0.elapsed().as_secs_f64(),
        })
    }

    /// Drain and stop the dense service thread.
    pub fn shutdown(mut self) {
        self.dense_tx.take(); // close the channel; service loop exits
        if let Some(h) = self.dense_thread.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.dense_tx.take();
        if let Some(h) = self.dense_thread.take() {
            let _ = h.join();
        }
    }
}

/// Body of the dense service thread: compile artifacts, report sizes,
/// then drain the queue until the coordinator closes it.
fn dense_service(
    dir: PathBuf,
    rx: mpsc::Receiver<DenseRequest>,
    size_tx: mpsc::Sender<Result<Vec<usize>>>,
    metrics: Arc<Metrics>,
) {
    let mut runtime = match DenseCensusRuntime::load_dir(&dir) {
        Ok(rt) => {
            let _ = size_tx.send(Ok(rt.sizes()));
            rt
        }
        Err(e) => {
            let _ = size_tx.send(Err(e));
            return;
        }
    };
    metrics.inc("dense_artifacts_compiled", runtime.stats().compiled as u64);
    while let Ok(req) = rx.recv() {
        let result = runtime.census(&req.graph);
        metrics.inc("dense_executions_total", 1);
        let _ = req.reply.send(result);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::census::merged;
    use crate::graph::generators;

    fn artifacts_available() -> bool {
        PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/manifest.tsv")
            .exists()
    }

    fn test_config() -> CoordinatorConfig {
        CoordinatorConfig {
            artifacts_dir: Some(PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")),
            ..CoordinatorConfig::default()
        }
    }

    #[test]
    fn sparse_only_when_artifacts_missing() {
        let cfg = CoordinatorConfig {
            artifacts_dir: Some(PathBuf::from("/nonexistent")),
            ..CoordinatorConfig::default()
        };
        let coord = Coordinator::start(cfg).unwrap();
        assert!(!coord.dense_enabled());
        let g = generators::erdos_renyi(40, 300, 3);
        let out = coord.census(&g).unwrap();
        assert_eq!(out.route, Route::Sparse);
        assert_eq!(out.census, merged::census(&g));
    }

    #[test]
    fn routes_and_answers_match_both_backends() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let coord = Coordinator::start(test_config()).unwrap();
        assert!(coord.dense_enabled());

        // dense route: small dense graph
        let g = generators::erdos_renyi(50, 500, 7);
        let out = coord.census(&g).unwrap();
        assert!(matches!(out.route, Route::Dense { size: 64 }), "{:?}", out.route);
        assert_eq!(out.census, merged::census(&g));

        // sparse route: large graph
        let g = generators::power_law(2000, 2.2, 6.0, 5);
        let out = coord.census(&g).unwrap();
        assert_eq!(out.route, Route::Sparse);
        assert_eq!(out.census, merged::census(&g));

        assert_eq!(coord.metrics().get("census_dense_total"), 1);
        assert_eq!(coord.metrics().get("census_sparse_total"), 1);
        coord.shutdown();
    }

    #[test]
    fn many_requests_through_the_queue() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let coord = Coordinator::start(test_config()).unwrap();
        for seed in 0..8 {
            let g = generators::erdos_renyi(30, 200, seed);
            let out = coord.census(&g).unwrap();
            assert_eq!(out.census, merged::census(&g), "seed {seed}");
        }
        assert_eq!(coord.metrics().get("dense_executions_total"), 8);
    }
}
