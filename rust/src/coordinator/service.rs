//! The coordinator service: job-oriented request intake
//! ([`Coordinator::submit`] → [`JobHandle`]), graph loading (with an
//! mmap-aware cache), backend dispatch, dense service thread, metrics.
//!
//! The serving pipeline is job-first: every request — local
//! [`Coordinator::submit`], the TCP protocol, or the blocking
//! [`Coordinator::census`] / [`Coordinator::census_path`] compatibility
//! shims — lands in one internal [`Core::serve`] path that resolves the
//! graph source, routes, runs the engine (with a cooperative
//! [`CancelToken`]) and assembles a versioned
//! [`CensusResponse`](super::protocol::CensusResponse).

use std::collections::HashMap;
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex, Weak};
use std::time::Instant;

use super::client::{ClientTimeouts, TriadicClient};
use super::protocol::{
    CensusRequest, CensusResponse, ErrorCode, Fidelity, GraphSource, JobReport, JobStateKind,
    Provenance, SampleReport, SchedStats, Shard, WireError, DEFAULT_PRIORITY, PROTOCOL_VERSION,
};
use super::router::{Route, Router, RoutingPolicy};
use crate::census::{
    census_parallel_range, estimate_sampled, hybrid_registry, sample_base, Census, CensusEngine,
    EngineRegistry, ParallelConfig, ParallelRun, DEFAULT_CONFIDENCE_Z, DEFAULT_SAMPLE_SEED,
};
use crate::error::{Context, Error, Result};
use crate::graph::relabel;
use crate::graph::{generators, io, CsrGraph, GraphBuilder, GraphView, HubSplit, VertexOrdering};
use crate::metrics::Metrics;
use crate::runtime::DenseCensusRuntime;
use crate::sched::{CancelToken, Executor, ExecutorConfig, PinMode, Policy, ThreadPoolStats};

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Artifact directory for the dense backend; `None` disables it.
    pub artifacts_dir: Option<PathBuf>,
    /// Sparse engine configuration (the base that per-request
    /// `threads` / `policy` overrides are applied to).
    pub sparse: ParallelConfig,
    /// Routing overrides (dense sizes are filled from the manifest).
    pub routing: RoutingPolicy,
    /// Dense request queue depth (backpressure bound).
    pub dense_queue: usize,
    /// Worker threads for edge-list ingestion on [`Coordinator::census_path`].
    pub ingest_threads: usize,
    /// Graphs kept resident by the path cache (FIFO eviction; 0
    /// disables caching). Mapped v2 graphs cost almost no heap, so
    /// serving the same converted graph across requests is free.
    pub graph_cache: usize,
    /// Trust `TRIADIC2` files on [`Coordinator::census_path`]: skip the
    /// whole-file checksum scan and mmap in O(1) (header bounds checks
    /// only). Enable when the coordinator serves files it converted
    /// itself; leave off for files of unknown provenance.
    pub trusted_mmap: bool,
    /// Sparse census engine, resolved by name from the
    /// [`EngineRegistry`] (`naive`, `batagelj-mrvar`, `merged`,
    /// `parallel`, `moody`). Requests may override per-job.
    pub engine: String,
    /// Worker threads of the shared executor (`0` = host parallelism).
    /// This caps the pool for the whole process lifetime: K concurrent
    /// requests interleave chunks on these workers instead of holding
    /// K × `sparse.threads` OS threads.
    pub pool_threads: usize,
    /// Census jobs admitted to the executor at once (`0` = unlimited);
    /// excess requests queue at the admission gate.
    pub max_concurrent_jobs: usize,
    /// Job-runner threads draining the submit queue (`0` = min(4, host
    /// parallelism)). Each runner serves one job at a time; the census
    /// itself still parallelizes on the shared executor, so this bounds
    /// *concurrent jobs in flight*, not CPU use.
    pub job_workers: usize,
    /// Largest node count a request may *materialize* server-side
    /// (inline and generator sources; `0` = unlimited). Without a bound
    /// one ~60-byte frame could ask for a terabyte-sized generator and
    /// abort the whole process on allocation failure. Path sources are
    /// exempt — the operator controls what is on disk.
    pub max_request_nodes: usize,
    /// Worker pool for the distributed planner: `host:port` addresses
    /// of `repro worker` processes. When non-empty, natural-ordering
    /// census requests are partitioned into vertex-range shards over
    /// `flat_offsets`, scattered to the workers as wire sub-jobs, and
    /// merged by exact summation (byte-identical to a single-process
    /// run). Empty = everything runs in-process.
    pub workers: Vec<String>,
    /// CPU affinity for the executor's workers (`--pin`): pin each
    /// worker to its socket's CPU set (default), to one CPU, or not at
    /// all. Pinning failures degrade to unpinned and are reported via
    /// `SchedStats::pinned_workers`, never errors. Ignored by
    /// [`Coordinator::start_with_executor`] (the pool already exists).
    pub pin: PinMode,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            artifacts_dir: Some(PathBuf::from("artifacts")),
            sparse: ParallelConfig::default(),
            routing: RoutingPolicy::default(),
            dense_queue: 64,
            ingest_threads: std::thread::available_parallelism()
                .map(|p| p.get())
                .unwrap_or(1),
            graph_cache: 8,
            trusted_mmap: false,
            engine: "parallel".to_string(),
            pool_threads: 0,
            max_concurrent_jobs: 0,
            job_workers: 0,
            max_request_nodes: 10_000_000,
            workers: Vec::new(),
            pin: PinMode::default(),
        }
    }
}

/// Path-keyed cache of loaded graphs with FIFO eviction, freshness
/// validation and single-flight loading.
struct GraphStore {
    capacity: usize,
    ingest_threads: usize,
    trusted_mmap: bool,
    inner: Mutex<StoreInner>,
    /// Signalled when an in-flight load finishes (single-flight wakeup).
    loaded_cv: Condvar,
}

/// A cached graph plus the file identity it was loaded from, so a
/// rewritten file invalidates the entry instead of serving stale data.
struct CachedGraph {
    graph: Arc<CsrGraph>,
    len: u64,
    modified: Option<std::time::SystemTime>,
}

#[derive(Default)]
struct StoreInner {
    map: HashMap<PathBuf, CachedGraph>,
    order: VecDeque<PathBuf>,
    /// Paths currently being loaded by some thread (single-flight: a
    /// concurrent first request for the same multi-GB file waits for
    /// the loader instead of parsing it again).
    loading: std::collections::HashSet<PathBuf>,
}

/// The (length, mtime) identity of a file, for staleness checks.
fn file_identity(path: &Path) -> Option<(u64, Option<std::time::SystemTime>)> {
    let meta = std::fs::metadata(path).ok()?;
    Some((meta.len(), meta.modified().ok()))
}

impl GraphStore {
    fn new(capacity: usize, ingest_threads: usize, trusted_mmap: bool) -> GraphStore {
        GraphStore {
            capacity,
            ingest_threads,
            trusted_mmap,
            inner: Mutex::new(StoreInner::default()),
            loaded_cv: Condvar::new(),
        }
    }

    /// Fetch a cached graph or load it (mmap for v2 files, parallel
    /// parse for edge lists) and cache it.
    ///
    /// A hit re-checks the file's (length, mtime) identity and reloads
    /// on mismatch, so converting a new graph over a served path takes
    /// effect on the next request. (Note that rewriting a file *while*
    /// it is memory-mapped is still an OS-level hazard — prefer
    /// write-to-temp + rename for files a live coordinator serves.)
    fn get_or_load(&self, path: &Path, metrics: &Metrics) -> Result<Arc<CsrGraph>> {
        let identity = file_identity(path);
        if self.capacity > 0 {
            let mut cache = self.inner.lock().unwrap();
            loop {
                match cache.map.get(path) {
                    Some(c) if identity == Some((c.len, c.modified)) => {
                        metrics.inc("graph_cache_hits_total", 1);
                        return Ok(c.graph.clone());
                    }
                    Some(_) => {
                        // stale: the file changed since it was cached
                        metrics.inc("graph_cache_stale_total", 1);
                        cache.map.remove(path);
                        cache.order.retain(|p| p != path);
                    }
                    None => {}
                }
                if !cache.loading.contains(path) {
                    cache.loading.insert(path.to_path_buf());
                    break;
                }
                // another thread is loading this path: wait and re-check
                cache = self.loaded_cv.wait(cache).unwrap();
            }
        }
        metrics.inc("graph_cache_misses_total", 1);
        let loaded = metrics
            .time("graph_load", || {
                io::load_auto_with(path, self.ingest_threads, !self.trusted_mmap)
            })
            .with_context(|| format!("loading graph {}", path.display()));
        match loaded {
            Ok(graph) => {
                let g = Arc::new(graph);
                if self.capacity > 0 {
                    let mut cache = self.inner.lock().unwrap();
                    cache.loading.remove(path);
                    while cache.order.len() >= self.capacity {
                        if let Some(old) = cache.order.pop_front() {
                            cache.map.remove(&old);
                        }
                    }
                    let (len, modified) = identity.unwrap_or((0, None));
                    cache.map.insert(
                        path.to_path_buf(),
                        CachedGraph {
                            graph: g.clone(),
                            len,
                            modified,
                        },
                    );
                    cache.order.push_back(path.to_path_buf());
                    drop(cache);
                    self.loaded_cv.notify_all();
                }
                Ok(g)
            }
            Err(e) => {
                if self.capacity > 0 {
                    let mut cache = self.inner.lock().unwrap();
                    cache.loading.remove(path);
                    drop(cache);
                    self.loaded_cv.notify_all();
                }
                Err(e)
            }
        }
    }
}

/// Cache of degree-relabeled hub-split forms, keyed by graph *identity*
/// (the `Arc<CsrGraph>` allocation) rather than by path — it sits next
/// to [`GraphStore`], which pins the `Arc`s that make identity stable
/// across requests. Holding [`Weak`] keys means the cache never keeps
/// an evicted or rewritten graph alive; entries whose graph died are
/// pruned on the next lookup and counted as `split_cache_stale_total`.
struct SplitCache {
    capacity: usize,
    entries: Mutex<VecDeque<(Weak<CsrGraph>, Arc<HubSplit>)>>,
}

impl SplitCache {
    fn new(capacity: usize) -> SplitCache {
        SplitCache {
            capacity,
            entries: Mutex::new(VecDeque::new()),
        }
    }

    /// The cached split of exactly this graph allocation, if still live.
    fn get(&self, g: &Arc<CsrGraph>, metrics: &Metrics) -> Option<Arc<HubSplit>> {
        let mut entries = self.entries.lock().unwrap();
        let before = entries.len();
        entries.retain(|(weak, _)| weak.strong_count() > 0);
        let dead = before - entries.len();
        if dead > 0 {
            metrics.inc("split_cache_stale_total", dead as u64);
        }
        let hit = entries.iter().find_map(|(weak, split)| {
            weak.upgrade()
                .filter(|live| Arc::ptr_eq(live, g))
                .map(|_| split.clone())
        });
        match &hit {
            Some(_) => metrics.inc("split_cache_hits_total", 1),
            None => metrics.inc("split_cache_misses_total", 1),
        }
        hit
    }

    fn put(&self, g: &Arc<CsrGraph>, split: Arc<HubSplit>) {
        if self.capacity == 0 {
            return;
        }
        let mut entries = self.entries.lock().unwrap();
        while entries.len() >= self.capacity {
            entries.pop_front();
        }
        entries.push_back((Arc::downgrade(g), split));
    }

    /// Swap the cached split of this graph allocation in place (the
    /// adaptive-`k` retune path); a plain insert when no entry exists.
    fn replace(&self, g: &Arc<CsrGraph>, split: Arc<HubSplit>) {
        if self.capacity == 0 {
            return;
        }
        {
            let mut entries = self.entries.lock().unwrap();
            let slot = entries
                .iter_mut()
                .find(|(weak, _)| weak.upgrade().is_some_and(|live| Arc::ptr_eq(&live, g)));
            if let Some((_, cached)) = slot {
                *cached = split;
                return;
            }
        }
        self.put(g, split);
    }
}

/// A served census with provenance, timing and (for sparse jobs) the
/// per-seat scheduler telemetry of the executor job that computed it.
/// This is the *in-process* result shape of the [`Coordinator::census`]
/// shim; the job API returns the richer, wire-encodable
/// [`CensusResponse`].
#[derive(Debug, Clone)]
pub struct CensusOutcome {
    pub census: Census,
    pub route: Route,
    pub seconds: f64,
    /// Per-job stats from the shared executor; `None` for dense routes
    /// (the dense service thread has no chunk scheduler).
    pub stats: Option<ThreadPoolStats>,
    /// The vertex ordering that actually ran (dense routes ignore the
    /// requested ordering and report `Natural`).
    pub ordering: VertexOrdering,
}

/// Request envelope for the dense service thread.
struct DenseRequest {
    graph: CsrGraph,
    reply: mpsc::Sender<Result<Census>>,
}

// ---------------------------------------------------------------------------
// Jobs
// ---------------------------------------------------------------------------

/// Internal job lifecycle record (behind the handle's mutex).
enum JobProgress {
    Queued,
    Running,
    Done(Box<CensusResponse>),
    Failed(WireError),
    Cancelled,
}

impl JobProgress {
    fn kind(&self) -> JobStateKind {
        match self {
            JobProgress::Queued => JobStateKind::Queued,
            JobProgress::Running => JobStateKind::Running,
            JobProgress::Done(_) => JobStateKind::Done,
            JobProgress::Failed(_) => JobStateKind::Failed,
            JobProgress::Cancelled => JobStateKind::Cancelled,
        }
    }
}

/// State shared between a [`JobHandle`], the submit queue and the job
/// runner executing it.
struct JobShared {
    id: u64,
    state: Mutex<JobProgress>,
    cv: Condvar,
    cancel: CancelToken,
    metrics: Arc<Metrics>,
}

impl JobShared {
    fn new(id: u64, metrics: Arc<Metrics>) -> Arc<JobShared> {
        Arc::new(JobShared {
            id,
            state: Mutex::new(JobProgress::Queued),
            cv: Condvar::new(),
            cancel: CancelToken::new(),
            metrics,
        })
    }

    /// Terminal transition (first one wins); wakes waiters and keeps the
    /// job counters/gauge consistent.
    fn finish(&self, progress: JobProgress) {
        debug_assert!(progress.kind().is_terminal());
        let mut s = self.state.lock().unwrap();
        if s.kind().is_terminal() {
            return;
        }
        let metric = match progress.kind() {
            JobStateKind::Done => "jobs_done_total",
            JobStateKind::Failed => "jobs_failed_total",
            _ => "jobs_cancelled_total",
        };
        *s = progress;
        drop(s);
        self.metrics.inc(metric, 1);
        self.metrics.add_gauge("jobs_inflight", -1);
        self.cv.notify_all();
    }

    /// Queued → Running, unless a cancel already landed.
    fn set_running(&self) -> bool {
        let mut s = self.state.lock().unwrap();
        if matches!(*s, JobProgress::Queued) {
            *s = JobProgress::Running;
            true
        } else {
            false
        }
    }
}

/// Point-in-time snapshot of a job, from [`JobHandle::poll`].
#[derive(Debug, Clone)]
pub enum JobStatus {
    Queued,
    Running,
    Done(Box<CensusResponse>),
    Failed(WireError),
    Cancelled,
}

impl JobStatus {
    pub fn kind(&self) -> JobStateKind {
        match self {
            JobStatus::Queued => JobStateKind::Queued,
            JobStatus::Running => JobStateKind::Running,
            JobStatus::Done(_) => JobStateKind::Done,
            JobStatus::Failed(_) => JobStateKind::Failed,
            JobStatus::Cancelled => JobStateKind::Cancelled,
        }
    }

    /// Whether the job will never change state again.
    pub fn is_terminal(&self) -> bool {
        self.kind().is_terminal()
    }
}

/// Handle to an asynchronously running census job. Clone-able; all
/// clones observe the same job.
#[derive(Clone)]
pub struct JobHandle {
    shared: Arc<JobShared>,
}

impl JobHandle {
    /// Coordinator-assigned job id (also carried in the response).
    pub fn id(&self) -> u64 {
        self.shared.id
    }

    /// Non-blocking state snapshot.
    pub fn poll(&self) -> JobStatus {
        let s = self.shared.state.lock().unwrap();
        match &*s {
            JobProgress::Queued => JobStatus::Queued,
            JobProgress::Running => JobStatus::Running,
            JobProgress::Done(r) => JobStatus::Done(r.clone()),
            JobProgress::Failed(e) => JobStatus::Failed(e.clone()),
            JobProgress::Cancelled => JobStatus::Cancelled,
        }
    }

    /// Block until the job is terminal; `Ok` carries the response,
    /// failures and cancellation come back as structured [`WireError`]s.
    pub fn wait(&self) -> std::result::Result<CensusResponse, WireError> {
        let mut s = self.shared.state.lock().unwrap();
        loop {
            match &*s {
                JobProgress::Done(r) => return Ok((**r).clone()),
                JobProgress::Failed(e) => return Err(e.clone()),
                JobProgress::Cancelled => {
                    return Err(WireError::new(ErrorCode::Cancelled, "job cancelled"))
                }
                _ => s = self.shared.cv.wait(s).unwrap(),
            }
        }
    }

    /// Request cancellation. A queued job cancels immediately; a running
    /// job stops cooperatively (the engine checks the token between
    /// scheduler chunks), which is best-effort — a job within its final
    /// chunk may still complete `Done`. Returns `false` when the job was
    /// already terminal.
    pub fn cancel(&self) -> bool {
        self.shared.cancel.cancel();
        let queued = {
            let s = self.shared.state.lock().unwrap();
            match &*s {
                JobProgress::Queued => true,
                JobProgress::Running => return true,
                _ => return false,
            }
        };
        if queued {
            self.shared.finish(JobProgress::Cancelled);
        }
        true
    }

    /// Wire-encodable report of the current state (the `poll` verb's
    /// payload).
    pub fn report(&self) -> JobReport {
        let (state, response, error) = match self.poll() {
            JobStatus::Queued => (JobStateKind::Queued, None, None),
            JobStatus::Running => (JobStateKind::Running, None, None),
            JobStatus::Done(r) => (JobStateKind::Done, Some(*r), None),
            JobStatus::Failed(e) => (JobStateKind::Failed, None, Some(e)),
            JobStatus::Cancelled => (JobStateKind::Cancelled, None, None),
        };
        JobReport {
            job: self.id(),
            state,
            response,
            error,
        }
    }
}

/// One queued unit of work.
struct QueuedJob {
    shared: Arc<JobShared>,
    request: CensusRequest,
    /// Submit-queue priority (higher drains sooner, FIFO within a
    /// level). From the request, or [`DEFAULT_PRIORITY`].
    priority: u8,
}

#[derive(Default)]
struct JobQueueInner {
    queue: VecDeque<QueuedJob>,
    shutdown: bool,
}

/// The submit queue drained by the job-runner threads.
#[derive(Default)]
struct JobQueue {
    inner: Mutex<JobQueueInner>,
    cv: Condvar,
}

/// Body of one job-runner thread: pop, mark running, serve, finish.
fn job_worker(core: &Core, queue: &JobQueue) {
    loop {
        let job = {
            let mut q = queue.inner.lock().unwrap();
            loop {
                if let Some(job) = q.queue.pop_front() {
                    break job;
                }
                if q.shutdown {
                    return;
                }
                q = queue.cv.wait(q).unwrap();
            }
        };
        if !job.shared.set_running() {
            // cancelled while queued; already terminal
            continue;
        }
        let result = catch_unwind(AssertUnwindSafe(|| {
            core.serve(&job.request, &job.shared.cancel, job.shared.id)
        }));
        let progress = match result {
            Ok(Ok(response)) => JobProgress::Done(Box::new(response)),
            Ok(Err(e)) if e.code == ErrorCode::Cancelled => JobProgress::Cancelled,
            Ok(Err(e)) => JobProgress::Failed(e),
            Err(_) => JobProgress::Failed(WireError::new(
                ErrorCode::Internal,
                "census job panicked (see server log)",
            )),
        };
        job.shared.finish(progress);
    }
}

// ---------------------------------------------------------------------------
// Coordinator
// ---------------------------------------------------------------------------

/// The shared serving internals: router, engine registry, executor,
/// dense queue, metrics and the graph cache. Job-runner threads and the
/// public [`Coordinator`] facade both hold an `Arc<Core>`.
struct Core {
    router: Router,
    engines: EngineRegistry,
    /// The five engines instantiated over the hub-split view — the
    /// sparse path under `ordering: degree`, where `parallel` is the
    /// hub-bitmap hybrid kernel.
    split_engines: EngineRegistry<HubSplit>,
    /// Preprocessed hub-split forms keyed to graph-cache entries.
    splits: SplitCache,
    engine: String,
    default_sparse: ParallelConfig,
    executor: Arc<Executor>,
    /// Behind a mutex so shutdown can close the channel while runners
    /// still hold the `Arc<Core>`.
    dense_tx: Mutex<Option<mpsc::SyncSender<DenseRequest>>>,
    metrics: Arc<Metrics>,
    graphs: GraphStore,
    max_request_nodes: usize,
    /// Distributed worker pool (`host:port` of `repro worker`
    /// processes); empty = serve everything in-process.
    workers: Vec<String>,
}

fn cancelled_error() -> WireError {
    WireError::new(ErrorCode::Cancelled, "job cancelled")
}

/// What [`Core::run_route`] hands back. Under sampled fidelity,
/// `census` holds the rounded unbiased estimates and `sampling` the
/// unrounded intervals; under exact fidelity `sampling` is `None`.
struct RouteOutcome {
    census: Census,
    route: Route,
    stats: Option<ThreadPoolStats>,
    engine: String,
    ordering: VertexOrdering,
    fidelity: Fidelity,
    sampling: Option<SampleReport>,
    /// Hub-bitmap rows the degree-ordered run used, and the serving
    /// split's retune generation; `None` off the degree-ordered path.
    hub_k: Option<u64>,
    hub_retunes: Option<u64>,
}

/// Resolve and run one sparse engine over any [`GraphView`] — the
/// natural path hands the CSR straight in, the degree-ordered path
/// hands in the relabeled hub-split form; per-request seat/policy
/// overrides re-parameterize the engine either way.
#[allow(clippy::too_many_arguments)]
fn sparse_engine_run<G: GraphView>(
    engines: &EngineRegistry<G>,
    name: &str,
    default_sparse: &ParallelConfig,
    threads: Option<usize>,
    policy: Option<Policy>,
    g: &G,
    exec: &Executor,
    cancel: &CancelToken,
) -> std::result::Result<(ParallelRun, String), WireError> {
    let engine = engines
        .get_or_err(name)
        .map_err(|e| WireError::new(ErrorCode::UnknownEngine, e))?;
    // per-request seat/policy overrides re-parameterize configurable
    // engines (the parallel and hybrid ones) over the configured base;
    // serial engines have no knobs and run as registered
    let custom = if threads.is_some() || policy.is_some() {
        engine.with_config(ParallelConfig {
            threads: threads.unwrap_or(default_sparse.threads),
            policy: policy.unwrap_or(default_sparse.policy),
            accumulation: default_sparse.accumulation,
        })
    } else {
        None
    };
    let engine: &dyn CensusEngine<G> = match &custom {
        Some(e) => e.as_ref(),
        None => engine,
    };
    let run = engine
        .census_cancellable(g, exec, cancel)
        .ok_or_else(cancelled_error)?;
    Ok((run, engine.name().to_string()))
}

impl Core {
    /// Serve one request end to end: resolve the source, route, run,
    /// assemble the versioned response. All intake paths land here.
    fn serve(
        &self,
        req: &CensusRequest,
        cancel: &CancelToken,
        job: u64,
    ) -> std::result::Result<CensusResponse, WireError> {
        let t0 = Instant::now();
        if cancel.is_cancelled() {
            return Err(cancelled_error());
        }
        let g = self.resolve_graph(&req.source)?;
        if cancel.is_cancelled() {
            return Err(cancelled_error());
        }
        // Distributed-planner paths. A request carrying a shard is the
        // *leaf*: compute that slice's raw partial and return it. A
        // whole-graph request on a coordinator with a worker pool is the
        // *root*: partition, scatter to the workers, merge. (Degree
        // ordering reshuffles vertex ids, so range shards would not
        // compose; those requests run in-process below.)
        if let Some(shard) = req.shard {
            return self.serve_shard(req, &g, shard, cancel, job, t0);
        }
        if !self.workers.is_empty()
            && matches!(req.ordering, None | Some(VertexOrdering::Natural))
            && matches!(req.fidelity, None | Some(Fidelity::Exact))
        {
            return self.serve_distributed(req, &g, cancel, job, t0);
        }
        let out = self.run_route(
            &g,
            Some(&g),
            req.engine.as_deref(),
            req.threads,
            req.policy,
            req.ordering,
            req.fidelity,
            cancel,
        )?;
        Ok(CensusResponse {
            protocol_version: PROTOCOL_VERSION,
            job,
            census: out.census,
            classes: req.classes.clone(),
            provenance: Provenance {
                source: req.source.describe(),
                engine: out.engine,
                route: match out.route {
                    Route::Sparse => "sparse".to_string(),
                    Route::Dense { size } => format!("dense:{size}"),
                },
                ordering: out.ordering.name().to_string(),
                fidelity: out.fidelity.wire_name(),
                nodes: g.node_count() as u64,
                arcs: g.arc_count(),
                hub_k: out.hub_k,
                hub_retunes: out.hub_retunes,
            },
            stats: out.stats.map(|s| SchedStats::from_pool(&s)),
            sampling: out.sampling,
            seconds: t0.elapsed().as_secs_f64(),
        })
    }

    /// Reject inline/generator sizes the operator has not allowed this
    /// coordinator to materialize.
    fn check_request_nodes(&self, nodes: usize) -> std::result::Result<(), WireError> {
        if self.max_request_nodes > 0 && nodes > self.max_request_nodes {
            return Err(WireError::new(
                ErrorCode::BadRequest,
                format!(
                    "requested {nodes} nodes exceeds this server's limit of {} \
                     (CoordinatorConfig::max_request_nodes)",
                    self.max_request_nodes
                ),
            ));
        }
        Ok(())
    }

    /// Materialize a request's graph source.
    fn resolve_graph(
        &self,
        source: &GraphSource,
    ) -> std::result::Result<Arc<CsrGraph>, WireError> {
        match source {
            GraphSource::Path(p) => self
                .graphs
                .get_or_load(Path::new(p), &self.metrics)
                .map_err(|e| WireError::new(ErrorCode::GraphLoad, e)),
            GraphSource::Inline { nodes, arcs } => {
                self.check_request_nodes(*nodes)?;
                if *nodes as u64 > CsrGraph::MAX_NODE_ID as u64 + 1 {
                    return Err(WireError::new(
                        ErrorCode::BadRequest,
                        format!("inline node count {nodes} exceeds the 30-bit id space"),
                    ));
                }
                if let Some(&(u, v)) =
                    arcs.iter().find(|&&(u, v)| {
                        u as usize >= *nodes || v as usize >= *nodes
                    })
                {
                    return Err(WireError::new(
                        ErrorCode::BadRequest,
                        format!("inline arc ({u},{v}) outside 0..{nodes}"),
                    ));
                }
                let mut b = GraphBuilder::new(*nodes);
                b.extend(arcs.iter().copied());
                Ok(Arc::new(b.build()))
            }
            GraphSource::Generator { name, nodes, seed } => {
                self.check_request_nodes(*nodes)?;
                if *nodes < 2 {
                    return Err(WireError::new(
                        ErrorCode::BadRequest,
                        "generator sources need at least 2 nodes",
                    ));
                }
                let spec = generators::spec_by_name(name, *nodes, *seed)
                    .map_err(|e| WireError::new(ErrorCode::BadRequest, e))?;
                Ok(Arc::new(
                    self.metrics.time("graph_generate", || spec.generate()),
                ))
            }
        }
    }

    /// Degree-relabel `g` and build the hub-split form (direction-split
    /// plus hub bitmaps) — the sparse path's `ordering: degree`
    /// preprocessing, timed under the `order_preprocess` metric. When
    /// the caller can vouch for the graph's identity (an `Arc` pinned by
    /// the graph cache or a resolved source), the preprocessed form is
    /// cached next to it, so repeated degree-ordered requests over a
    /// cached graph skip the relabel + split + bitmap build entirely.
    fn degree_split(&self, g: &CsrGraph, identity: Option<&Arc<CsrGraph>>) -> Arc<HubSplit> {
        self.metrics.inc("census_degree_ordered_total", 1);
        if let Some(arc) = identity {
            if let Some(split) = self.splits.get(arc, &self.metrics) {
                return split;
            }
        }
        let split = Arc::new(self.metrics.time("order_preprocess", || {
            HubSplit::build(relabel::degree_split(g, self.graphs.ingest_threads).1)
        }));
        if let Some(arc) = identity {
            self.splits.put(arc, split.clone());
        }
        split
    }

    /// Route and run one in-memory graph. Naming an engine forces the
    /// sparse path through it; otherwise the router may pick the dense
    /// backend. `ordering: degree` preprocesses the sparse path with
    /// the degree-descending relabel + direction split (the census is
    /// invariant; dense routes ignore the knob). Sampled fidelity
    /// detours through [`Core::run_sampled`].
    #[allow(clippy::too_many_arguments)]
    fn run_route(
        &self,
        g: &CsrGraph,
        identity: Option<&Arc<CsrGraph>>,
        engine_override: Option<&str>,
        threads: Option<usize>,
        policy: Option<Policy>,
        ordering: Option<VertexOrdering>,
        fidelity: Option<Fidelity>,
        cancel: &CancelToken,
    ) -> std::result::Result<RouteOutcome, WireError> {
        if let Some(Fidelity::Sampled { p }) = fidelity {
            return self.run_sampled(g, engine_override, threads, policy, ordering, p, cancel);
        }
        if let Some(p) = &policy {
            p.validate()
                .map_err(|e| WireError::new(ErrorCode::BadRequest, e))?;
        }
        let route = match engine_override {
            Some(_) => Route::Sparse,
            None => self.router.route(g),
        };
        let dense_tx = self.dense_tx.lock().unwrap().clone();
        if let (Route::Dense { .. }, Some(tx)) = (route, dense_tx) {
            self.metrics.inc("census_dense_total", 1);
            let (reply_tx, reply_rx) = mpsc::channel();
            tx.send(DenseRequest {
                graph: g.clone(),
                reply: reply_tx,
            })
            .map_err(|_| WireError::new(ErrorCode::Internal, "dense service thread gone"))?;
            let census = self
                .metrics
                .time("dense_census", || reply_rx.recv())
                .map_err(|_| {
                    WireError::new(ErrorCode::Internal, "dense service dropped the request")
                })?
                .map_err(|e| WireError::new(ErrorCode::Internal, e))?;
            return Ok(RouteOutcome {
                census,
                route,
                stats: None,
                engine: "dense".to_string(),
                ordering: VertexOrdering::Natural,
                fidelity: Fidelity::Exact,
                sampling: None,
                hub_k: None,
                hub_retunes: None,
            });
        }
        self.metrics.inc("census_sparse_total", 1);
        let name = engine_override.unwrap_or(&self.engine);
        let ordering = ordering.unwrap_or_default();
        let mut hub_k = None;
        let mut hub_retunes = None;
        let (run, engine_name) = match ordering {
            VertexOrdering::Natural => self.metrics.time("sparse_census", || {
                sparse_engine_run(
                    &self.engines,
                    name,
                    &self.default_sparse,
                    threads,
                    policy,
                    g,
                    &self.executor,
                    cancel,
                )
            })?,
            VertexOrdering::Degree => {
                // validate the engine before paying for preprocessing
                self.engines
                    .get_or_err(name)
                    .map_err(|e| WireError::new(ErrorCode::UnknownEngine, e))?;
                let split = self.degree_split(g, identity);
                if cancel.is_cancelled() {
                    return Err(cancelled_error());
                }
                let out = self.metrics.time("sparse_census", || {
                    sparse_engine_run(
                        &self.split_engines,
                        name,
                        &self.default_sparse,
                        threads,
                        policy,
                        split.as_ref(),
                        &self.executor,
                        cancel,
                    )
                })?;
                hub_k = Some(split.hub_count() as u64);
                hub_retunes = Some(split.retune_count());
                self.maybe_retune(&split, identity);
                out
            }
        };
        // per-job telemetry: slots walked by this job (executor job
        // counts live in Executor::stats, not here — serial engines
        // never submit one)
        self.metrics.inc(
            "census_slots_total",
            run.stats.items.iter().sum::<usize>() as u64,
        );
        self.metrics
            .inc("census_steals_local_total", run.stats.local_steals);
        self.metrics
            .inc("census_steals_remote_total", run.stats.remote_steals);
        Ok(RouteOutcome {
            census: run.census,
            route,
            stats: Some(run.stats),
            engine: engine_name,
            ordering,
            fidelity: Fidelity::Exact,
            sampling: None,
            hub_k,
            hub_retunes,
        })
    }

    /// After a degree-ordered census, let the split's measured hub-row
    /// traffic propose a better `k` ([`HubSplit::retune_k`]); when it
    /// does, the rebuilt split replaces the cache entry so subsequent
    /// requests for the same graph run with the corrected hub count.
    /// The request that triggered the retune already ran — retunes are
    /// between-census work, never on the serving path of a job.
    fn maybe_retune(&self, split: &Arc<HubSplit>, identity: Option<&Arc<CsrGraph>>) {
        let Some(arc) = identity else { return };
        let Some(new_k) = split.retune_k() else { return };
        let rebuilt = Arc::new(
            self.metrics.time("split_retune", || split.rebuild_with_k(new_k)),
        );
        self.metrics.inc("split_retunes_total", 1);
        self.splits.replace(arc, rebuilt);
    }

    /// The sampled-fidelity route: filter the base graph down to the
    /// deterministically kept dyads, census the sampled graph exactly
    /// with the sparse machinery (the dense backend only produces exact
    /// tables, so the engine is always pinned), then invert the
    /// estimator — the response census holds the rounded unbiased
    /// estimates and `sampling` the unrounded intervals. The sampled
    /// graph is ephemeral and never touches the split cache.
    fn run_sampled(
        &self,
        g: &CsrGraph,
        engine_override: Option<&str>,
        threads: Option<usize>,
        policy: Option<Policy>,
        ordering: Option<VertexOrdering>,
        p: f64,
        cancel: &CancelToken,
    ) -> std::result::Result<RouteOutcome, WireError> {
        self.metrics.inc("census_sampled_total", 1);
        self.metrics.histogram("sample_rate").observe(p);
        let sampled = self.metrics.time("sample_filter", || {
            sample_base(g, p, DEFAULT_SAMPLE_SEED)
        });
        if cancel.is_cancelled() {
            return Err(cancelled_error());
        }
        let name = engine_override.unwrap_or(&self.engine);
        let mut out = self.run_route(
            &sampled,
            None,
            Some(name),
            threads,
            policy,
            ordering,
            None,
            cancel,
        )?;
        let est = estimate_sampled(
            &out.census,
            g.node_count(),
            sampled.dyad_count(),
            p,
            DEFAULT_CONFIDENCE_Z,
        );
        out.census = est.census();
        out.fidelity = Fidelity::Sampled { p };
        out.sampling = Some(SampleReport::from_estimate(&est));
        Ok(out)
    }

    /// Serve the leaf of a distributed census: the *raw* partial tallies
    /// of one vertex-range shard, computed by the range-restricted
    /// parallel engine. The 003 slot stays zero — null closure is global
    /// (`C(n,3)` minus everything) and happens exactly once, on the
    /// coordinator that merges the partials.
    ///
    /// Inverted ranges never reach here (decode rejects them); ranges
    /// past the graph's node count are only detectable once the source
    /// is resolved, so they are rejected now, with the valid range.
    fn serve_shard(
        &self,
        req: &CensusRequest,
        g: &CsrGraph,
        shard: Shard,
        cancel: &CancelToken,
        job: u64,
        t0: Instant,
    ) -> std::result::Result<CensusResponse, WireError> {
        let n = g.node_count();
        if shard.hi > n {
            return Err(WireError::new(
                ErrorCode::BadRequest,
                format!("shard {shard} out of bounds (valid: 0 <= lo <= hi <= {n})"),
            ));
        }
        if matches!(req.fidelity, Some(Fidelity::Sampled { .. })) {
            return Err(WireError::new(
                ErrorCode::BadRequest,
                "shard sub-censuses are exact-only (sampled unbiasing is a \
                 whole-graph operation); drop the fidelity field",
            ));
        }
        if let Some(p) = &req.policy {
            p.validate()
                .map_err(|e| WireError::new(ErrorCode::BadRequest, e))?;
        }
        self.metrics.inc("census_shard_total", 1);
        let cfg = ParallelConfig {
            threads: req.threads.unwrap_or(self.default_sparse.threads),
            policy: req.policy.unwrap_or(self.default_sparse.policy),
            accumulation: self.default_sparse.accumulation,
        };
        let run = self
            .metrics
            .time("shard_census", || {
                census_parallel_range(g, &cfg, &self.executor, cancel, shard.lo, shard.hi)
            })
            .ok_or_else(cancelled_error)?;
        Ok(CensusResponse {
            protocol_version: PROTOCOL_VERSION,
            job,
            census: run.census,
            classes: req.classes.clone(),
            provenance: Provenance {
                source: req.source.describe(),
                engine: "parallel".to_string(),
                route: "sparse".to_string(),
                ordering: VertexOrdering::Natural.name().to_string(),
                fidelity: Fidelity::Exact.wire_name(),
                nodes: n as u64,
                arcs: g.arc_count(),
                hub_k: None,
                hub_retunes: None,
            },
            stats: Some(SchedStats::from_pool(&run.stats)),
            sampling: None,
            seconds: t0.elapsed().as_secs_f64(),
        })
    }

    /// Serve the root of a distributed census: partition the collapsed
    /// triad space into one entry-balanced vertex-range shard per
    /// worker, scatter them as wire sub-jobs, gather the raw partials
    /// and merge by exact summation. Merging is associative integer
    /// addition over disjoint entry ranges, so the result is
    /// byte-identical to a single-process run of any engine.
    fn serve_distributed(
        &self,
        req: &CensusRequest,
        g: &CsrGraph,
        cancel: &CancelToken,
        job: u64,
        t0: Instant,
    ) -> std::result::Result<CensusResponse, WireError> {
        let n = g.node_count();
        let shards = partition_shards(&g.flat_offsets(), self.workers.len());
        let census = self.distributed_census(req, n, &shards, cancel)?;
        self.metrics.inc("census_distributed_total", 1);
        Ok(CensusResponse {
            protocol_version: PROTOCOL_VERSION,
            job,
            census,
            classes: req.classes.clone(),
            provenance: Provenance {
                source: req.source.describe(),
                engine: format!("distributed:{}", shards.len()),
                route: "sparse".to_string(),
                ordering: VertexOrdering::Natural.name().to_string(),
                fidelity: Fidelity::Exact.wire_name(),
                nodes: n as u64,
                arcs: g.arc_count(),
                hub_k: None,
                hub_retunes: None,
            },
            stats: None,
            sampling: None,
            seconds: t0.elapsed().as_secs_f64(),
        })
    }

    /// Scatter/gather: one thread per shard, each cycling through the
    /// worker pool on transport-level failures. Any shard failing on
    /// *every* worker fails the whole request (partial merges would be
    /// silently wrong). Returns the merged, null-closed census.
    fn distributed_census(
        &self,
        req: &CensusRequest,
        n: usize,
        shards: &[Shard],
        cancel: &CancelToken,
    ) -> std::result::Result<Census, WireError> {
        let partials: Vec<std::result::Result<Census, WireError>> =
            self.metrics.time("distributed_scatter", || {
                std::thread::scope(|scope| {
                    let handles: Vec<_> = shards
                        .iter()
                        .enumerate()
                        .map(|(i, &shard)| {
                            scope.spawn(move || self.dispatch_shard(req, shard, i, cancel))
                        })
                        .collect();
                    handles.into_iter().map(|h| h.join().unwrap()).collect()
                })
            });
        let mut merged = Census::zero();
        for partial in partials {
            merged += partial?;
            self.metrics.inc("shards_merged_total", 1);
        }
        merged.close_with_null(n);
        Ok(merged)
    }

    /// Ship one shard, starting at worker `index % pool` (so concurrent
    /// shards spread over the pool) and advancing to the next worker on
    /// retryable failures — transport errors and draining workers.
    /// Structured remote verdicts (bad request, graph load) propagate
    /// immediately: every worker would refuse them identically. A shard
    /// no worker could hold reports [`ErrorCode::WorkerUnavailable`].
    fn dispatch_shard(
        &self,
        req: &CensusRequest,
        shard: Shard,
        index: usize,
        cancel: &CancelToken,
    ) -> std::result::Result<Census, WireError> {
        let pool = &self.workers;
        let mut last = None;
        for attempt in 0..pool.len() {
            if cancel.is_cancelled() {
                return Err(cancelled_error());
            }
            let addr = pool[(index + attempt) % pool.len()].as_str();
            self.metrics.inc("shards_dispatched_total", 1);
            if attempt > 0 {
                self.metrics.inc("shards_retried_total", 1);
            }
            let t = Instant::now();
            match dispatch_once(addr, req, shard) {
                Ok(census) => {
                    self.metrics
                        .histogram(&format!("shard_worker_{addr}"))
                        .observe(t.elapsed().as_secs_f64());
                    return Ok(census);
                }
                Err(e) if shard_retryable(&e) => {
                    self.metrics.inc("shard_worker_failures_total", 1);
                    last = Some(e);
                }
                Err(e) => return Err(e),
            }
        }
        let detail = last.map(|e| format!(" (last: {e})")).unwrap_or_default();
        Err(WireError::new(
            ErrorCode::WorkerUnavailable,
            format!("shard {shard}: every worker in the pool failed{detail}"),
        ))
    }
}

/// One dispatch attempt: connect to a worker, run the shard as a
/// blocking census call, hand back its raw partial. The sub-request
/// keeps the parent's source verbatim (path sources make each worker
/// mmap the file locally; generator/inline sources re-materialize
/// deterministically) plus its `threads`/`policy` knobs; `engine`,
/// `ordering`, `classes`, `fidelity` and admission fields are
/// planner-level concerns and are stripped. Connection and transport failures
/// surface as `transport` errors, which [`Core::dispatch_shard`]
/// treats as retryable. Connecting is bounded so one dead worker
/// costs seconds, not a planner thread pinned forever; the read stays
/// unbounded — shard censuses legitimately run long.
fn dispatch_once(
    addr: &str,
    req: &CensusRequest,
    shard: Shard,
) -> std::result::Result<Census, WireError> {
    let mut sub = req.clone();
    sub.shard = Some(shard);
    sub.engine = None;
    sub.ordering = None;
    sub.classes = None;
    sub.tenant = None;
    sub.priority = None;
    sub.fidelity = None;
    let timeouts = ClientTimeouts::default().connect(std::time::Duration::from_secs(5));
    let mut client = TriadicClient::connect_with_timeouts(addr, timeouts)?;
    Ok(client.census(&sub)?.census)
}

/// Worker failures worth retrying on a different worker. Everything
/// else (bad request, graph load, unknown engine) is a verdict about
/// the request itself and would repeat on any worker.
fn shard_retryable(e: &WireError) -> bool {
    matches!(
        e.code,
        ErrorCode::Internal | ErrorCode::ShuttingDown | ErrorCode::Transport
    )
}

/// Split the vertices `0..n` into at most `k` contiguous ranges
/// balanced by *entry* count over the collapsed offsets (`offsets[v]` =
/// collapsed entries before vertex `v`; `offsets[n]` = total). Each
/// boundary is the first vertex whose cumulative entry count reaches
/// the ideal split point, so shards carry near-equal work even on
/// skewed degree distributions — the same balancing argument as the
/// paper's manhattan collapse, applied across processes. The ranges
/// cover `0..n` exactly: no gaps, no overlaps.
fn partition_shards(offsets: &[usize], k: usize) -> Vec<Shard> {
    let n = offsets.len() - 1;
    let total = offsets[n];
    let k = k.clamp(1, n.max(1));
    let mut shards = Vec::with_capacity(k);
    let mut lo = 0usize;
    for i in 1..=k {
        let target = (total as u128 * i as u128 / k as u128) as usize;
        let hi = if i == k {
            n
        } else {
            offsets.partition_point(|&o| o < target).clamp(lo, n)
        };
        shards.push(Shard::new(lo, hi));
        lo = hi;
    }
    shards
}

/// The coordinator: owns the router, the engine registry, one shared
/// process-lifetime [`Executor`] for all sparse census traffic, the
/// job-runner pool draining [`Coordinator::submit`], and (if artifacts
/// are present) the dense service thread.
pub struct Coordinator {
    core: Arc<Core>,
    dense_thread: Option<std::thread::JoinHandle<()>>,
    job_queue: Arc<JobQueue>,
    job_threads: Vec<std::thread::JoinHandle<()>>,
    job_seq: AtomicU64,
}

impl Coordinator {
    /// Start the coordinator on its own executor sized per
    /// `cfg.pool_threads` / `cfg.max_concurrent_jobs`. Compiles all
    /// dense artifacts up front (on the service thread) if an artifact
    /// directory is configured and readable; otherwise runs sparse-only.
    pub fn start(cfg: CoordinatorConfig) -> Result<Coordinator> {
        let executor = Arc::new(Executor::new(ExecutorConfig {
            workers: cfg.pool_threads,
            max_concurrent_jobs: cfg.max_concurrent_jobs,
            pin: cfg.pin,
        }));
        Coordinator::start_with_executor(cfg, executor)
    }

    /// Start on an existing shared pool — several coordinators (or a
    /// coordinator plus other parallel subsystems) can interleave jobs
    /// on one executor. `cfg.pool_threads` / `cfg.max_concurrent_jobs`
    /// are ignored here; the executor's own configuration governs.
    pub fn start_with_executor(
        cfg: CoordinatorConfig,
        executor: Arc<Executor>,
    ) -> Result<Coordinator> {
        let engines = EngineRegistry::builtin(cfg.sparse);
        if let Err(e) = engines.get_or_err(&cfg.engine) {
            return Err(Error::msg(e));
        }
        let metrics = Arc::new(Metrics::new());
        let mut routing = cfg.routing.clone();

        let (dense_tx, dense_thread) = match &cfg.artifacts_dir {
            Some(dir) if dir.join("manifest.tsv").exists() => {
                let (tx, rx) = mpsc::sync_channel::<DenseRequest>(cfg.dense_queue);
                let (size_tx, size_rx) = mpsc::channel::<Result<Vec<usize>>>();
                let dir = dir.clone();
                let m = metrics.clone();
                // PjRtLoadedExecutable is not Send: the runtime lives and
                // dies on this thread; requests arrive by channel.
                let handle = std::thread::Builder::new()
                    .name("dense-census".into())
                    .spawn(move || dense_service(dir, rx, size_tx, m))
                    .context("spawning dense service thread")?;
                let sizes = size_rx
                    .recv()
                    .context("dense service thread died during startup")??;
                routing.dense_sizes = sizes;
                (Some(tx), Some(handle))
            }
            _ => (None, None),
        };

        let core = Arc::new(Core {
            router: Router::new(routing),
            engines,
            split_engines: hybrid_registry(cfg.sparse),
            engine: cfg.engine,
            default_sparse: cfg.sparse,
            executor,
            dense_tx: Mutex::new(dense_tx),
            metrics,
            graphs: GraphStore::new(cfg.graph_cache, cfg.ingest_threads.max(1), cfg.trusted_mmap),
            splits: SplitCache::new(cfg.graph_cache),
            max_request_nodes: cfg.max_request_nodes,
            workers: cfg.workers,
        });

        let job_workers = if cfg.job_workers == 0 {
            std::thread::available_parallelism()
                .map(|p| p.get().min(4))
                .unwrap_or(2)
        } else {
            cfg.job_workers
        };
        let job_queue = Arc::new(JobQueue::default());
        let mut job_threads = Vec::with_capacity(job_workers);
        for i in 0..job_workers {
            let core = core.clone();
            let queue = job_queue.clone();
            let handle = std::thread::Builder::new()
                .name(format!("census-job-{i}"))
                .spawn(move || job_worker(&core, &queue))
                .context("spawning job runner thread")?;
            job_threads.push(handle);
        }

        Ok(Coordinator {
            core,
            dense_thread,
            job_queue,
            job_threads,
            job_seq: AtomicU64::new(0),
        })
    }

    /// Whether the dense backend is live.
    pub fn dense_enabled(&self) -> bool {
        self.core.dense_tx.lock().unwrap().is_some()
    }

    /// The routing table in force.
    pub fn router(&self) -> &Router {
        &self.core.router
    }

    /// Shared metrics registry.
    pub fn metrics(&self) -> &Metrics {
        &self.core.metrics
    }

    /// The shared executor serving all sparse census jobs.
    pub fn executor(&self) -> &Arc<Executor> {
        &self.core.executor
    }

    /// Name of the default sparse engine (requests may override).
    pub fn engine_name(&self) -> &str {
        &self.core.engine
    }

    /// Job-runner threads draining the submit queue.
    pub fn job_worker_count(&self) -> usize {
        self.job_threads.len()
    }

    /// The distributed worker pool this coordinator scatters shards to
    /// (empty when everything is served in-process).
    pub fn worker_pool(&self) -> &[String] {
        &self.core.workers
    }

    /// Materialize a request's graph source through the same path (and
    /// path cache) the census pipeline uses — the intake for streaming
    /// census sessions, which need the graph itself rather than a job.
    pub fn resolve_source(
        &self,
        source: &GraphSource,
    ) -> std::result::Result<Arc<CsrGraph>, WireError> {
        self.core.resolve_graph(source)
    }

    /// Compute the full census that seeds a streaming session, on the
    /// configured sparse engine (or `engine_override`) over the shared
    /// executor. `ordering: degree` runs the seed over the relabeled
    /// direction-split form — the census is relabeling-invariant, so
    /// the result seeds the *original* base exactly; the overlay keeps
    /// operating in original ids. Sampled fidelity first filters the
    /// base down to the deterministically kept dyads; the returned
    /// graph is then the *sampled* base the session must maintain over
    /// (exact fidelity hands `g` back unchanged). Returns the census,
    /// the engine name that produced it, and the session base.
    pub fn seed_census(
        &self,
        g: &Arc<CsrGraph>,
        engine_override: Option<&str>,
        ordering: Option<VertexOrdering>,
        fidelity: Option<Fidelity>,
    ) -> std::result::Result<(Census, String, Arc<CsrGraph>), WireError> {
        let base = match fidelity {
            Some(Fidelity::Sampled { p }) if p < 1.0 => {
                self.core.metrics.inc("census_sampled_total", 1);
                self.core.metrics.histogram("sample_rate").observe(p);
                let sampled = self.core.metrics.time("sample_filter", || {
                    sample_base(g, p, DEFAULT_SAMPLE_SEED)
                });
                Arc::new(sampled)
            }
            _ => g.clone(),
        };
        let name = engine_override.unwrap_or(&self.core.engine);
        match ordering.unwrap_or_default() {
            VertexOrdering::Natural => {
                let engine = self
                    .core
                    .engines
                    .get_or_err(name)
                    .map_err(|e| WireError::new(ErrorCode::UnknownEngine, e))?;
                let run = self.core.metrics.time("stream_seed_census", || {
                    engine.census(base.as_ref(), &self.core.executor)
                });
                Ok((run.census, engine.name().to_string(), base))
            }
            VertexOrdering::Degree => {
                let engine = self
                    .core
                    .split_engines
                    .get_or_err(name)
                    .map_err(|e| WireError::new(ErrorCode::UnknownEngine, e))?;
                let split = self.core.degree_split(&base, Some(&base));
                let run = self.core.metrics.time("stream_seed_census", || {
                    engine.census(split.as_ref(), &self.core.executor)
                });
                Ok((run.census, engine.name().to_string(), base))
            }
        }
    }

    /// Submit a census request for asynchronous execution. Always
    /// returns a handle: structurally broken requests (unknown engine,
    /// bad source) surface as an immediately-`Failed` job, which keeps
    /// local and remote error handling on one path.
    pub fn submit(&self, request: CensusRequest) -> JobHandle {
        let id = self.job_seq.fetch_add(1, Ordering::Relaxed) + 1;
        let shared = JobShared::new(id, self.core.metrics.clone());
        self.core.metrics.inc("jobs_submitted_total", 1);
        self.core.metrics.add_gauge("jobs_inflight", 1);
        let handle = JobHandle {
            shared: shared.clone(),
        };
        // fast-fail: validate the engine name before queueing so a typo
        // is observable on the very first poll
        if let Some(name) = &request.engine {
            if let Err(e) = self.core.engines.get_or_err(name) {
                shared.finish(JobProgress::Failed(WireError::new(
                    ErrorCode::UnknownEngine,
                    e,
                )));
                return handle;
            }
        }
        {
            let mut q = self.job_queue.inner.lock().unwrap();
            if q.shutdown {
                drop(q);
                shared.finish(JobProgress::Failed(WireError::new(
                    ErrorCode::ShuttingDown,
                    "coordinator is shutting down",
                )));
                return handle;
            }
            // priority insertion: ahead of strictly lower levels only,
            // so equal-priority jobs stay FIFO
            let priority = request.priority.unwrap_or(DEFAULT_PRIORITY);
            let job = QueuedJob {
                shared,
                request,
                priority,
            };
            match q.queue.iter().position(|j| j.priority < priority) {
                Some(i) => q.queue.insert(i, job),
                None => q.queue.push_back(job),
            }
        }
        self.job_queue.cv.notify_one();
        handle
    }

    /// Submit a batch of requests in order; handles come back in the
    /// same order. Jobs run concurrently up to the job-runner count.
    pub fn submit_batch<I>(&self, requests: I) -> Vec<JobHandle>
    where
        I: IntoIterator<Item = CensusRequest>,
    {
        requests.into_iter().map(|r| self.submit(r)).collect()
    }

    /// Serve one census request synchronously — a thin compatibility
    /// shim over the job pipeline's serving core (same routing, engine
    /// dispatch and metrics; no queue hop). Concurrent callers remain
    /// the intended workload: every sparse request is one job on the
    /// shared executor.
    pub fn census(&self, g: &CsrGraph) -> Result<CensusOutcome> {
        self.census_ordered(g, None)
    }

    /// [`Coordinator::census`] with a vertex-ordering override — the
    /// CLI's `--order` lands here; requests over the wire carry the
    /// knob in `CensusRequest::ordering` instead.
    pub fn census_ordered(
        &self,
        g: &CsrGraph,
        ordering: Option<VertexOrdering>,
    ) -> Result<CensusOutcome> {
        let t0 = Instant::now();
        let out = self
            .core
            .run_route(g, None, None, None, None, ordering, None, &CancelToken::new())
            .map_err(Error::msg)?;
        Ok(CensusOutcome {
            census: out.census,
            route: out.route,
            seconds: t0.elapsed().as_secs_f64(),
            stats: out.stats,
            ordering: out.ordering,
        })
    }

    /// Serve a census for an on-disk graph through the path cache —
    /// the second compatibility shim ([`GraphSource::Path`] requests use
    /// the same cache). `TRIADIC2` files are memory-mapped —
    /// checksum-verified on first touch by default (one sequential
    /// scan), or O(1) with [`CoordinatorConfig::trusted_mmap`] — which
    /// is the workflow for multi-GB graphs converted once and served
    /// across restarts; legacy binaries and edge lists are parsed on
    /// first touch and cached.
    pub fn census_path<P: AsRef<Path>>(&self, path: P) -> Result<CensusOutcome> {
        let g = self.core.graphs.get_or_load(path.as_ref(), &self.core.metrics)?;
        self.census(&g)
    }

    /// Drain and stop the job runners and the dense service thread.
    pub fn shutdown(mut self) {
        self.stop_workers();
    }

    fn stop_workers(&mut self) {
        // 1. close the submit queue; cancel whatever never started
        let drained: Vec<QueuedJob> = {
            let mut q = self.job_queue.inner.lock().unwrap();
            q.shutdown = true;
            q.queue.drain(..).collect()
        };
        self.job_queue.cv.notify_all();
        for job in drained {
            job.shared.finish(JobProgress::Cancelled);
        }
        for h in self.job_threads.drain(..) {
            let _ = h.join();
        }
        // 2. close the dense channel; the service loop exits on recv Err
        self.core.dense_tx.lock().unwrap().take();
        if let Some(h) = self.dense_thread.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.stop_workers();
    }
}

/// Body of the dense service thread: compile artifacts, report sizes,
/// then drain the queue until the coordinator closes it.
fn dense_service(
    dir: PathBuf,
    rx: mpsc::Receiver<DenseRequest>,
    size_tx: mpsc::Sender<Result<Vec<usize>>>,
    metrics: Arc<Metrics>,
) {
    let mut runtime = match DenseCensusRuntime::load_dir(&dir) {
        Ok(rt) => {
            let _ = size_tx.send(Ok(rt.sizes()));
            rt
        }
        Err(e) => {
            let _ = size_tx.send(Err(e));
            return;
        }
    };
    metrics.inc("dense_artifacts_compiled", runtime.stats().compiled as u64);
    while let Ok(req) = rx.recv() {
        let result = runtime.census(&req.graph);
        metrics.inc("dense_executions_total", 1);
        let _ = req.reply.send(result);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::census::merged;
    use crate::graph::generators;

    #[cfg(feature = "xla")]
    fn artifacts_available() -> bool {
        PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/manifest.tsv")
            .exists()
    }

    #[cfg(feature = "xla")]
    fn test_config() -> CoordinatorConfig {
        CoordinatorConfig {
            artifacts_dir: Some(PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")),
            ..CoordinatorConfig::default()
        }
    }

    fn sparse_coordinator() -> Coordinator {
        Coordinator::start(CoordinatorConfig {
            artifacts_dir: None,
            ..CoordinatorConfig::default()
        })
        .unwrap()
    }

    #[test]
    fn sparse_only_when_artifacts_missing() {
        let cfg = CoordinatorConfig {
            artifacts_dir: Some(PathBuf::from("/nonexistent")),
            ..CoordinatorConfig::default()
        };
        let coord = Coordinator::start(cfg).unwrap();
        assert!(!coord.dense_enabled());
        let g = generators::erdos_renyi(40, 300, 3);
        let out = coord.census(&g).unwrap();
        assert_eq!(out.route, Route::Sparse);
        assert_eq!(out.census, merged::census(&g));
        // sparse requests carry per-job executor telemetry
        let stats = out.stats.expect("sparse route returns job stats");
        assert_eq!(stats.items.iter().sum::<usize>(), g.entry_count());
        assert_eq!(
            coord.metrics().get("census_slots_total"),
            g.entry_count() as u64
        );
        assert_eq!(coord.executor().stats().jobs, 1);
    }

    #[test]
    fn engine_is_selected_by_name() {
        for engine in ["naive", "bm", "merged", "parallel", "moody"] {
            let coord = Coordinator::start(CoordinatorConfig {
                artifacts_dir: None,
                engine: engine.to_string(),
                pool_threads: 2,
                ..CoordinatorConfig::default()
            })
            .unwrap();
            let g = generators::erdos_renyi(30, 150, 7);
            let out = coord.census(&g).unwrap();
            assert_eq!(out.census, merged::census(&g), "engine {engine}");
        }
    }

    #[test]
    fn unknown_engine_is_rejected_at_startup() {
        let err = Coordinator::start(CoordinatorConfig {
            artifacts_dir: None,
            engine: "quantum".to_string(),
            ..CoordinatorConfig::default()
        })
        .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("unknown census engine"), "{msg}");
        assert!(msg.contains("parallel"), "should list available: {msg}");
    }

    #[test]
    fn coordinators_can_share_one_executor() {
        let exec = std::sync::Arc::new(crate::sched::Executor::with_workers(2));
        let mk = || {
            Coordinator::start_with_executor(
                CoordinatorConfig {
                    artifacts_dir: None,
                    ..CoordinatorConfig::default()
                },
                exec.clone(),
            )
            .unwrap()
        };
        let (a, b) = (mk(), mk());
        let g = generators::power_law(300, 2.2, 6.0, 9);
        let want = merged::census(&g);
        assert_eq!(a.census(&g).unwrap().census, want);
        assert_eq!(b.census(&g).unwrap().census, want);
        assert!(exec.stats().jobs >= 2, "both coordinators used the pool");
    }

    #[cfg(feature = "xla")]
    #[test]
    fn routes_and_answers_match_both_backends() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let coord = Coordinator::start(test_config()).unwrap();
        assert!(coord.dense_enabled());

        // dense route: small dense graph
        let g = generators::erdos_renyi(50, 500, 7);
        let out = coord.census(&g).unwrap();
        assert!(matches!(out.route, Route::Dense { size: 64 }), "{:?}", out.route);
        assert_eq!(out.census, merged::census(&g));

        // sparse route: large graph
        let g = generators::power_law(2000, 2.2, 6.0, 5);
        let out = coord.census(&g).unwrap();
        assert_eq!(out.route, Route::Sparse);
        assert_eq!(out.census, merged::census(&g));

        assert_eq!(coord.metrics().get("census_dense_total"), 1);
        assert_eq!(coord.metrics().get("census_sparse_total"), 1);
        coord.shutdown();
    }

    #[cfg(feature = "xla")]
    #[test]
    fn many_requests_through_the_queue() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let coord = Coordinator::start(test_config()).unwrap();
        for seed in 0..8 {
            let g = generators::erdos_renyi(30, 200, seed);
            let out = coord.census(&g).unwrap();
            assert_eq!(out.census, merged::census(&g), "seed {seed}");
        }
        assert_eq!(coord.metrics().get("dense_executions_total"), 8);
    }

    #[test]
    fn census_path_serves_mapped_v2_files_from_cache() {
        let coord = sparse_coordinator();
        let g = generators::power_law(600, 2.2, 6.0, 41);
        let want = merged::census(&g);
        let path = std::env::temp_dir().join("triadic_coord_cache.csr");
        crate::graph::io::write_binary_v2_file(&g, &path).unwrap();

        let out = coord.census_path(&path).unwrap();
        assert_eq!(out.census, want);
        let out = coord.census_path(&path).unwrap();
        assert_eq!(out.census, want);
        assert_eq!(coord.metrics().get("graph_cache_misses_total"), 1);
        assert_eq!(coord.metrics().get("graph_cache_hits_total"), 1);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn graph_cache_invalidates_rewritten_files() {
        let coord = sparse_coordinator();
        let dir = std::env::temp_dir();
        let path = dir.join("triadic_stale_cache.csr");
        let g1 = generators::power_law(300, 2.2, 6.0, 1);
        crate::graph::io::write_binary_v2_file(&g1, &path).unwrap();
        assert_eq!(coord.census_path(&path).unwrap().census, merged::census(&g1));
        // replace atomically (write-to-temp + rename) with a new graph
        let g2 = generators::power_law(450, 2.2, 6.0, 2);
        let tmp = dir.join("triadic_stale_cache.csr.tmp");
        crate::graph::io::write_binary_v2_file(&g2, &tmp).unwrap();
        std::fs::rename(&tmp, &path).unwrap();
        assert_eq!(coord.census_path(&path).unwrap().census, merged::census(&g2));
        assert_eq!(coord.metrics().get("graph_cache_stale_total"), 1);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn census_path_reports_load_errors() {
        let coord = sparse_coordinator();
        let err = coord.census_path("/nonexistent/graph.csr").unwrap_err();
        assert!(err.to_string().contains("loading graph"), "{err}");
    }

    #[test]
    fn graph_cache_evicts_fifo() {
        let store = GraphStore::new(2, 1, false);
        let metrics = Metrics::new();
        let dir = std::env::temp_dir();
        let mut paths = Vec::new();
        for i in 0..3u64 {
            let g = generators::erdos_renyi(20, 40, i);
            let p = dir.join(format!("triadic_store_{i}.csr"));
            crate::graph::io::write_binary_v2_file(&g, &p).unwrap();
            paths.push(p);
        }
        for p in &paths {
            store.get_or_load(p, &metrics).unwrap();
        }
        // capacity 2: the first path was evicted, reloading it misses
        store.get_or_load(&paths[0], &metrics).unwrap();
        assert_eq!(metrics.get("graph_cache_misses_total"), 4);
        // the most recent two still hit
        store.get_or_load(&paths[2], &metrics).unwrap();
        assert_eq!(metrics.get("graph_cache_hits_total"), 1);
        for p in paths {
            let _ = std::fs::remove_file(p);
        }
    }

    // --- job API ---

    #[test]
    fn submit_wait_returns_a_versioned_response() {
        let coord = sparse_coordinator();
        let handle = coord.submit(CensusRequest::generator("patents", 300).seed(5));
        let response = handle.wait().unwrap();
        let want = merged::census(
            &generators::spec_by_name("patents", 300, Some(5))
                .unwrap()
                .generate(),
        );
        assert_eq!(response.census, want);
        assert_eq!(response.protocol_version, PROTOCOL_VERSION);
        assert_eq!(response.job, handle.id());
        assert_eq!(response.provenance.route, "sparse");
        assert_eq!(response.provenance.engine, "parallel");
        assert!(response.provenance.source.starts_with("generator:patents"));
        assert_eq!(response.provenance.nodes, 300);
        assert!(response.stats.is_some());
        assert!(matches!(handle.poll(), JobStatus::Done(_)));
        assert_eq!(coord.metrics().get("jobs_submitted_total"), 1);
        assert_eq!(coord.metrics().get("jobs_done_total"), 1);
        assert_eq!(coord.metrics().gauge("jobs_inflight"), 0);
    }

    #[test]
    fn submit_batch_runs_mixed_sources_and_engines() {
        let coord = sparse_coordinator();
        let inline_arcs = vec![(0u32, 1u32), (1, 2), (2, 0), (2, 3)];
        let path = std::env::temp_dir().join("triadic_job_batch.csr");
        let path_graph = generators::power_law(250, 2.2, 6.0, 8);
        crate::graph::io::write_binary_v2_file(&path_graph, &path).unwrap();

        let handles = coord.submit_batch(vec![
            CensusRequest::path(path.to_str().unwrap()),
            CensusRequest::inline(4, inline_arcs.clone()).engine("merged"),
            CensusRequest::generator("orkut", 120).seed(3).engine("bm"),
            CensusRequest::generator("web", 150)
                .seed(4)
                .engine("parallel")
                .threads(3)
                .policy(Policy::Static { chunk: 64 }),
        ]);
        assert_eq!(handles.len(), 4);

        let wants = [
            merged::census(&path_graph),
            merged::census(&GraphBuilder::new(4).arcs(&inline_arcs).build()),
            merged::census(
                &generators::spec_by_name("orkut", 120, Some(3))
                    .unwrap()
                    .generate(),
            ),
            merged::census(
                &generators::spec_by_name("web", 150, Some(4))
                    .unwrap()
                    .generate(),
            ),
        ];
        for (handle, want) in handles.iter().zip(&wants) {
            let response = handle.wait().unwrap();
            assert_eq!(&response.census, want, "job {}", handle.id());
        }
        assert_eq!(coord.metrics().get("jobs_done_total"), 4);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn class_subset_requests_echo_their_selection() {
        let coord = sparse_coordinator();
        let g = generators::named::cycle3();
        let handle = coord.submit(
            CensusRequest::inline(3, vec![(0, 1), (1, 2), (2, 0)])
                .engine("merged")
                .classes(vec![crate::census::TriadType::T030C]),
        );
        let response = handle.wait().unwrap();
        assert_eq!(response.census, merged::census(&g));
        assert_eq!(
            response.selected_counts(),
            vec![(crate::census::TriadType::T030C, 1)]
        );
    }

    #[test]
    fn degree_ordered_jobs_return_identical_censuses() {
        let coord = sparse_coordinator();
        let natural = coord
            .submit(CensusRequest::generator("patents", 400).seed(11).engine("merged"))
            .wait()
            .unwrap();
        assert_eq!(natural.provenance.ordering, "natural");
        for engine in ["naive", "bm", "merged", "parallel", "moody"] {
            let ordered = coord
                .submit(
                    CensusRequest::generator("patents", 400)
                        .seed(11)
                        .engine(engine)
                        .ordering(crate::graph::VertexOrdering::Degree),
                )
                .wait()
                .unwrap();
            assert_eq!(ordered.census, natural.census, "engine {engine}");
            assert_eq!(ordered.provenance.ordering, "degree", "engine {engine}");
        }
        assert_eq!(coord.metrics().get("census_degree_ordered_total"), 5);
        // the shim-level override agrees too
        let g = generators::spec_by_name("patents", 400, Some(11))
            .unwrap()
            .generate();
        let out = coord
            .census_ordered(&g, Some(crate::graph::VertexOrdering::Degree))
            .unwrap();
        assert_eq!(out.census, natural.census);
        assert_eq!(out.ordering, crate::graph::VertexOrdering::Degree);
        // plain census() reports the ordering it ran: natural
        assert_eq!(coord.census(&g).unwrap().ordering, crate::graph::VertexOrdering::Natural);
    }

    #[test]
    fn degree_split_cache_reuses_preprocessed_forms() {
        let coord = sparse_coordinator();
        let g = generators::power_law(500, 2.2, 6.0, 23);
        let want = merged::census(&g);
        let path = std::env::temp_dir().join("triadic_split_cache.csr");
        crate::graph::io::write_binary_v2_file(&g, &path).unwrap();

        // Path sources resolve to the graph cache's pinned Arc, so the
        // hub-split form is built once and reused by identity.
        for _ in 0..3 {
            let out = coord
                .submit(
                    CensusRequest::path(path.to_str().unwrap())
                        .ordering(crate::graph::VertexOrdering::Degree),
                )
                .wait()
                .unwrap();
            assert_eq!(out.census, want);
            assert_eq!(out.provenance.ordering, "degree");
        }
        assert_eq!(coord.metrics().get("split_cache_misses_total"), 1);
        assert_eq!(coord.metrics().get("split_cache_hits_total"), 2);

        // Generator sources materialize a fresh Arc per request: each
        // one misses, and its weak entry is pruned as stale once the
        // graph dies.
        for _ in 0..2 {
            let out = coord
                .submit(
                    CensusRequest::generator("patents", 300)
                        .seed(7)
                        .ordering(crate::graph::VertexOrdering::Degree),
                )
                .wait()
                .unwrap();
            assert_eq!(out.provenance.ordering, "degree");
        }
        assert_eq!(coord.metrics().get("split_cache_misses_total"), 3);
        assert_eq!(coord.metrics().get("split_cache_hits_total"), 2);
        assert_eq!(coord.metrics().get("split_cache_stale_total"), 1);
        assert_eq!(coord.metrics().get("census_degree_ordered_total"), 5);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn unknown_engine_fails_the_job_immediately() {
        let coord = sparse_coordinator();
        let handle = coord.submit(CensusRequest::generator("patents", 100).engine("quantum"));
        match handle.poll() {
            JobStatus::Failed(e) => {
                assert_eq!(e.code, ErrorCode::UnknownEngine);
                assert!(e.message.contains("quantum"), "{e}");
            }
            other => panic!("expected immediate failure, got {:?}", other.kind()),
        }
        assert!(handle.wait().is_err());
        assert_eq!(coord.metrics().get("jobs_failed_total"), 1);
    }

    #[test]
    fn bad_sources_fail_with_structured_codes() {
        let coord = sparse_coordinator();
        let cases = [
            (
                CensusRequest::path("/nonexistent/never.csr"),
                ErrorCode::GraphLoad,
            ),
            (
                CensusRequest::generator("martian", 100),
                ErrorCode::BadRequest,
            ),
            (
                CensusRequest::inline(2, vec![(0, 5)]),
                ErrorCode::BadRequest,
            ),
        ];
        for (req, want_code) in cases {
            let err = coord.submit(req).wait().unwrap_err();
            assert_eq!(err.code, want_code, "{err}");
        }
    }

    #[test]
    fn oversized_requests_are_rejected_not_materialized() {
        let coord = Coordinator::start(CoordinatorConfig {
            artifacts_dir: None,
            max_request_nodes: 1_000,
            ..CoordinatorConfig::default()
        })
        .unwrap();
        for req in [
            CensusRequest::generator("patents", 1_001),
            CensusRequest::inline(1_001, vec![]),
        ] {
            let err = coord.submit(req).wait().unwrap_err();
            assert_eq!(err.code, ErrorCode::BadRequest, "{err}");
            assert!(err.message.contains("max_request_nodes"), "{err}");
        }
        // at the limit is fine
        let ok = coord.submit(CensusRequest::generator("patents", 1_000).seed(1));
        assert!(ok.wait().is_ok());
    }

    #[test]
    fn resolve_source_and_seed_census_back_streams() {
        let coord = sparse_coordinator();
        let g = coord
            .resolve_source(&GraphSource::Generator {
                name: "patents".to_string(),
                nodes: 200,
                seed: Some(3),
            })
            .unwrap();
        assert_eq!(g.node_count(), 200);
        let (census, engine, base) = coord.seed_census(&g, Some("merged"), None, None).unwrap();
        assert_eq!(census, merged::census(g.as_ref()));
        assert_eq!(engine, "merged");
        assert!(Arc::ptr_eq(&base, &g), "exact fidelity keeps the base");
        let (default_census, default_engine, _) = coord.seed_census(&g, None, None, None).unwrap();
        assert_eq!(default_census, census);
        assert_eq!(default_engine, "parallel");
        // degree-ordered seeding is census-invariant
        let (ordered_census, _, _) = coord
            .seed_census(&g, Some("merged"), Some(VertexOrdering::Degree), None)
            .unwrap();
        assert_eq!(ordered_census, census);
        // sampled fidelity seeds over the filtered base
        let fid = Some(Fidelity::Sampled { p: 0.5 });
        let (sampled_census, _, sampled_base) =
            coord.seed_census(&g, Some("merged"), None, fid).unwrap();
        assert!(sampled_base.arc_count() < g.arc_count());
        assert_eq!(sampled_census, merged::census(sampled_base.as_ref()));
        assert_eq!(coord.metrics().get("census_sampled_total"), 1);
        let err = coord.seed_census(&g, Some("quantum"), None, None).unwrap_err();
        assert_eq!(err.code, ErrorCode::UnknownEngine);
        let err = coord
            .seed_census(&g, Some("quantum"), Some(VertexOrdering::Degree), None)
            .unwrap_err();
        assert_eq!(err.code, ErrorCode::UnknownEngine);
        let err = coord
            .resolve_source(&GraphSource::Path("/nonexistent/x.csr".to_string()))
            .unwrap_err();
        assert_eq!(err.code, ErrorCode::GraphLoad);
    }

    // --- distributed planner ---

    #[test]
    fn partition_shards_covers_the_vertex_space() {
        let g = generators::power_law(500, 2.2, 6.0, 13);
        let offsets = g.flat_offsets();
        let n = g.node_count();
        for k in [1usize, 2, 3, 7, 64, 1_000] {
            let shards = partition_shards(&offsets, k);
            assert_eq!(shards.len(), k.min(n), "k={k}");
            assert_eq!(shards[0].lo, 0, "k={k}");
            assert_eq!(shards.last().unwrap().hi, n, "k={k}");
            for pair in shards.windows(2) {
                assert_eq!(pair[0].hi, pair[1].lo, "contiguous, k={k}");
            }
            // entry-balanced: no shard exceeds its fair share by more
            // than one vertex's worth of entries
            let total = offsets[n];
            let heaviest = shards
                .iter()
                .map(|s| offsets[s.hi] - offsets[s.lo])
                .max()
                .unwrap();
            let max_vertex = offsets.windows(2).map(|w| w[1] - w[0]).max().unwrap();
            assert!(
                heaviest <= total / k.min(n) + max_vertex + 1,
                "k={k}: heaviest {heaviest} vs fair {} + {max_vertex}",
                total / k.min(n)
            );
        }
        // degenerate inputs: k=0 clamps to 1; an arcless graph still
        // partitions into covering (mostly empty) ranges
        assert_eq!(partition_shards(&offsets, 0), vec![Shard::new(0, n)]);
        let empty = [0usize; 6]; // 5 nodes, no entries
        let shards = partition_shards(&empty, 3);
        assert_eq!(shards[0].lo, 0);
        assert_eq!(shards.last().unwrap().hi, 5);
        for pair in shards.windows(2) {
            assert_eq!(pair[0].hi, pair[1].lo);
        }
    }

    #[test]
    fn shard_requests_return_raw_partials_that_merge_exactly() {
        let coord = sparse_coordinator();
        let g = generators::spec_by_name("patents", 300, Some(21))
            .unwrap()
            .generate();
        let want = merged::census(&g);
        // an uneven cut with an empty and a single-node shard
        let cuts = [0usize, 0, 1, 97, 205, 300];
        let mut total = Census::zero();
        for pair in cuts.windows(2) {
            let response = coord
                .submit(
                    CensusRequest::generator("patents", 300)
                        .seed(21)
                        .shard(pair[0], pair[1]),
                )
                .wait()
                .unwrap();
            // raw partial: the null slot is never set by a leaf
            assert_eq!(
                response.census[crate::census::TriadType::T003],
                0,
                "shard {}..{}",
                pair[0],
                pair[1]
            );
            assert_eq!(response.provenance.route, "sparse");
            total += response.census;
        }
        total.close_with_null(g.node_count());
        assert_eq!(total, want);
        assert_eq!(coord.metrics().get("census_shard_total"), 5);
    }

    #[test]
    fn out_of_bounds_shards_are_rejected_with_the_valid_range() {
        let coord = sparse_coordinator();
        let err = coord
            .submit(CensusRequest::generator("patents", 100).seed(1).shard(50, 101))
            .wait()
            .unwrap_err();
        assert_eq!(err.code, ErrorCode::BadRequest);
        assert!(err.message.contains("50..101"), "{err}");
        assert!(err.message.contains("0 <= lo <= hi <= 100"), "{err}");
    }

    #[test]
    fn queued_jobs_cancel_immediately() {
        // one runner: occupy it, then cancel a job that is still queued
        let coord = Coordinator::start(CoordinatorConfig {
            artifacts_dir: None,
            job_workers: 1,
            ..CoordinatorConfig::default()
        })
        .unwrap();
        let blocker = coord.submit(CensusRequest::generator("patents", 60_000).seed(1));
        while !matches!(blocker.poll(), JobStatus::Running) {
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        let queued = coord.submit(CensusRequest::generator("patents", 300).seed(2));
        assert!(matches!(queued.poll(), JobStatus::Queued));
        assert!(queued.cancel());
        assert!(matches!(queued.poll(), JobStatus::Cancelled));
        let err = queued.wait().unwrap_err();
        assert_eq!(err.code, ErrorCode::Cancelled);
        // the blocker is unaffected
        assert!(blocker.wait().is_ok());
        assert_eq!(coord.metrics().get("jobs_cancelled_total"), 1);
        // cancelling a terminal job reports no effect
        assert!(!queued.cancel());
    }

    #[test]
    fn running_jobs_cancel_cooperatively() {
        let coord = Coordinator::start(CoordinatorConfig {
            artifacts_dir: None,
            job_workers: 1,
            ..CoordinatorConfig::default()
        })
        .unwrap();
        // big enough that generation + census outlive the cancel below
        let handle = coord.submit(CensusRequest::generator("patents", 80_000).seed(9));
        while !matches!(handle.poll(), JobStatus::Running) {
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert!(handle.cancel());
        let err = handle.wait().unwrap_err();
        assert_eq!(err.code, ErrorCode::Cancelled);
        assert_eq!(coord.metrics().get("jobs_cancelled_total"), 1);
    }

    #[test]
    fn shutdown_cancels_whatever_never_started() {
        let coord = Coordinator::start(CoordinatorConfig {
            artifacts_dir: None,
            job_workers: 1,
            ..CoordinatorConfig::default()
        })
        .unwrap();
        let blocker = coord.submit(CensusRequest::generator("patents", 50_000).seed(1));
        while !matches!(blocker.poll(), JobStatus::Running) {
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        let queued = coord.submit(CensusRequest::generator("patents", 300).seed(2));
        coord.shutdown();
        assert!(matches!(queued.poll(), JobStatus::Cancelled));
    }
}
