//! The coordinator service: request intake, graph loading (with an
//! mmap-aware cache), backend dispatch, dense service thread, metrics.

use std::collections::HashMap;
use std::collections::VecDeque;
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use super::router::{Route, Router, RoutingPolicy};
use crate::census::{census_parallel, Census, ParallelConfig};
use crate::error::{Context, Result};
use crate::graph::{io, CsrGraph};
use crate::metrics::Metrics;
use crate::runtime::DenseCensusRuntime;

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Artifact directory for the dense backend; `None` disables it.
    pub artifacts_dir: Option<PathBuf>,
    /// Sparse engine configuration.
    pub sparse: ParallelConfig,
    /// Routing overrides (dense sizes are filled from the manifest).
    pub routing: RoutingPolicy,
    /// Dense request queue depth (backpressure bound).
    pub dense_queue: usize,
    /// Worker threads for edge-list ingestion on [`Coordinator::census_path`].
    pub ingest_threads: usize,
    /// Graphs kept resident by the path cache (FIFO eviction; 0
    /// disables caching). Mapped v2 graphs cost almost no heap, so
    /// serving the same converted graph across requests is free.
    pub graph_cache: usize,
    /// Trust `TRIADIC2` files on [`Coordinator::census_path`]: skip the
    /// whole-file checksum scan and mmap in O(1) (header bounds checks
    /// only). Enable when the coordinator serves files it converted
    /// itself; leave off for files of unknown provenance.
    pub trusted_mmap: bool,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            artifacts_dir: Some(PathBuf::from("artifacts")),
            sparse: ParallelConfig::default(),
            routing: RoutingPolicy::default(),
            dense_queue: 64,
            ingest_threads: std::thread::available_parallelism()
                .map(|p| p.get())
                .unwrap_or(1),
            graph_cache: 8,
            trusted_mmap: false,
        }
    }
}

/// Path-keyed cache of loaded graphs with FIFO eviction.
struct GraphStore {
    capacity: usize,
    ingest_threads: usize,
    trusted_mmap: bool,
    inner: Mutex<StoreInner>,
}

#[derive(Default)]
struct StoreInner {
    map: HashMap<PathBuf, Arc<CsrGraph>>,
    order: VecDeque<PathBuf>,
}

impl GraphStore {
    fn new(capacity: usize, ingest_threads: usize, trusted_mmap: bool) -> GraphStore {
        GraphStore {
            capacity,
            ingest_threads,
            trusted_mmap,
            inner: Mutex::new(StoreInner::default()),
        }
    }

    /// Fetch a cached graph or load it (mmap for v2 files, parallel
    /// parse for edge lists) and cache it.
    fn get_or_load(&self, path: &Path, metrics: &Metrics) -> Result<Arc<CsrGraph>> {
        if self.capacity > 0 {
            let cache = self.inner.lock().unwrap();
            if let Some(g) = cache.map.get(path) {
                metrics.inc("graph_cache_hits_total", 1);
                return Ok(g.clone());
            }
        }
        metrics.inc("graph_cache_misses_total", 1);
        let loaded = metrics
            .time("graph_load", || {
                io::load_auto_with(path, self.ingest_threads, !self.trusted_mmap)
            })
            .with_context(|| format!("loading graph {}", path.display()))?;
        let g = Arc::new(loaded);
        if self.capacity > 0 {
            let mut cache = self.inner.lock().unwrap();
            if !cache.map.contains_key(path) {
                while cache.order.len() >= self.capacity {
                    if let Some(old) = cache.order.pop_front() {
                        cache.map.remove(&old);
                    }
                }
                cache.map.insert(path.to_path_buf(), g.clone());
                cache.order.push_back(path.to_path_buf());
            }
        }
        Ok(g)
    }
}

/// A served census with provenance and timing.
#[derive(Debug, Clone)]
pub struct CensusOutcome {
    pub census: Census,
    pub route: Route,
    pub seconds: f64,
}

/// Request envelope for the dense service thread.
struct DenseRequest {
    graph: CsrGraph,
    reply: mpsc::Sender<Result<Census>>,
}

/// The coordinator: owns the router, the sparse engine configuration and
/// (if artifacts are present) the dense service thread.
pub struct Coordinator {
    router: Router,
    sparse: ParallelConfig,
    dense_tx: Option<mpsc::SyncSender<DenseRequest>>,
    dense_thread: Option<std::thread::JoinHandle<()>>,
    metrics: Arc<Metrics>,
    graphs: GraphStore,
}

impl Coordinator {
    /// Start the coordinator. Compiles all dense artifacts up front (on
    /// the service thread) if an artifact directory is configured and
    /// readable; otherwise runs sparse-only.
    pub fn start(cfg: CoordinatorConfig) -> Result<Coordinator> {
        let metrics = Arc::new(Metrics::new());
        let mut routing = cfg.routing.clone();

        let (dense_tx, dense_thread) = match &cfg.artifacts_dir {
            Some(dir) if dir.join("manifest.tsv").exists() => {
                let (tx, rx) = mpsc::sync_channel::<DenseRequest>(cfg.dense_queue);
                let (size_tx, size_rx) = mpsc::channel::<Result<Vec<usize>>>();
                let dir = dir.clone();
                let m = metrics.clone();
                // PjRtLoadedExecutable is not Send: the runtime lives and
                // dies on this thread; requests arrive by channel.
                let handle = std::thread::Builder::new()
                    .name("dense-census".into())
                    .spawn(move || dense_service(dir, rx, size_tx, m))
                    .context("spawning dense service thread")?;
                let sizes = size_rx
                    .recv()
                    .context("dense service thread died during startup")??;
                routing.dense_sizes = sizes;
                (Some(tx), Some(handle))
            }
            _ => (None, None),
        };

        Ok(Coordinator {
            router: Router::new(routing),
            sparse: cfg.sparse,
            dense_tx,
            dense_thread,
            metrics,
            graphs: GraphStore::new(cfg.graph_cache, cfg.ingest_threads.max(1), cfg.trusted_mmap),
        })
    }

    /// Whether the dense backend is live.
    pub fn dense_enabled(&self) -> bool {
        self.dense_tx.is_some()
    }

    /// The routing table in force.
    pub fn router(&self) -> &Router {
        &self.router
    }

    /// Shared metrics registry.
    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Serve one census request synchronously (the monitor and the CLI
    /// drive this; concurrent callers are fine — the sparse engine is
    /// reentrant and the dense service serializes behind its queue).
    pub fn census(&self, g: &CsrGraph) -> Result<CensusOutcome> {
        let t0 = Instant::now();
        let route = self.router.route(g);
        let census = match (route, &self.dense_tx) {
            (Route::Dense { .. }, Some(tx)) => {
                self.metrics.inc("census_dense_total", 1);
                let (reply_tx, reply_rx) = mpsc::channel();
                tx.send(DenseRequest {
                    graph: g.clone(),
                    reply: reply_tx,
                })
                .ok()
                .context("dense service thread gone")?;
                self.metrics
                    .time("dense_census", || reply_rx.recv())
                    .context("dense service dropped the request")??
            }
            _ => {
                self.metrics.inc("census_sparse_total", 1);
                self.metrics
                    .time("sparse_census", || census_parallel(g, &self.sparse))
                    .census
            }
        };
        Ok(CensusOutcome {
            census,
            route,
            seconds: t0.elapsed().as_secs_f64(),
        })
    }

    /// Serve a census for an on-disk graph through the path cache.
    /// `TRIADIC2` files are memory-mapped — checksum-verified on first
    /// touch by default (one sequential scan), or O(1) with
    /// [`CoordinatorConfig::trusted_mmap`] — which is the workflow for
    /// multi-GB graphs converted once and served across restarts;
    /// legacy binaries and edge lists are parsed on first touch and
    /// cached.
    pub fn census_path<P: AsRef<Path>>(&self, path: P) -> Result<CensusOutcome> {
        let g = self.graphs.get_or_load(path.as_ref(), &self.metrics)?;
        self.census(&g)
    }

    /// Drain and stop the dense service thread.
    pub fn shutdown(mut self) {
        self.dense_tx.take(); // close the channel; service loop exits
        if let Some(h) = self.dense_thread.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.dense_tx.take();
        if let Some(h) = self.dense_thread.take() {
            let _ = h.join();
        }
    }
}

/// Body of the dense service thread: compile artifacts, report sizes,
/// then drain the queue until the coordinator closes it.
fn dense_service(
    dir: PathBuf,
    rx: mpsc::Receiver<DenseRequest>,
    size_tx: mpsc::Sender<Result<Vec<usize>>>,
    metrics: Arc<Metrics>,
) {
    let mut runtime = match DenseCensusRuntime::load_dir(&dir) {
        Ok(rt) => {
            let _ = size_tx.send(Ok(rt.sizes()));
            rt
        }
        Err(e) => {
            let _ = size_tx.send(Err(e));
            return;
        }
    };
    metrics.inc("dense_artifacts_compiled", runtime.stats().compiled as u64);
    while let Ok(req) = rx.recv() {
        let result = runtime.census(&req.graph);
        metrics.inc("dense_executions_total", 1);
        let _ = req.reply.send(result);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::census::merged;
    use crate::graph::generators;

    #[cfg(feature = "xla")]
    fn artifacts_available() -> bool {
        PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/manifest.tsv")
            .exists()
    }

    #[cfg(feature = "xla")]
    fn test_config() -> CoordinatorConfig {
        CoordinatorConfig {
            artifacts_dir: Some(PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")),
            ..CoordinatorConfig::default()
        }
    }

    #[test]
    fn sparse_only_when_artifacts_missing() {
        let cfg = CoordinatorConfig {
            artifacts_dir: Some(PathBuf::from("/nonexistent")),
            ..CoordinatorConfig::default()
        };
        let coord = Coordinator::start(cfg).unwrap();
        assert!(!coord.dense_enabled());
        let g = generators::erdos_renyi(40, 300, 3);
        let out = coord.census(&g).unwrap();
        assert_eq!(out.route, Route::Sparse);
        assert_eq!(out.census, merged::census(&g));
    }

    #[cfg(feature = "xla")]
    #[test]
    fn routes_and_answers_match_both_backends() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let coord = Coordinator::start(test_config()).unwrap();
        assert!(coord.dense_enabled());

        // dense route: small dense graph
        let g = generators::erdos_renyi(50, 500, 7);
        let out = coord.census(&g).unwrap();
        assert!(matches!(out.route, Route::Dense { size: 64 }), "{:?}", out.route);
        assert_eq!(out.census, merged::census(&g));

        // sparse route: large graph
        let g = generators::power_law(2000, 2.2, 6.0, 5);
        let out = coord.census(&g).unwrap();
        assert_eq!(out.route, Route::Sparse);
        assert_eq!(out.census, merged::census(&g));

        assert_eq!(coord.metrics().get("census_dense_total"), 1);
        assert_eq!(coord.metrics().get("census_sparse_total"), 1);
        coord.shutdown();
    }

    #[cfg(feature = "xla")]
    #[test]
    fn many_requests_through_the_queue() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let coord = Coordinator::start(test_config()).unwrap();
        for seed in 0..8 {
            let g = generators::erdos_renyi(30, 200, seed);
            let out = coord.census(&g).unwrap();
            assert_eq!(out.census, merged::census(&g), "seed {seed}");
        }
        assert_eq!(coord.metrics().get("dense_executions_total"), 8);
    }

    #[test]
    fn census_path_serves_mapped_v2_files_from_cache() {
        let coord = Coordinator::start(CoordinatorConfig {
            artifacts_dir: None,
            ..CoordinatorConfig::default()
        })
        .unwrap();
        let g = generators::power_law(600, 2.2, 6.0, 41);
        let want = merged::census(&g);
        let path = std::env::temp_dir().join("triadic_coord_cache.csr");
        crate::graph::io::write_binary_v2_file(&g, &path).unwrap();

        let out = coord.census_path(&path).unwrap();
        assert_eq!(out.census, want);
        let out = coord.census_path(&path).unwrap();
        assert_eq!(out.census, want);
        assert_eq!(coord.metrics().get("graph_cache_misses_total"), 1);
        assert_eq!(coord.metrics().get("graph_cache_hits_total"), 1);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn census_path_reports_load_errors() {
        let coord = Coordinator::start(CoordinatorConfig {
            artifacts_dir: None,
            ..CoordinatorConfig::default()
        })
        .unwrap();
        let err = coord.census_path("/nonexistent/graph.csr").unwrap_err();
        assert!(err.to_string().contains("loading graph"), "{err}");
    }

    #[test]
    fn graph_cache_evicts_fifo() {
        let store = GraphStore::new(2, 1, false);
        let metrics = Metrics::new();
        let dir = std::env::temp_dir();
        let mut paths = Vec::new();
        for i in 0..3u64 {
            let g = generators::erdos_renyi(20, 40, i);
            let p = dir.join(format!("triadic_store_{i}.csr"));
            crate::graph::io::write_binary_v2_file(&g, &p).unwrap();
            paths.push(p);
        }
        for p in &paths {
            store.get_or_load(p, &metrics).unwrap();
        }
        // capacity 2: the first path was evicted, reloading it misses
        store.get_or_load(&paths[0], &metrics).unwrap();
        assert_eq!(metrics.get("graph_cache_misses_total"), 4);
        // the most recent two still hit
        store.get_or_load(&paths[2], &metrics).unwrap();
        assert_eq!(metrics.get("graph_cache_hits_total"), 1);
        for p in paths {
            let _ = std::fs::remove_file(p);
        }
    }
}
