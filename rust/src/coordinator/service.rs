//! The coordinator service: request intake, graph loading (with an
//! mmap-aware cache), backend dispatch, dense service thread, metrics.

use std::collections::HashMap;
use std::collections::VecDeque;
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use super::router::{Route, Router, RoutingPolicy};
use crate::census::{Census, EngineRegistry, ParallelConfig};
use crate::error::{Context, Error, Result};
use crate::graph::{io, CsrGraph};
use crate::metrics::Metrics;
use crate::runtime::DenseCensusRuntime;
use crate::sched::{Executor, ExecutorConfig, ThreadPoolStats};

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Artifact directory for the dense backend; `None` disables it.
    pub artifacts_dir: Option<PathBuf>,
    /// Sparse engine configuration.
    pub sparse: ParallelConfig,
    /// Routing overrides (dense sizes are filled from the manifest).
    pub routing: RoutingPolicy,
    /// Dense request queue depth (backpressure bound).
    pub dense_queue: usize,
    /// Worker threads for edge-list ingestion on [`Coordinator::census_path`].
    pub ingest_threads: usize,
    /// Graphs kept resident by the path cache (FIFO eviction; 0
    /// disables caching). Mapped v2 graphs cost almost no heap, so
    /// serving the same converted graph across requests is free.
    pub graph_cache: usize,
    /// Trust `TRIADIC2` files on [`Coordinator::census_path`]: skip the
    /// whole-file checksum scan and mmap in O(1) (header bounds checks
    /// only). Enable when the coordinator serves files it converted
    /// itself; leave off for files of unknown provenance.
    pub trusted_mmap: bool,
    /// Sparse census engine, resolved by name from the
    /// [`EngineRegistry`] (`naive`, `batagelj-mrvar`, `merged`,
    /// `parallel`, `moody`).
    pub engine: String,
    /// Worker threads of the shared executor (`0` = host parallelism).
    /// This caps the pool for the whole process lifetime: K concurrent
    /// requests interleave chunks on these workers instead of holding
    /// K × `sparse.threads` OS threads.
    pub pool_threads: usize,
    /// Census jobs admitted to the executor at once (`0` = unlimited);
    /// excess requests queue at the admission gate.
    pub max_concurrent_jobs: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            artifacts_dir: Some(PathBuf::from("artifacts")),
            sparse: ParallelConfig::default(),
            routing: RoutingPolicy::default(),
            dense_queue: 64,
            ingest_threads: std::thread::available_parallelism()
                .map(|p| p.get())
                .unwrap_or(1),
            graph_cache: 8,
            trusted_mmap: false,
            engine: "parallel".to_string(),
            pool_threads: 0,
            max_concurrent_jobs: 0,
        }
    }
}

/// Path-keyed cache of loaded graphs with FIFO eviction, freshness
/// validation and single-flight loading.
struct GraphStore {
    capacity: usize,
    ingest_threads: usize,
    trusted_mmap: bool,
    inner: Mutex<StoreInner>,
    /// Signalled when an in-flight load finishes (single-flight wakeup).
    loaded_cv: Condvar,
}

/// A cached graph plus the file identity it was loaded from, so a
/// rewritten file invalidates the entry instead of serving stale data.
struct CachedGraph {
    graph: Arc<CsrGraph>,
    len: u64,
    modified: Option<std::time::SystemTime>,
}

#[derive(Default)]
struct StoreInner {
    map: HashMap<PathBuf, CachedGraph>,
    order: VecDeque<PathBuf>,
    /// Paths currently being loaded by some thread (single-flight: a
    /// concurrent first request for the same multi-GB file waits for
    /// the loader instead of parsing it again).
    loading: std::collections::HashSet<PathBuf>,
}

/// The (length, mtime) identity of a file, for staleness checks.
fn file_identity(path: &Path) -> Option<(u64, Option<std::time::SystemTime>)> {
    let meta = std::fs::metadata(path).ok()?;
    Some((meta.len(), meta.modified().ok()))
}

impl GraphStore {
    fn new(capacity: usize, ingest_threads: usize, trusted_mmap: bool) -> GraphStore {
        GraphStore {
            capacity,
            ingest_threads,
            trusted_mmap,
            inner: Mutex::new(StoreInner::default()),
            loaded_cv: Condvar::new(),
        }
    }

    /// Fetch a cached graph or load it (mmap for v2 files, parallel
    /// parse for edge lists) and cache it.
    ///
    /// A hit re-checks the file's (length, mtime) identity and reloads
    /// on mismatch, so converting a new graph over a served path takes
    /// effect on the next request. (Note that rewriting a file *while*
    /// it is memory-mapped is still an OS-level hazard — prefer
    /// write-to-temp + rename for files a live coordinator serves.)
    fn get_or_load(&self, path: &Path, metrics: &Metrics) -> Result<Arc<CsrGraph>> {
        let identity = file_identity(path);
        if self.capacity > 0 {
            let mut cache = self.inner.lock().unwrap();
            loop {
                match cache.map.get(path) {
                    Some(c) if identity == Some((c.len, c.modified)) => {
                        metrics.inc("graph_cache_hits_total", 1);
                        return Ok(c.graph.clone());
                    }
                    Some(_) => {
                        // stale: the file changed since it was cached
                        metrics.inc("graph_cache_stale_total", 1);
                        cache.map.remove(path);
                        cache.order.retain(|p| p != path);
                    }
                    None => {}
                }
                if !cache.loading.contains(path) {
                    cache.loading.insert(path.to_path_buf());
                    break;
                }
                // another thread is loading this path: wait and re-check
                cache = self.loaded_cv.wait(cache).unwrap();
            }
        }
        metrics.inc("graph_cache_misses_total", 1);
        let loaded = metrics
            .time("graph_load", || {
                io::load_auto_with(path, self.ingest_threads, !self.trusted_mmap)
            })
            .with_context(|| format!("loading graph {}", path.display()));
        match loaded {
            Ok(graph) => {
                let g = Arc::new(graph);
                if self.capacity > 0 {
                    let mut cache = self.inner.lock().unwrap();
                    cache.loading.remove(path);
                    while cache.order.len() >= self.capacity {
                        if let Some(old) = cache.order.pop_front() {
                            cache.map.remove(&old);
                        }
                    }
                    let (len, modified) = identity.unwrap_or((0, None));
                    cache.map.insert(
                        path.to_path_buf(),
                        CachedGraph {
                            graph: g.clone(),
                            len,
                            modified,
                        },
                    );
                    cache.order.push_back(path.to_path_buf());
                    drop(cache);
                    self.loaded_cv.notify_all();
                }
                Ok(g)
            }
            Err(e) => {
                if self.capacity > 0 {
                    let mut cache = self.inner.lock().unwrap();
                    cache.loading.remove(path);
                    drop(cache);
                    self.loaded_cv.notify_all();
                }
                Err(e)
            }
        }
    }
}

/// A served census with provenance, timing and (for sparse jobs) the
/// per-seat scheduler telemetry of the executor job that computed it.
#[derive(Debug, Clone)]
pub struct CensusOutcome {
    pub census: Census,
    pub route: Route,
    pub seconds: f64,
    /// Per-job stats from the shared executor; `None` for dense routes
    /// (the dense service thread has no chunk scheduler).
    pub stats: Option<ThreadPoolStats>,
}

/// Request envelope for the dense service thread.
struct DenseRequest {
    graph: CsrGraph,
    reply: mpsc::Sender<Result<Census>>,
}

/// The coordinator: owns the router, the engine registry, one shared
/// process-lifetime [`Executor`] for all sparse census traffic, and (if
/// artifacts are present) the dense service thread.
pub struct Coordinator {
    router: Router,
    engines: EngineRegistry,
    engine: String,
    executor: Arc<Executor>,
    dense_tx: Option<mpsc::SyncSender<DenseRequest>>,
    dense_thread: Option<std::thread::JoinHandle<()>>,
    metrics: Arc<Metrics>,
    graphs: GraphStore,
}

impl Coordinator {
    /// Start the coordinator on its own executor sized per
    /// `cfg.pool_threads` / `cfg.max_concurrent_jobs`. Compiles all
    /// dense artifacts up front (on the service thread) if an artifact
    /// directory is configured and readable; otherwise runs sparse-only.
    pub fn start(cfg: CoordinatorConfig) -> Result<Coordinator> {
        let executor = Arc::new(Executor::new(ExecutorConfig {
            workers: cfg.pool_threads,
            max_concurrent_jobs: cfg.max_concurrent_jobs,
        }));
        Coordinator::start_with_executor(cfg, executor)
    }

    /// Start on an existing shared pool — several coordinators (or a
    /// coordinator plus other parallel subsystems) can interleave jobs
    /// on one executor. `cfg.pool_threads` / `cfg.max_concurrent_jobs`
    /// are ignored here; the executor's own configuration governs.
    pub fn start_with_executor(
        cfg: CoordinatorConfig,
        executor: Arc<Executor>,
    ) -> Result<Coordinator> {
        let engines = EngineRegistry::builtin(cfg.sparse);
        if let Err(e) = engines.get_or_err(&cfg.engine) {
            return Err(Error::msg(e));
        }
        let metrics = Arc::new(Metrics::new());
        let mut routing = cfg.routing.clone();

        let (dense_tx, dense_thread) = match &cfg.artifacts_dir {
            Some(dir) if dir.join("manifest.tsv").exists() => {
                let (tx, rx) = mpsc::sync_channel::<DenseRequest>(cfg.dense_queue);
                let (size_tx, size_rx) = mpsc::channel::<Result<Vec<usize>>>();
                let dir = dir.clone();
                let m = metrics.clone();
                // PjRtLoadedExecutable is not Send: the runtime lives and
                // dies on this thread; requests arrive by channel.
                let handle = std::thread::Builder::new()
                    .name("dense-census".into())
                    .spawn(move || dense_service(dir, rx, size_tx, m))
                    .context("spawning dense service thread")?;
                let sizes = size_rx
                    .recv()
                    .context("dense service thread died during startup")??;
                routing.dense_sizes = sizes;
                (Some(tx), Some(handle))
            }
            _ => (None, None),
        };

        Ok(Coordinator {
            router: Router::new(routing),
            engines,
            engine: cfg.engine,
            executor,
            dense_tx,
            dense_thread,
            metrics,
            graphs: GraphStore::new(cfg.graph_cache, cfg.ingest_threads.max(1), cfg.trusted_mmap),
        })
    }

    /// Whether the dense backend is live.
    pub fn dense_enabled(&self) -> bool {
        self.dense_tx.is_some()
    }

    /// The routing table in force.
    pub fn router(&self) -> &Router {
        &self.router
    }

    /// Shared metrics registry.
    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// The shared executor serving all sparse census jobs.
    pub fn executor(&self) -> &Arc<Executor> {
        &self.executor
    }

    /// Name of the sparse engine in force.
    pub fn engine_name(&self) -> &str {
        &self.engine
    }

    /// Serve one census request synchronously. Concurrent callers are
    /// the intended workload: every sparse request is submitted as one
    /// job to the shared executor, so K simultaneous clients interleave
    /// chunks on the same worker pool (bounded by `pool_threads` and the
    /// admission gate) instead of oversubscribing K × threads; the dense
    /// service serializes behind its queue.
    pub fn census(&self, g: &CsrGraph) -> Result<CensusOutcome> {
        let t0 = Instant::now();
        let route = self.router.route(g);
        let (census, stats) = match (route, &self.dense_tx) {
            (Route::Dense { .. }, Some(tx)) => {
                self.metrics.inc("census_dense_total", 1);
                let (reply_tx, reply_rx) = mpsc::channel();
                tx.send(DenseRequest {
                    graph: g.clone(),
                    reply: reply_tx,
                })
                .ok()
                .context("dense service thread gone")?;
                let census = self
                    .metrics
                    .time("dense_census", || reply_rx.recv())
                    .context("dense service dropped the request")??;
                (census, None)
            }
            _ => {
                self.metrics.inc("census_sparse_total", 1);
                let engine = self
                    .engines
                    .get(&self.engine)
                    .expect("engine name validated at startup");
                let run = self
                    .metrics
                    .time("sparse_census", || engine.census(g, &self.executor));
                // per-job telemetry: slots walked by this job (executor
                // job counts live in Executor::stats, not here — serial
                // engines never submit one)
                self.metrics.inc(
                    "census_slots_total",
                    run.stats.items.iter().sum::<usize>() as u64,
                );
                (run.census, Some(run.stats))
            }
        };
        Ok(CensusOutcome {
            census,
            route,
            seconds: t0.elapsed().as_secs_f64(),
            stats,
        })
    }

    /// Serve a census for an on-disk graph through the path cache.
    /// `TRIADIC2` files are memory-mapped — checksum-verified on first
    /// touch by default (one sequential scan), or O(1) with
    /// [`CoordinatorConfig::trusted_mmap`] — which is the workflow for
    /// multi-GB graphs converted once and served across restarts;
    /// legacy binaries and edge lists are parsed on first touch and
    /// cached.
    pub fn census_path<P: AsRef<Path>>(&self, path: P) -> Result<CensusOutcome> {
        let g = self.graphs.get_or_load(path.as_ref(), &self.metrics)?;
        self.census(&g)
    }

    /// Drain and stop the dense service thread.
    pub fn shutdown(mut self) {
        self.dense_tx.take(); // close the channel; service loop exits
        if let Some(h) = self.dense_thread.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.dense_tx.take();
        if let Some(h) = self.dense_thread.take() {
            let _ = h.join();
        }
    }
}

/// Body of the dense service thread: compile artifacts, report sizes,
/// then drain the queue until the coordinator closes it.
fn dense_service(
    dir: PathBuf,
    rx: mpsc::Receiver<DenseRequest>,
    size_tx: mpsc::Sender<Result<Vec<usize>>>,
    metrics: Arc<Metrics>,
) {
    let mut runtime = match DenseCensusRuntime::load_dir(&dir) {
        Ok(rt) => {
            let _ = size_tx.send(Ok(rt.sizes()));
            rt
        }
        Err(e) => {
            let _ = size_tx.send(Err(e));
            return;
        }
    };
    metrics.inc("dense_artifacts_compiled", runtime.stats().compiled as u64);
    while let Ok(req) = rx.recv() {
        let result = runtime.census(&req.graph);
        metrics.inc("dense_executions_total", 1);
        let _ = req.reply.send(result);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::census::merged;
    use crate::graph::generators;

    #[cfg(feature = "xla")]
    fn artifacts_available() -> bool {
        PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/manifest.tsv")
            .exists()
    }

    #[cfg(feature = "xla")]
    fn test_config() -> CoordinatorConfig {
        CoordinatorConfig {
            artifacts_dir: Some(PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")),
            ..CoordinatorConfig::default()
        }
    }

    #[test]
    fn sparse_only_when_artifacts_missing() {
        let cfg = CoordinatorConfig {
            artifacts_dir: Some(PathBuf::from("/nonexistent")),
            ..CoordinatorConfig::default()
        };
        let coord = Coordinator::start(cfg).unwrap();
        assert!(!coord.dense_enabled());
        let g = generators::erdos_renyi(40, 300, 3);
        let out = coord.census(&g).unwrap();
        assert_eq!(out.route, Route::Sparse);
        assert_eq!(out.census, merged::census(&g));
        // sparse requests carry per-job executor telemetry
        let stats = out.stats.expect("sparse route returns job stats");
        assert_eq!(stats.items.iter().sum::<usize>(), g.entry_count());
        assert_eq!(
            coord.metrics().get("census_slots_total"),
            g.entry_count() as u64
        );
        assert_eq!(coord.executor().stats().jobs, 1);
    }

    #[test]
    fn engine_is_selected_by_name() {
        for engine in ["naive", "bm", "merged", "parallel", "moody"] {
            let coord = Coordinator::start(CoordinatorConfig {
                artifacts_dir: None,
                engine: engine.to_string(),
                pool_threads: 2,
                ..CoordinatorConfig::default()
            })
            .unwrap();
            let g = generators::erdos_renyi(30, 150, 7);
            let out = coord.census(&g).unwrap();
            assert_eq!(out.census, merged::census(&g), "engine {engine}");
        }
    }

    #[test]
    fn unknown_engine_is_rejected_at_startup() {
        let err = Coordinator::start(CoordinatorConfig {
            artifacts_dir: None,
            engine: "quantum".to_string(),
            ..CoordinatorConfig::default()
        })
        .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("unknown census engine"), "{msg}");
        assert!(msg.contains("parallel"), "should list available: {msg}");
    }

    #[test]
    fn coordinators_can_share_one_executor() {
        let exec = std::sync::Arc::new(crate::sched::Executor::with_workers(2));
        let mk = || {
            Coordinator::start_with_executor(
                CoordinatorConfig {
                    artifacts_dir: None,
                    ..CoordinatorConfig::default()
                },
                exec.clone(),
            )
            .unwrap()
        };
        let (a, b) = (mk(), mk());
        let g = generators::power_law(300, 2.2, 6.0, 9);
        let want = merged::census(&g);
        assert_eq!(a.census(&g).unwrap().census, want);
        assert_eq!(b.census(&g).unwrap().census, want);
        assert!(exec.stats().jobs >= 2, "both coordinators used the pool");
    }

    #[cfg(feature = "xla")]
    #[test]
    fn routes_and_answers_match_both_backends() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let coord = Coordinator::start(test_config()).unwrap();
        assert!(coord.dense_enabled());

        // dense route: small dense graph
        let g = generators::erdos_renyi(50, 500, 7);
        let out = coord.census(&g).unwrap();
        assert!(matches!(out.route, Route::Dense { size: 64 }), "{:?}", out.route);
        assert_eq!(out.census, merged::census(&g));

        // sparse route: large graph
        let g = generators::power_law(2000, 2.2, 6.0, 5);
        let out = coord.census(&g).unwrap();
        assert_eq!(out.route, Route::Sparse);
        assert_eq!(out.census, merged::census(&g));

        assert_eq!(coord.metrics().get("census_dense_total"), 1);
        assert_eq!(coord.metrics().get("census_sparse_total"), 1);
        coord.shutdown();
    }

    #[cfg(feature = "xla")]
    #[test]
    fn many_requests_through_the_queue() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let coord = Coordinator::start(test_config()).unwrap();
        for seed in 0..8 {
            let g = generators::erdos_renyi(30, 200, seed);
            let out = coord.census(&g).unwrap();
            assert_eq!(out.census, merged::census(&g), "seed {seed}");
        }
        assert_eq!(coord.metrics().get("dense_executions_total"), 8);
    }

    #[test]
    fn census_path_serves_mapped_v2_files_from_cache() {
        let coord = Coordinator::start(CoordinatorConfig {
            artifacts_dir: None,
            ..CoordinatorConfig::default()
        })
        .unwrap();
        let g = generators::power_law(600, 2.2, 6.0, 41);
        let want = merged::census(&g);
        let path = std::env::temp_dir().join("triadic_coord_cache.csr");
        crate::graph::io::write_binary_v2_file(&g, &path).unwrap();

        let out = coord.census_path(&path).unwrap();
        assert_eq!(out.census, want);
        let out = coord.census_path(&path).unwrap();
        assert_eq!(out.census, want);
        assert_eq!(coord.metrics().get("graph_cache_misses_total"), 1);
        assert_eq!(coord.metrics().get("graph_cache_hits_total"), 1);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn graph_cache_invalidates_rewritten_files() {
        let coord = Coordinator::start(CoordinatorConfig {
            artifacts_dir: None,
            ..CoordinatorConfig::default()
        })
        .unwrap();
        let dir = std::env::temp_dir();
        let path = dir.join("triadic_stale_cache.csr");
        let g1 = generators::power_law(300, 2.2, 6.0, 1);
        crate::graph::io::write_binary_v2_file(&g1, &path).unwrap();
        assert_eq!(coord.census_path(&path).unwrap().census, merged::census(&g1));
        // replace atomically (write-to-temp + rename) with a new graph
        let g2 = generators::power_law(450, 2.2, 6.0, 2);
        let tmp = dir.join("triadic_stale_cache.csr.tmp");
        crate::graph::io::write_binary_v2_file(&g2, &tmp).unwrap();
        std::fs::rename(&tmp, &path).unwrap();
        assert_eq!(coord.census_path(&path).unwrap().census, merged::census(&g2));
        assert_eq!(coord.metrics().get("graph_cache_stale_total"), 1);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn census_path_reports_load_errors() {
        let coord = Coordinator::start(CoordinatorConfig {
            artifacts_dir: None,
            ..CoordinatorConfig::default()
        })
        .unwrap();
        let err = coord.census_path("/nonexistent/graph.csr").unwrap_err();
        assert!(err.to_string().contains("loading graph"), "{err}");
    }

    #[test]
    fn graph_cache_evicts_fifo() {
        let store = GraphStore::new(2, 1, false);
        let metrics = Metrics::new();
        let dir = std::env::temp_dir();
        let mut paths = Vec::new();
        for i in 0..3u64 {
            let g = generators::erdos_renyi(20, 40, i);
            let p = dir.join(format!("triadic_store_{i}.csr"));
            crate::graph::io::write_binary_v2_file(&g, &p).unwrap();
            paths.push(p);
        }
        for p in &paths {
            store.get_or_load(p, &metrics).unwrap();
        }
        // capacity 2: the first path was evicted, reloading it misses
        store.get_or_load(&paths[0], &metrics).unwrap();
        assert_eq!(metrics.get("graph_cache_misses_total"), 4);
        // the most recent two still hit
        store.get_or_load(&paths[2], &metrics).unwrap();
        assert_eq!(metrics.get("graph_cache_hits_total"), 1);
        for p in paths {
            let _ = std::fs::remove_file(p);
        }
    }
}
