//! Minimal error-context machinery (the offline vendor set has no
//! `anyhow`, so this provides the small subset the crate uses).
//!
//! [`Error`] is a flat context chain rendered as `outer: inner: root`,
//! the [`Context`] extension trait adds `.context(..)` /
//! `.with_context(..)` to `Result` and `Option`, and the [`bail!`] /
//! [`ensure!`] macros build early returns. The API mirrors `anyhow`
//! closely enough that swapping the real crate back in (in a networked
//! build) is a one-line import change per module.

use std::fmt;

/// Crate-wide result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A boxed-string error with a context chain, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(msg: M) -> Error {
        Error {
            chain: vec![msg.to_string()],
        }
    }

    /// Prepend a context layer (the anyhow `.context(..)` semantics).
    pub fn push_context<C: fmt::Display>(mut self, ctx: C) -> Error {
        self.chain.insert(0, ctx.to_string());
        self
    }

    /// The context layers, outermost first.
    pub fn chain(&self) -> &[String] {
        &self.chain
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `{e}` and `{e:#}` both render the full chain, like anyhow's
        // alternate format; the crate only ever prints errors whole.
        f.write_str(&self.chain.join(": "))
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(self, f)
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::msg(e)
    }
}

impl From<String> for Error {
    fn from(s: String) -> Error {
        Error { chain: vec![s] }
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Error {
        Error::msg(s)
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result<_, impl Display>` and `Option<_>`.
pub trait Context<T> {
    /// Wrap the error (or `None`) with a fixed context message.
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    /// Wrap the error (or `None`) with a lazily built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(e).push_context(ctx))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(e).push_context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::error::Error::msg(format!($($arg)*)))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return ::std::result::Result::Err($crate::error::Error::msg(format!($($arg)*)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "no such file")
    }

    #[test]
    fn context_chains_render_outermost_first() {
        let r: Result<()> = Err(io_err()).context("reading manifest");
        let e = r.unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest: no such file");
        assert_eq!(format!("{e:#}"), "reading manifest: no such file");
        assert_eq!(e.root_cause(), "no such file");
    }

    #[test]
    fn with_context_is_lazy() {
        let ok: Result<u32> = std::result::Result::<u32, std::io::Error>::Ok(5)
            .with_context(|| -> String { unreachable!("not evaluated on Ok") });
        assert_eq!(ok.unwrap(), 5);
        let err: std::result::Result<u32, &str> = Err("root");
        let e = err.with_context(|| format!("layer {}", 1)).unwrap_err();
        assert_eq!(e.to_string(), "layer 1: root");
    }

    #[test]
    fn option_context() {
        let none: Option<u32> = None;
        assert_eq!(none.context("missing flag").unwrap_err().to_string(), "missing flag");
        assert_eq!(Some(3).context("unused").unwrap(), 3);
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 7 {
                bail!("unlucky {x}");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(12).unwrap_err().to_string(), "x too big: 12");
        assert_eq!(f(7).unwrap_err().to_string(), "unlucky 7");
    }

    #[test]
    fn from_conversions() {
        let e: Error = io_err().into();
        assert!(e.to_string().contains("no such file"));
        let e: Error = String::from("boom").into();
        assert_eq!(e.to_string(), "boom");
        let e: Error = "boom".into();
        assert_eq!(e.to_string(), "boom");
    }

    #[test]
    fn nested_contexts_stack() {
        let r: Result<()> = Err(io_err())
            .context("inner step")
            .map_err(|e| e.push_context("outer step"));
        assert_eq!(r.unwrap_err().to_string(), "outer step: inner step: no such file");
    }
}
