//! Figure regeneration harness: one function per table/figure of the
//! paper's evaluation section, each returning the plotted series as a
//! TSV-formatted string (and usable programmatically). The `repro
//! figures` CLI subcommand and the `benches/figNN_*` benches are thin
//! wrappers over this module; EXPERIMENTS.md records paper-vs-measured
//! for each.
//!
//! Scaling: the paper's graphs (16.5M–2.5B edges) exceed this container,
//! so each figure runs on the DESIGN.md-documented synthetic stand-ins
//! at a `--scale`-controlled size. Shapes (who wins, where the
//! crossovers fall) are the reproduction target, not absolute seconds.

use std::fmt::Write as _;

use crate::census::{census_parallel, Accumulation, ParallelConfig};
use crate::graph::degree::{fit_out_degree_exponent, out_degrees, DegreeStats, OutDegreeHistogram};
use crate::graph::GraphSpec;
use crate::sched::Policy;
use crate::simulator::{
    efficiencies, simulate, speedups, sweep, Machine, NumaMachine, ScalePoint, SuperdomeMachine,
    WorkloadProfile, XmtMachine,
};

/// Workload scale for figure regeneration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    /// Small graphs — seconds-fast, CI-friendly.
    Small,
    /// The DESIGN.md default sizes (a few hundred thousand nodes).
    Full,
}

impl Scale {
    pub fn parse(s: &str) -> Result<Scale, String> {
        match s {
            "small" => Ok(Scale::Small),
            "full" => Ok(Scale::Full),
            other => Err(format!("unknown scale {other:?} (small|full)")),
        }
    }

    fn patents(self) -> GraphSpec {
        GraphSpec::patents(match self {
            Scale::Small => 40_000,
            Scale::Full => 200_000,
        })
    }

    fn orkut(self) -> GraphSpec {
        GraphSpec::orkut(match self {
            Scale::Small => 10_000,
            Scale::Full => 50_000,
        })
    }

    fn web(self) -> GraphSpec {
        GraphSpec::webgraph(match self {
            Scale::Small => 60_000,
            Scale::Full => 400_000,
        })
    }
}

/// Profile a spec's workload (generation + characterization).
fn profile_of(spec: &GraphSpec) -> WorkloadProfile {
    let g = spec.generate();
    WorkloadProfile::from_graph(spec.name, &g)
}

/// Fig 6: outdegree distribution charts (log-binned) and power-law
/// exponents for the three workloads.
pub fn fig6(scale: Scale) -> String {
    let mut out = String::new();
    let _ = writeln!(
        out,
        "# FIG6: outdegree distributions (paper exponents: patents 3.126, orkut 2.127, web 1.516)"
    );
    for spec in [scale.patents(), scale.orkut(), scale.web()] {
        let g = spec.generate();
        let degs = out_degrees(&g);
        let stats = DegreeStats::from_sequence(&degs);
        let fitted = fit_out_degree_exponent(&g).unwrap_or(f64::NAN);
        let _ = writeln!(
            out,
            "## {}: n={} arcs={} max_outdeg={} fitted_gamma={:.3} (target {:.3})",
            spec.name,
            g.node_count(),
            g.arc_count(),
            stats.max,
            fitted,
            spec.gamma
        );
        let _ = writeln!(out, "degree\tfrequency_density");
        for (k, dens) in OutDegreeHistogram::new(&g).log_binned(4) {
            let _ = writeln!(out, "{k:.1}\t{dens:.4}");
        }
    }
    out
}

/// Fig 9: CPU utilization over time, Orkut @ 8 XMT processors.
pub fn fig9(scale: Scale) -> String {
    let prof = profile_of(&scale.orkut());
    let m = XmtMachine::pnnl();
    let r = simulate(&m, &prof, 8, Policy::dynamic_default());
    let mut out = String::new();
    let _ = writeln!(
        out,
        "# FIG9: simulated XMT CPU utilization, {} @ 8 procs (paper: 60-70% steady state)",
        prof.name
    );
    let _ = writeln!(out, "seconds\tutilization");
    for (t, u) in r.utilization_timeline(40) {
        let _ = writeln!(out, "{t:.4}\t{u:.3}");
    }
    out
}

/// A three-machine sweep table (Figs 10a/11a) plus speedups (10b/11b).
fn machine_comparison(prof: &WorkloadProfile, procs: &[usize], header: &str) -> String {
    let xmt = XmtMachine::pnnl();
    let numa = NumaMachine::magny_cours();
    let sd = SuperdomeMachine::sd64();
    let pol = Policy::dynamic_default();

    let sx = sweep(&xmt, prof, pol, procs);
    let sn: Vec<ScalePoint> = procs
        .iter()
        .filter(|&&p| p <= numa.max_procs())
        .map(|&p| ScalePoint {
            procs: p,
            seconds: simulate(&numa, prof, p, pol).makespan,
        })
        .collect();
    let ss = sweep(&sd, prof, pol, procs);

    let mut out = String::new();
    let _ = writeln!(out, "{header}");
    let _ = writeln!(out, "procs\txmt_s\tnuma_s\tsuperdome_s");
    for (i, &p) in procs.iter().enumerate() {
        let numa_s = sn
            .iter()
            .find(|sp| sp.procs == p)
            .map(|sp| format!("{:.6}", sp.seconds))
            .unwrap_or_else(|| "-".into());
        let _ = writeln!(
            out,
            "{p}\t{:.6}\t{}\t{:.6}",
            sx[i].seconds, numa_s, ss[i].seconds
        );
    }
    let _ = writeln!(out, "\nprocs\txmt_speedup\tnuma_speedup\tsuperdome_speedup");
    let spx = speedups(&sx);
    let spn = speedups(&sn);
    let sps = speedups(&ss);
    for (i, &p) in procs.iter().enumerate() {
        let n = spn
            .iter()
            .find(|(pp, _)| *pp == p)
            .map(|(_, s)| format!("{s:.2}"))
            .unwrap_or_else(|| "-".into());
        let _ = writeln!(out, "{p}\t{:.2}\t{n}\t{:.2}", spx[i].1, sps[i].1);
    }
    out
}

const SWEEP_PROCS: &[usize] = &[1, 2, 4, 8, 12, 16, 24, 32, 36, 40, 44, 48, 56, 64, 96, 128];

/// Fig 10: patents network across the three machines.
pub fn fig10(scale: Scale) -> String {
    let prof = profile_of(&scale.patents());
    machine_comparison(
        &prof,
        SWEEP_PROCS,
        "# FIG10: patents — exec time & speedup (paper: NUMA best at low p, XMT crosses at ~36, Superdome cell boundary at 8)",
    )
}

/// Fig 11: Orkut network across the three machines.
pub fn fig11(scale: Scale) -> String {
    let prof = profile_of(&scale.orkut());
    machine_comparison(
        &prof,
        SWEEP_PROCS,
        "# FIG11: orkut — exec time & speedup (paper: NUMA leads to ~64 vcores, Superdome cabinet boundary at 64, flat XMT efficiency)",
    )
}

/// Fig 12: NUMA parallel-efficiency detail, cores 32–48.
pub fn fig12(scale: Scale) -> String {
    let prof = profile_of(&scale.orkut());
    let numa = NumaMachine::magny_cours();
    let pol = Policy::dynamic_default();
    let procs: Vec<usize> = (32..=48).collect();
    let series: Vec<ScalePoint> = std::iter::once(1usize)
        .chain(procs.iter().copied())
        .map(|p| ScalePoint {
            procs: p,
            seconds: simulate(&numa, &prof, p, pol).makespan,
        })
        .collect();
    let effs = efficiencies(&series);
    let mut out = String::new();
    let _ = writeln!(
        out,
        "# FIG12: NUMA orkut detail 32-48 cores (paper: efficiency deteriorates through the 40s)"
    );
    let _ = writeln!(out, "cores\tseconds\tparallel_efficiency");
    for (sp, (p, e)) in series.iter().zip(&effs).skip(1) {
        let _ = writeln!(out, "{}\t{:.6}\t{:.3}", p, sp.seconds, e);
    }
    out
}

/// Fig 13: webgraph on the 512-proc XMT, 64–512 processors.
pub fn fig13(scale: Scale) -> String {
    let prof = profile_of(&scale.web());
    let m = XmtMachine::cray512();
    let procs = [64usize, 96, 128, 192, 256, 384, 512];
    let series = sweep(&m, &prof, Policy::dynamic_default(), &procs);
    let mut out = String::new();
    let _ = writeln!(
        out,
        "# FIG13: webgraph on 512p XMT (paper: good linear speedup 64-512)"
    );
    let _ = writeln!(out, "procs\tseconds\tspeedup_vs_64");
    let t64 = series[0].seconds;
    for sp in &series {
        let _ = writeln!(
            out,
            "{}\t{:.6}\t{:.2}",
            sp.procs,
            sp.seconds,
            t64 / sp.seconds * 64.0
        );
    }
    out
}

/// SCHED: the scheduling-policy study on the real thread pool (measured,
/// this host) and on the simulated machines — the paper's "dynamic best,
/// guided severely underperformed" claim.
pub fn fig_sched(scale: Scale) -> String {
    let spec = match scale {
        Scale::Small => GraphSpec::patents(20_000),
        Scale::Full => GraphSpec::patents(100_000),
    };
    let g = spec.generate();
    let prof = WorkloadProfile::from_graph(spec.name, &g);
    let policies = [
        ("static", Policy::static_default()),
        ("dynamic", Policy::dynamic_default()),
        ("guided", Policy::guided_default()),
    ];

    let mut out = String::new();
    let _ = writeln!(
        out,
        "# SCHED: scheduling policies on patents-like workload (paper: dynamic best, guided severely underperforms)"
    );
    // simulated Superdome & NUMA at 32 cores
    for (mname, m) in [
        ("superdome", &SuperdomeMachine::sd64() as &dyn Machine),
        ("numa", &NumaMachine::magny_cours() as &dyn Machine),
    ] {
        let _ = writeln!(out, "## simulated {mname} @32 cores");
        let _ = writeln!(out, "policy\tseconds\tbalance");
        for (pname, pol) in policies {
            let r = simulate(m, &prof, 32, pol);
            let _ = writeln!(out, "{pname}\t{:.6}\t{:.3}", r.makespan, r.balance());
        }
    }
    // measured on this host (thread pool, wall-clock)
    let threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let _ = writeln!(out, "## measured on this host ({threads} hw threads)");
    let _ = writeln!(out, "policy\tseconds\timbalance");
    for (pname, pol) in policies {
        let cfg = ParallelConfig {
            threads: threads.max(2),
            policy: pol,
            accumulation: Accumulation::Bank { slots: 64 },
        };
        let run = census_parallel(&g, &cfg);
        let _ = writeln!(
            out,
            "{pname}\t{:.6}\t{:.3}",
            run.stats.wall,
            run.stats.imbalance()
        );
    }
    out
}

/// All figures, concatenated (the `--fig all` path).
pub fn all_figures(scale: Scale) -> Vec<(&'static str, String)> {
    vec![
        ("fig06_degree", fig6(scale)),
        ("fig09_utilization", fig9(scale)),
        ("fig10_patents", fig10(scale)),
        ("fig11_orkut", fig11(scale)),
        ("fig12_numa_detail", fig12(scale)),
        ("fig13_webgraph", fig13(scale)),
        ("sched_policies", fig_sched(scale)),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig6_reports_three_workloads() {
        let s = fig6(Scale::Small);
        for name in ["patents", "orkut", "webgraph"] {
            assert!(s.contains(name), "missing {name} in:\n{s}");
        }
        assert!(s.contains("fitted_gamma"));
    }

    #[test]
    fn fig9_steady_state_in_paper_band() {
        let s = fig9(Scale::Small);
        // parse utilization column; steady state = middle samples
        let utils: Vec<f64> = s
            .lines()
            .filter(|l| !l.starts_with('#') && !l.starts_with("seconds"))
            .filter_map(|l| l.split('\t').nth(1)?.parse().ok())
            .collect();
        assert!(utils.len() >= 30);
        let mid = &utils[utils.len() / 3..utils.len() * 2 / 3];
        let avg = mid.iter().sum::<f64>() / mid.len() as f64;
        assert!(
            (0.55..=0.75).contains(&avg),
            "steady-state utilization {avg} outside the paper's 60-70% band"
        );
    }

    #[test]
    fn fig10_contains_crossover() {
        let s = fig10(Scale::Small);
        assert!(s.contains("procs\txmt_s"));
        // parse the time table and verify NUMA wins at p=4 while XMT wins
        // at a high count where NUMA data exists (48)
        let mut xmt4 = 0.0;
        let mut numa4 = 0.0;
        let mut xmt48 = 0.0;
        let mut numa48 = 0.0;
        // only the first (execution-time) table — stop at the blank line
        for l in s.lines().take_while(|l| !l.trim().is_empty()) {
            let cols: Vec<&str> = l.split('\t').collect();
            if cols.len() == 4 {
                if cols[0] == "4" {
                    xmt4 = cols[1].parse().unwrap_or(0.0);
                    numa4 = cols[2].parse().unwrap_or(f64::NAN);
                }
                if cols[0] == "48" {
                    xmt48 = cols[1].parse().unwrap_or(0.0);
                    numa48 = cols[2].parse().unwrap_or(f64::NAN);
                }
            }
        }
        assert!(numa4 < xmt4, "NUMA should lead at 4 cores");
        assert!(xmt48 < numa48 * 1.35, "XMT should be at/near crossover by 48");
    }

    #[test]
    fn fig13_near_linear() {
        let s = fig13(Scale::Small);
        let last = s.lines().last().unwrap();
        let speedup: f64 = last.split('\t').nth(2).unwrap().parse().unwrap();
        assert!(speedup > 280.0, "64->512 speedup only {speedup}");
    }

    #[test]
    fn sched_guided_underperforms_on_simulated_machines() {
        let s = fig_sched(Scale::Small);
        // within each simulated section, guided must be slowest
        for section in s.split("## ").filter(|x| x.starts_with("simulated")) {
            let mut times = std::collections::HashMap::new();
            for l in section.lines() {
                let cols: Vec<&str> = l.split('\t').collect();
                if cols.len() == 3 {
                    if let Ok(t) = cols[1].parse::<f64>() {
                        times.insert(cols[0].to_string(), t);
                    }
                }
            }
            if times.len() == 3 {
                assert!(
                    times["guided"] > times["dynamic"],
                    "guided {} should trail dynamic {} in {section}",
                    times["guided"],
                    times["dynamic"]
                );
            }
        }
    }
}
