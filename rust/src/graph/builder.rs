//! Edge-list → compact CSR builder.
//!
//! Takes an arbitrary stream of directed arcs `(u, v)` (possibly with
//! duplicates and self-loops), merges opposite arcs into single packed
//! entries with the Fig 7 two-bit direction encoding, sorts each node's
//! neighbor sub-array, and emits a validated [`CsrGraph`].

use super::csr::{CsrGraph, Dir, PackedEdge};

/// Builder accumulating directed arcs.
#[derive(Debug, Clone)]
pub struct GraphBuilder {
    n: usize,
    arcs: Vec<(u32, u32)>,
}

impl GraphBuilder {
    /// A builder for a graph over nodes `0..n`.
    pub fn new(n: usize) -> GraphBuilder {
        assert!(
            n as u64 <= CsrGraph::MAX_NODE_ID as u64 + 1,
            "node count exceeds 30-bit id space"
        );
        GraphBuilder {
            n,
            arcs: Vec::new(),
        }
    }

    /// Add a single directed arc. Self-loops are dropped silently (the
    /// triad taxonomy is defined over simple digraphs, matching the
    /// paper's datasets).
    pub fn arc(&mut self, u: u32, v: u32) -> &mut Self {
        debug_assert!((u as usize) < self.n && (v as usize) < self.n);
        if u != v {
            self.arcs.push((u, v));
        }
        self
    }

    /// Add many arcs (chainable, consumes and returns `self` for
    /// fixture-style use).
    pub fn arcs(mut self, arcs: &[(u32, u32)]) -> Self {
        for &(u, v) in arcs {
            self.arc(u, v);
        }
        self
    }

    /// Add arcs from an iterator.
    pub fn extend<I: IntoIterator<Item = (u32, u32)>>(&mut self, it: I) -> &mut Self {
        for (u, v) in it {
            self.arc(u, v);
        }
        self
    }

    /// Number of raw (pre-dedup) arcs accumulated.
    pub fn raw_arc_count(&self) -> usize {
        self.arcs.len()
    }

    /// Build the CSR graph: dedup arcs, merge directions, sort rows.
    ///
    /// Runs in O(m log m) using a sort over the symmetrized arc list —
    /// this mirrors the paper's one-shot ingest (the edge array is
    /// allocated exactly once).
    pub fn build(self) -> CsrGraph {
        let n = self.n;
        // Symmetrize: every arc (u,v) contributes entry (u,v,out-bit) and
        // (v,u,in-bit). Sorting groups duplicates and both directions of a
        // dyad so a single linear merge pass assembles packed entries.
        let mut sym: Vec<(u32, u32, u32)> = Vec::with_capacity(self.arcs.len() * 2);
        for (u, v) in self.arcs {
            sym.push((u, v, Dir::Out as u32));
            sym.push((v, u, Dir::In as u32));
        }
        sym.sort_unstable_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));

        let mut offsets = vec![0usize; n + 1];
        let mut edges: Vec<PackedEdge> = Vec::with_capacity(sym.len());
        let mut arc_count = 0u64;

        let mut i = 0;
        while i < sym.len() {
            let (u, v, mut bits) = sym[i];
            i += 1;
            while i < sym.len() && sym[i].0 == u && sym[i].1 == v {
                bits |= sym[i].2;
                i += 1;
            }
            edges.push(PackedEdge::new(v, Dir::from_bits(bits)));
            arc_count += (bits & 0b01 != 0) as u64;
            offsets[u as usize + 1] += 1;
        }
        for u in 0..n {
            offsets[u + 1] += offsets[u];
        }
        CsrGraph::from_parts(offsets, edges, arc_count)
    }
}

/// Convenience: build a graph directly from an arc slice.
pub fn from_arcs(n: usize, arcs: &[(u32, u32)]) -> CsrGraph {
    GraphBuilder::new(n).arcs(arcs).build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::csr::DyadType;

    #[test]
    fn dedups_parallel_arcs() {
        let g = from_arcs(2, &[(0, 1), (0, 1), (0, 1)]);
        assert_eq!(g.arc_count(), 1);
        assert_eq!(g.dyad(0, 1), DyadType::Asym);
    }

    #[test]
    fn merges_opposite_arcs_to_mutual() {
        let g = from_arcs(2, &[(0, 1), (1, 0)]);
        assert_eq!(g.dyad(0, 1), DyadType::Mutual);
        assert_eq!(g.arc_count(), 2);
        assert_eq!(g.entry_count(), 2);
    }

    #[test]
    fn drops_self_loops() {
        let g = from_arcs(3, &[(0, 0), (1, 1), (0, 1)]);
        assert_eq!(g.arc_count(), 1);
    }

    #[test]
    fn rows_sorted() {
        let g = from_arcs(6, &[(0, 5), (0, 2), (0, 4), (0, 1), (3, 0)]);
        let ids: Vec<u32> = g.row(0).iter().map(|e| e.nbr()).collect();
        assert_eq!(ids, vec![1, 2, 3, 4, 5]);
        assert!(g.validate().is_ok());
    }

    #[test]
    fn build_is_order_insensitive() {
        let a = from_arcs(5, &[(0, 1), (2, 3), (1, 0), (4, 1)]);
        let b = from_arcs(5, &[(4, 1), (1, 0), (0, 1), (2, 3)]);
        assert_eq!(a, b);
    }

    #[test]
    fn extend_and_chaining_agree() {
        let mut b = GraphBuilder::new(4);
        b.extend(vec![(0, 1), (1, 2)]);
        b.arc(2, 3);
        let g1 = b.build();
        let g2 = GraphBuilder::new(4).arcs(&[(0, 1), (1, 2), (2, 3)]).build();
        assert_eq!(g1, g2);
    }

    #[test]
    fn big_random_validates() {
        use crate::rng::Rng;
        let mut rng = Rng::new(99);
        let n = 500u32;
        let mut b = GraphBuilder::new(n as usize);
        for _ in 0..5000 {
            b.arc(rng.node(n), rng.node(n));
        }
        let g = b.build();
        assert!(g.validate().is_ok());
    }
}
