//! Edge-list → compact CSR builder.
//!
//! Takes an arbitrary stream of directed arcs `(u, v)` (possibly with
//! duplicates and self-loops), merges opposite arcs into single packed
//! entries with the Fig 7 two-bit direction encoding, sorts each node's
//! neighbor sub-array, and emits a validated [`CsrGraph`].

use super::csr::{CsrGraph, Dir, PackedEdge};

/// Builder accumulating directed arcs.
#[derive(Debug, Clone)]
pub struct GraphBuilder {
    n: usize,
    arcs: Vec<(u32, u32)>,
}

impl GraphBuilder {
    /// A builder for a graph over nodes `0..n`.
    pub fn new(n: usize) -> GraphBuilder {
        assert!(
            n as u64 <= CsrGraph::MAX_NODE_ID as u64 + 1,
            "node count exceeds 30-bit id space"
        );
        GraphBuilder {
            n,
            arcs: Vec::new(),
        }
    }

    /// Add a single directed arc. Self-loops are dropped silently (the
    /// triad taxonomy is defined over simple digraphs, matching the
    /// paper's datasets).
    pub fn arc(&mut self, u: u32, v: u32) -> &mut Self {
        debug_assert!((u as usize) < self.n && (v as usize) < self.n);
        if u != v {
            self.arcs.push((u, v));
        }
        self
    }

    /// Add many arcs (chainable, consumes and returns `self` for
    /// fixture-style use).
    pub fn arcs(mut self, arcs: &[(u32, u32)]) -> Self {
        for &(u, v) in arcs {
            self.arc(u, v);
        }
        self
    }

    /// Add arcs from an iterator.
    pub fn extend<I: IntoIterator<Item = (u32, u32)>>(&mut self, it: I) -> &mut Self {
        for (u, v) in it {
            self.arc(u, v);
        }
        self
    }

    /// Number of raw (pre-dedup) arcs accumulated.
    pub fn raw_arc_count(&self) -> usize {
        self.arcs.len()
    }

    /// Build the CSR graph: dedup arcs, merge directions, sort rows.
    ///
    /// Runs in O(m log m) using a sort over the symmetrized arc list —
    /// this mirrors the paper's one-shot ingest (the edge array is
    /// allocated exactly once). Serial; see [`GraphBuilder::build_parallel`]
    /// for the multi-threaded ingest path.
    pub fn build(self) -> CsrGraph {
        self.build_parallel(1)
    }

    /// Build the CSR graph with up to `threads` worker threads.
    ///
    /// Symmetrization and the dominating O(m log m) sort are chunked
    /// across scoped threads (chunk-sort + pairwise parallel merges);
    /// the final linear dedup/offsets pass stays serial. Output is
    /// bit-identical to [`GraphBuilder::build`] for any thread count —
    /// equal `(u, v)` keys only ever OR their direction bits together,
    /// so merge order between duplicates cannot matter.
    pub fn build_parallel(self, threads: usize) -> CsrGraph {
        let n = self.n;
        let arcs = self.arcs;
        let threads = threads.max(1);
        // Symmetrize: every arc (u,v) contributes entry (u,v,out-bit) and
        // (v,u,in-bit). Sorting groups duplicates and both directions of a
        // dyad so a single linear merge pass assembles packed entries.
        let mut sym: Vec<Sym> = vec![(0, 0, 0); arcs.len() * 2];
        // below this, thread spawn + merge staging cost more than they save
        const PAR_MIN_ARCS: usize = 1 << 15;
        if threads > 1 && arcs.len() >= PAR_MIN_ARCS {
            let chunk = arcs.len().div_ceil(threads);
            std::thread::scope(|s| {
                for (src, dst) in arcs.chunks(chunk).zip(sym.chunks_mut(2 * chunk)) {
                    s.spawn(move || symmetrize_into(src, dst));
                }
            });
            parallel_sort(&mut sym, threads);
        } else {
            symmetrize_into(&arcs, &mut sym);
            sym.sort_unstable_by_key(sym_key);
        }
        assemble(n, &sym)
    }
}

/// One symmetrized half-arc: `(from, to, direction-bit)`.
type Sym = (u32, u32, u32);

#[inline]
fn sym_key(t: &Sym) -> (u32, u32) {
    (t.0, t.1)
}

/// Expand `arcs` into its symmetrized entries, writing exactly
/// `2 * arcs.len()` slots of `out`.
fn symmetrize_into(arcs: &[(u32, u32)], out: &mut [Sym]) {
    debug_assert_eq!(out.len(), arcs.len() * 2);
    for (i, &(u, v)) in arcs.iter().enumerate() {
        out[2 * i] = (u, v, Dir::Out as u32);
        out[2 * i + 1] = (v, u, Dir::In as u32);
    }
}

/// Parallel merge sort by `(from, to)`: chunk-sort on scoped threads,
/// then pairwise-merge runs (also in parallel) until one run remains.
fn parallel_sort(data: &mut Vec<Sym>, threads: usize) {
    let len = data.len();
    let chunk = len.div_ceil(threads.max(1)).max(1);
    std::thread::scope(|s| {
        for part in data.chunks_mut(chunk) {
            s.spawn(move || part.sort_unstable_by_key(sym_key));
        }
    });
    if chunk >= len {
        return; // single run — already sorted
    }
    let mut src = std::mem::take(data);
    let mut dst: Vec<Sym> = vec![(0, 0, 0); len];
    let mut width = chunk;
    while width < len {
        std::thread::scope(|s| {
            let mut rest: &mut [Sym] = &mut dst;
            let mut start = 0usize;
            while start < len {
                let mid = (start + width).min(len);
                let end = (start + 2 * width).min(len);
                let (out, tail) = std::mem::take(&mut rest).split_at_mut(end - start);
                rest = tail;
                let a = &src[start..mid];
                let b = &src[mid..end];
                s.spawn(move || merge_runs(a, b, out));
                start = end;
            }
        });
        std::mem::swap(&mut src, &mut dst);
        width *= 2;
    }
    *data = src;
}

/// Merge two sorted runs into `out` (`out.len() == a.len() + b.len()`).
fn merge_runs(a: &[Sym], b: &[Sym], out: &mut [Sym]) {
    debug_assert_eq!(a.len() + b.len(), out.len());
    let (mut i, mut j) = (0usize, 0usize);
    for slot in out.iter_mut() {
        let take_a = j >= b.len() || (i < a.len() && sym_key(&a[i]) <= sym_key(&b[j]));
        if take_a {
            *slot = a[i];
            i += 1;
        } else {
            *slot = b[j];
            j += 1;
        }
    }
}

/// The linear dedup/merge pass over the sorted symmetrized entries:
/// OR direction bits of equal `(u, v)` groups, emit packed edges and
/// per-node counts, prefix-sum into offsets.
fn assemble(n: usize, sym: &[Sym]) -> CsrGraph {
    let mut offsets = vec![0usize; n + 1];
    let mut edges: Vec<PackedEdge> = Vec::with_capacity(sym.len());
    let mut arc_count = 0u64;

    let mut i = 0;
    while i < sym.len() {
        let (u, v, mut bits) = sym[i];
        i += 1;
        while i < sym.len() && sym[i].0 == u && sym[i].1 == v {
            bits |= sym[i].2;
            i += 1;
        }
        edges.push(PackedEdge::new(v, Dir::from_bits(bits)));
        arc_count += (bits & 0b01 != 0) as u64;
        offsets[u as usize + 1] += 1;
    }
    for u in 0..n {
        offsets[u + 1] += offsets[u];
    }
    CsrGraph::from_parts(offsets, edges, arc_count)
}

/// Convenience: build a graph directly from an arc slice.
pub fn from_arcs(n: usize, arcs: &[(u32, u32)]) -> CsrGraph {
    GraphBuilder::new(n).arcs(arcs).build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::csr::DyadType;

    #[test]
    fn dedups_parallel_arcs() {
        let g = from_arcs(2, &[(0, 1), (0, 1), (0, 1)]);
        assert_eq!(g.arc_count(), 1);
        assert_eq!(g.dyad(0, 1), DyadType::Asym);
    }

    #[test]
    fn merges_opposite_arcs_to_mutual() {
        let g = from_arcs(2, &[(0, 1), (1, 0)]);
        assert_eq!(g.dyad(0, 1), DyadType::Mutual);
        assert_eq!(g.arc_count(), 2);
        assert_eq!(g.entry_count(), 2);
    }

    #[test]
    fn drops_self_loops() {
        let g = from_arcs(3, &[(0, 0), (1, 1), (0, 1)]);
        assert_eq!(g.arc_count(), 1);
    }

    #[test]
    fn rows_sorted() {
        let g = from_arcs(6, &[(0, 5), (0, 2), (0, 4), (0, 1), (3, 0)]);
        let ids: Vec<u32> = g.row(0).iter().map(|e| e.nbr()).collect();
        assert_eq!(ids, vec![1, 2, 3, 4, 5]);
        assert!(g.validate().is_ok());
    }

    #[test]
    fn build_is_order_insensitive() {
        let a = from_arcs(5, &[(0, 1), (2, 3), (1, 0), (4, 1)]);
        let b = from_arcs(5, &[(4, 1), (1, 0), (0, 1), (2, 3)]);
        assert_eq!(a, b);
    }

    #[test]
    fn extend_and_chaining_agree() {
        let mut b = GraphBuilder::new(4);
        b.extend(vec![(0, 1), (1, 2)]);
        b.arc(2, 3);
        let g1 = b.build();
        let g2 = GraphBuilder::new(4).arcs(&[(0, 1), (1, 2), (2, 3)]).build();
        assert_eq!(g1, g2);
    }

    #[test]
    fn big_random_validates() {
        use crate::rng::Rng;
        let mut rng = Rng::new(99);
        let n = 500u32;
        let mut b = GraphBuilder::new(n as usize);
        for _ in 0..5000 {
            b.arc(rng.node(n), rng.node(n));
        }
        let g = b.build();
        assert!(g.validate().is_ok());
    }

    #[test]
    fn parallel_build_is_bit_identical_to_serial() {
        use crate::rng::Rng;
        // large enough to cross the parallel threshold (2^15 arcs)
        let n = 2_000u32;
        for seed in [1u64, 2, 3] {
            let mut rng = Rng::new(seed);
            let arcs: Vec<(u32, u32)> = (0..40_000).map(|_| (rng.node(n), rng.node(n))).collect();
            let mut serial = GraphBuilder::new(n as usize);
            serial.extend(arcs.iter().copied());
            let want = serial.build();
            for threads in [2usize, 3, 8] {
                let mut par = GraphBuilder::new(n as usize);
                par.extend(arcs.iter().copied());
                let got = par.build_parallel(threads);
                assert_eq!(got, want, "seed {seed} threads {threads}");
                assert!(got.validate().is_ok());
            }
        }
    }

    #[test]
    fn parallel_build_small_inputs_and_empty() {
        let g = GraphBuilder::new(4).arcs(&[(0, 1), (1, 0), (2, 3)]);
        let want = g.clone().build();
        assert_eq!(g.build_parallel(8), want);
        assert_eq!(
            GraphBuilder::new(3).build_parallel(4),
            GraphBuilder::new(3).build()
        );
    }

    #[test]
    fn parallel_sort_helper_sorts() {
        use crate::rng::Rng;
        let mut rng = Rng::new(7);
        let mut data: Vec<Sym> = (0..100_000)
            .map(|_| (rng.node(1000), rng.node(1000), 1 + (rng.node(3))))
            .collect();
        let mut want = data.clone();
        want.sort_unstable_by_key(sym_key);
        parallel_sort(&mut data, 7);
        // keys must match exactly; payloads of equal keys may permute
        let got_keys: Vec<_> = data.iter().map(sym_key).collect();
        let want_keys: Vec<_> = want.iter().map(sym_key).collect();
        assert_eq!(got_keys, want_keys);
    }
}
