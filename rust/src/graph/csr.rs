//! Compact CSR graph with 2-bit edge-direction encoding — the paper's
//! Fig 7 data structure.
//!
//! Graph nodes are elements of an offsets array; the collective set of
//! edges for all nodes lives in a single allocation. Each neighbor entry
//! packs the neighbor id in the high 30 bits and the edge direction in
//! the low 2 bits:
//!
//! * `01` — unidirectional edge from the current node to the neighbor,
//! * `10` — unidirectional edge from the neighbor to the current node,
//! * `11` — bidirectional (mutual) edge.
//!
//! Per-node neighbor sub-arrays are sorted by neighbor id, enabling both
//! binary-searched `has_arc` queries and the merged two-pointer traversal
//! of Fig 8. Because the direction bits occupy the *low* bits, packed
//! entries sort exactly as their neighbor ids do.

use std::fmt;

use super::storage::CsrStorage;

/// Direction of the edge(s) between a node and one of its neighbors, as
/// encoded in the low two bits of a packed neighbor entry.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(u32)]
pub enum Dir {
    /// `01` — arc from current node to neighbor.
    Out = 0b01,
    /// `10` — arc from neighbor to current node.
    In = 0b10,
    /// `11` — arcs both ways (mutual dyad).
    Both = 0b11,
}

impl Dir {
    /// Decode from the low two bits of a packed entry. `00` is invalid —
    /// a neighbor entry exists only if at least one arc exists.
    #[inline]
    pub fn from_bits(bits: u32) -> Dir {
        match bits & 0b11 {
            0b01 => Dir::Out,
            0b10 => Dir::In,
            0b11 => Dir::Both,
            _ => unreachable!("packed edge with 00 direction bits"),
        }
    }

    /// The same relation seen from the other endpoint.
    #[inline]
    pub fn reversed(self) -> Dir {
        match self {
            Dir::Out => Dir::In,
            Dir::In => Dir::Out,
            Dir::Both => Dir::Both,
        }
    }

    /// True if there is an arc current→neighbor.
    #[inline]
    pub fn has_out(self) -> bool {
        (self as u32) & 0b01 != 0
    }

    /// True if there is an arc neighbor→current.
    #[inline]
    pub fn has_in(self) -> bool {
        (self as u32) & 0b10 != 0
    }
}

/// Classification of the ordered pair `(u, v)` as a dyad.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DyadType {
    /// No arc in either direction.
    Null,
    /// Arc `u -> v` only.
    Asym,
    /// Arc `v -> u` only.
    AsymRev,
    /// Arcs both ways.
    Mutual,
}

impl DyadType {
    /// True if at least one arc exists.
    #[inline]
    pub fn connected(self) -> bool {
        !matches!(self, DyadType::Null)
    }
}

/// A packed neighbor entry: `(neighbor_id << 2) | direction_bits`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
#[repr(transparent)]
pub struct PackedEdge(pub u32);

impl PackedEdge {
    /// Pack a neighbor id and direction. `nbr` must fit in 30 bits.
    #[inline]
    pub fn new(nbr: u32, dir: Dir) -> PackedEdge {
        debug_assert!(nbr <= CsrGraph::MAX_NODE_ID, "node id exceeds 30 bits");
        PackedEdge((nbr << 2) | dir as u32)
    }

    /// The neighbor node id.
    #[inline]
    pub fn nbr(self) -> u32 {
        self.0 >> 2
    }

    /// The direction bits.
    #[inline]
    pub fn dir(self) -> Dir {
        Dir::from_bits(self.0)
    }
}

impl fmt::Display for PackedEdge {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{:?}", self.nbr(), self.dir())
    }
}

/// The paper's compact shared-memory graph representation (Fig 7):
/// compressed sparse row over *undirected adjacency* with per-entry
/// direction bits. Symmetric: if `v` appears in `u`'s list, `u` appears
/// in `v`'s list with the reversed direction.
///
/// The two hot arrays (`offsets[u]..offsets[u+1]` indexes the packed,
/// per-node-sorted `edges` array) live behind [`CsrStorage`]: either
/// heap-owned `Vec`s from the ingest pipeline or zero-copy windows into
/// a memory-mapped v2 binary file (see [`crate::graph::io`]). Every
/// engine goes through the same slice accessors, so a mapped multi-GB
/// graph serves censuses with no load-time rebuild at all.
pub struct CsrGraph {
    /// Backing storage for offsets + packed edges.
    storage: CsrStorage,
    /// Number of directed arcs (a mutual dyad counts as two arcs).
    arc_count: u64,
}

impl Clone for CsrGraph {
    /// Cloning materializes mapped storage into owned `Vec`s (a clone
    /// must not extend the mapped file's lifetime invisibly).
    fn clone(&self) -> CsrGraph {
        CsrGraph {
            storage: self.storage.to_owned_storage(),
            arc_count: self.arc_count,
        }
    }
}

impl PartialEq for CsrGraph {
    /// Structural equality — storage backend does not matter.
    fn eq(&self, other: &CsrGraph) -> bool {
        self.arc_count == other.arc_count
            && self.offsets() == other.offsets()
            && self.edges() == other.edges()
    }
}

impl Eq for CsrGraph {}

impl fmt::Debug for CsrGraph {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("CsrGraph")
            .field("nodes", &self.node_count())
            .field("entries", &self.entry_count())
            .field("arcs", &self.arc_count)
            .field("storage", &self.storage)
            .finish()
    }
}

impl CsrGraph {
    /// Largest representable node id (30 bits; two low bits hold the
    /// direction encoding).
    pub const MAX_NODE_ID: u32 = (1 << 30) - 1;

    /// Assemble from raw parts. `offsets` must be monotonically
    /// non-decreasing with `offsets[0] == 0` and
    /// `offsets[n] == edges.len()`; each node's sub-array must be sorted
    /// by neighbor id with no duplicates and no self-loops. Checked in
    /// debug builds (and by [`CsrGraph::validate`]).
    pub fn from_parts(offsets: Vec<usize>, edges: Vec<PackedEdge>, arc_count: u64) -> CsrGraph {
        let g = CsrGraph {
            storage: CsrStorage::Owned { offsets, edges },
            arc_count,
        };
        debug_assert!(g.validate().is_ok(), "{:?}", g.validate());
        g
    }

    /// Assemble from any storage backend without debug validation —
    /// the mmap loader's entry point (it performs its own header and
    /// checksum validation before construction).
    pub(crate) fn from_storage_unchecked(storage: CsrStorage, arc_count: u64) -> CsrGraph {
        CsrGraph { storage, arc_count }
    }

    /// An empty graph with `n` isolated nodes.
    pub fn empty(n: usize) -> CsrGraph {
        CsrGraph {
            storage: CsrStorage::Owned {
                offsets: vec![0; n + 1],
                edges: Vec::new(),
            },
            arc_count: 0,
        }
    }

    /// Structural validation: returns a description of the first
    /// violated invariant, if any.
    pub fn validate(&self) -> Result<(), String> {
        let offsets = self.offsets();
        let edges = self.edges();
        if offsets.is_empty() {
            return Err("offsets must have at least one entry".into());
        }
        if offsets[0] != 0 {
            return Err("offsets[0] != 0".into());
        }
        if *offsets.last().unwrap() != edges.len() {
            return Err("offsets[n] != edges.len()".into());
        }
        let n = self.node_count();
        let mut arcs = 0u64;
        for u in 0..n {
            if offsets[u] > offsets[u + 1] {
                return Err(format!("offsets not monotone at node {u}"));
            }
            let row = &edges[offsets[u]..offsets[u + 1]];
            let mut prev: Option<u32> = None;
            for e in row {
                let v = e.nbr();
                if v as usize >= n {
                    return Err(format!("node {u} has neighbor {v} out of range"));
                }
                if v as usize == u {
                    return Err(format!("self-loop at node {u}"));
                }
                if let Some(p) = prev {
                    if v <= p {
                        return Err(format!("row of node {u} not strictly sorted at {v}"));
                    }
                }
                prev = Some(v);
                let d = e.dir();
                arcs += d.has_out() as u64;
                // symmetry: v must list u with reversed direction
                match self.find_entry(v, u as u32) {
                    Some(back) if back.dir() == d.reversed() => {}
                    Some(back) => {
                        return Err(format!(
                            "asymmetric encoding: {u}->{v} is {:?} but {v}->{u} is {:?}",
                            d,
                            back.dir()
                        ))
                    }
                    None => return Err(format!("missing reverse entry for {u}->{v}")),
                }
            }
        }
        if arcs != self.arc_count {
            return Err(format!(
                "arc_count mismatch: stored {} counted {arcs}",
                self.arc_count
            ));
        }
        Ok(())
    }

    /// Number of nodes.
    #[inline]
    pub fn node_count(&self) -> usize {
        self.offsets().len() - 1
    }

    /// Number of directed arcs (mutual dyads count twice).
    #[inline]
    pub fn arc_count(&self) -> u64 {
        self.arc_count
    }

    /// Number of connected (non-null) dyads, i.e. undirected adjacency
    /// entries / 2.
    #[inline]
    pub fn dyad_count(&self) -> u64 {
        (self.edges().len() / 2) as u64
    }

    /// Total packed entries (2× dyad count).
    #[inline]
    pub fn entry_count(&self) -> usize {
        self.edges().len()
    }

    /// The sorted packed-neighbor row of `u`.
    #[inline]
    pub fn row(&self, u: u32) -> &[PackedEdge] {
        let offsets = self.offsets();
        &self.edges()[offsets[u as usize]..offsets[u as usize + 1]]
    }

    /// The CSR offsets array (`n + 1` entries). Exposed for the
    /// manhattan-collapsed flat iteration space of the parallel engine.
    #[inline]
    pub fn offsets(&self) -> &[usize] {
        self.storage.offsets()
    }

    /// The packed-edge array in flat (collapsed) index order.
    #[inline]
    pub fn edges(&self) -> &[PackedEdge] {
        self.storage.edges()
    }

    /// The storage backend (diagnostics; engines use the slice
    /// accessors and never branch on this).
    #[inline]
    pub fn storage(&self) -> &CsrStorage {
        &self.storage
    }

    /// True if the hot arrays are served from a mapped file.
    #[inline]
    pub fn is_mapped(&self) -> bool {
        self.storage.is_mapped()
    }

    /// The packed edge at flat index `idx` (`0..entry_count()`).
    #[inline]
    pub fn entry(&self, idx: usize) -> PackedEdge {
        self.edges()[idx]
    }

    /// The node owning flat entry `idx` — the inverse of the offsets
    /// mapping, via binary search. Used to seat a scheduler chunk inside
    /// the collapsed iteration space in `O(log n)`, after which the
    /// worker walks forward linearly.
    #[inline]
    pub fn owner_of_entry(&self, idx: usize) -> u32 {
        debug_assert!(idx < self.entry_count());
        // partition_point: first u with offsets[u+1] > idx
        (self.offsets().partition_point(|&o| o <= idx) - 1) as u32
    }

    /// Undirected degree (number of distinct neighbors).
    #[inline]
    pub fn degree(&self, u: u32) -> usize {
        let offsets = self.offsets();
        offsets[u as usize + 1] - offsets[u as usize]
    }

    /// Out-degree (arcs leaving `u`).
    pub fn out_degree(&self, u: u32) -> usize {
        self.row(u).iter().filter(|e| e.dir().has_out()).count()
    }

    /// In-degree (arcs entering `u`).
    pub fn in_degree(&self, u: u32) -> usize {
        self.row(u).iter().filter(|e| e.dir().has_in()).count()
    }

    /// Binary-search `u`'s row for neighbor `v` (the paper's fast edge
    /// search over sorted sub-arrays).
    #[inline]
    pub fn find_entry(&self, u: u32, v: u32) -> Option<PackedEdge> {
        let row = self.row(u);
        row.binary_search_by_key(&v, |e| e.nbr())
            .ok()
            .map(|i| row[i])
    }

    /// True if the arc `u -> v` exists.
    #[inline]
    pub fn has_arc(&self, u: u32, v: u32) -> bool {
        self.find_entry(u, v).is_some_and(|e| e.dir().has_out())
    }

    /// True if `v` is a neighbor of `u` in either direction (the paper's
    /// `uÂv` relation).
    #[inline]
    pub fn is_neighbor(&self, u: u32, v: u32) -> bool {
        self.find_entry(u, v).is_some()
    }

    /// Classify the ordered pair `(u, v)`.
    #[inline]
    pub fn dyad(&self, u: u32, v: u32) -> DyadType {
        match self.find_entry(u, v).map(PackedEdge::dir) {
            None => DyadType::Null,
            Some(Dir::Out) => DyadType::Asym,
            Some(Dir::In) => DyadType::AsymRev,
            Some(Dir::Both) => DyadType::Mutual,
        }
    }

    /// Iterate all connected dyads `(u, v, dir)` with `u < v`.
    pub fn dyads(&self) -> impl Iterator<Item = (u32, u32, Dir)> + '_ {
        (0..self.node_count() as u32).flat_map(move |u| {
            self.row(u)
                .iter()
                .filter(move |e| e.nbr() > u)
                .map(move |e| (u, e.nbr(), e.dir()))
        })
    }

    /// Iterate all directed arcs `(u, v)`.
    pub fn arcs(&self) -> impl Iterator<Item = (u32, u32)> + '_ {
        (0..self.node_count() as u32).flat_map(move |u| {
            self.row(u)
                .iter()
                .filter(|e| e.dir().has_out())
                .map(move |e| (u, e.nbr()))
        })
    }

    /// The transpose graph (every arc reversed). Mutual dyads are
    /// unchanged; asymmetric entries flip direction. O(m).
    pub fn transpose(&self) -> CsrGraph {
        let edges = self
            .edges()
            .iter()
            .map(|e| PackedEdge::new(e.nbr(), e.dir().reversed()))
            .collect();
        CsrGraph {
            storage: CsrStorage::Owned {
                offsets: self.offsets().to_vec(),
                edges,
            },
            arc_count: self.arc_count,
        }
    }

    /// Dense adjacency matrix (row-major `n*n`, `1.0` where `u -> v`),
    /// used to feed the dense (Moody / AOT) census backends.
    pub fn to_dense_f32(&self) -> Vec<f32> {
        let n = self.node_count();
        let mut a = vec![0f32; n * n];
        for (u, v) in self.arcs() {
            a[u as usize * n + v as usize] = 1.0;
        }
        a
    }

    /// Approximate resident *heap* memory of the structure in bytes
    /// (mapped graphs report only their bookkeeping — file pages are
    /// shared, evictable cache).
    pub fn memory_bytes(&self) -> usize {
        self.storage.heap_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;

    fn triangle() -> CsrGraph {
        // 0 -> 1, 1 -> 2, 2 -> 0 (3-cycle) plus mutual 0 <-> 2? no: keep cycle
        GraphBuilder::new(3)
            .arcs(&[(0, 1), (1, 2), (2, 0)])
            .build()
    }

    #[test]
    fn dir_bits_round_trip() {
        for d in [Dir::Out, Dir::In, Dir::Both] {
            assert_eq!(Dir::from_bits(d as u32), d);
            assert_eq!(d.reversed().reversed(), d);
        }
        assert!(Dir::Out.has_out() && !Dir::Out.has_in());
        assert!(!Dir::In.has_out() && Dir::In.has_in());
        assert!(Dir::Both.has_out() && Dir::Both.has_in());
    }

    #[test]
    fn packed_edge_round_trip() {
        let e = PackedEdge::new(123_456, Dir::Both);
        assert_eq!(e.nbr(), 123_456);
        assert_eq!(e.dir(), Dir::Both);
        let max = PackedEdge::new(CsrGraph::MAX_NODE_ID, Dir::In);
        assert_eq!(max.nbr(), CsrGraph::MAX_NODE_ID);
        assert_eq!(max.dir(), Dir::In);
    }

    #[test]
    fn packed_edges_sort_by_neighbor() {
        let mut v = vec![
            PackedEdge::new(5, Dir::Out),
            PackedEdge::new(2, Dir::Both),
            PackedEdge::new(9, Dir::In),
        ];
        v.sort();
        let ids: Vec<u32> = v.iter().map(|e| e.nbr()).collect();
        assert_eq!(ids, vec![2, 5, 9]);
    }

    #[test]
    fn cycle_structure() {
        let g = triangle();
        assert_eq!(g.node_count(), 3);
        assert_eq!(g.arc_count(), 3);
        assert_eq!(g.dyad_count(), 3);
        assert!(g.has_arc(0, 1) && !g.has_arc(1, 0));
        assert!(g.has_arc(2, 0) && !g.has_arc(0, 2));
        assert_eq!(g.dyad(0, 1), DyadType::Asym);
        assert_eq!(g.dyad(1, 0), DyadType::AsymRev);
        assert_eq!(g.dyad(0, 2), DyadType::AsymRev);
        assert_eq!(g.out_degree(0), 1);
        assert_eq!(g.in_degree(0), 1);
        assert_eq!(g.degree(0), 2);
        assert!(g.validate().is_ok());
    }

    #[test]
    fn mutual_encoding() {
        let g = GraphBuilder::new(2).arcs(&[(0, 1), (1, 0)]).build();
        assert_eq!(g.dyad(0, 1), DyadType::Mutual);
        assert_eq!(g.dyad(1, 0), DyadType::Mutual);
        assert_eq!(g.arc_count(), 2);
        assert_eq!(g.dyad_count(), 1);
        assert_eq!(g.row(0)[0].dir(), Dir::Both);
    }

    #[test]
    fn transpose_flips_asym_keeps_mutual() {
        let g = GraphBuilder::new(4)
            .arcs(&[(0, 1), (1, 2), (2, 1), (3, 0)])
            .build();
        let t = g.transpose();
        assert_eq!(t.dyad(1, 0), DyadType::Asym);
        assert_eq!(t.dyad(0, 1), DyadType::AsymRev);
        assert_eq!(t.dyad(1, 2), DyadType::Mutual);
        assert_eq!(t.transpose(), g);
        assert!(t.validate().is_ok());
    }

    #[test]
    fn dyads_iterator_yields_each_pair_once() {
        let g = GraphBuilder::new(4)
            .arcs(&[(0, 1), (1, 0), (2, 3), (1, 3)])
            .build();
        let ds: Vec<_> = g.dyads().collect();
        assert_eq!(ds.len(), 3);
        for (u, v, _) in &ds {
            assert!(u < v);
        }
    }

    #[test]
    fn arcs_iterator_matches_arc_count() {
        let g = GraphBuilder::new(5)
            .arcs(&[(0, 1), (1, 0), (2, 3), (4, 2), (3, 2)])
            .build();
        assert_eq!(g.arcs().count() as u64, g.arc_count());
    }

    #[test]
    fn dense_round_trip() {
        let g = triangle();
        let a = g.to_dense_f32();
        assert_eq!(a.len(), 9);
        assert_eq!(a[1], 1.0); // 0 -> 1
        assert_eq!(a[5], 1.0); // 1 -> 2
        assert_eq!(a[6], 1.0); // 2 -> 0
        assert_eq!(a.iter().sum::<f32>(), 3.0);
    }

    #[test]
    fn empty_graph() {
        let g = CsrGraph::empty(10);
        assert_eq!(g.node_count(), 10);
        assert_eq!(g.arc_count(), 0);
        assert!(g.validate().is_ok());
        assert_eq!(g.dyads().count(), 0);
    }

    #[test]
    fn owner_of_entry_inverts_offsets() {
        let g = GraphBuilder::new(6)
            .arcs(&[(1, 2), (1, 3), (4, 5), (0, 4)])
            .build();
        for u in 0..6u32 {
            let (s, e) = (g.offsets()[u as usize], g.offsets()[u as usize + 1]);
            for idx in s..e {
                assert_eq!(g.owner_of_entry(idx), u, "idx {idx}");
                assert_eq!(g.entry(idx), g.row(u)[idx - s]);
            }
        }
    }

    #[test]
    fn validate_rejects_broken_symmetry() {
        // hand-build an asymmetric structure: 0 lists 1, but 1's row empty
        let g = CsrGraph::from_storage_unchecked(
            CsrStorage::Owned {
                offsets: vec![0, 1, 1],
                edges: vec![PackedEdge::new(1, Dir::Out)],
            },
            1,
        );
        assert!(g.validate().is_err());
    }

    #[test]
    fn clone_and_eq_are_structural() {
        let g = triangle();
        let h = g.clone();
        assert_eq!(g, h);
        assert!(!h.is_mapped());
        assert_eq!(g.storage().offsets(), h.storage().offsets());
    }
}
