//! Degree-distribution analysis and power-law fitting (paper Fig 6).
//!
//! The paper characterizes each dataset by the power-law exponent of its
//! outdegree distribution (patents 3.126, Orkut 2.127, webgraph 1.516).
//! [`OutDegreeHistogram`] reproduces the Fig 6 log-log charts, and
//! [`fit_power_law`] estimates the exponent with the discrete
//! maximum-likelihood estimator of Clauset–Shalizi–Newman.

use super::csr::CsrGraph;

/// Summary statistics over a degree sequence.
#[derive(Debug, Clone, PartialEq)]
pub struct DegreeStats {
    pub min: usize,
    pub max: usize,
    pub mean: f64,
    /// Degree variance (population).
    pub variance: f64,
    /// Gini-style imbalance: max/mean — the paper's inner-loop imbalance
    /// driver on power-law graphs.
    pub imbalance: f64,
}

impl DegreeStats {
    /// Compute over an explicit degree sequence.
    pub fn from_sequence(degs: &[usize]) -> DegreeStats {
        assert!(!degs.is_empty());
        let n = degs.len() as f64;
        let mean = degs.iter().sum::<usize>() as f64 / n;
        let variance = degs
            .iter()
            .map(|&d| {
                let x = d as f64 - mean;
                x * x
            })
            .sum::<f64>()
            / n;
        let max = *degs.iter().max().unwrap();
        DegreeStats {
            min: *degs.iter().min().unwrap(),
            max,
            mean,
            variance,
            imbalance: if mean > 0.0 { max as f64 / mean } else { 0.0 },
        }
    }
}

/// Outdegree sequence of a graph.
pub fn out_degrees(g: &CsrGraph) -> Vec<usize> {
    (0..g.node_count() as u32).map(|u| g.out_degree(u)).collect()
}

/// In-degree sequence of a graph.
pub fn in_degrees(g: &CsrGraph) -> Vec<usize> {
    (0..g.node_count() as u32).map(|u| g.in_degree(u)).collect()
}

/// Histogram of outdegree frequencies: `counts[k]` = number of nodes with
/// outdegree `k`. Renders the Fig 6 log-log series.
#[derive(Debug, Clone)]
pub struct OutDegreeHistogram {
    pub counts: Vec<u64>,
}

impl OutDegreeHistogram {
    /// Build from a graph.
    pub fn new(g: &CsrGraph) -> OutDegreeHistogram {
        let degs = out_degrees(g);
        let max = degs.iter().copied().max().unwrap_or(0);
        let mut counts = vec![0u64; max + 1];
        for d in degs {
            counts[d] += 1;
        }
        OutDegreeHistogram { counts }
    }

    /// Non-zero `(degree, frequency)` points — the Fig 6 scatter series.
    pub fn points(&self) -> Vec<(usize, u64)> {
        self.counts
            .iter()
            .enumerate()
            .filter(|&(k, &c)| k > 0 && c > 0)
            .map(|(k, &c)| (k, c))
            .collect()
    }

    /// Log-binned `(degree, frequency-density)` points, the standard way
    /// to plot heavy tails without scatter noise.
    pub fn log_binned(&self, bins_per_decade: usize) -> Vec<(f64, f64)> {
        let pts = self.points();
        if pts.is_empty() {
            return Vec::new();
        }
        let ratio = 10f64.powf(1.0 / bins_per_decade as f64);
        let mut out = Vec::new();
        let mut lo = 1.0f64;
        let max_deg = pts.last().unwrap().0 as f64;
        while lo <= max_deg {
            let hi = lo * ratio;
            let mass: u64 = pts
                .iter()
                .filter(|&&(k, _)| (k as f64) >= lo && (k as f64) < hi)
                .map(|&(_, c)| c)
                .sum();
            if mass > 0 {
                let width = hi - lo;
                out.push(((lo * hi).sqrt(), mass as f64 / width));
            }
            lo = hi;
        }
        out
    }
}

/// Discrete power-law exponent MLE (Clauset–Shalizi–Newman eq. 3.7
/// continuous approximation): `γ ≈ 1 + n / Σ ln(k_i / (kmin - 1/2))`,
/// over degrees `k_i ≥ kmin`. Returns `None` if fewer than 10 samples
/// qualify.
pub fn fit_power_law(degs: &[usize], kmin: usize) -> Option<f64> {
    let kmin = kmin.max(1);
    let xs: Vec<f64> = degs
        .iter()
        .filter(|&&d| d >= kmin)
        .map(|&d| d as f64)
        .collect();
    if xs.len() < 10 {
        return None;
    }
    let denom: f64 = xs.iter().map(|&x| (x / (kmin as f64 - 0.5)).ln()).sum();
    Some(1.0 + xs.len() as f64 / denom)
}

/// Fit the outdegree exponent of a graph. `kmin` is set above the mean
/// outdegree: the configuration-model generator rescales degrees toward
/// a target mean, which flattens the distribution head below that knee
/// (and real datasets have noisy heads too — CSN recommend fitting the
/// tail only).
pub fn fit_out_degree_exponent(g: &CsrGraph) -> Option<f64> {
    let degs = out_degrees(g);
    let mean = degs.iter().sum::<usize>() as f64 / degs.len().max(1) as f64;
    let kmin = (mean.ceil() as usize).max(2);
    fit_power_law(&degs, kmin)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::power_law;
    use crate::rng::Rng;

    #[test]
    fn stats_on_known_sequence() {
        let s = DegreeStats::from_sequence(&[1, 2, 3, 4]);
        assert_eq!(s.min, 1);
        assert_eq!(s.max, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert!((s.variance - 1.25).abs() < 1e-12);
        assert!((s.imbalance - 1.6).abs() < 1e-12);
    }

    #[test]
    fn histogram_counts_sum_to_n() {
        let g = power_law(1000, 2.3, 6.0, 17);
        let h = OutDegreeHistogram::new(&g);
        assert_eq!(h.counts.iter().sum::<u64>(), 1000);
    }

    #[test]
    fn histogram_points_skip_zero_frequency() {
        let g = power_law(500, 2.3, 5.0, 17);
        for (k, c) in OutDegreeHistogram::new(&g).points() {
            assert!(k > 0 && c > 0);
        }
    }

    #[test]
    fn log_binning_preserves_mass_roughly() {
        let g = power_law(2000, 2.2, 8.0, 23);
        let h = OutDegreeHistogram::new(&g);
        let binned = h.log_binned(5);
        assert!(!binned.is_empty());
        // densities positive and x monotone
        for w in binned.windows(2) {
            assert!(w[0].0 < w[1].0);
        }
    }

    #[test]
    fn mle_recovers_exponent_of_pure_draws() {
        // Draw a large pure power-law sample and check the MLE lands near.
        // Fit above kmin=10: flooring continuous draws biases the head,
        // so the continuous-approximation MLE is only accurate in the tail.
        let mut rng = Rng::new(4);
        for gamma in [1.8f64, 2.5, 3.1] {
            let degs: Vec<usize> = (0..200_000)
                .map(|_| rng.power_law(gamma, 1.0, 1e7) as usize)
                .collect();
            let est = fit_power_law(&degs, 10).unwrap();
            assert!(
                (est - gamma).abs() < 0.3,
                "gamma={gamma} est={est}"
            );
        }
    }

    #[test]
    fn mle_needs_samples() {
        assert!(fit_power_law(&[5, 6, 7], 2).is_none());
    }

    #[test]
    fn generated_graph_exponent_in_band() {
        // The erased configuration model distorts the tail a little; the
        // fitted exponent should still sit in a broad band around target.
        let g = power_law(20_000, 2.127, 12.0, 11);
        let est = fit_out_degree_exponent(&g).unwrap();
        assert!(est > 1.6 && est < 2.8, "est={est}");
    }
}
