//! Deterministic graph generators.
//!
//! The paper evaluates on three real scale-free graphs (NBER patents,
//! Orkut, and a .uk webgraph). Those datasets are not redistributable /
//! not feasible at container scale, so — per the substitution rule in
//! DESIGN.md — we generate synthetic graphs whose *outdegree power-law
//! exponents match the paper's measured exponents* (3.126, 2.127, 1.516)
//! and whose density matches the originals' average degree, at a
//! CLI-scalable node count.

use super::builder::GraphBuilder;
use super::csr::CsrGraph;
use crate::rng::Rng;

/// A named, reproducible workload specification.
#[derive(Debug, Clone, PartialEq)]
pub struct GraphSpec {
    /// Human-readable name (used in figures and EXPERIMENTS.md).
    pub name: &'static str,
    /// Node count.
    pub n: usize,
    /// Target power-law exponent of the outdegree distribution.
    pub gamma: f64,
    /// Average outdegree (arcs / node).
    pub avg_out_degree: f64,
    /// RNG seed.
    pub seed: u64,
}

impl GraphSpec {
    /// Synthetic stand-in for the NBER patents citation network
    /// (paper: 16.5M arcs, outdegree exponent 3.126 — sparse).
    pub fn patents(n: usize) -> GraphSpec {
        GraphSpec {
            name: "patents",
            n,
            gamma: 3.126,
            avg_out_degree: 4.4,
            seed: 0x9a7e_2012,
        }
    }

    /// Synthetic stand-in for the Orkut social network
    /// (paper: 3.1M nodes / 234.4M arcs, exponent 2.127 — dense).
    pub fn orkut(n: usize) -> GraphSpec {
        GraphSpec {
            name: "orkut",
            n,
            gamma: 2.127,
            avg_out_degree: 75.0,
            seed: 0x0e4b_2012,
        }
    }

    /// Synthetic stand-in for the .uk webgraph
    /// (paper: 105.2M nodes / 2.5B arcs, exponent 1.516 — heavy tail).
    pub fn webgraph(n: usize) -> GraphSpec {
        GraphSpec {
            name: "webgraph",
            n,
            gamma: 1.516,
            avg_out_degree: 23.0,
            seed: 0x7eb_2012,
        }
    }

    /// Generate the graph for this spec.
    pub fn generate(&self) -> CsrGraph {
        power_law(self.n, self.gamma, self.avg_out_degree, self.seed)
    }
}

/// Resolve a named synthetic workload at an explicit node count — the
/// single source of the `patents` / `orkut` / `web` name mapping, shared
/// by the CLI flags and the serving protocol's generator graph source.
/// `seed` overrides the spec's default when given.
pub fn spec_by_name(name: &str, nodes: usize, seed: Option<u64>) -> Result<GraphSpec, String> {
    let mut spec = match name {
        "patents" => GraphSpec::patents(nodes),
        "orkut" => GraphSpec::orkut(nodes),
        "web" | "webgraph" => GraphSpec::webgraph(nodes),
        other => return Err(format!("unknown graph {other:?} (patents|orkut|web)")),
    };
    if let Some(s) = seed {
        spec.seed = s;
    }
    Ok(spec)
}

/// Directed scale-free graph via the configuration model: outdegrees are
/// drawn from a truncated discrete power law `P(k) ∝ k^(-gamma)`, scaled
/// to hit `avg_out_degree`, then each arc's head is sampled uniformly.
/// Duplicate arcs / self-loops are dropped by the builder (standard
/// "erased" configuration model).
pub fn power_law(n: usize, gamma: f64, avg_out_degree: f64, seed: u64) -> CsrGraph {
    assert!(n >= 2, "need at least two nodes");
    let mut rng = Rng::new(seed);
    let kmax = ((n - 1) as f64).min(1.0e6);
    // Draw raw degrees, then rescale to the target mean: the truncated
    // zeta mean depends on gamma, so fix it empirically.
    let mut degs: Vec<u64> = (0..n).map(|_| rng.power_law(gamma, 1.0, kmax)).collect();
    let raw_mean = degs.iter().sum::<u64>() as f64 / n as f64;
    let scale = avg_out_degree / raw_mean;
    if scale < 1.0 {
        // Thin by dropping arcs probabilistically, preserving the shape.
        for d in degs.iter_mut() {
            let keep = (*d as f64 * scale).floor() as u64;
            let frac = *d as f64 * scale - keep as f64;
            *d = keep + rng.chance(frac) as u64;
        }
    } else if scale > 1.0 {
        for d in degs.iter_mut() {
            let want = *d as f64 * scale;
            let keep = want.floor() as u64;
            let frac = want - keep as f64;
            *d = (keep + rng.chance(frac) as u64).min(n as u64 - 1);
        }
    }
    let mut b = GraphBuilder::new(n);
    for (u, &d) in degs.iter().enumerate() {
        for _ in 0..d {
            let mut v = rng.node(n as u32);
            if v as usize == u {
                v = (v + 1) % n as u32;
            }
            b.arc(u as u32, v);
        }
    }
    b.build()
}

/// Directed Barabási–Albert preferential attachment: each new node emits
/// `m` arcs to targets chosen proportionally to (in-degree + 1) via a
/// repeated-endpoint urn.
pub fn barabasi_albert(n: usize, m: usize, seed: u64) -> CsrGraph {
    assert!(n > m && m >= 1);
    let mut rng = Rng::new(seed);
    let mut urn: Vec<u32> = (0..m as u32).collect(); // seed clique targets
    let mut b = GraphBuilder::new(n);
    for u in m..n {
        for _ in 0..m {
            // preferential: mostly sample the urn, occasionally uniform
            let v = if !urn.is_empty() && rng.chance(0.9) {
                urn[rng.below(urn.len() as u64) as usize]
            } else {
                rng.node(u as u32)
            };
            if v != u as u32 {
                b.arc(u as u32, v);
                urn.push(v);
                urn.push(u as u32);
            }
        }
    }
    b.build()
}

/// Directed Erdős–Rényi G(n, m): `m` arcs sampled uniformly.
pub fn erdos_renyi(n: usize, m: usize, seed: u64) -> CsrGraph {
    let mut rng = Rng::new(seed);
    let mut b = GraphBuilder::new(n);
    for _ in 0..m {
        let u = rng.node(n as u32);
        let mut v = rng.node(n as u32);
        if v == u {
            v = (v + 1) % n as u32;
        }
        b.arc(u, v);
    }
    b.build()
}

/// Named tiny fixtures with hand-computable censuses, used across the
/// test suites.
pub mod named {
    use super::*;
    use crate::graph::builder::from_arcs;

    /// 3-cycle: one 030C triad.
    pub fn cycle3() -> CsrGraph {
        from_arcs(3, &[(0, 1), (1, 2), (2, 0)])
    }

    /// Transitive triple 0→1, 1→2, 0→2: one 030T triad.
    pub fn transitive3() -> CsrGraph {
        from_arcs(3, &[(0, 1), (1, 2), (0, 2)])
    }

    /// Complete mutual triangle: one 300 triad.
    pub fn mutual3() -> CsrGraph {
        from_arcs(3, &[(0, 1), (1, 0), (1, 2), (2, 1), (0, 2), (2, 0)])
    }

    /// Out-star on 4 nodes (0→1, 0→2, 0→3): three 021D triads plus one 003.
    pub fn out_star4() -> CsrGraph {
        from_arcs(4, &[(0, 1), (0, 2), (0, 3)])
    }

    /// In-star on 4 nodes: three 021U triads plus one 003.
    pub fn in_star4() -> CsrGraph {
        from_arcs(4, &[(1, 0), (2, 0), (3, 0)])
    }

    /// Directed 5-cycle.
    pub fn cycle5() -> CsrGraph {
        from_arcs(5, &[(0, 1), (1, 2), (2, 3), (3, 4), (4, 0)])
    }

    /// Complete mutual digraph K5 (all dyads mutual): C(5,3)=10 300-triads.
    pub fn complete_mutual(n: usize) -> CsrGraph {
        let mut b = GraphBuilder::new(n);
        for u in 0..n as u32 {
            for v in 0..n as u32 {
                if u != v {
                    b.arc(u, v);
                }
            }
        }
        b.build()
    }

    /// The paper's Fig 1 examples combined: reciprocity, transitivity and
    /// intransitivity patterns on 7 nodes.
    pub fn fig1() -> CsrGraph {
        from_arcs(
            7,
            &[
                (0, 1),
                (1, 0), // reciprocal pair
                (2, 3),
                (3, 4),
                (2, 4), // transitive triple
                (4, 5),
                (5, 6), // chain (intransitive)
            ],
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn power_law_deterministic() {
        let a = power_law(500, 2.2, 8.0, 42);
        let b = power_law(500, 2.2, 8.0, 42);
        assert_eq!(a, b);
    }

    #[test]
    fn power_law_seed_changes_graph() {
        let a = power_law(500, 2.2, 8.0, 1);
        let b = power_law(500, 2.2, 8.0, 2);
        assert_ne!(a, b);
    }

    #[test]
    fn power_law_hits_target_density() {
        let n = 4000;
        let target = 10.0;
        let g = power_law(n, 2.5, target, 7);
        let avg = g.arc_count() as f64 / n as f64;
        // erasure of duplicates loses a little density
        assert!(avg > target * 0.7 && avg < target * 1.1, "avg={avg}");
    }

    #[test]
    fn power_law_is_heavy_tailed() {
        let g = power_law(3000, 2.0, 10.0, 3);
        let mut degs: Vec<usize> = (0..3000).map(|u| g.out_degree(u as u32)).collect();
        degs.sort_unstable_by(|a, b| b.cmp(a));
        // hub much larger than the median
        let median = degs[1500];
        assert!(degs[0] > 10 * median.max(1), "hub={} median={}", degs[0], median);
    }

    #[test]
    fn spec_generators_validate() {
        for spec in [
            GraphSpec::patents(2000),
            GraphSpec::orkut(1000),
            GraphSpec::webgraph(2000),
        ] {
            let g = spec.generate();
            assert_eq!(g.node_count(), spec.n);
            assert!(g.validate().is_ok(), "{}", spec.name);
            assert!(g.arc_count() > 0);
        }
    }

    #[test]
    fn ba_validates_and_is_dense_enough() {
        let g = barabasi_albert(800, 3, 5);
        assert!(g.validate().is_ok());
        assert!(g.arc_count() as usize > 800);
    }

    #[test]
    fn er_arc_count_close() {
        let g = erdos_renyi(1000, 5000, 9);
        // duplicates get merged; expect most to survive
        assert!(g.arc_count() > 4800);
    }

    #[test]
    fn named_fixtures_validate() {
        for g in [
            named::cycle3(),
            named::transitive3(),
            named::mutual3(),
            named::out_star4(),
            named::in_star4(),
            named::cycle5(),
            named::complete_mutual(5),
            named::fig1(),
        ] {
            assert!(g.validate().is_ok());
        }
    }
}
