//! Hub-bitmap rows over a [`DirSplit`]: the data side of the hybrid
//! census kernel.
//!
//! After degree-descending relabeling the hubs are exactly the nodes
//! `0..k`, and the canonical `u < v` dyad enumeration classifies every
//! hub-involving triad from its hub endpoint. The merged union walk
//! pays O(deg(u) + deg(v)) per dyad — dominated by the hub's enormous
//! row. `HubSplit` stores the top-`k` rows *additionally* as packed
//! 2-bit-direction bitmaps (an out plane and an in plane of `n` bits
//! each), so the census kernel (`census/hybrid.rs`) can
//!
//! * answer "what is the `(u, w)` dyad?" for a hub `u` in O(1) — two
//!   masked loads — while walking only the *short* neighborhood
//!   `N(v)`; and
//! * bulk-count the hub's remaining neighbors above any id with O(1)
//!   rank arithmetic (word-granularity prefix ranks per direction
//!   class, closed with one masked popcount), instead of draining the
//!   hub row element by element.
//!
//! `k` is picked adaptively: rows qualify while their degree exceeds a
//! density threshold (the merge-walk cost model: a hub repays its
//! bitmap once `deg²` beats the row-build cost `n/64`), capped by a
//! memory budget. `k = 0` (nothing qualifies — e.g. natural ordering
//! or a degree-uniform graph) degrades to plain [`DirSplit`] behavior:
//! the view delegates every [`GraphView`] method to the inner split,
//! so generic engines run unchanged and byte-identical.

use std::borrow::Cow;
use std::sync::atomic::{AtomicU64, Ordering};

use super::relabel::{DirSplit, DirSplitNeighbors};
use super::view::GraphView;

/// Memory ceiling for the bitmap planes + rank arrays (bytes).
const DEFAULT_MEMORY_BUDGET: usize = 64 << 20;

/// Stripes for the hub-row traffic counters (power of two). The hot
/// path bumps one counter per canonical dyad task; striping by `u`
/// keeps concurrent workers off a single contended line.
const TRAFFIC_STRIPES: usize = 8;

/// Below this many measured dyad tasks a retune has no signal.
const RETUNE_MIN_DYADS: u64 = 1024;

/// A cache-line-padded counter stripe: adjacent stripes must not share
/// a line or the striping buys nothing.
#[repr(align(64))]
struct PaddedCounter(AtomicU64);

fn counter_stripes() -> [PaddedCounter; TRAFFIC_STRIPES] {
    std::array::from_fn(|_| PaddedCounter(AtomicU64::new(0)))
}

/// Measured hub-row traffic accumulated by censuses since the last
/// [`HubSplit::reset_hub_stats`] (or since the split was built).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct HubStats {
    /// Canonical dyad tasks answered from a hub bitmap row.
    pub hits: u64,
    /// Dyad tasks that fell through to the merged union walk.
    pub misses: u64,
}

impl HubStats {
    /// Total dyad tasks measured.
    pub fn total(&self) -> u64 {
        self.hits + self.misses
    }

    /// Fraction of dyad tasks the bitmap rows answered (0.0 when
    /// nothing was measured).
    pub fn hit_rate(&self) -> f64 {
        let total = self.total();
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Degree above which a row repays its bitmap: the hub kernel saves
/// ~deg(u) work on each of the hub's ~deg(u) canonical dyads, while the
/// row costs O(n/64) words to build — profitable once deg ≳ √n/4, with
/// a small floor so trivial rows never qualify.
fn hub_degree_threshold(n: usize) -> usize {
    (((n as f64).sqrt() / 4.0) as usize).max(32)
}

/// [`DirSplit`] plus packed direction-bitmap rows for the top-`k`
/// (hub) nodes. See the module docs for layout and the cost model.
pub struct HubSplit {
    split: DirSplit,
    /// Hubs are nodes `0..k`.
    k: usize,
    /// Words per bit plane row: `ceil(n / 64)`.
    words: usize,
    /// `k × words` — bit `w` of row `u` set iff the arc `u -> w` exists.
    out_plane: Vec<u64>,
    /// `k × words` — bit `w` set iff the arc `w -> u` exists.
    in_plane: Vec<u64>,
    /// `k × (words + 1)` per class: `rank[u][wi]` = neighbors of that
    /// class in words `< wi`. Suffix counts close with one masked
    /// popcount of the boundary word.
    rank_recip: Vec<u32>,
    rank_out: Vec<u32>,
    rank_in: Vec<u32>,
    /// Striped dyad-task counters: tasks answered from a bitmap row.
    hits: [PaddedCounter; TRAFFIC_STRIPES],
    /// Striped dyad-task counters: tasks that fell to the merged walk.
    misses: [PaddedCounter; TRAFFIC_STRIPES],
    /// Adaptive-`k` rebuild generation (0 = never retuned).
    retunes: u64,
}

impl HubSplit {
    /// Build with the adaptive hub count (degree threshold + the
    /// default memory budget).
    pub fn build(split: DirSplit) -> HubSplit {
        let k = Self::adaptive_hub_count(&split, DEFAULT_MEMORY_BUDGET);
        Self::with_hub_count(split, k)
    }

    /// Longest prefix of rows whose degree clears the density
    /// threshold, capped by `memory_budget` bytes of plane + rank
    /// storage. On a degree-descending relabeled graph this is exactly
    /// "every row above the threshold"; under other orderings the
    /// prefix scan stops at the first light row (conservative by
    /// design — bitmap rows only pay off for hubs).
    pub fn adaptive_hub_count(split: &DirSplit, memory_budget: usize) -> usize {
        let n = split.node_count();
        if n == 0 {
            return 0;
        }
        let cap = Self::budget_hub_cap(n, memory_budget);
        let threshold = hub_degree_threshold(n);
        let mut k = 0;
        while k < cap && split.degree(k as u32) >= threshold {
            k += 1;
        }
        k
    }

    /// Maximum hub rows `memory_budget` bytes of plane + rank storage
    /// can hold for an `n`-node graph.
    pub fn budget_hub_cap(n: usize, memory_budget: usize) -> usize {
        if n == 0 {
            return 0;
        }
        let words = n.div_ceil(64);
        let bytes_per_hub = 2 * words * 8 + 3 * (words + 1) * 4;
        (memory_budget / bytes_per_hub.max(1)).min(n)
    }

    /// Build with an explicit hub count (tests force `k = 0` / `k = n`;
    /// production callers use [`HubSplit::build`]).
    pub fn with_hub_count(split: DirSplit, k: usize) -> HubSplit {
        let n = split.node_count();
        let k = k.min(n);
        let words = n.div_ceil(64);
        let mut out_plane = vec![0u64; k * words];
        let mut in_plane = vec![0u64; k * words];
        for u in 0..k {
            let row = u * words;
            let (recip, out_only, in_only) = split.runs(u as u32);
            for &w in recip {
                out_plane[row + w as usize / 64] |= 1 << (w % 64);
                in_plane[row + w as usize / 64] |= 1 << (w % 64);
            }
            for &w in out_only {
                out_plane[row + w as usize / 64] |= 1 << (w % 64);
            }
            for &w in in_only {
                in_plane[row + w as usize / 64] |= 1 << (w % 64);
            }
        }
        let mut rank_recip = vec![0u32; k * (words + 1)];
        let mut rank_out = vec![0u32; k * (words + 1)];
        let mut rank_in = vec![0u32; k * (words + 1)];
        for u in 0..k {
            let row = u * words;
            let base = u * (words + 1);
            for wi in 0..words {
                let o = out_plane[row + wi];
                let i = in_plane[row + wi];
                rank_recip[base + wi + 1] = rank_recip[base + wi] + (o & i).count_ones();
                rank_out[base + wi + 1] = rank_out[base + wi] + (o & !i).count_ones();
                rank_in[base + wi + 1] = rank_in[base + wi] + (i & !o).count_ones();
            }
        }
        HubSplit {
            split,
            k,
            words,
            out_plane,
            in_plane,
            rank_recip,
            rank_out,
            rank_in,
            hits: counter_stripes(),
            misses: counter_stripes(),
            retunes: 0,
        }
    }

    /// Rebuild the planes and rank arrays for a different hub count.
    /// The inner split is cloned (O(m)); the traffic counters of the
    /// new split start at zero and its retune generation advances.
    /// This is the retune path — cheap enough to run between censuses,
    /// never on one.
    pub fn rebuild_with_k(&self, k: usize) -> HubSplit {
        let mut h = Self::with_hub_count(self.split.clone(), k);
        h.retunes = self.retunes + 1;
        h
    }

    /// How many adaptive-`k` rebuilds produced this split (0 = the
    /// original build).
    pub fn retune_count(&self) -> u64 {
        self.retunes
    }

    /// Count one dyad task answered from a hub bitmap row.
    #[inline]
    pub fn record_hub_hit(&self, u: u32) {
        self.hits[u as usize % TRAFFIC_STRIPES].0.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one dyad task that fell through to the merged walk.
    #[inline]
    pub fn record_hub_miss(&self, u: u32) {
        self.misses[u as usize % TRAFFIC_STRIPES].0.fetch_add(1, Ordering::Relaxed);
    }

    /// Traffic measured since the last reset (or since build).
    pub fn hub_stats(&self) -> HubStats {
        let sum = |strips: &[PaddedCounter; TRAFFIC_STRIPES]| {
            strips.iter().map(|s| s.0.load(Ordering::Relaxed)).sum()
        };
        HubStats {
            hits: sum(&self.hits),
            misses: sum(&self.misses),
        }
    }

    /// Zero the traffic counters (a retune window boundary).
    pub fn reset_hub_stats(&self) {
        for s in self.hits.iter().chain(self.misses.iter()) {
            s.0.store(0, Ordering::Relaxed);
        }
    }

    /// Propose a better hub count from measured traffic, or `None` when
    /// the current `k` is fine (or there is not enough signal yet).
    ///
    /// * **Shrink** when the bitmap budget is mis-spent: rows exist but
    ///   answer under 1/16 of dyad tasks — halve `k` (possibly to 0,
    ///   degrading to plain [`DirSplit`] behavior).
    /// * **Grow** when hub rows answer the majority of tasks, budget
    ///   remains, and the next rows still clear a relaxed (halved)
    ///   degree threshold — measured traffic has proven the bitmap
    ///   path out, so the admission bar drops. Growth is capped at
    ///   `2k` per retune so one window cannot overshoot.
    ///
    /// The dead band between 1/16 and 1/2 prevents shrink/grow
    /// oscillation across retune windows.
    pub fn retune_k(&self) -> Option<usize> {
        let s = self.hub_stats();
        if self.k == 0 || s.total() < RETUNE_MIN_DYADS {
            return None;
        }
        if s.hits * 16 < s.total() {
            return Some(self.k / 2);
        }
        let n = self.split.node_count();
        let cap = Self::budget_hub_cap(n, DEFAULT_MEMORY_BUDGET);
        if s.hits * 2 > s.total() && self.k < cap {
            let relaxed = hub_degree_threshold(n) / 2;
            let ceiling = cap.min(self.k * 2);
            let mut new_k = self.k;
            while new_k < ceiling && self.split.degree(new_k as u32) >= relaxed {
                new_k += 1;
            }
            if new_k > self.k {
                return Some(new_k);
            }
        }
        None
    }

    /// Number of bitmap-backed hub rows.
    pub fn hub_count(&self) -> usize {
        self.k
    }

    /// The inner direction-split form (the sparse-tail path).
    pub fn split(&self) -> &DirSplit {
        &self.split
    }

    /// True if `u` has a bitmap row.
    #[inline]
    pub fn is_hub(&self, u: u32) -> bool {
        (u as usize) < self.k
    }

    /// Words per bit-plane row.
    pub fn words(&self) -> usize {
        self.words
    }

    /// O(1) dyad lookup from hub `u`'s bitmap row: direction bits of
    /// `(u, w)` from `u`'s perspective (`0` = null).
    #[inline]
    pub fn hub_dyad_bits(&self, u: u32, w: u32) -> u8 {
        debug_assert!(self.is_hub(u));
        let row = u as usize * self.words;
        let (wi, bit) = (w as usize / 64, w as usize % 64);
        let o = (self.out_plane[row + wi] >> bit) & 1;
        let i = (self.in_plane[row + wi] >> bit) & 1;
        (o | (i << 1)) as u8
    }

    /// Bit-plane words `(out, in)` of hub `u`'s row — the dense
    /// hub–hub word-intersection path of the kernel.
    #[inline]
    pub fn planes(&self, u: u32) -> (&[u64], &[u64]) {
        debug_assert!(self.is_hub(u));
        let row = u as usize * self.words;
        (
            &self.out_plane[row..row + self.words],
            &self.in_plane[row..row + self.words],
        )
    }

    /// Per direction class, the number of neighbors of hub `u` with id
    /// strictly greater than `v`, indexed by the class's 2-bit dyad
    /// code (`[_, out-only, in-only, reciprocal]`). O(1): one rank
    /// lookup plus one masked popcount per class.
    #[inline]
    pub fn counts_above(&self, u: u32, v: u32) -> [u64; 4] {
        debug_assert!(self.is_hub(u));
        let (recip, out_only, in_only) = self.split.runs(u);
        let row = u as usize * self.words;
        let base = u as usize * (self.words + 1);
        let (wi, bit) = (v as usize / 64, v as usize % 64);
        // bits with id <= v inside the boundary word
        let low = if bit == 63 {
            u64::MAX
        } else {
            (1u64 << (bit + 1)) - 1
        };
        let o = self.out_plane[row + wi];
        let i = self.in_plane[row + wi];
        let le_out = self.rank_out[base + wi] as u64 + ((o & !i) & low).count_ones() as u64;
        let le_in = self.rank_in[base + wi] as u64 + ((i & !o) & low).count_ones() as u64;
        let le_recip = self.rank_recip[base + wi] as u64 + ((o & i) & low).count_ones() as u64;
        [
            0,
            out_only.len() as u64 - le_out,
            in_only.len() as u64 - le_in,
            recip.len() as u64 - le_recip,
        ]
    }
}

impl GraphView for HubSplit {
    type Neighbors<'a>
        = DirSplitNeighbors<'a>
    where
        Self: 'a;

    #[inline]
    fn node_count(&self) -> usize {
        self.split.node_count()
    }

    #[inline]
    fn arc_count(&self) -> u64 {
        self.split.arc_count()
    }

    #[inline]
    fn neighbors(&self, u: u32) -> DirSplitNeighbors<'_> {
        self.split.neighbors(u)
    }

    #[inline]
    fn dyad_bits(&self, u: u32, v: u32) -> u8 {
        if self.is_hub(u) {
            self.hub_dyad_bits(u, v)
        } else {
            self.split.dyad_bits(u, v)
        }
    }

    #[inline]
    fn degree(&self, u: u32) -> usize {
        self.split.degree(u)
    }

    #[inline]
    fn entry_count(&self) -> usize {
        self.split.entry_count()
    }

    #[inline]
    fn flat_offsets(&self) -> Cow<'_, [usize]> {
        self.split.flat_offsets()
    }

    #[inline]
    fn out_degree(&self, u: u32) -> usize {
        self.split.out_degree(u)
    }

    #[inline]
    fn in_degree(&self, u: u32) -> usize {
        self.split.in_degree(u)
    }

    #[inline]
    fn reciprocal_degree(&self, u: u32) -> usize {
        self.split.reciprocal_degree(u)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::from_arcs;
    use crate::graph::generators;
    use crate::graph::relabel::degree_split;

    fn forced(n: usize, seed: u64, k: usize) -> HubSplit {
        let g = generators::power_law(n, 2.2, 6.0, seed);
        let (_, split) = degree_split(&g, 2);
        HubSplit::with_hub_count(split, k)
    }

    #[test]
    fn hub_bits_match_the_split_lookup() {
        let h = forced(150, 7, 150);
        let n = h.node_count() as u32;
        for u in 0..n {
            for w in 0..n {
                if u != w {
                    assert_eq!(
                        h.hub_dyad_bits(u, w),
                        h.split().dyad_bits(u, w),
                        "dyad ({u},{w})"
                    );
                }
            }
        }
    }

    #[test]
    fn counts_above_match_a_linear_scan() {
        let h = forced(140, 11, 140);
        let n = h.node_count() as u32;
        for u in 0..n {
            for v in 0..n {
                let mut want = [0u64; 4];
                for (w, bits) in h.split().neighbors(u) {
                    if w > v {
                        want[bits as usize] += 1;
                    }
                }
                let got = h.counts_above(u, v);
                assert_eq!(got, want, "hub {u} above {v}");
            }
        }
    }

    #[test]
    fn adaptive_k_takes_the_heavy_prefix_only() {
        // star: one mega-hub, tails of degree 1
        let arcs: Vec<(u32, u32)> = (1..200u32).map(|v| (0, v)).collect();
        let g = from_arcs(200, &arcs);
        let (_, split) = degree_split(&g, 2);
        let k = HubSplit::adaptive_hub_count(&split, DEFAULT_MEMORY_BUDGET);
        assert_eq!(k, 1, "only the star center clears the threshold");
        // degree-uniform sparse graph: nothing qualifies
        let ring: Vec<(u32, u32)> = (0..100u32).map(|u| (u, (u + 1) % 100)).collect();
        let g = from_arcs(100, &ring);
        let (_, split) = degree_split(&g, 2);
        assert_eq!(HubSplit::adaptive_hub_count(&split, DEFAULT_MEMORY_BUDGET), 0);
    }

    #[test]
    fn memory_budget_caps_the_hub_count() {
        let g = generators::power_law(512, 2.0, 8.0, 3);
        let (_, split) = degree_split(&g, 2);
        let unbounded = HubSplit::adaptive_hub_count(&split, usize::MAX);
        // a budget of ~two rows keeps at most two hubs
        let words = 512usize.div_ceil(64);
        let per_hub = 2 * words * 8 + 3 * (words + 1) * 4;
        let capped = HubSplit::adaptive_hub_count(&split, 2 * per_hub);
        assert!(capped <= 2 && capped <= unbounded);
    }

    #[test]
    fn view_delegates_to_the_inner_split() {
        let h = forced(120, 5, 8);
        let n = h.node_count() as u32;
        assert_eq!(h.entry_count(), h.split().entry_count());
        assert_eq!(h.arc_count(), h.split().arc_count());
        assert_eq!(h.flat_offsets(), h.split().flat_offsets());
        for u in 0..n {
            let a: Vec<(u32, u8)> = h.neighbors(u).collect();
            let b: Vec<(u32, u8)> = h.split().neighbors(u).collect();
            assert_eq!(a, b, "node {u}");
            assert_eq!(h.degree(u), h.split().degree(u));
            assert_eq!(h.out_degree(u), h.split().out_degree(u));
            assert_eq!(h.in_degree(u), h.split().in_degree(u));
            assert_eq!(h.reciprocal_degree(u), h.split().reciprocal_degree(u));
            for v in 0..n {
                if u != v {
                    assert_eq!(h.dyad_bits(u, v), h.split().dyad_bits(u, v));
                }
            }
        }
    }

    #[test]
    fn traffic_counters_accumulate_and_reset() {
        let h = forced(100, 3, 10);
        assert_eq!(h.hub_stats(), HubStats::default());
        for u in 0..40u32 {
            h.record_hub_hit(u);
        }
        for u in 0..60u32 {
            h.record_hub_miss(u);
        }
        let s = h.hub_stats();
        assert_eq!((s.hits, s.misses, s.total()), (40, 60, 100));
        assert!((s.hit_rate() - 0.4).abs() < 1e-12);
        h.reset_hub_stats();
        assert_eq!(h.hub_stats().total(), 0);
        assert_eq!(HubStats::default().hit_rate(), 0.0);
    }

    #[test]
    fn retune_needs_signal_before_proposing() {
        let h = forced(100, 3, 8);
        for _ in 0..100 {
            h.record_hub_miss(1);
        }
        assert_eq!(h.retune_k(), None, "under the signal floor");
        let h0 = forced(100, 3, 0);
        for _ in 0..5000 {
            h0.record_hub_miss(1);
        }
        assert_eq!(h0.retune_k(), None, "k = 0 has no rows to tune");
    }

    #[test]
    fn retune_shrinks_idle_rows_and_grows_hot_ones() {
        // idle rows: 64 bitmap rows answering < 1/16 of the traffic
        let h = forced(200, 5, 64);
        for _ in 0..100 {
            h.record_hub_hit(0);
        }
        for _ in 0..5000 {
            h.record_hub_miss(100);
        }
        assert_eq!(h.retune_k(), Some(32), "halve the mis-spent budget");
        // hot rows: the majority of traffic is hub-answered and every
        // row of the mutual clique clears the relaxed threshold
        let g = crate::graph::generators::named::complete_mutual(128);
        let (_, split) = degree_split(&g, 2);
        let h = HubSplit::with_hub_count(split, 3);
        for _ in 0..900 {
            h.record_hub_hit(1);
        }
        for _ in 0..300 {
            h.record_hub_miss(50);
        }
        assert_eq!(h.retune_k(), Some(6), "double within the budget cap");
        // dead band: neither branch fires between 1/16 and 1/2
        h.reset_hub_stats();
        for _ in 0..400 {
            h.record_hub_hit(1);
        }
        for _ in 0..800 {
            h.record_hub_miss(50);
        }
        assert_eq!(h.retune_k(), None, "hit rate 1/3 sits in the dead band");
    }

    #[test]
    fn rebuild_with_k_matches_a_fresh_build() {
        let h = forced(150, 7, 150);
        let r = h.rebuild_with_k(5);
        assert_eq!(r.hub_count(), 5);
        assert_eq!(r.hub_stats().total(), 0, "rebuilt counters start at zero");
        assert_eq!((h.retune_count(), r.retune_count()), (0, 1));
        assert_eq!(r.rebuild_with_k(3).retune_count(), 2);
        let n = r.node_count() as u32;
        for u in 0..5u32 {
            for w in 0..n {
                if u != w {
                    assert_eq!(r.hub_dyad_bits(u, w), h.split().dyad_bits(u, w));
                }
            }
            assert_eq!(r.counts_above(u, n - 1), [0, 0, 0, 0]);
        }
    }

    #[test]
    fn zero_and_empty_edge_cases() {
        let g = crate::graph::CsrGraph::empty(0);
        let split = DirSplit::build(&g);
        let h = HubSplit::build(split);
        assert_eq!(h.hub_count(), 0);
        assert_eq!(h.node_count(), 0);
        let h = forced(60, 1, 0);
        assert_eq!(h.hub_count(), 0);
        assert!(!h.is_hub(0));
    }
}
