//! Graph I/O.
//!
//! Three on-disk representations, slowest to fastest to load:
//!
//! * **Edge-list text** (`u v` per line, `#`/`%` comments) — the format
//!   the paper's datasets ship in (SNAP/LAW style). Parsed serially
//!   ([`read_edge_list`]) or with a chunked parallel parser + parallel
//!   CSR build ([`read_edge_list_parallel`]).
//! * **v1 binary** (`TRIADIC1`) — the legacy streamed CSR dump; loads
//!   without re-sorting but still allocates and copies everything.
//! * **v2 binary** (`TRIADIC2`) — the zero-copy mmap layout: a 64-byte
//!   header, then the offsets section (`n + 1` × `u64` LE) and the
//!   packed-edge section (`m` × `u32` LE), each 64-byte aligned, with an
//!   FNV-1a checksum over both sections. [`load_mmap_file`] maps the
//!   file and serves the census engines directly from the page cache —
//!   no parsing, no allocation proportional to the graph.
//!
//! ```text
//! v2 header (64 bytes, little-endian):
//!   0.. 8  magic "TRIADIC2"       32..40  arc_count u64
//!   8..12  version u32 (= 1)      40..48  offsets section offset u64
//!  12..16  flags u32 (reserved)   48..56  edges section offset u64
//!  16..24  node count n u64       56..64  FNV-1a-64 of both sections
//!  24..32  entry count m u64
//! ```
//!
//! [`load_auto`] sniffs the magic and picks the right reader.

use std::fs::File;
use std::io::{self, BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};

use super::builder::GraphBuilder;
use super::csr::{CsrGraph, PackedEdge};
use super::mmap::MmapFile;
use super::storage::{CsrStorage, MappedCsr};

/// Magic + version for the legacy (v1) binary format.
const MAGIC: &[u8; 8] = b"TRIADIC1";

/// Magic for the zero-copy (v2) binary format.
pub const MAGIC_V2: &[u8; 8] = b"TRIADIC2";
/// Current v2 layout version.
const V2_VERSION: u32 = 1;
/// Fixed v2 header size.
const V2_HEADER_BYTES: usize = 64;
/// Section alignment (cache-line) — the mmap base is page-aligned, so
/// this guarantees every section pointer is at least 8-byte aligned.
const V2_SECTION_ALIGN: u64 = 64;

fn bad(m: impl std::fmt::Display) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, m.to_string())
}

/// Parse a whitespace/tab separated edge list (`u v` per line, `#`
/// comments allowed, ids arbitrary u32 — the max id defines `n`).
pub fn read_edge_list<R: BufRead>(r: R) -> io::Result<CsrGraph> {
    let mut arcs: Vec<(u32, u32)> = Vec::new();
    let mut max_id = 0u32;
    for (lineno, line) in r.lines().enumerate() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        let (a, b) = match (it.next(), it.next()) {
            (Some(a), Some(b)) => (a, b),
            _ => {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    format!("line {}: expected two ids", lineno + 1),
                ))
            }
        };
        let parse = |s: &str| {
            s.parse::<u32>().map_err(|e| {
                io::Error::new(
                    io::ErrorKind::InvalidData,
                    format!("line {}: bad id {s:?}: {e}", lineno + 1),
                )
            })
        };
        let (u, v) = (parse(a)?, parse(b)?);
        max_id = max_id.max(u).max(v);
        arcs.push((u, v));
    }
    let n = if arcs.is_empty() { 0 } else { max_id as usize + 1 };
    let mut b = GraphBuilder::new(n);
    b.extend(arcs);
    Ok(b.build())
}

/// Read an edge-list file.
pub fn read_edge_list_file<P: AsRef<Path>>(path: P) -> io::Result<CsrGraph> {
    read_edge_list(BufReader::new(File::open(path)?))
}

/// Per-worker accumulator of the parallel edge-list parser.
#[derive(Default)]
struct ParseAcc {
    arcs: Vec<(u32, u32)>,
    max_id: u32,
    /// Earliest error seen, keyed by byte offset for determinism.
    err: Option<(usize, String)>,
}

/// Parse an edge list held in memory with `threads` workers: the byte
/// range is split at newline boundaries into dynamically claimed
/// chunks, each parsed into thread-local arc vectors, then assembled
/// with the parallel CSR builder. Produces the same graph as
/// [`read_edge_list`] on the same ASCII bytes (arc order never matters
/// — the builder sorts and OR-merges duplicates); the only divergence
/// is that non-ASCII Unicode whitespace is not treated as a separator
/// here.
pub fn read_edge_list_parallel(bytes: &[u8], threads: usize) -> io::Result<CsrGraph> {
    let threads = threads.max(1);
    // below ~64 KiB the spawn + merge overhead dominates
    if threads == 1 || bytes.len() < (1 << 16) {
        return read_edge_list(bytes);
    }

    // chunk boundaries snapped forward to newline edges
    let nchunks = threads * 4;
    let mut bounds: Vec<usize> = Vec::with_capacity(nchunks + 1);
    bounds.push(0);
    for i in 1..nchunks {
        let guess = bytes.len() * i / nchunks;
        let snapped = match bytes[guess..].iter().position(|&b| b == b'\n') {
            Some(p) => guess + p + 1,
            None => bytes.len(),
        };
        if snapped > *bounds.last().unwrap() && snapped < bytes.len() {
            bounds.push(snapped);
        }
    }
    bounds.push(bytes.len());

    let cursor = AtomicUsize::new(0);
    let mut parts: Vec<ParseAcc> = Vec::with_capacity(threads);
    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(threads);
        for _ in 0..threads {
            let cursor = &cursor;
            let bounds = &bounds;
            handles.push(s.spawn(move || {
                let mut acc = ParseAcc::default();
                loop {
                    let k = cursor.fetch_add(1, Ordering::Relaxed);
                    if k + 1 >= bounds.len() {
                        break;
                    }
                    parse_chunk(&bytes[bounds[k]..bounds[k + 1]], bounds[k], &mut acc);
                }
                acc
            }));
        }
        for h in handles {
            parts.push(h.join().expect("edge-list parser thread panicked"));
        }
    });

    // surface the earliest parse error (byte offset keeps it stable
    // across thread schedules)
    let mut first_err: Option<(usize, String)> = None;
    for p in &parts {
        if let Some((off, msg)) = &p.err {
            let better = match &first_err {
                None => true,
                Some((o, _)) => off < o,
            };
            if better {
                first_err = Some((*off, msg.clone()));
            }
        }
    }
    if let Some((off, msg)) = first_err {
        return Err(bad(format!("byte offset {off}: {msg}")));
    }

    let total: usize = parts.iter().map(|p| p.arcs.len()).sum();
    let max_id = parts.iter().map(|p| p.max_id).max().unwrap_or(0);
    let n = if total == 0 { 0 } else { max_id as usize + 1 };
    let mut b = GraphBuilder::new(n);
    for p in parts {
        b.extend(p.arcs);
    }
    Ok(b.build_parallel(threads))
}

/// Read an edge-list file with the parallel parser.
pub fn read_edge_list_file_parallel<P: AsRef<Path>>(
    path: P,
    threads: usize,
) -> io::Result<CsrGraph> {
    let bytes = std::fs::read(path)?;
    read_edge_list_parallel(&bytes, threads)
}

/// Parse one newline-delimited chunk; `base` is the chunk's byte offset
/// in the whole input (error reporting only).
fn parse_chunk(chunk: &[u8], base: usize, acc: &mut ParseAcc) {
    let mut line_start = 0usize;
    while line_start < chunk.len() {
        let line_end = chunk[line_start..]
            .iter()
            .position(|&b| b == b'\n')
            .map(|p| line_start + p)
            .unwrap_or(chunk.len());
        if let Err(msg) = parse_line(&chunk[line_start..line_end], acc) {
            let better = match &acc.err {
                None => true,
                Some((o, _)) => base + line_start < *o,
            };
            if better {
                acc.err = Some((base + line_start, msg));
            }
        }
        line_start = line_end + 1;
    }
}

/// Parse one text line into `acc` (same grammar as [`read_edge_list`]:
/// two u32 tokens, trailing tokens ignored, `#`/`%` comments skipped).
fn parse_line(line: &[u8], acc: &mut ParseAcc) -> Result<(), String> {
    let t = line.trim_ascii();
    if t.is_empty() || t[0] == b'#' || t[0] == b'%' {
        return Ok(());
    }
    let (u, rest) = parse_u32_token(t)?;
    let rest = skip_ascii_ws(rest);
    let (v, _) = parse_u32_token(rest)?;
    acc.arcs.push((u, v));
    acc.max_id = acc.max_id.max(u).max(v);
    Ok(())
}

#[inline]
fn skip_ascii_ws(b: &[u8]) -> &[u8] {
    let mut i = 0;
    while i < b.len() && b[i].is_ascii_whitespace() {
        i += 1;
    }
    &b[i..]
}

/// Parse a decimal u32 token (optional leading `+`, matching
/// `str::parse::<u32>`) that must terminate at whitespace or the end of
/// the slice; returns the value and the remaining bytes.
fn parse_u32_token(b: &[u8]) -> Result<(u32, &[u8]), String> {
    let mut i = 0usize;
    if i < b.len() && b[i] == b'+' {
        i += 1;
    }
    let digits_start = i;
    let mut val: u64 = 0;
    while i < b.len() && b[i].is_ascii_digit() {
        val = val * 10 + (b[i] - b'0') as u64;
        if val > u32::MAX as u64 {
            return Err("id exceeds u32".to_string());
        }
        i += 1;
    }
    if i == digits_start {
        return Err("expected two ids".to_string());
    }
    if i < b.len() && !b[i].is_ascii_whitespace() {
        return Err(format!("bad id: trailing byte {:?}", b[i] as char));
    }
    Ok((val as u32, &b[i..]))
}

/// Write a graph as a directed edge list (one arc per line).
pub fn write_edge_list<W: Write>(g: &CsrGraph, mut w: W) -> io::Result<()> {
    writeln!(w, "# triadic edge list: {} nodes {} arcs", g.node_count(), g.arc_count())?;
    for (u, v) in g.arcs() {
        writeln!(w, "{u}\t{v}")?;
    }
    Ok(())
}

/// Write an edge-list file.
pub fn write_edge_list_file<P: AsRef<Path>>(g: &CsrGraph, path: P) -> io::Result<()> {
    write_edge_list(g, BufWriter::new(File::create(path)?))
}

/// Serialize the CSR structure verbatim (offsets + packed edges) in the
/// legacy v1 stream — loads back without rebuilding/sorting.
pub fn write_binary<W: Write>(g: &CsrGraph, mut w: W) -> io::Result<()> {
    w.write_all(MAGIC)?;
    let n = g.node_count() as u64;
    let m = g.entry_count() as u64;
    w.write_all(&n.to_le_bytes())?;
    w.write_all(&m.to_le_bytes())?;
    w.write_all(&g.arc_count().to_le_bytes())?;
    for u in 0..g.node_count() as u32 {
        w.write_all(&(g.degree(u) as u32).to_le_bytes())?;
    }
    for u in 0..g.node_count() as u32 {
        for e in g.row(u) {
            w.write_all(&e.0.to_le_bytes())?;
        }
    }
    Ok(())
}

/// Deserialize the v1 binary format.
pub fn read_binary<R: Read>(mut r: R) -> io::Result<CsrGraph> {
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(bad("bad magic"));
    }
    let mut b8 = [0u8; 8];
    r.read_exact(&mut b8)?;
    let n = u64::from_le_bytes(b8) as usize;
    r.read_exact(&mut b8)?;
    let m = u64::from_le_bytes(b8) as usize;
    r.read_exact(&mut b8)?;
    let arc_count = u64::from_le_bytes(b8);
    let mut offsets = Vec::with_capacity(n + 1);
    offsets.push(0usize);
    let mut b4 = [0u8; 4];
    for _ in 0..n {
        r.read_exact(&mut b4)?;
        let d = u32::from_le_bytes(b4) as usize;
        offsets.push(offsets.last().unwrap() + d);
    }
    if *offsets.last().unwrap() != m {
        return Err(bad("degree sum != edge count"));
    }
    let mut edges = Vec::with_capacity(m);
    for _ in 0..m {
        r.read_exact(&mut b4)?;
        edges.push(PackedEdge(u32::from_le_bytes(b4)));
    }
    let g = CsrGraph::from_parts(offsets, edges, arc_count);
    g.validate()
        .map_err(|e| bad(format!("invalid graph: {e}")))?;
    Ok(g)
}

/// Write the v1 binary format to a file.
pub fn write_binary_file<P: AsRef<Path>>(g: &CsrGraph, path: P) -> io::Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    write_binary(g, &mut w)?;
    w.flush()
}

/// Read the v1 binary format from a file.
pub fn read_binary_file<P: AsRef<Path>>(path: P) -> io::Result<CsrGraph> {
    read_binary(BufReader::new(File::open(path)?))
}

// ---------------------------------------------------------------------
// v2: the zero-copy mmap layout
// ---------------------------------------------------------------------

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Streamable FNV-1a-64 step over a byte chunk.
fn fnv1a64(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

#[inline]
fn align_up(x: u64, align: u64) -> u64 {
    x.div_ceil(align) * align
}

/// Parsed + bounds-checked v2 header.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct V2Header {
    pub n: usize,
    pub m: usize,
    pub arc_count: u64,
    pub offsets_off: usize,
    pub edges_off: usize,
    pub checksum: u64,
}

/// Section placement for a graph of `n` nodes / `m` entries.
fn v2_layout(n: u64, m: u64) -> (u64, u64, u64) {
    let offsets_off = V2_HEADER_BYTES as u64;
    let edges_off = align_up(offsets_off + (n + 1) * 8, V2_SECTION_ALIGN);
    let file_len = edges_off + m * 4;
    (offsets_off, edges_off, file_len)
}

/// Serialize a graph in the v2 zero-copy layout.
///
/// The checksum covers header bytes `0..56` (everything but the
/// checksum field itself) plus every byte from the header's end to the
/// end of the edges section — so any flipped bit in metadata, offsets,
/// alignment padding or edges fails verification.
pub fn write_binary_v2<W: Write>(g: &CsrGraph, mut w: W) -> io::Result<()> {
    const CHUNK: usize = 1 << 16;
    let n = g.node_count() as u64;
    let m = g.entry_count() as u64;
    let (offsets_off, edges_off, _) = v2_layout(n, m);
    let pad = (edges_off - (offsets_off + (n + 1) * 8)) as usize;

    // header (checksum filled below)
    let mut header = [0u8; V2_HEADER_BYTES];
    header[0..8].copy_from_slice(MAGIC_V2);
    header[8..12].copy_from_slice(&V2_VERSION.to_le_bytes());
    // 12..16: flags, reserved zero
    header[16..24].copy_from_slice(&n.to_le_bytes());
    header[24..32].copy_from_slice(&m.to_le_bytes());
    header[32..40].copy_from_slice(&g.arc_count().to_le_bytes());
    header[40..48].copy_from_slice(&offsets_off.to_le_bytes());
    header[48..56].copy_from_slice(&edges_off.to_le_bytes());

    // pass 1: checksum (header prefix, offsets, padding, edges)
    let mut h = fnv1a64(FNV_OFFSET, &header[0..56]);
    let mut buf: Vec<u8> = Vec::with_capacity(CHUNK + 8);
    for &o in g.offsets() {
        buf.extend_from_slice(&(o as u64).to_le_bytes());
        if buf.len() >= CHUNK {
            h = fnv1a64(h, &buf);
            buf.clear();
        }
    }
    h = fnv1a64(h, &buf);
    buf.clear();
    h = fnv1a64(h, &vec![0u8; pad]);
    for e in g.edges() {
        buf.extend_from_slice(&e.0.to_le_bytes());
        if buf.len() >= CHUNK {
            h = fnv1a64(h, &buf);
            buf.clear();
        }
    }
    h = fnv1a64(h, &buf);
    buf.clear();
    header[56..64].copy_from_slice(&h.to_le_bytes());
    w.write_all(&header)?;

    // pass 2: offsets section, alignment padding, edges section
    for &o in g.offsets() {
        buf.extend_from_slice(&(o as u64).to_le_bytes());
        if buf.len() >= CHUNK {
            w.write_all(&buf)?;
            buf.clear();
        }
    }
    w.write_all(&buf)?;
    buf.clear();
    w.write_all(&vec![0u8; pad])?;
    for e in g.edges() {
        buf.extend_from_slice(&e.0.to_le_bytes());
        if buf.len() >= CHUNK {
            w.write_all(&buf)?;
            buf.clear();
        }
    }
    w.write_all(&buf)?;
    Ok(())
}

/// Write the v2 format to a file.
pub fn write_binary_v2_file<P: AsRef<Path>>(g: &CsrGraph, path: P) -> io::Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    write_binary_v2(g, &mut w)?;
    w.flush()
}

/// Parse and bounds-check the v2 header against the file bytes.
pub fn parse_v2_header(bytes: &[u8]) -> io::Result<V2Header> {
    if bytes.len() < V2_HEADER_BYTES {
        return Err(bad("file shorter than the v2 header"));
    }
    if &bytes[0..8] != MAGIC_V2 {
        return Err(bad("bad magic (not a TRIADIC2 file)"));
    }
    let u32_at = |off: usize| u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap());
    let u64_at = |off: usize| u64::from_le_bytes(bytes[off..off + 8].try_into().unwrap());
    let version = u32_at(8);
    if version != V2_VERSION {
        return Err(bad(format!("unsupported v2 version {version}")));
    }
    let flags = u32_at(12);
    if flags != 0 {
        return Err(bad(format!("unknown v2 flags {flags:#x} (reserved, must be zero)")));
    }
    let n = u64_at(16);
    let m = u64_at(24);
    let arc_count = u64_at(32);
    let offsets_off = u64_at(40);
    let edges_off = u64_at(48);
    let checksum = u64_at(56);

    if n > CsrGraph::MAX_NODE_ID as u64 + 1 {
        return Err(bad(format!("node count {n} exceeds the 30-bit id space")));
    }
    let file_len = bytes.len() as u64;
    let offsets_bytes = (n + 1)
        .checked_mul(8)
        .ok_or_else(|| bad("offsets section size overflow"))?;
    let edges_bytes = m
        .checked_mul(4)
        .ok_or_else(|| bad("edges section size overflow"))?;
    let offsets_end = offsets_off
        .checked_add(offsets_bytes)
        .ok_or_else(|| bad("offsets section offset overflow"))?;
    let edges_end = edges_off
        .checked_add(edges_bytes)
        .ok_or_else(|| bad("edges section offset overflow"))?;
    if offsets_off < V2_HEADER_BYTES as u64 || offsets_off % 8 != 0 {
        return Err(bad(format!("misaligned offsets section at {offsets_off}")));
    }
    if edges_off % 4 != 0 {
        return Err(bad(format!("misaligned edges section at {edges_off}")));
    }
    if offsets_end > edges_off || edges_end > file_len {
        return Err(bad(format!(
            "sections exceed file bounds: offsets {offsets_off}..{offsets_end}, \
             edges {edges_off}..{edges_end}, file {file_len} bytes"
        )));
    }
    Ok(V2Header {
        n: n as usize,
        m: m as usize,
        arc_count,
        offsets_off: offsets_off as usize,
        edges_off: edges_off as usize,
        checksum,
    })
}

/// Recompute the checksum (header prefix + everything between the
/// header's end and the end of the edges section) and compare with the
/// header's.
fn verify_v2_checksum(bytes: &[u8], hdr: &V2Header) -> io::Result<()> {
    let edges_end = hdr.edges_off + hdr.m * 4;
    let h = fnv1a64(
        fnv1a64(FNV_OFFSET, &bytes[0..56]),
        &bytes[V2_HEADER_BYTES..edges_end],
    );
    if h != hdr.checksum {
        return Err(bad(format!(
            "checksum mismatch: header {:#018x}, computed {h:#018x}",
            hdr.checksum
        )));
    }
    Ok(())
}

/// O(n) structural sanity of an offsets slice against `m`.
fn check_offsets(offsets: &[usize], m: usize) -> io::Result<()> {
    if offsets.first() != Some(&0) {
        return Err(bad("offsets[0] != 0"));
    }
    if offsets.last() != Some(&m) {
        return Err(bad("offsets[n] != entry count"));
    }
    for w in offsets.windows(2) {
        if w[0] > w[1] {
            return Err(bad("offsets not monotone"));
        }
    }
    Ok(())
}

/// Map a v2 file and serve the graph zero-copy (checksum + O(n)
/// structure verification; see [`load_mmap_file_unverified`] for the
/// trusted O(1) path).
pub fn load_mmap_file<P: AsRef<Path>>(path: P) -> io::Result<CsrGraph> {
    load_mmap_file_with(path, true)
}

/// Map a v2 file with header bounds checks only — O(1) regardless of
/// graph size. For files this process (or another trusted run of it)
/// wrote; a corrupted edge section will surface as wrong census output
/// or an index panic, never undefined behaviour.
pub fn load_mmap_file_unverified<P: AsRef<Path>>(path: P) -> io::Result<CsrGraph> {
    load_mmap_file_with(path, false)
}

fn load_mmap_file_with<P: AsRef<Path>>(path: P, verify: bool) -> io::Result<CsrGraph> {
    let map = MmapFile::open(path)?;
    let hdr = parse_v2_header(map.bytes())?;
    if verify {
        verify_v2_checksum(map.bytes(), &hdr)?;
    }
    if cfg!(all(target_endian = "little", target_pointer_width = "64")) {
        let mapped = MappedCsr::new(map, hdr.offsets_off, hdr.n, hdr.edges_off, hdr.m);
        if verify {
            check_offsets(mapped.offsets(), hdr.m)?;
        }
        Ok(CsrGraph::from_storage_unchecked(
            CsrStorage::Mapped(mapped),
            hdr.arc_count,
        ))
    } else {
        // big-endian / 32-bit fallback: decode into owned storage
        let bytes = map.bytes();
        let mut offsets = Vec::with_capacity(hdr.n + 1);
        for i in 0..=hdr.n {
            let off = hdr.offsets_off + i * 8;
            let v = u64::from_le_bytes(bytes[off..off + 8].try_into().unwrap());
            let v =
                usize::try_from(v).map_err(|_| bad("offset exceeds this address space"))?;
            offsets.push(v);
        }
        check_offsets(&offsets, hdr.m)?;
        let mut edges = Vec::with_capacity(hdr.m);
        for i in 0..hdr.m {
            let off = hdr.edges_off + i * 4;
            edges.push(PackedEdge(u32::from_le_bytes(
                bytes[off..off + 4].try_into().unwrap(),
            )));
        }
        Ok(CsrGraph::from_storage_unchecked(
            CsrStorage::Owned { offsets, edges },
            hdr.arc_count,
        ))
    }
}

/// Load a graph from any supported format, sniffing the magic bytes:
/// `TRIADIC2` → zero-copy mmap (checksum-verified), `TRIADIC1` →
/// legacy binary, anything else → edge-list text (parsed with
/// `threads` workers).
pub fn load_auto<P: AsRef<Path>>(path: P, threads: usize) -> io::Result<CsrGraph> {
    load_auto_with(path, threads, true)
}

/// [`load_auto`] with the v2 verification policy explicit: pass
/// `verify_v2 = false` to mmap trusted `TRIADIC2` files in O(1)
/// (header bounds checks only, no whole-file checksum scan).
pub fn load_auto_with<P: AsRef<Path>>(
    path: P,
    threads: usize,
    verify_v2: bool,
) -> io::Result<CsrGraph> {
    let mut magic = [0u8; 8];
    let sniffed = {
        let mut f = File::open(&path)?;
        f.read_exact(&mut magic).is_ok()
    };
    if sniffed && &magic == MAGIC_V2 {
        load_mmap_file_with(path, verify_v2)
    } else if sniffed && &magic == MAGIC {
        read_binary_file(path)
    } else {
        read_edge_list_file_parallel(path, threads)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{named, power_law};

    #[test]
    fn edge_list_round_trip() {
        let g = power_law(300, 2.4, 5.0, 77);
        let mut buf = Vec::new();
        write_edge_list(&g, &mut buf).unwrap();
        let g2 = read_edge_list(BufReader::new(&buf[..])).unwrap();
        assert_eq!(g, g2);
    }

    #[test]
    fn edge_list_parses_comments_and_blank_lines() {
        let text = "# comment\n\n0 1\n% also comment\n1\t2\n";
        let g = read_edge_list(BufReader::new(text.as_bytes())).unwrap();
        assert_eq!(g.node_count(), 3);
        assert_eq!(g.arc_count(), 2);
    }

    #[test]
    fn edge_list_rejects_garbage() {
        assert!(read_edge_list(BufReader::new("0 x\n".as_bytes())).is_err());
        assert!(read_edge_list(BufReader::new("0\n".as_bytes())).is_err());
    }

    #[test]
    fn parallel_edge_list_matches_serial() {
        let g = power_law(2_000, 2.2, 8.0, 9);
        let mut buf = Vec::new();
        write_edge_list(&g, &mut buf).unwrap();
        let serial = read_edge_list(&buf[..]).unwrap();
        for threads in [1usize, 2, 5, 8] {
            let par = read_edge_list_parallel(&buf, threads).unwrap();
            assert_eq!(par, serial, "threads {threads}");
        }
    }

    #[test]
    fn parallel_edge_list_rejects_garbage_anywhere() {
        // force the parallel path with >64 KiB of valid lines plus one
        // bad line in the middle
        let mut buf = Vec::new();
        for i in 0..20_000u32 {
            buf.extend_from_slice(format!("{} {}\n", i % 97, (i + 1) % 97).as_bytes());
        }
        buf.extend_from_slice(b"12 oops\n");
        for i in 0..20_000u32 {
            buf.extend_from_slice(format!("{} {}\n", i % 89, (i + 2) % 89).as_bytes());
        }
        assert!(buf.len() > (1 << 16));
        assert!(read_edge_list_parallel(&buf, 4).is_err());
    }

    #[test]
    fn parallel_parser_grammar_matches_serial_quirks() {
        // leading '+' (str::parse accepts it) and assorted ASCII
        // whitespace separators must parse identically on both paths;
        // pad with valid lines to force the parallel code path
        let mut buf = Vec::new();
        buf.extend_from_slice(b"+3 4\n0\x0c1\n7   8\n");
        for i in 0..20_000u32 {
            buf.extend_from_slice(format!("{} {}\n", i % 50, (i + 1) % 50).as_bytes());
        }
        assert!(buf.len() > (1 << 16));
        let serial = read_edge_list(&buf[..]).unwrap();
        let par = read_edge_list_parallel(&buf, 4).unwrap();
        assert_eq!(par, serial);
        assert!(par.has_arc(3, 4) && par.has_arc(0, 1) && par.has_arc(7, 8));
    }

    #[test]
    fn parallel_edge_list_handles_comments_and_crlf() {
        let mut buf = Vec::new();
        buf.extend_from_slice(b"# header comment\r\n");
        for i in 0..30_000u32 {
            buf.extend_from_slice(format!("{}\t{}\r\n", i % 300, (i + 7) % 300).as_bytes());
            if i % 1000 == 0 {
                buf.extend_from_slice(b"% interleaved comment\n\n");
            }
        }
        let serial = read_edge_list(&buf[..]).unwrap();
        let par = read_edge_list_parallel(&buf, 3).unwrap();
        assert_eq!(par, serial);
    }

    #[test]
    fn binary_round_trip() {
        let g = power_law(500, 2.1, 8.0, 5);
        let mut buf = Vec::new();
        write_binary(&g, &mut buf).unwrap();
        let g2 = read_binary(&buf[..]).unwrap();
        assert_eq!(g, g2);
    }

    #[test]
    fn binary_rejects_corruption() {
        let g = named::cycle5();
        let mut buf = Vec::new();
        write_binary(&g, &mut buf).unwrap();
        // corrupt magic
        let mut bad = buf.clone();
        bad[0] ^= 0xff;
        assert!(read_binary(&bad[..]).is_err());
        // truncate
        assert!(read_binary(&buf[..buf.len() - 2]).is_err());
    }

    #[test]
    fn file_round_trip() {
        let g = named::fig1();
        let dir = std::env::temp_dir();
        let p1 = dir.join("triadic_test_graph.txt");
        let p2 = dir.join("triadic_test_graph.bin");
        write_edge_list_file(&g, &p1).unwrap();
        write_binary_file(&g, &p2).unwrap();
        assert_eq!(read_edge_list_file(&p1).unwrap(), g);
        assert_eq!(read_binary_file(&p2).unwrap(), g);
        let _ = std::fs::remove_file(p1);
        let _ = std::fs::remove_file(p2);
    }

    fn tmp_path(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("triadic_io_v2_{name}.csr"))
    }

    #[test]
    fn v2_round_trip_through_mmap() {
        let g = power_law(800, 2.2, 7.0, 31);
        let path = tmp_path("roundtrip");
        write_binary_v2_file(&g, &path).unwrap();
        let m = load_mmap_file(&path).unwrap();
        assert_eq!(m, g);
        assert!(m.validate().is_ok());
        if cfg!(all(target_endian = "little", target_pointer_width = "64")) {
            assert!(m.is_mapped());
        }
        let fast = load_mmap_file_unverified(&path).unwrap();
        assert_eq!(fast, g);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn v2_layout_is_aligned() {
        let g = power_law(100, 2.0, 4.0, 3);
        let mut buf = Vec::new();
        write_binary_v2(&g, &mut buf).unwrap();
        let hdr = parse_v2_header(&buf).unwrap();
        assert_eq!(hdr.offsets_off % 8, 0);
        assert_eq!(hdr.edges_off % 64, 0);
        assert_eq!(hdr.n, 100);
        assert_eq!(buf.len(), hdr.edges_off + hdr.m * 4);
    }

    #[test]
    fn v2_empty_graph_round_trips() {
        for g in [CsrGraph::empty(0), CsrGraph::empty(17)] {
            let path = tmp_path(&format!("empty{}", g.node_count()));
            write_binary_v2_file(&g, &path).unwrap();
            let m = load_mmap_file(&path).unwrap();
            assert_eq!(m, g);
            assert_eq!(m.entry_count(), 0);
            let _ = std::fs::remove_file(path);
        }
    }

    #[test]
    fn v2_rejects_bad_magic_and_version() {
        let g = named::cycle5();
        let mut buf = Vec::new();
        write_binary_v2(&g, &mut buf).unwrap();
        let path = tmp_path("badmagic");

        let mut broken = buf.clone();
        broken[0] ^= 0xff;
        std::fs::write(&path, &broken).unwrap();
        assert!(load_mmap_file(&path).is_err());

        let mut broken = buf.clone();
        broken[8] = 0x7f; // absurd version
        std::fs::write(&path, &broken).unwrap();
        assert!(load_mmap_file(&path).is_err());
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn v2_rejects_truncation_and_corruption() {
        let g = power_law(300, 2.1, 6.0, 8);
        let mut buf = Vec::new();
        write_binary_v2(&g, &mut buf).unwrap();
        let path = tmp_path("corrupt");

        // truncated mid-edges
        std::fs::write(&path, &buf[..buf.len() - 5]).unwrap();
        assert!(load_mmap_file(&path).is_err());

        // truncated inside the header
        std::fs::write(&path, &buf[..40]).unwrap();
        assert!(load_mmap_file(&path).is_err());

        // flipped byte inside the edge section → checksum mismatch
        let mut broken = buf.clone();
        let last = broken.len() - 3;
        broken[last] ^= 0x55;
        std::fs::write(&path, &broken).unwrap();
        let err = load_mmap_file(&path).unwrap_err();
        assert!(err.to_string().contains("checksum"), "{err}");

        // flipped byte inside the offsets section
        let mut broken = buf.clone();
        broken[70] ^= 0x55;
        std::fs::write(&path, &broken).unwrap();
        assert!(load_mmap_file(&path).is_err());
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn v2_rejects_out_of_bounds_sections() {
        let g = named::cycle5();
        let mut buf = Vec::new();
        write_binary_v2(&g, &mut buf).unwrap();
        // claim far more entries than the file holds
        let mut broken = buf.clone();
        broken[24..32].copy_from_slice(&u64::MAX.to_le_bytes());
        let path = tmp_path("oob");
        std::fs::write(&path, &broken).unwrap();
        assert!(load_mmap_file(&path).is_err());
        assert!(load_mmap_file_unverified(&path).is_err());
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn load_auto_sniffs_all_three_formats() {
        let g = power_law(400, 2.3, 5.0, 12);
        let dir = std::env::temp_dir();
        let pt = dir.join("triadic_auto.txt");
        let p1 = dir.join("triadic_auto.bin");
        let p2 = dir.join("triadic_auto.csr");
        write_edge_list_file(&g, &pt).unwrap();
        write_binary_file(&g, &p1).unwrap();
        write_binary_v2_file(&g, &p2).unwrap();
        assert_eq!(load_auto(&pt, 2).unwrap(), g);
        assert_eq!(load_auto(&p1, 2).unwrap(), g);
        assert_eq!(load_auto(&p2, 2).unwrap(), g);
        for p in [pt, p1, p2] {
            let _ = std::fs::remove_file(p);
        }
    }
}
