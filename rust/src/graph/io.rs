//! Graph I/O: whitespace edge-list text (the format the paper's datasets
//! ship in — SNAP/LAW style) and a compact binary format for fast reload
//! of generated workloads.

use std::fs::File;
use std::io::{self, BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use super::builder::GraphBuilder;
use super::csr::{CsrGraph, PackedEdge};

/// Magic + version for the binary format.
const MAGIC: &[u8; 8] = b"TRIADIC1";

/// Parse a whitespace/tab separated edge list (`u v` per line, `#`
/// comments allowed, ids arbitrary u32 — the max id defines `n`).
pub fn read_edge_list<R: BufRead>(r: R) -> io::Result<CsrGraph> {
    let mut arcs: Vec<(u32, u32)> = Vec::new();
    let mut max_id = 0u32;
    for (lineno, line) in r.lines().enumerate() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        let (a, b) = match (it.next(), it.next()) {
            (Some(a), Some(b)) => (a, b),
            _ => {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    format!("line {}: expected two ids", lineno + 1),
                ))
            }
        };
        let parse = |s: &str| {
            s.parse::<u32>().map_err(|e| {
                io::Error::new(
                    io::ErrorKind::InvalidData,
                    format!("line {}: bad id {s:?}: {e}", lineno + 1),
                )
            })
        };
        let (u, v) = (parse(a)?, parse(b)?);
        max_id = max_id.max(u).max(v);
        arcs.push((u, v));
    }
    let n = if arcs.is_empty() { 0 } else { max_id as usize + 1 };
    let mut b = GraphBuilder::new(n);
    b.extend(arcs);
    Ok(b.build())
}

/// Read an edge-list file.
pub fn read_edge_list_file<P: AsRef<Path>>(path: P) -> io::Result<CsrGraph> {
    read_edge_list(BufReader::new(File::open(path)?))
}

/// Write a graph as a directed edge list (one arc per line).
pub fn write_edge_list<W: Write>(g: &CsrGraph, mut w: W) -> io::Result<()> {
    writeln!(w, "# triadic edge list: {} nodes {} arcs", g.node_count(), g.arc_count())?;
    for (u, v) in g.arcs() {
        writeln!(w, "{u}\t{v}")?;
    }
    Ok(())
}

/// Write an edge-list file.
pub fn write_edge_list_file<P: AsRef<Path>>(g: &CsrGraph, path: P) -> io::Result<()> {
    write_edge_list(g, BufWriter::new(File::create(path)?))
}

/// Serialize the CSR structure verbatim (offsets + packed edges) —
/// loads back without rebuilding/sorting.
pub fn write_binary<W: Write>(g: &CsrGraph, mut w: W) -> io::Result<()> {
    w.write_all(MAGIC)?;
    let n = g.node_count() as u64;
    let m = g.entry_count() as u64;
    w.write_all(&n.to_le_bytes())?;
    w.write_all(&m.to_le_bytes())?;
    w.write_all(&g.arc_count().to_le_bytes())?;
    for u in 0..g.node_count() as u32 {
        w.write_all(&(g.degree(u) as u32).to_le_bytes())?;
    }
    for u in 0..g.node_count() as u32 {
        for e in g.row(u) {
            w.write_all(&e.0.to_le_bytes())?;
        }
    }
    Ok(())
}

/// Deserialize the binary format.
pub fn read_binary<R: Read>(mut r: R) -> io::Result<CsrGraph> {
    let bad = |m: &str| io::Error::new(io::ErrorKind::InvalidData, m.to_string());
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(bad("bad magic"));
    }
    let mut b8 = [0u8; 8];
    r.read_exact(&mut b8)?;
    let n = u64::from_le_bytes(b8) as usize;
    r.read_exact(&mut b8)?;
    let m = u64::from_le_bytes(b8) as usize;
    r.read_exact(&mut b8)?;
    let arc_count = u64::from_le_bytes(b8);
    let mut offsets = Vec::with_capacity(n + 1);
    offsets.push(0usize);
    let mut b4 = [0u8; 4];
    for _ in 0..n {
        r.read_exact(&mut b4)?;
        let d = u32::from_le_bytes(b4) as usize;
        offsets.push(offsets.last().unwrap() + d);
    }
    if *offsets.last().unwrap() != m {
        return Err(bad("degree sum != edge count"));
    }
    let mut edges = Vec::with_capacity(m);
    for _ in 0..m {
        r.read_exact(&mut b4)?;
        edges.push(PackedEdge(u32::from_le_bytes(b4)));
    }
    let g = CsrGraph::from_parts(offsets, edges, arc_count);
    g.validate()
        .map_err(|e| bad(&format!("invalid graph: {e}")))?;
    Ok(g)
}

/// Write the binary format to a file.
pub fn write_binary_file<P: AsRef<Path>>(g: &CsrGraph, path: P) -> io::Result<()> {
    write_binary(g, BufWriter::new(File::create(path)?))
}

/// Read the binary format from a file.
pub fn read_binary_file<P: AsRef<Path>>(path: P) -> io::Result<CsrGraph> {
    read_binary(BufReader::new(File::open(path)?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{named, power_law};

    #[test]
    fn edge_list_round_trip() {
        let g = power_law(300, 2.4, 5.0, 77);
        let mut buf = Vec::new();
        write_edge_list(&g, &mut buf).unwrap();
        let g2 = read_edge_list(BufReader::new(&buf[..])).unwrap();
        assert_eq!(g, g2);
    }

    #[test]
    fn edge_list_parses_comments_and_blank_lines() {
        let text = "# comment\n\n0 1\n% also comment\n1\t2\n";
        let g = read_edge_list(BufReader::new(text.as_bytes())).unwrap();
        assert_eq!(g.node_count(), 3);
        assert_eq!(g.arc_count(), 2);
    }

    #[test]
    fn edge_list_rejects_garbage() {
        assert!(read_edge_list(BufReader::new("0 x\n".as_bytes())).is_err());
        assert!(read_edge_list(BufReader::new("0\n".as_bytes())).is_err());
    }

    #[test]
    fn binary_round_trip() {
        let g = power_law(500, 2.1, 8.0, 5);
        let mut buf = Vec::new();
        write_binary(&g, &mut buf).unwrap();
        let g2 = read_binary(&buf[..]).unwrap();
        assert_eq!(g, g2);
    }

    #[test]
    fn binary_rejects_corruption() {
        let g = named::cycle5();
        let mut buf = Vec::new();
        write_binary(&g, &mut buf).unwrap();
        // corrupt magic
        let mut bad = buf.clone();
        bad[0] ^= 0xff;
        assert!(read_binary(&bad[..]).is_err());
        // truncate
        assert!(read_binary(&buf[..buf.len() - 2]).is_err());
    }

    #[test]
    fn file_round_trip() {
        let g = named::fig1();
        let dir = std::env::temp_dir();
        let p1 = dir.join("triadic_test_graph.txt");
        let p2 = dir.join("triadic_test_graph.bin");
        write_edge_list_file(&g, &p1).unwrap();
        write_binary_file(&g, &p2).unwrap();
        assert_eq!(read_edge_list_file(&p1).unwrap(), g);
        assert_eq!(read_binary_file(&p2).unwrap(), g);
        let _ = std::fs::remove_file(p1);
        let _ = std::fs::remove_file(p2);
    }
}
