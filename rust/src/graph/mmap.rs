//! Read-only memory-mapped file view (no external crates).
//!
//! The offline vendor set has no `memmap2`, so [`MmapFile`] talks to the
//! platform `mmap`/`munmap` directly through a two-symbol FFI block on
//! 64-bit Unix, and falls back to reading the file into an 8-byte
//! aligned heap buffer everywhere else. Either way the bytes are exposed
//! as one immutable `&[u8]` whose base pointer is at least 8-byte
//! aligned, which is what the zero-copy CSR views require.

use std::fs::File;
use std::io;
use std::path::Path;

#[cfg(all(unix, target_pointer_width = "64"))]
mod sys {
    use std::ffi::{c_int, c_void};

    pub const PROT_READ: c_int = 1;
    pub const MAP_PRIVATE: c_int = 2;

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> c_int;
    }
}

/// How the bytes of an [`MmapFile`] are backed.
enum Backing {
    /// A live `mmap(2)` mapping, unmapped on drop.
    #[cfg(all(unix, target_pointer_width = "64"))]
    Mapped { ptr: *const u8, len: usize },
    /// Heap fallback: the file copied into an 8-byte aligned buffer.
    /// `len` is the true byte length (the `Vec<u64>` is padded).
    Heap { buf: Vec<u64>, len: usize },
}

/// An immutable byte view of a whole file.
///
/// The mapping is read-only and never resized, so sharing the view
/// across threads is sound.
pub struct MmapFile {
    backing: Backing,
}

// SAFETY: the mapping is PROT_READ/MAP_PRIVATE (or an owned heap
// buffer) and is never mutated after construction.
unsafe impl Send for MmapFile {}
unsafe impl Sync for MmapFile {}

impl MmapFile {
    /// Map (or read) `path` in its entirety. Zero-length files are
    /// rejected — every format served through this type has a non-empty
    /// fixed header.
    pub fn open<P: AsRef<Path>>(path: P) -> io::Result<MmapFile> {
        let file = File::open(path)?;
        let len = file.metadata()?.len();
        if len == 0 {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                "cannot map an empty file",
            ));
        }
        if len > usize::MAX as u64 {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                "file too large for this address space",
            ));
        }
        MmapFile::from_file(&file, len as usize)
    }

    #[cfg(all(unix, target_pointer_width = "64"))]
    fn from_file(file: &File, len: usize) -> io::Result<MmapFile> {
        use std::os::unix::io::AsRawFd;
        let ptr = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                len,
                sys::PROT_READ,
                sys::MAP_PRIVATE,
                file.as_raw_fd(),
                0,
            )
        };
        if ptr.is_null() || ptr as usize == usize::MAX {
            // e.g. a filesystem without mmap support: degrade to a copy
            return Self::read_to_heap(file, len);
        }
        Ok(MmapFile {
            backing: Backing::Mapped {
                ptr: ptr as *const u8,
                len,
            },
        })
    }

    #[cfg(not(all(unix, target_pointer_width = "64")))]
    fn from_file(file: &File, len: usize) -> io::Result<MmapFile> {
        Self::read_to_heap(file, len)
    }

    /// Portable fallback: copy the file into an aligned heap buffer.
    fn read_to_heap(file: &File, len: usize) -> io::Result<MmapFile> {
        use std::io::Read;
        let words = len.div_ceil(8);
        let mut buf = vec![0u64; words];
        // view the u64 buffer as bytes for the read
        let bytes = unsafe { std::slice::from_raw_parts_mut(buf.as_mut_ptr() as *mut u8, len) };
        let mut reader = io::BufReader::new(file);
        reader.read_exact(bytes)?;
        Ok(MmapFile {
            backing: Backing::Heap { buf, len },
        })
    }

    /// Base pointer of the view (at least 8-byte aligned).
    #[inline]
    pub fn as_ptr(&self) -> *const u8 {
        match &self.backing {
            #[cfg(all(unix, target_pointer_width = "64"))]
            Backing::Mapped { ptr, .. } => *ptr,
            Backing::Heap { buf, .. } => buf.as_ptr() as *const u8,
        }
    }

    /// Byte length of the view.
    #[inline]
    pub fn len(&self) -> usize {
        match &self.backing {
            #[cfg(all(unix, target_pointer_width = "64"))]
            Backing::Mapped { len, .. } => *len,
            Backing::Heap { len, .. } => *len,
        }
    }

    /// True if the view is empty (never: `open` rejects empty files).
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The whole view as a byte slice.
    #[inline]
    pub fn bytes(&self) -> &[u8] {
        unsafe { std::slice::from_raw_parts(self.as_ptr(), self.len()) }
    }

    /// True if this view is an OS mapping (false: heap fallback copy).
    pub fn is_os_mapped(&self) -> bool {
        match &self.backing {
            #[cfg(all(unix, target_pointer_width = "64"))]
            Backing::Mapped { .. } => true,
            Backing::Heap { .. } => false,
        }
    }
}

impl Drop for MmapFile {
    fn drop(&mut self) {
        #[cfg(all(unix, target_pointer_width = "64"))]
        if let Backing::Mapped { ptr, len } = &self.backing {
            unsafe {
                sys::munmap(*ptr as *mut std::ffi::c_void, *len);
            }
        }
    }
}

impl std::fmt::Debug for MmapFile {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MmapFile")
            .field("len", &self.len())
            .field("os_mapped", &self.is_os_mapped())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn tmp(name: &str, contents: &[u8]) -> std::path::PathBuf {
        let path = std::env::temp_dir().join(format!("triadic_mmap_{name}"));
        let mut f = File::create(&path).unwrap();
        f.write_all(contents).unwrap();
        path
    }

    #[test]
    fn maps_file_contents() {
        let data: Vec<u8> = (0..=255u8).cycle().take(5000).collect();
        let path = tmp("contents", &data);
        let map = MmapFile::open(&path).unwrap();
        assert_eq!(map.len(), 5000);
        assert_eq!(map.bytes(), &data[..]);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn base_pointer_is_aligned() {
        let path = tmp("align", &[1, 2, 3, 4, 5, 6, 7, 8, 9]);
        let map = MmapFile::open(&path).unwrap();
        assert_eq!(map.as_ptr() as usize % 8, 0);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn rejects_empty_and_missing() {
        let path = tmp("empty", &[]);
        assert!(MmapFile::open(&path).is_err());
        let _ = std::fs::remove_file(path);
        assert!(MmapFile::open("/nonexistent/triadic").is_err());
    }

    #[test]
    fn heap_fallback_matches() {
        let data = b"zero-copy csr sections".repeat(100);
        let path = tmp("heap", &data);
        let file = File::open(&path).unwrap();
        let map = MmapFile::read_to_heap(&file, data.len()).unwrap();
        assert!(!map.is_os_mapped());
        assert_eq!(map.bytes(), &data[..]);
        assert_eq!(map.as_ptr() as usize % 8, 0);
        let _ = std::fs::remove_file(path);
    }
}
