//! Graph substrate: the paper's compact CSR structure (Fig 7), builders,
//! deterministic scale-free generators (the synthetic stand-ins for the
//! patents / Orkut / .uk-webgraph datasets), edge-list I/O and degree /
//! power-law analysis (Fig 6).

pub mod builder;
pub mod csr;
pub mod degree;
pub mod generators;
pub mod io;
pub mod mmap;
pub mod overlay;
pub mod storage;

pub use builder::GraphBuilder;
pub use csr::{CsrGraph, Dir, DyadType, PackedEdge};
pub use degree::{DegreeStats, OutDegreeHistogram};
pub use generators::{named, GraphSpec};
pub use mmap::MmapFile;
pub use overlay::{ApplyOutcome, DeltaOverlay, EdgeOp, RejectReason};
pub use storage::{CsrStorage, MappedCsr};
