//! Graph substrate: the paper's compact CSR structure (Fig 7), the
//! [`GraphView`] read interface every census engine is generic over
//! (owned CSR / mmap CSR / delta overlay / direction-split), builders,
//! census-invariant vertex-ordering preprocessing ([`relabel`]),
//! deterministic scale-free generators (the synthetic stand-ins for the
//! patents / Orkut / .uk-webgraph datasets), edge-list I/O and degree /
//! power-law analysis (Fig 6).

pub mod builder;
pub mod csr;
pub mod degree;
pub mod generators;
pub mod hub;
pub mod io;
pub mod mmap;
pub mod overlay;
pub mod relabel;
pub mod storage;
pub mod view;

pub use builder::GraphBuilder;
pub use csr::{CsrGraph, Dir, DyadType, PackedEdge};
pub use degree::{DegreeStats, OutDegreeHistogram};
pub use generators::{named, GraphSpec};
pub use hub::{HubSplit, HubStats};
pub use mmap::MmapFile;
pub use overlay::{ApplyOutcome, DeltaOverlay, EdgeOp, RejectReason};
pub use relabel::{DirSplit, Relabeling, VertexOrdering};
pub use storage::{CsrStorage, MappedCsr};
pub use view::GraphView;
