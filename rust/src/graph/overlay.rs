//! Mutable insert/delete overlay over an immutable CSR graph.
//!
//! The census engines operate on the frozen, cache-friendly [`CsrGraph`]
//! (possibly a zero-copy mmap of a multi-GB file). A live serving
//! workload, however, sees edge arrivals and retractions *between*
//! requests. [`DeltaOverlay`] layers a sparse set of per-node dyad
//! overrides on top of the immutable base: reads merge the sorted base
//! row with a sorted override map in O(deg), mutations touch only the
//! two endpoint maps, and [`DeltaOverlay::compact`] rebuilds a fresh
//! CSR once the overlay has grown past taste.
//!
//! The overlay stores *effective direction bits* per touched dyad (the
//! same 2-bit encoding as [`PackedEdge`]; `0` marks a base dyad that has
//! been fully deleted). An override that restores a dyad to exactly its
//! base state is dropped, so the overlay stays minimal under churn and
//! `edit_count` measures genuine divergence from the base.

use std::collections::{btree_map, BTreeMap, HashMap};
use std::sync::Arc;

use super::builder::GraphBuilder;
use super::csr::{CsrGraph, PackedEdge};

/// One directed-arc mutation in a stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EdgeOp {
    /// Add the arc `u -> v` (a no-op if it already exists).
    Insert(u32, u32),
    /// Remove the arc `u -> v` (a no-op if it does not exist).
    Delete(u32, u32),
}

impl EdgeOp {
    /// The `(tail, head)` endpoints of the op.
    #[inline]
    pub fn endpoints(self) -> (u32, u32) {
        match self {
            EdgeOp::Insert(u, v) | EdgeOp::Delete(u, v) => (u, v),
        }
    }

    /// True for [`EdgeOp::Insert`].
    #[inline]
    pub fn is_insert(self) -> bool {
        matches!(self, EdgeOp::Insert(..))
    }
}

/// Why a mutation was rejected without touching the graph.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RejectReason {
    /// `u == v` — the triad taxonomy is defined over simple digraphs.
    SelfLoop,
    /// An endpoint is `>= node_count()` (the overlay cannot grow the
    /// node set; open the stream over a larger base instead).
    OutOfRange,
}

/// Outcome of applying one [`EdgeOp`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ApplyOutcome {
    /// The `(u, v)` dyad changed: direction bits before and after, seen
    /// from `u` (`0b01` = `u -> v`, `0b10` = `v -> u`, `0` = null).
    Changed { old: u8, new: u8 },
    /// Duplicate insert or delete of an absent arc.
    NoChange,
    /// Structurally invalid op; the graph is untouched.
    Rejected(RejectReason),
}

/// Mirror 2-bit dyad direction bits to the other endpoint's view.
#[inline]
pub(crate) fn reverse_bits(bits: u8) -> u8 {
    ((bits & 0b01) << 1) | ((bits & 0b10) >> 1)
}

/// A mutable insert/delete layer over an immutable (possibly mmap'd)
/// [`CsrGraph`]. Reads see the *effective* graph; the base is never
/// modified.
pub struct DeltaOverlay {
    base: Arc<CsrGraph>,
    /// Per-node overrides: neighbor id → effective direction bits from
    /// this node's perspective (`0` = dyad deleted). Invariant: an entry
    /// is present iff its bits differ from the base, and the `(u, v)` /
    /// `(v, u)` entries always mirror each other.
    deltas: HashMap<u32, BTreeMap<u32, u8>>,
    /// Total override entries across all maps (2 per touched dyad).
    entries: usize,
    /// Effective directed-arc count.
    arc_count: u64,
    /// Effective connected-dyad count (maintained per mutation so the
    /// collapsed iteration space of the parallel engine is O(1) to
    /// size).
    dyads: u64,
}

impl DeltaOverlay {
    /// An empty overlay: reads pass straight through to `base`.
    pub fn new(base: Arc<CsrGraph>) -> DeltaOverlay {
        let arc_count = base.arc_count();
        let dyads = base.dyad_count();
        DeltaOverlay {
            base,
            deltas: HashMap::new(),
            entries: 0,
            arc_count,
            dyads,
        }
    }

    /// The immutable base graph under the overlay.
    #[inline]
    pub fn base(&self) -> &Arc<CsrGraph> {
        &self.base
    }

    /// Number of nodes (fixed by the base).
    #[inline]
    pub fn node_count(&self) -> usize {
        self.base.node_count()
    }

    /// Effective directed-arc count (mutual dyads count twice).
    #[inline]
    pub fn arc_count(&self) -> u64 {
        self.arc_count
    }

    /// Effective connected-dyad count (2 adjacency entries each).
    #[inline]
    pub fn dyad_count(&self) -> u64 {
        self.dyads
    }

    /// Dyads whose effective state differs from the base — the natural
    /// compaction trigger.
    #[inline]
    pub fn edit_count(&self) -> usize {
        debug_assert_eq!(self.entries % 2, 0);
        self.entries / 2
    }

    /// True if any mutation diverges from the base.
    #[inline]
    pub fn is_dirty(&self) -> bool {
        self.entries > 0
    }

    /// Base-graph direction bits of `(u, v)` from `u`'s perspective.
    #[inline]
    fn base_bits(&self, u: u32, v: u32) -> u8 {
        self.base
            .find_entry(u, v)
            .map(|e| (e.0 & 0b11) as u8)
            .unwrap_or(0)
    }

    /// Effective direction bits of `(u, v)` from `u`'s perspective
    /// (`0` = null dyad).
    #[inline]
    pub fn dyad_bits(&self, u: u32, v: u32) -> u8 {
        match self.deltas.get(&u).and_then(|m| m.get(&v)) {
            Some(&bits) => bits,
            None => self.base_bits(u, v),
        }
    }

    /// True if the arc `u -> v` effectively exists.
    #[inline]
    pub fn has_arc(&self, u: u32, v: u32) -> bool {
        self.dyad_bits(u, v) & 0b01 != 0
    }

    /// Write one side of a dyad override, keeping the minimality
    /// invariant (entries equal to the base are removed).
    fn set_side(&mut self, a: u32, b: u32, bits: u8) {
        if bits == self.base_bits(a, b) {
            if let Some(m) = self.deltas.get_mut(&a) {
                if m.remove(&b).is_some() {
                    self.entries -= 1;
                }
                if m.is_empty() {
                    self.deltas.remove(&a);
                }
            }
        } else if self.deltas.entry(a).or_default().insert(b, bits).is_none() {
            self.entries += 1;
        }
    }

    /// Apply one arc mutation. The returned old/new bits are what the
    /// streaming census needs to reclassify the touched triads.
    pub fn apply(&mut self, op: EdgeOp) -> ApplyOutcome {
        let (u, v) = op.endpoints();
        if u == v {
            return ApplyOutcome::Rejected(RejectReason::SelfLoop);
        }
        let n = self.node_count();
        if u as usize >= n || v as usize >= n {
            return ApplyOutcome::Rejected(RejectReason::OutOfRange);
        }
        let old = self.dyad_bits(u, v);
        let new = if op.is_insert() { old | 0b01 } else { old & !0b01 };
        if new == old {
            return ApplyOutcome::NoChange;
        }
        self.set_side(u, v, new);
        self.set_side(v, u, reverse_bits(new));
        if op.is_insert() {
            self.arc_count += 1;
        } else {
            self.arc_count -= 1;
        }
        if old == 0 {
            self.dyads += 1;
        } else if new == 0 {
            self.dyads -= 1;
        }
        ApplyOutcome::Changed { old, new }
    }

    /// Iterate the effective neighbors of `u` as `(neighbor, bits)` in
    /// ascending neighbor order — the overlay-aware analogue of
    /// [`CsrGraph::row`], with the same O(deg) cost.
    pub fn neighbors(&self, u: u32) -> OverlayRow<'_> {
        OverlayRow {
            base: self.base.row(u).iter().peekable(),
            over: self.deltas.get(&u).map(|m| m.iter().peekable()),
        }
    }

    /// Effective undirected degree of `u` (distinct connected
    /// neighbors). O(1) for untouched nodes, O(deg) where overrides
    /// exist — the [`GraphView`](super::view::GraphView) flat-offsets
    /// pass leans on the fast path.
    pub fn degree(&self, u: u32) -> usize {
        match self.deltas.get(&u) {
            None => self.base.degree(u),
            Some(_) => self.neighbors(u).count(),
        }
    }

    /// Materialize the effective graph as a fresh validated CSR,
    /// leaving the overlay untouched (callers swap it in and reset).
    pub fn compact(&self) -> CsrGraph {
        self.compact_with(1)
    }

    /// [`DeltaOverlay::compact`] with a parallel ingest sort.
    pub fn compact_with(&self, threads: usize) -> CsrGraph {
        let n = self.node_count();
        let mut b = GraphBuilder::new(n);
        for u in 0..n as u32 {
            for (v, bits) in self.neighbors(u) {
                if bits & 0b01 != 0 {
                    b.arc(u, v);
                }
            }
        }
        let g = b.build_parallel(threads);
        debug_assert_eq!(g.arc_count(), self.arc_count);
        debug_assert_eq!(g.dyad_count(), self.dyads);
        g
    }
}

/// Merged iterator over a base CSR row and its override map: overrides
/// win on equal keys, zero-bit overrides (deleted dyads) are skipped.
pub struct OverlayRow<'a> {
    base: std::iter::Peekable<std::slice::Iter<'a, PackedEdge>>,
    over: Option<std::iter::Peekable<btree_map::Iter<'a, u32, u8>>>,
}

impl Iterator for OverlayRow<'_> {
    type Item = (u32, u8);

    fn next(&mut self) -> Option<(u32, u8)> {
        loop {
            let b = self.base.peek().map(|e| e.nbr());
            let o = self
                .over
                .as_mut()
                .and_then(|it| it.peek().map(|(&k, _)| k));
            let take_over = match (b, o) {
                (None, None) => return None,
                (Some(_), None) => false,
                (None, Some(_)) => true,
                (Some(bn), Some(on)) => {
                    if bn == on {
                        self.base.next(); // override shadows the base entry
                    }
                    on <= bn
                }
            };
            if take_over {
                let (&v, &bits) = self.over.as_mut().unwrap().next().unwrap();
                if bits != 0 {
                    return Some((v, bits));
                }
            } else {
                let e = self.base.next().unwrap();
                return Some((e.nbr(), (e.0 & 0b11) as u8));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::from_arcs;
    use crate::graph::csr::Dir;

    fn overlay(n: usize, arcs: &[(u32, u32)]) -> DeltaOverlay {
        DeltaOverlay::new(Arc::new(from_arcs(n, arcs)))
    }

    fn row(o: &DeltaOverlay, u: u32) -> Vec<(u32, u8)> {
        o.neighbors(u).collect()
    }

    #[test]
    fn passthrough_without_edits() {
        let o = overlay(4, &[(0, 1), (1, 0), (2, 3)]);
        assert_eq!(o.arc_count(), 3);
        assert_eq!(o.edit_count(), 0);
        assert!(!o.is_dirty());
        assert_eq!(o.dyad_bits(0, 1), Dir::Both as u32 as u8);
        assert_eq!(o.dyad_bits(2, 3), Dir::Out as u32 as u8);
        assert_eq!(o.dyad_bits(3, 2), Dir::In as u32 as u8);
        assert_eq!(row(&o, 0), vec![(1, 0b11)]);
    }

    #[test]
    fn insert_creates_and_upgrades_dyads() {
        let mut o = overlay(4, &[(0, 1)]);
        assert_eq!(
            o.apply(EdgeOp::Insert(2, 3)),
            ApplyOutcome::Changed { old: 0, new: 0b01 }
        );
        assert_eq!(
            o.apply(EdgeOp::Insert(1, 0)),
            ApplyOutcome::Changed { old: 0b10, new: 0b11 }
        );
        assert_eq!(o.arc_count(), 3);
        assert!(o.has_arc(2, 3) && !o.has_arc(3, 2));
        assert_eq!(o.dyad_bits(0, 1), 0b11);
        // both endpoint views stay mirrored
        assert_eq!(o.dyad_bits(3, 2), 0b10);
    }

    #[test]
    fn duplicate_insert_and_absent_delete_are_noops() {
        let mut o = overlay(3, &[(0, 1)]);
        assert_eq!(o.apply(EdgeOp::Insert(0, 1)), ApplyOutcome::NoChange);
        assert_eq!(o.apply(EdgeOp::Delete(1, 0)), ApplyOutcome::NoChange);
        assert_eq!(o.apply(EdgeOp::Delete(1, 2)), ApplyOutcome::NoChange);
        assert_eq!(o.arc_count(), 1);
        assert_eq!(o.edit_count(), 0);
    }

    #[test]
    fn rejects_self_loops_and_out_of_range() {
        let mut o = overlay(3, &[]);
        assert_eq!(
            o.apply(EdgeOp::Insert(1, 1)),
            ApplyOutcome::Rejected(RejectReason::SelfLoop)
        );
        assert_eq!(
            o.apply(EdgeOp::Insert(0, 3)),
            ApplyOutcome::Rejected(RejectReason::OutOfRange)
        );
        assert_eq!(
            o.apply(EdgeOp::Delete(9, 0)),
            ApplyOutcome::Rejected(RejectReason::OutOfRange)
        );
        assert_eq!(o.arc_count(), 0);
    }

    #[test]
    fn delete_downgrades_and_removes() {
        let mut o = overlay(3, &[(0, 1), (1, 0), (1, 2)]);
        assert_eq!(
            o.apply(EdgeOp::Delete(0, 1)),
            ApplyOutcome::Changed { old: 0b11, new: 0b10 }
        );
        assert_eq!(
            o.apply(EdgeOp::Delete(1, 2)),
            ApplyOutcome::Changed { old: 0b01, new: 0 }
        );
        assert_eq!(o.arc_count(), 1);
        assert_eq!(o.dyad_bits(0, 1), 0b10);
        assert_eq!(o.dyad_bits(1, 2), 0);
        // node 1's effective row: only node 0 remains (2 was deleted)
        assert_eq!(row(&o, 1), vec![(0, 0b01)]);
    }

    #[test]
    fn reverting_an_edit_shrinks_the_overlay() {
        let mut o = overlay(3, &[(0, 1)]);
        o.apply(EdgeOp::Delete(0, 1));
        assert_eq!(o.edit_count(), 1);
        o.apply(EdgeOp::Insert(0, 1));
        assert_eq!(o.edit_count(), 0, "restored dyad drops its override");
        assert!(!o.is_dirty());
        assert_eq!(o.dyad_bits(0, 1), 0b01);
    }

    #[test]
    fn neighbors_merge_in_sorted_order() {
        let mut o = overlay(6, &[(0, 1), (0, 4)]);
        o.apply(EdgeOp::Insert(0, 3));
        o.apply(EdgeOp::Insert(5, 0));
        o.apply(EdgeOp::Delete(0, 4));
        let got = row(&o, 0);
        assert_eq!(got, vec![(1, 0b01), (3, 0b01), (5, 0b10)]);
        assert_eq!(o.degree(0), 3);
    }

    #[test]
    fn compact_materializes_the_effective_graph() {
        let mut o = overlay(5, &[(0, 1), (1, 2), (2, 0)]);
        o.apply(EdgeOp::Insert(3, 4));
        o.apply(EdgeOp::Insert(1, 0));
        o.apply(EdgeOp::Delete(2, 0));
        let g = o.compact();
        assert!(g.validate().is_ok());
        let want = from_arcs(5, &[(0, 1), (1, 2), (3, 4), (1, 0)]);
        assert_eq!(g, want);
        // overlay is untouched; compacting again is identical
        assert_eq!(o.compact_with(4), want);
    }

    #[test]
    fn compact_of_clean_overlay_equals_base() {
        let base = from_arcs(4, &[(0, 1), (1, 0), (2, 3)]);
        let o = DeltaOverlay::new(Arc::new(base.clone()));
        assert_eq!(o.compact(), base);
    }

    #[test]
    fn dyad_count_tracks_creations_and_removals() {
        let mut o = overlay(4, &[(0, 1), (1, 0), (2, 3)]);
        assert_eq!(o.dyad_count(), 2);
        o.apply(EdgeOp::Insert(0, 2)); // new dyad
        assert_eq!(o.dyad_count(), 3);
        o.apply(EdgeOp::Delete(0, 1)); // downgrade, dyad survives
        assert_eq!(o.dyad_count(), 3);
        o.apply(EdgeOp::Delete(1, 0)); // dyad gone
        assert_eq!(o.dyad_count(), 2);
        o.apply(EdgeOp::Insert(1, 1)); // rejected: no change
        o.apply(EdgeOp::Insert(0, 2)); // duplicate: no change
        assert_eq!(o.dyad_count(), 2);
    }

    #[test]
    fn reverse_bits_mirrors() {
        assert_eq!(reverse_bits(0), 0);
        assert_eq!(reverse_bits(0b01), 0b10);
        assert_eq!(reverse_bits(0b10), 0b01);
        assert_eq!(reverse_bits(0b11), 0b11);
    }
}
