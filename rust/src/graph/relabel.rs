//! Vertex-ordering preprocessing: degree-descending relabeling and the
//! direction-split neighborhood form.
//!
//! The paper's throughput is dominated by neighborhood traversal cost
//! and the load imbalance of power-law degrees. Two standard cures
//! (cf. Tom & Karypis; Arifuzzaman et al. on distributed triangle
//! counting) live here, both *census-invariant* — the triad census is
//! a graph invariant, so every preprocessed form must and does produce
//! byte-identical counts (enforced by tests and the CI parity step):
//!
//! * [`Relabeling`] — a permutation that renumbers vertices in
//!   descending degree order. High-degree hubs get the smallest ids,
//!   so the canonical `u < v` dyad enumeration classifies every triad
//!   from its *highest-degree* vertex, merged walks compare against the
//!   shortest possible tails, and the skewed head of the collapsed
//!   iteration space lands in the first scheduler chunks instead of
//!   straggling at the end.
//! * [`DirSplit`] — neighborhoods stored as three sorted runs per node
//!   (reciprocal / out-only / in-only). Direction bits are implied by
//!   run membership, so the hot tricode classification does one
//!   three-way merged walk with no per-entry bit masking, and the
//!   out/in/reciprocal degree hints are O(1) run-length arithmetic.
//!
//! [`VertexOrdering`] is the user-facing knob, threaded end to end:
//! `CensusRequest.ordering` on the wire, `--order` on the CLI.

use std::borrow::Cow;
use std::cmp::Reverse;
use std::fmt;

use super::builder::GraphBuilder;
use super::csr::CsrGraph;
use super::view::GraphView;

/// Which vertex numbering a census runs under. The census itself is
/// invariant; the knob trades preprocessing time for traversal speed.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum VertexOrdering {
    /// The input numbering, untouched.
    #[default]
    Natural,
    /// Degree-descending relabeling (+ direction-split neighborhoods on
    /// the sparse path).
    Degree,
}

impl VertexOrdering {
    /// Every ordering, in wire/CLI spelling order.
    pub const ALL: [VertexOrdering; 2] = [VertexOrdering::Natural, VertexOrdering::Degree];

    /// Canonical wire/CLI spelling.
    pub fn name(self) -> &'static str {
        match self {
            VertexOrdering::Natural => "natural",
            VertexOrdering::Degree => "degree",
        }
    }

    /// Parse the wire/CLI spelling. The error lists every valid
    /// ordering — the single source of the "unknown ordering" wording
    /// used at both the CLI parse and protocol decode sites.
    pub fn parse(s: &str) -> Result<VertexOrdering, String> {
        VertexOrdering::ALL
            .into_iter()
            .find(|o| o.name() == s)
            .ok_or_else(|| {
                let names: Vec<&str> = VertexOrdering::ALL.iter().map(|o| o.name()).collect();
                format!("unknown ordering {s:?} (available: {})", names.join(", "))
            })
    }
}

impl fmt::Display for VertexOrdering {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// A vertex renumbering: `perm[old] = new` and its inverse
/// `inv[new] = old`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Relabeling {
    perm: Vec<u32>,
    inv: Vec<u32>,
}

impl Relabeling {
    /// The identity relabeling over `n` nodes.
    pub fn identity(n: usize) -> Relabeling {
        let perm: Vec<u32> = (0..n as u32).collect();
        Relabeling {
            inv: perm.clone(),
            perm,
        }
    }

    /// Build from an explicit `new -> old` order (must be a permutation
    /// of `0..n`; checked).
    pub fn from_order(order: Vec<u32>) -> Relabeling {
        let n = order.len();
        let mut perm = vec![u32::MAX; n];
        for (new, &old) in order.iter().enumerate() {
            assert!(
                (old as usize) < n && perm[old as usize] == u32::MAX,
                "order is not a permutation of 0..{n}"
            );
            perm[old as usize] = new as u32;
        }
        Relabeling { perm, inv: order }
    }

    /// Degree-descending relabeling: node of rank 0 has the highest
    /// undirected degree. Ties break on the old id ascending, so the
    /// pass is deterministic for any [`GraphView`].
    pub fn degree_descending<G: GraphView>(g: &G) -> Relabeling {
        let n = g.node_count();
        let mut order: Vec<u32> = (0..n as u32).collect();
        order.sort_by_key(|&u| (Reverse(g.degree(u)), u));
        Relabeling::from_order(order)
    }

    /// Number of nodes covered.
    pub fn len(&self) -> usize {
        self.perm.len()
    }

    /// True for the zero-node relabeling.
    pub fn is_empty(&self) -> bool {
        self.perm.is_empty()
    }

    /// New id of old node `u`.
    #[inline]
    pub fn map(&self, u: u32) -> u32 {
        self.perm[u as usize]
    }

    /// Old id of new node `u`.
    #[inline]
    pub fn unmap(&self, u: u32) -> u32 {
        self.inv[u as usize]
    }

    /// The `old -> new` permutation.
    pub fn perm(&self) -> &[u32] {
        &self.perm
    }

    /// The `new -> old` inverse.
    pub fn inverse(&self) -> &[u32] {
        &self.inv
    }

    /// True if the relabeling moves nothing.
    pub fn is_identity(&self) -> bool {
        self.perm.iter().enumerate().all(|(i, &p)| p == i as u32)
    }
}

/// Materialize `g` under relabeling `r` as a fresh CSR (serial ingest
/// sort). The censuses of `g` and the result are identical.
pub fn relabel<G: GraphView>(g: &G, r: &Relabeling) -> CsrGraph {
    relabel_with(g, r, 1)
}

/// [`relabel`] with a parallel ingest sort.
pub fn relabel_with<G: GraphView>(g: &G, r: &Relabeling, threads: usize) -> CsrGraph {
    let n = g.node_count();
    assert_eq!(r.len(), n, "relabeling covers a different node count");
    let mut b = GraphBuilder::new(n);
    for u in 0..n as u32 {
        for (v, bits) in g.neighbors(u) {
            if bits & 0b01 != 0 {
                b.arc(r.map(u), r.map(v));
            }
        }
    }
    let out = b.build_parallel(threads.max(1));
    debug_assert_eq!(out.arc_count(), g.arc_count());
    out
}

/// Degree-relabel + direction-split in one call: the sparse serving
/// path's preparation for [`VertexOrdering::Degree`]. Returns the
/// relabeling alongside the split form (callers that must map ids back
/// — e.g. streaming — keep the permutation).
pub fn degree_split<G: GraphView>(g: &G, threads: usize) -> (Relabeling, DirSplit) {
    let r = Relabeling::degree_descending(g);
    let relabeled = relabel_with(g, &r, threads);
    let split = DirSplit::build(&relabeled);
    (r, split)
}

/// Direction-split neighborhood form: per node, three sorted neighbor
/// runs — reciprocal, out-only, in-only — in one flat array. A
/// [`GraphView`] whose merged iteration is a three-way run merge with
/// direction bits implied by run membership, and whose directional
/// degree hints are O(1).
#[derive(Clone)]
pub struct DirSplit {
    /// `n + 1` offsets into `nbrs` (whole-node segments).
    offsets: Vec<usize>,
    /// Absolute end of each node's reciprocal run.
    recip_end: Vec<usize>,
    /// Absolute end of each node's out-only run (in-only runs to
    /// `offsets[u + 1]`).
    out_end: Vec<usize>,
    /// Neighbor ids: `[recip… | out-only… | in-only…]` per node, each
    /// run ascending.
    nbrs: Vec<u32>,
    arc_count: u64,
}

impl DirSplit {
    /// Build from any view (one ascending pass per node).
    pub fn build<G: GraphView>(g: &G) -> DirSplit {
        let n = g.node_count();
        let entries = g.entry_count();
        let mut offsets = Vec::with_capacity(n + 1);
        let mut recip_end = Vec::with_capacity(n);
        let mut out_end = Vec::with_capacity(n);
        let mut nbrs = Vec::with_capacity(entries);
        let mut out_run = Vec::new();
        let mut in_run = Vec::new();
        offsets.push(0);
        for u in 0..n as u32 {
            out_run.clear();
            in_run.clear();
            for (v, bits) in g.neighbors(u) {
                match bits {
                    0b11 => nbrs.push(v),
                    0b01 => out_run.push(v),
                    _ => in_run.push(v),
                }
            }
            recip_end.push(nbrs.len());
            nbrs.extend_from_slice(&out_run);
            out_end.push(nbrs.len());
            nbrs.extend_from_slice(&in_run);
            offsets.push(nbrs.len());
        }
        debug_assert_eq!(nbrs.len(), entries);
        DirSplit {
            offsets,
            recip_end,
            out_end,
            nbrs,
            arc_count: g.arc_count(),
        }
    }

    /// The three runs of node `u`: `(reciprocal, out-only, in-only)`.
    #[inline]
    pub fn runs(&self, u: u32) -> (&[u32], &[u32], &[u32]) {
        let u = u as usize;
        (
            &self.nbrs[self.offsets[u]..self.recip_end[u]],
            &self.nbrs[self.recip_end[u]..self.out_end[u]],
            &self.nbrs[self.out_end[u]..self.offsets[u + 1]],
        )
    }
}

/// Three-way run merge: ascending `(neighbor, bits)` with the bits of
/// each element implied by the run it came from.
pub struct DirSplitNeighbors<'a> {
    recip: &'a [u32],
    out: &'a [u32],
    inn: &'a [u32],
}

impl Iterator for DirSplitNeighbors<'_> {
    type Item = (u32, u8);

    #[inline]
    fn next(&mut self) -> Option<(u32, u8)> {
        // The three runs are disjoint (a dyad has exactly one state),
        // so strict minimum selection is unambiguous. `u32::MAX` is an
        // unreachable node id (ids fit in 30 bits), so it serves as the
        // empty sentinel.
        let mut v = u32::MAX;
        let mut bits = 0u8;
        if let Some(&w) = self.recip.first() {
            v = w;
            bits = 0b11;
        }
        if let Some(&w) = self.out.first() {
            if w < v {
                v = w;
                bits = 0b01;
            }
        }
        if let Some(&w) = self.inn.first() {
            if w < v {
                v = w;
                bits = 0b10;
            }
        }
        match bits {
            0 => None,
            0b11 => {
                self.recip = &self.recip[1..];
                Some((v, bits))
            }
            0b01 => {
                self.out = &self.out[1..];
                Some((v, bits))
            }
            _ => {
                self.inn = &self.inn[1..];
                Some((v, bits))
            }
        }
    }

    /// Positional seek by whole interleaving blocks: the run holding
    /// the globally smallest head owns a contiguous prefix of the
    /// merged order (everything below the other heads), so one binary
    /// search skips it at once. This is what keeps parallel-engine
    /// chunk seating cheap on degree-ordered hub rows, where a single
    /// row spans many scheduler chunks.
    fn nth(&mut self, mut n: usize) -> Option<(u32, u8)> {
        loop {
            let rh = self.recip.first().copied().unwrap_or(u32::MAX);
            let oh = self.out.first().copied().unwrap_or(u32::MAX);
            let ih = self.inn.first().copied().unwrap_or(u32::MAX);
            if rh == u32::MAX && oh == u32::MAX && ih == u32::MAX {
                return None;
            }
            // exactly one run holds the (strict, runs are disjoint)
            // minimum head; its elements below the other heads form the
            // next contiguous block of the merged order
            if rh < oh && rh < ih {
                let block = self.recip.partition_point(|&x| x < oh.min(ih));
                if n < block {
                    let w = self.recip[n];
                    self.recip = &self.recip[n + 1..];
                    return Some((w, 0b11));
                }
                n -= block;
                self.recip = &self.recip[block..];
            } else if oh < ih {
                let block = self.out.partition_point(|&x| x < rh.min(ih));
                if n < block {
                    let w = self.out[n];
                    self.out = &self.out[n + 1..];
                    return Some((w, 0b01));
                }
                n -= block;
                self.out = &self.out[block..];
            } else {
                let block = self.inn.partition_point(|&x| x < rh.min(oh));
                if n < block {
                    let w = self.inn[n];
                    self.inn = &self.inn[n + 1..];
                    return Some((w, 0b10));
                }
                n -= block;
                self.inn = &self.inn[block..];
            }
        }
    }

    #[inline]
    fn size_hint(&self) -> (usize, Option<usize>) {
        let len = self.recip.len() + self.out.len() + self.inn.len();
        (len, Some(len))
    }
}

impl ExactSizeIterator for DirSplitNeighbors<'_> {}

impl GraphView for DirSplit {
    type Neighbors<'a> = DirSplitNeighbors<'a>
    where
        Self: 'a;

    #[inline]
    fn node_count(&self) -> usize {
        self.offsets.len() - 1
    }

    #[inline]
    fn arc_count(&self) -> u64 {
        self.arc_count
    }

    #[inline]
    fn neighbors(&self, u: u32) -> DirSplitNeighbors<'_> {
        let (recip, out, inn) = self.runs(u);
        DirSplitNeighbors { recip, out, inn }
    }

    #[inline]
    fn dyad_bits(&self, u: u32, v: u32) -> u8 {
        let (recip, out, inn) = self.runs(u);
        if recip.binary_search(&v).is_ok() {
            0b11
        } else if out.binary_search(&v).is_ok() {
            0b01
        } else if inn.binary_search(&v).is_ok() {
            0b10
        } else {
            0
        }
    }

    #[inline]
    fn degree(&self, u: u32) -> usize {
        self.offsets[u as usize + 1] - self.offsets[u as usize]
    }

    #[inline]
    fn entry_count(&self) -> usize {
        self.nbrs.len()
    }

    #[inline]
    fn flat_offsets(&self) -> Cow<'_, [usize]> {
        Cow::Borrowed(&self.offsets)
    }

    #[inline]
    fn out_degree(&self, u: u32) -> usize {
        self.out_end[u as usize] - self.offsets[u as usize]
    }

    #[inline]
    fn in_degree(&self, u: u32) -> usize {
        let u = u as usize;
        (self.recip_end[u] - self.offsets[u]) + (self.offsets[u + 1] - self.out_end[u])
    }

    #[inline]
    fn reciprocal_degree(&self, u: u32) -> usize {
        self.recip_end[u as usize] - self.offsets[u as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::from_arcs;
    use crate::graph::generators;

    fn fixture() -> CsrGraph {
        from_arcs(6, &[(0, 1), (1, 0), (1, 2), (3, 1), (4, 5), (5, 4), (2, 4)])
    }

    #[test]
    fn ordering_parses_and_lists_valid_values() {
        assert_eq!(
            VertexOrdering::parse("natural").unwrap(),
            VertexOrdering::Natural
        );
        assert_eq!(
            VertexOrdering::parse("degree").unwrap(),
            VertexOrdering::Degree
        );
        let err = VertexOrdering::parse("random").unwrap_err();
        assert!(err.contains("unknown ordering"), "{err}");
        assert!(err.contains("natural") && err.contains("degree"), "{err}");
        for o in VertexOrdering::ALL {
            assert_eq!(VertexOrdering::parse(o.name()).unwrap(), o);
        }
        assert_eq!(VertexOrdering::default(), VertexOrdering::Natural);
    }

    #[test]
    fn identity_and_inverse_round_trip() {
        let r = Relabeling::identity(5);
        assert!(r.is_identity());
        let g = fixture();
        let r = Relabeling::degree_descending(&g);
        assert_eq!(r.len(), 6);
        for u in 0..6u32 {
            assert_eq!(r.unmap(r.map(u)), u);
            assert_eq!(r.map(r.unmap(u)), u);
        }
    }

    #[test]
    fn degree_descending_puts_hubs_first() {
        let g = fixture();
        let r = Relabeling::degree_descending(&g);
        // node 1 has degree 3 — it must get rank 0
        assert_eq!(r.map(1), 0);
        let degs: Vec<usize> = (0..6u32).map(|new| g.degree(r.unmap(new))).collect();
        for w in degs.windows(2) {
            assert!(w[0] >= w[1], "degrees not descending: {degs:?}");
        }
        // determinism: equal degrees keep old-id order
        assert_eq!(r, Relabeling::degree_descending(&g));
    }

    #[test]
    #[should_panic(expected = "not a permutation")]
    fn from_order_rejects_duplicates() {
        Relabeling::from_order(vec![0, 0, 1]);
    }

    #[test]
    fn relabel_preserves_structure() {
        let g = fixture();
        let r = Relabeling::degree_descending(&g);
        let h = relabel(&g, &r);
        assert!(h.validate().is_ok());
        assert_eq!(h.arc_count(), g.arc_count());
        assert_eq!(h.dyad_count(), g.dyad_count());
        // every arc maps: u -> v in g iff map(u) -> map(v) in h
        for u in 0..6u32 {
            for v in 0..6u32 {
                if u != v {
                    assert_eq!(
                        GraphView::has_arc(&g, u, v),
                        GraphView::has_arc(&h, r.map(u), r.map(v)),
                        "arc ({u},{v})"
                    );
                }
            }
        }
        // parallel ingest is bit-identical
        assert_eq!(relabel_with(&g, &r, 4), h);
    }

    #[test]
    fn dir_split_matches_the_source_view() {
        for seed in 0..4 {
            let g = generators::power_law(120, 2.2, 5.0, seed);
            let s = DirSplit::build(&g);
            assert_eq!(GraphView::node_count(&s), g.node_count());
            assert_eq!(GraphView::arc_count(&s), g.arc_count());
            assert_eq!(GraphView::entry_count(&s), g.entry_count());
            for u in 0..g.node_count() as u32 {
                let a: Vec<(u32, u8)> = g.neighbors(u).collect();
                let b: Vec<(u32, u8)> = s.neighbors(u).collect();
                assert_eq!(a, b, "seed {seed} node {u}");
                assert_eq!(GraphView::degree(&s, u), GraphView::degree(&g, u));
                assert_eq!(GraphView::out_degree(&s, u), GraphView::out_degree(&g, u));
                assert_eq!(GraphView::in_degree(&s, u), GraphView::in_degree(&g, u));
                assert_eq!(s.reciprocal_degree(u), g.reciprocal_degree(u));
                for v in 0..g.node_count() as u32 {
                    if u != v {
                        assert_eq!(
                            s.dyad_bits(u, v),
                            GraphView::dyad_bits(&g, u, v),
                            "seed {seed} dyad ({u},{v})"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn dir_split_runs_are_sorted_and_disjoint() {
        let g = fixture();
        let s = DirSplit::build(&g);
        for u in 0..6u32 {
            let (recip, out, inn) = s.runs(u);
            for run in [recip, out, inn] {
                for w in run.windows(2) {
                    assert!(w[0] < w[1], "run not strictly ascending");
                }
            }
            let mut all: Vec<u32> = [recip, out, inn].concat();
            all.sort_unstable();
            all.dedup();
            assert_eq!(all.len(), recip.len() + out.len() + inn.len());
        }
        // node 1: recip {0}, out {2}, in {3}
        assert_eq!(s.runs(1), (&[0u32][..], &[2u32][..], &[3u32][..]));
    }

    #[test]
    fn dir_split_nth_matches_linear_iteration() {
        // block-skipping positional seek == skipping one by one, from
        // every start offset (this is the parallel engine's chunk-seat
        // path on degree-ordered rows)
        let g = generators::power_law(80, 2.1, 6.0, 3);
        let s = DirSplit::build(&g);
        for u in 0..g.node_count() as u32 {
            let full: Vec<(u32, u8)> = s.neighbors(u).collect();
            for start in 0..=full.len() {
                let seek: Vec<(u32, u8)> = s.neighbors(u).skip(start).collect();
                assert_eq!(seek, full[start..], "node {u} start {start}");
                let mut it = s.neighbors(u);
                assert_eq!(it.nth(start), full.get(start).copied(), "node {u} nth {start}");
            }
        }
    }

    #[test]
    fn degree_split_composes_both_passes() {
        let g = generators::power_law(200, 2.3, 6.0, 9);
        let (r, s) = degree_split(&g, 2);
        assert_eq!(r.len(), 200);
        assert_eq!(GraphView::entry_count(&s), g.entry_count());
        // rank 0 is a maximum-degree node
        let max_deg = (0..200u32).map(|u| g.degree(u)).max().unwrap();
        assert_eq!(GraphView::degree(&s, 0), max_deg);
    }
}
