//! Storage backends for the CSR hot arrays.
//!
//! The census engines only ever see two slices — the offsets array and
//! the packed-edge array — so [`CsrStorage`] abstracts where those
//! slices live:
//!
//! * [`CsrStorage::Owned`] — freshly built `Vec`s (the ingest path);
//! * [`CsrStorage::Mapped`] — windows into a memory-mapped v2 binary
//!   file ([`crate::graph::io`]'s `TRIADIC2` layout), giving O(1) load
//!   of multi-GB graphs with zero parsing and zero copying.
//!
//! Zero-copy mapping reinterprets the on-disk little-endian `u64`
//! offsets / `u32` packed edges in place, so it is only constructed on
//! little-endian 64-bit targets (the loader falls back to an owned
//! decode elsewhere). Section alignment is guaranteed by the format
//! (64-byte aligned sections over an 8-byte aligned base).

use super::csr::PackedEdge;
use super::mmap::MmapFile;

/// Where a graph's offsets and packed edges live.
pub enum CsrStorage {
    /// Heap-owned arrays (built by the ingest pipeline).
    Owned {
        offsets: Vec<usize>,
        edges: Vec<PackedEdge>,
    },
    /// Zero-copy windows into a mapped v2 binary file.
    Mapped(MappedCsr),
}

impl CsrStorage {
    /// The offsets slice (`n + 1` entries).
    #[inline]
    pub fn offsets(&self) -> &[usize] {
        match self {
            CsrStorage::Owned { offsets, .. } => offsets,
            CsrStorage::Mapped(m) => m.offsets(),
        }
    }

    /// The packed-edge slice (`m` entries).
    #[inline]
    pub fn edges(&self) -> &[PackedEdge] {
        match self {
            CsrStorage::Owned { edges, .. } => edges,
            CsrStorage::Mapped(m) => m.edges(),
        }
    }

    /// True for file-mapped storage.
    #[inline]
    pub fn is_mapped(&self) -> bool {
        matches!(self, CsrStorage::Mapped(_))
    }

    /// Heap bytes owned by this storage (a mapped graph owns almost
    /// nothing — the file pages are shared, evictable cache).
    pub fn heap_bytes(&self) -> usize {
        match self {
            CsrStorage::Owned { offsets, edges } => {
                offsets.len() * std::mem::size_of::<usize>()
                    + edges.len() * std::mem::size_of::<PackedEdge>()
            }
            CsrStorage::Mapped(_) => std::mem::size_of::<MappedCsr>(),
        }
    }

    /// Deep-copy into owned storage (mapped graphs materialize).
    pub fn to_owned_storage(&self) -> CsrStorage {
        CsrStorage::Owned {
            offsets: self.offsets().to_vec(),
            edges: self.edges().to_vec(),
        }
    }
}

/// Zero-copy CSR windows over a mapped v2 file.
///
/// Invariants (established by the loader, which validates the header
/// before construction):
///
/// * `offsets_off` and `edges_off` are in-bounds, 8-byte aligned
///   section offsets with room for `nodes + 1` `u64`s and `entries`
///   `u32`s respectively;
/// * the base pointer of `map` is at least 8-byte aligned.
pub struct MappedCsr {
    map: MmapFile,
    offsets_off: usize,
    nodes: usize,
    edges_off: usize,
    entries: usize,
}

impl MappedCsr {
    /// Wrap validated section windows of a mapped file.
    ///
    /// Callers (the v2 loader) must have bounds- and alignment-checked
    /// the sections; this re-asserts the cheap invariants.
    pub(crate) fn new(
        map: MmapFile,
        offsets_off: usize,
        nodes: usize,
        edges_off: usize,
        entries: usize,
    ) -> MappedCsr {
        assert!(
            cfg!(all(target_endian = "little", target_pointer_width = "64")),
            "zero-copy CSR mapping requires a little-endian 64-bit target"
        );
        assert!(offsets_off % 8 == 0 && edges_off % 4 == 0, "misaligned sections");
        assert!(
            offsets_off + (nodes + 1) * 8 <= map.len() && edges_off + entries * 4 <= map.len(),
            "sections out of bounds"
        );
        MappedCsr {
            map,
            offsets_off,
            nodes,
            edges_off,
            entries,
        }
    }

    /// The offsets section viewed as `&[usize]` (valid: LE 64-bit
    /// target, 8-byte aligned base + 8-byte aligned section offset).
    #[inline]
    pub fn offsets(&self) -> &[usize] {
        unsafe {
            std::slice::from_raw_parts(
                self.map.as_ptr().add(self.offsets_off) as *const usize,
                self.nodes + 1,
            )
        }
    }

    /// The edges section viewed as `&[PackedEdge]` (`repr(transparent)`
    /// over `u32`).
    #[inline]
    pub fn edges(&self) -> &[PackedEdge] {
        unsafe {
            std::slice::from_raw_parts(
                self.map.as_ptr().add(self.edges_off) as *const PackedEdge,
                self.entries,
            )
        }
    }

    /// Whether the backing view is a real OS mapping.
    pub fn is_os_mapped(&self) -> bool {
        self.map.is_os_mapped()
    }
}

impl std::fmt::Debug for CsrStorage {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CsrStorage::Owned { offsets, edges } => f
                .debug_struct("Owned")
                .field("nodes", &offsets.len().saturating_sub(1))
                .field("entries", &edges.len())
                .finish(),
            CsrStorage::Mapped(m) => f
                .debug_struct("Mapped")
                .field("nodes", &m.nodes)
                .field("entries", &m.entries)
                .field("os_mapped", &m.is_os_mapped())
                .finish(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn owned_accessors_round_trip() {
        let s = CsrStorage::Owned {
            offsets: vec![0, 1, 2],
            edges: vec![PackedEdge(0b101), PackedEdge(0b110)],
        };
        assert_eq!(s.offsets(), &[0, 1, 2]);
        assert_eq!(s.edges().len(), 2);
        assert!(!s.is_mapped());
        assert!(s.heap_bytes() > 0);
    }

    #[test]
    fn to_owned_copies() {
        let s = CsrStorage::Owned {
            offsets: vec![0, 2],
            edges: vec![PackedEdge(0b101), PackedEdge(0b111)],
        };
        let t = s.to_owned_storage();
        assert_eq!(s.offsets(), t.offsets());
        assert_eq!(s.edges(), t.edges());
    }
}
