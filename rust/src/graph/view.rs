//! `GraphView` — the one read interface every census engine walks.
//!
//! PRs 1–4 grew three parallel graph read paths: the owned CSR, the
//! zero-copy mmap CSR (both behind [`CsrGraph`]) and the mutable
//! [`DeltaOverlay`]. Each was hand-specialized inside engines and the
//! streaming scanner, which blocked representation-level speedups
//! (degree relabeling, direction-split neighborhoods) from reaching
//! every engine at once. `GraphView` collapses those paths into one
//! trait: ascending merged-neighborhood iteration with the 2-bit dyad
//! direction encoding, O(log deg) dyad lookup, and the collapsed
//! (manhattan) iteration space the parallel scheduler chunks over.
//!
//! Implementors:
//!
//! * [`CsrGraph`] — owned *and* mmap-backed storage (one impl; the
//!   slice accessors are already storage-agnostic);
//! * [`DeltaOverlay`] — the streaming overlay (merged base + override
//!   reads);
//! * [`DirSplit`](super::relabel::DirSplit) — the direction-split
//!   preprocessed form (reciprocal / out-only / in-only runs).
//!
//! Every engine in [`crate::census`] is generic over `GraphView`, so a
//! census over any of these is the *same monomorphized kernel* — and
//! tests assert the results are byte-identical across views.

use std::borrow::Cow;

use super::csr::{CsrGraph, PackedEdge};
use super::overlay::DeltaOverlay;

/// Read-only view of a simple directed graph in the crate's 2-bit dyad
/// encoding. All neighbor iteration is in ascending neighbor-id order
/// (the invariant every merged two-pointer walk relies on); direction
/// bits are `0b01` = arc to the neighbor, `0b10` = arc from the
/// neighbor, `0b11` = reciprocal, and a returned `0` from
/// [`GraphView::dyad_bits`] means the dyad is null.
///
/// `Sync` is a supertrait: views are shared read-only across executor
/// seats by the parallel engine.
pub trait GraphView: Sync {
    /// Ascending `(neighbor, direction bits)` iterator over one node's
    /// connected neighbors.
    type Neighbors<'a>: Iterator<Item = (u32, u8)> + 'a
    where
        Self: 'a;

    /// Number of nodes.
    fn node_count(&self) -> usize;

    /// Number of directed arcs (a reciprocal dyad counts as two).
    fn arc_count(&self) -> u64;

    /// The merged neighborhood of `u`, ascending by neighbor id.
    fn neighbors(&self, u: u32) -> Self::Neighbors<'_>;

    /// Direction bits of the ordered pair `(u, v)` from `u`'s
    /// perspective (`0` = null dyad).
    fn dyad_bits(&self, u: u32, v: u32) -> u8;

    /// Undirected degree (distinct connected neighbors).
    fn degree(&self, u: u32) -> usize {
        self.neighbors(u).count()
    }

    /// Total adjacency entries (2 × connected dyads) — the length of
    /// the collapsed iteration space the parallel engine schedules.
    fn entry_count(&self) -> usize;

    /// CSR-style offsets into the collapsed entry space (`n + 1`
    /// monotone entries, `offsets[u+1] - offsets[u] == degree(u)`).
    /// Borrowed where the representation already stores them; computed
    /// in O(n + entries) otherwise. The parallel engine fetches this
    /// once per census and seats scheduler chunks by binary search.
    fn flat_offsets(&self) -> Cow<'_, [usize]>;

    /// True if the arc `u -> v` exists.
    fn has_arc(&self, u: u32, v: u32) -> bool {
        self.dyad_bits(u, v) & 0b01 != 0
    }

    /// True if at least one arc connects `u` and `v` (the paper's `uÂv`
    /// relation).
    fn is_neighbor(&self, u: u32, v: u32) -> bool {
        self.dyad_bits(u, v) != 0
    }

    /// Out-degree hint (arcs leaving `u`). O(deg) default; preprocessed
    /// forms override with O(1) run arithmetic.
    fn out_degree(&self, u: u32) -> usize {
        self.neighbors(u).filter(|&(_, b)| b & 0b01 != 0).count()
    }

    /// In-degree hint (arcs entering `u`).
    fn in_degree(&self, u: u32) -> usize {
        self.neighbors(u).filter(|&(_, b)| b & 0b10 != 0).count()
    }

    /// Reciprocal-degree hint (mutual dyads at `u`) — the load-balance
    /// signal degree-ordering keys on for mutual-heavy graphs.
    fn reciprocal_degree(&self, u: u32) -> usize {
        self.neighbors(u).filter(|&(_, b)| b == 0b11).count()
    }
}

/// Ascending `(neighbor, bits)` iterator over a packed CSR row.
pub struct CsrNeighbors<'a> {
    inner: std::slice::Iter<'a, PackedEdge>,
}

impl CsrNeighbors<'_> {
    #[inline]
    fn unpack(e: &PackedEdge) -> (u32, u8) {
        (e.nbr(), (e.0 & 0b11) as u8)
    }
}

impl Iterator for CsrNeighbors<'_> {
    type Item = (u32, u8);

    #[inline]
    fn next(&mut self) -> Option<(u32, u8)> {
        self.inner.next().map(Self::unpack)
    }

    /// O(1) via the slice iterator — `neighbors(u).skip(k)` seats a
    /// scheduler chunk mid-row without replaying the prefix.
    #[inline]
    fn nth(&mut self, n: usize) -> Option<(u32, u8)> {
        self.inner.nth(n).map(Self::unpack)
    }

    #[inline]
    fn size_hint(&self) -> (usize, Option<usize>) {
        self.inner.size_hint()
    }
}

impl ExactSizeIterator for CsrNeighbors<'_> {}

impl GraphView for CsrGraph {
    type Neighbors<'a> = CsrNeighbors<'a>
    where
        Self: 'a;

    #[inline]
    fn node_count(&self) -> usize {
        CsrGraph::node_count(self)
    }

    #[inline]
    fn arc_count(&self) -> u64 {
        CsrGraph::arc_count(self)
    }

    #[inline]
    fn neighbors(&self, u: u32) -> CsrNeighbors<'_> {
        CsrNeighbors {
            inner: self.row(u).iter(),
        }
    }

    #[inline]
    fn dyad_bits(&self, u: u32, v: u32) -> u8 {
        self.find_entry(u, v).map_or(0, |e| (e.0 & 0b11) as u8)
    }

    #[inline]
    fn degree(&self, u: u32) -> usize {
        CsrGraph::degree(self, u)
    }

    #[inline]
    fn entry_count(&self) -> usize {
        CsrGraph::entry_count(self)
    }

    #[inline]
    fn flat_offsets(&self) -> Cow<'_, [usize]> {
        Cow::Borrowed(self.offsets())
    }

    #[inline]
    fn out_degree(&self, u: u32) -> usize {
        CsrGraph::out_degree(self, u)
    }

    #[inline]
    fn in_degree(&self, u: u32) -> usize {
        CsrGraph::in_degree(self, u)
    }
}

impl GraphView for DeltaOverlay {
    type Neighbors<'a> = super::overlay::OverlayRow<'a>
    where
        Self: 'a;

    #[inline]
    fn node_count(&self) -> usize {
        DeltaOverlay::node_count(self)
    }

    #[inline]
    fn arc_count(&self) -> u64 {
        DeltaOverlay::arc_count(self)
    }

    #[inline]
    fn neighbors(&self, u: u32) -> super::overlay::OverlayRow<'_> {
        DeltaOverlay::neighbors(self, u)
    }

    #[inline]
    fn dyad_bits(&self, u: u32, v: u32) -> u8 {
        DeltaOverlay::dyad_bits(self, u, v)
    }

    #[inline]
    fn degree(&self, u: u32) -> usize {
        DeltaOverlay::degree(self, u)
    }

    #[inline]
    fn entry_count(&self) -> usize {
        (self.dyad_count() * 2) as usize
    }

    fn flat_offsets(&self) -> Cow<'_, [usize]> {
        let n = DeltaOverlay::node_count(self);
        let mut offsets = Vec::with_capacity(n + 1);
        let mut acc = 0usize;
        offsets.push(0);
        for u in 0..n as u32 {
            acc += DeltaOverlay::degree(self, u);
            offsets.push(acc);
        }
        debug_assert_eq!(acc, GraphView::entry_count(self));
        Cow::Owned(offsets)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::from_arcs;
    use crate::graph::overlay::EdgeOp;
    use std::sync::Arc;

    fn fixture() -> CsrGraph {
        from_arcs(6, &[(0, 1), (1, 0), (1, 2), (3, 1), (4, 5), (5, 4)])
    }

    #[test]
    fn csr_view_matches_inherent_accessors() {
        let g = fixture();
        assert_eq!(GraphView::node_count(&g), 6);
        assert_eq!(GraphView::arc_count(&g), 6);
        assert_eq!(GraphView::entry_count(&g), g.entry_count());
        assert_eq!(GraphView::flat_offsets(&g).as_ref(), g.offsets());
        let row1: Vec<(u32, u8)> = g.neighbors(1).collect();
        assert_eq!(row1, vec![(0, 0b11), (2, 0b01), (3, 0b10)]);
        assert_eq!(g.dyad_bits(1, 0), 0b11);
        assert_eq!(g.dyad_bits(2, 1), 0b10);
        assert_eq!(g.dyad_bits(0, 4), 0);
        assert!(GraphView::has_arc(&g, 1, 2) && !GraphView::has_arc(&g, 2, 1));
        assert!(GraphView::is_neighbor(&g, 2, 1));
        assert_eq!(GraphView::out_degree(&g, 1), 2);
        assert_eq!(GraphView::in_degree(&g, 1), 2);
        assert_eq!(g.reciprocal_degree(1), 1);
        assert_eq!(g.reciprocal_degree(4), 1);
    }

    #[test]
    fn csr_neighbors_nth_is_positional() {
        let g = from_arcs(5, &[(0, 1), (0, 2), (0, 3), (0, 4)]);
        let mut it = g.neighbors(0);
        assert_eq!(it.nth(2), Some((3, 0b01)));
        assert_eq!(it.next(), Some((4, 0b01)));
        assert_eq!(it.next(), None);
        let skipped: Vec<u32> = g.neighbors(0).skip(1).map(|(v, _)| v).collect();
        assert_eq!(skipped, vec![2, 3, 4]);
    }

    #[test]
    fn overlay_view_tracks_edits() {
        let mut o = DeltaOverlay::new(Arc::new(fixture()));
        o.apply(EdgeOp::Insert(0, 2));
        o.apply(EdgeOp::Delete(4, 5));
        assert_eq!(GraphView::node_count(&o), 6);
        assert_eq!(GraphView::arc_count(&o), 6);
        // dyads: {0,1} {1,2} {1,3} {4,5} {0,2} = 5 connected
        assert_eq!(GraphView::entry_count(&o), 10);
        let offs = GraphView::flat_offsets(&o);
        assert_eq!(offs.len(), 7);
        assert_eq!(*offs.last().unwrap(), 10);
        for u in 0..6u32 {
            assert_eq!(
                offs[u as usize + 1] - offs[u as usize],
                GraphView::degree(&o, u),
                "node {u}"
            );
        }
        assert_eq!(o.dyad_bits(0, 2), 0b01);
        assert_eq!(GraphView::dyad_bits(&o, 5, 4), 0b01);
    }

    #[test]
    fn clean_overlay_and_base_agree_entirely() {
        let g = fixture();
        let o = DeltaOverlay::new(Arc::new(g.clone()));
        assert_eq!(GraphView::entry_count(&o), GraphView::entry_count(&g));
        assert_eq!(
            GraphView::flat_offsets(&o).as_ref(),
            GraphView::flat_offsets(&g).as_ref()
        );
        for u in 0..6u32 {
            let a: Vec<(u32, u8)> = g.neighbors(u).collect();
            let b: Vec<(u32, u8)> = GraphView::neighbors(&o, u).collect();
            assert_eq!(a, b, "node {u}");
        }
    }
}
