//! # triadic — scalable triadic analysis of large-scale graphs
//!
//! Reproduction of Chin, Marquez, Choudhury & Feo (PNNL, 2012),
//! *"Scalable Triadic Analysis of Large-Scale Graphs: Multi-Core vs.
//! Multi-Processor vs. Multi-Threaded Shared Memory Architectures"*.
//!
//! The crate provides, as a library:
//!
//! * [`graph`] — the paper's compact CSR graph structure (Fig 7) with
//!   2-bit edge-direction encoding, the [`graph::GraphView`] trait every
//!   census engine is generic over (owned / mmap / overlay /
//!   direction-split views census byte-identically), census-invariant
//!   degree-descending relabeling ([`graph::relabel`]), deterministic
//!   scale-free generators, I/O, and degree / power-law analysis
//!   (Fig 6).
//! * [`census`] — the triad taxonomy (64 tricodes → 16 isomorphism
//!   classes), a naive `O(n^3)` oracle, Batagelj–Mrvar's `O(m)` census
//!   (Fig 5), the merged-traversal optimized variant (Fig 8), Moody's
//!   dense matrix-method census, and the parallel engine with
//!   hash-distributed local census vectors — all behind the
//!   [`census::CensusEngine`] trait and its by-name registry — plus
//!   [`census::StreamingCensus`], which keeps a census live under edge
//!   insertions/deletions at O(deg) per mutation over a
//!   [`graph::overlay::DeltaOverlay`].
//! * [`sched`] — an OpenMP-like scheduler (static / dynamic / guided)
//!   over a manhattan-collapsed iteration space, on a persistent
//!   work-stealing executor (spawn once, park workers, per-seat chunk
//!   deques) shared by every parallel loop in the process.
//! * [`simulator`] — analytic machine models of the paper's three
//!   testbeds (Cray XMT, HP Superdome, AMD Magny-Cours NUMA) driven by a
//!   measured workload characterization; regenerates Figs 9–13.
//! * [`analysis`] — the triadic security-monitoring application of the
//!   paper's Figs 3–4: windowed census streams, threat triad patterns,
//!   and baseline/z-score anomaly detection.
//! * [`runtime`] — a PJRT (XLA) runtime that loads AOT-compiled HLO
//!   artifacts (the JAX/Pallas dense census) and executes them from Rust.
//! * [`coordinator`] — the job-oriented service layer: a versioned
//!   request/response model (`CensusRequest` builder → `submit` →
//!   `JobHandle` with poll/wait/cancel), routing between the sparse
//!   engines and the dense AOT backend on one shared process-lifetime
//!   executor, a newline-delimited-JSON TCP server + `TriadicClient`,
//!   and metrics. The blocking `census`/`census_path` calls survive as
//!   compatibility shims.
//! * [`net`] — the nonblocking multi-tenant serving gateway: reactor
//!   threads over raw-syscall epoll (portable scan fallback), one
//!   listener speaking both newline-JSON and minimal HTTP/1.1, with
//!   per-tenant token-bucket rate limits, inflight quotas, priorities,
//!   and structured load shedding.
//!
//! Python (JAX + Pallas) appears only at build time: `make artifacts`
//! lowers Moody's matrix census to HLO text which [`runtime`] loads; no
//! Python is on the request path.

// This crate is developed offline and linted in CI at whatever stable
// clippy the runner ships; index-loops over fixed 16-element census
// arrays are idiomatic here, so this style lint stays off globally
// rather than risking version-dependent CI breakage.
#![allow(clippy::needless_range_loop)]

pub mod analysis;
pub mod bench;
pub mod census;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod figures;
pub mod graph;
pub mod metrics;
pub mod net;
pub mod rng;
pub mod runtime;
pub mod sched;
pub mod simulator;

pub use census::{Census, TriadType};
pub use error::{Context, Error, Result};
pub use graph::CsrGraph;
