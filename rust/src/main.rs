//! `repro` — the triadic-analysis CLI (leader entrypoint).
//!
//! Subcommands:
//!
//! * `census`   — compute the triad census of a generated or loaded graph
//!                through the coordinator (sparse engine or dense AOT
//!                backend, routed automatically).
//! * `generate` — write a synthetic workload graph to disk.
//! * `convert`  — re-encode a graph file (edge list / v1 binary) into the
//!                zero-copy v2 mmap format.
//! * `smoke`    — CI perf smoke: generate a power-law graph, run the
//!                parallel census, cross-check against the merged serial
//!                engine and the mmap round-trip, print timings.
//! * `figures`  — regenerate the paper's evaluation figures (Figs 6–13 +
//!                the scheduling study) as TSV tables.
//! * `simulate` — sweep one machine model over processor counts.
//! * `monitor`  — run the Fig 3/4 security monitor on synthetic traffic.
//! * `stream`   — replay a timestamped edge-mutation stream through the
//!                incremental census, batch by batch, optionally
//!                compacting periodically and cross-checking the live
//!                census against a full merged-engine recompute.
//! * `serve`    — start the coordinator and serve the versioned census
//!                wire protocol over TCP (`--listen ADDR`; newline-
//!                delimited JSON frames, see README "Serving API"), or
//!                the legacy one-path-per-line stdin loop (`--stdin`).
//!                With `--workers addr,addr,...` (or `--workers-file`)
//!                the coordinator becomes a distributed planner: census
//!                requests are partitioned into vertex-range shards,
//!                scattered to `repro worker` processes and merged by
//!                exact summation.
//! * `worker`   — run one distributed census worker: a sparse-only
//!                coordinator behind the same TCP server, fed shard
//!                sub-jobs by a planning coordinator.
//! * `client`   — drive a running server: submit census jobs (path /
//!                generator sources), poll them to completion, or issue
//!                `status` / `metrics` / `shutdown` control verbs.

use std::io::BufRead;
use std::path::PathBuf;
use std::sync::Arc;

use triadic::analysis::{builtin_patterns, census_series, MonitorConfig, TriadMonitor};
use triadic::analysis::{TrafficGenerator, TrafficScenario};
use triadic::bail;
use triadic::census::{
    census_parallel, estimate_sampled, hybrid_registry, merged, sample_base, Accumulation,
    EngineRegistry, ParallelConfig, SampledCensus, StreamingCensus, TriadType,
    DEFAULT_CONFIDENCE_Z, DEFAULT_SAMPLE_SEED,
};
use triadic::config::{graph_spec_from, Args};
use triadic::coordinator::protocol::Json;
use triadic::coordinator::{
    CensusRequest, CensusResponse, CensusServer, Coordinator, CoordinatorConfig, ErrorCode,
    JobStateKind, TriadicClient, WireError,
};
use triadic::error::{Context, Error, Result};
use triadic::figures::{self, Scale};
use triadic::graph::relabel::{self, Relabeling};
use triadic::graph::{degree, io, CsrGraph, DeltaOverlay, EdgeOp, HubSplit, VertexOrdering};
use triadic::net::{Gateway, GatewayConfig, TenantTable};
use triadic::sched::{Executor, ExecutorConfig, PinMode, Policy};
use triadic::simulator::{
    simulate, Machine, NumaMachine, SuperdomeMachine, WorkloadProfile, XmtMachine,
};

const USAGE: &str = "\
repro — scalable triadic analysis (paper reproduction)

USAGE: repro <command> [flags]

COMMANDS
  census    --graph patents|orkut|web [--nodes N] [--seed S] [--input FILE]
            [--threads T] [--policy static|dynamic|guided[:chunk]]
            [--engine naive|bm|merged|parallel|moody] [--pool-threads W]
            [--order natural|degree] [--backend auto|sparse]
            [--artifacts DIR] [--mmap] [--sample-p P] [--pin cpus|sockets|none]
  generate  --graph ... --out FILE [--format txt|bin|v2]
  convert   --input FILE --out FILE [--threads T] [--verify]
  smoke     [--nodes N] [--threads T] [--seed S] [--engine E]
            [--pool-threads W] [--order natural|degree] [--json FILE]
            [--pin cpus|sockets|none]
  figures   [--fig 6|9|10|11|12|13|sched|all] [--scale small|full] [--out DIR]
  simulate  --machine xmt|xmt512|numa|superdome --graph ... [--procs 1,2,...]
  monitor   [--hosts N] [--rate EPS] [--duration S] [--window S]
            [--attack scan|ddos|relay|botnet|all]
  stream    --input FILE [--nodes N] [--base FILE] [--batch K]
            [--threads T] [--pool-threads W] [--order natural|degree]
            [--compact-every B] [--verify-every B] [--oracle] [--json FILE]
            [--sample-p P] [--oracle-interval]
  serve     [--listen ADDR] [--stdin] [--artifacts DIR] [--threads T]
            [--trusted] [--engine E] [--pool-threads W] [--max-jobs K]
            [--job-workers J] [--max-request-nodes N]
            [--workers HOST:PORT,HOST:PORT,...] [--workers-file FILE]
            [--reactor-threads R] [--max-conns C] [--tenant-config FILE]
            [--scan-backend] [--legacy-accept] [--pin cpus|sockets|none]
  worker    [--listen ADDR] [--threads T] [--pool-threads W]
            [--max-jobs K] [--job-workers J] [--trusted]
            [--max-request-nodes N] [--pin cpus|sockets|none]
  client    [--addr HOST:PORT] [--verb census|status|metrics|poll|cancel|shutdown]
            [--input FILE | --graph patents|orkut|web --nodes N [--seed S]]
            [--engine E] [--threads T] [--policy P] [--order natural|degree]
            [--classes 030T,030C] [--job ID] [--raw]

`--order degree` renumbers vertices in descending degree order and
direction-splits neighborhoods before the sparse census runs; the
census itself is invariant (byte-identical tables), only timing moves.

`--pin MODE` sets worker CPU affinity: `sockets` (default) confines each
worker to its socket's CPU set, `cpus` binds one worker per CPU, `none`
leaves placement to the OS. Pinning soft-fails — unsupported platforms
degrade to unpinned and report `pinned_workers=0` in stats/metrics.

`--sample-p P` (census, stream) trades exactness for throughput: the
census runs over a deterministic hash-sample of the dyads (keep
probability P in (0, 1]), printing rounded unbiased per-class estimates
plus `# interval LABEL est stderr lo hi` bounds; at P=1 the table is
byte-identical to exact. `--oracle-interval` (stream) also replays the
ops exactly and exits nonzero if any class's exact count falls outside
its widened interval.
";

fn main() {
    let code = match run() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            2
        }
    };
    std::process::exit(code);
}

fn run() -> Result<()> {
    let args = Args::from_env().map_err(Error::msg)?;
    match args.command.as_deref() {
        Some("census") => cmd_census(&args),
        Some("generate") => cmd_generate(&args),
        Some("convert") => cmd_convert(&args),
        Some("smoke") => cmd_smoke(&args),
        Some("figures") => cmd_figures(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("monitor") => cmd_monitor(&args),
        Some("stream") => cmd_stream(&args),
        Some("serve") => cmd_serve(&args),
        Some("worker") => cmd_worker(&args),
        Some("client") => cmd_client(&args),
        Some("help") | None => {
            print!("{USAGE}");
            Ok(())
        }
        Some(other) => bail!("unknown command {other:?}\n\n{USAGE}"),
    }
}

fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1)
}

fn load_or_generate(args: &Args) -> Result<(String, triadic::graph::CsrGraph)> {
    if let Some(path) = args.opt_str("input") {
        let t0 = std::time::Instant::now();
        // `--mmap` demands the O(1) zero-copy path (v2 files only);
        // otherwise sniff the magic and use the fastest reader that fits.
        let g = if args.flag("mmap") {
            io::load_mmap_file_unverified(&path)
                .with_context(|| format!("--mmap requires a v2 file (repro convert): {path}"))?
        } else {
            io::load_auto(&path, default_threads())?
        };
        eprintln!(
            "loaded {path}: n={} arcs={} mapped={} in {:.3}s",
            g.node_count(),
            g.arc_count(),
            g.is_mapped(),
            t0.elapsed().as_secs_f64()
        );
        Ok((path, g))
    } else {
        let spec = graph_spec_from(args).map_err(Error::msg)?;
        eprintln!(
            "generating {} graph: n={} gamma={} avg_deg={}",
            spec.name, spec.n, spec.gamma, spec.avg_out_degree
        );
        Ok((spec.name.to_string(), spec.generate()))
    }
}

fn cmd_census(args: &Args) -> Result<()> {
    let (name, g) = load_or_generate(args)?;
    let threads = args.get_or("threads", default_threads()).map_err(Error::msg)?;
    let policy = Policy::parse(&args.str_or("policy", "dynamic")).map_err(Error::msg)?;
    let engine_name = args.str_or("engine", "parallel");
    let pool_threads = args.get_or("pool-threads", 0usize).map_err(Error::msg)?;
    // VertexOrdering::parse's error names the valid orderings — the
    // CLI-parse side of the "unknown value" contract
    let order = VertexOrdering::parse(&args.str_or("order", "natural")).map_err(Error::msg)?;
    let backend = args.str_or("backend", "auto");
    let artifacts = args.str_or("artifacts", "artifacts");
    let sample_p = parse_sample_p(args)?;
    let pin = parse_pin(args)?;
    args.reject_unknown().map_err(Error::msg)?;

    // Banked sizes the accumulation to the socket topology and seat
    // count (auto_bank_slots) instead of the paper's fixed 64 slots.
    let sparse = ParallelConfig {
        threads,
        policy,
        accumulation: Accumulation::Banked,
    };

    if let Some(p) = sample_p {
        return census_sampled_cli(&name, &g, p, pool_threads, pin, sparse, &engine_name);
    }

    let t0 = std::time::Instant::now();
    let census = if backend == "sparse" {
        let exec = Executor::new(ExecutorConfig {
            workers: pool_threads,
            max_concurrent_jobs: 0,
            pin,
        });
        let (run, engine_label) = match order {
            VertexOrdering::Natural => {
                let registry = EngineRegistry::builtin(sparse);
                let engine = registry.get_or_err(&engine_name).map_err(Error::msg)?;
                (engine.census(&g, &exec), engine.name().to_string())
            }
            VertexOrdering::Degree => {
                let t_prep = std::time::Instant::now();
                let (_relabeling, split) = relabel::degree_split(&g, threads.max(1));
                let split = HubSplit::build(split);
                eprintln!(
                    "# degree ordering: relabel + direction-split + {} hub rows in {:.3}s",
                    split.hub_count(),
                    t_prep.elapsed().as_secs_f64()
                );
                let registry = hybrid_registry(sparse);
                let engine = registry.get_or_err(&engine_name).map_err(Error::msg)?;
                (engine.census(&split, &exec), engine.name().to_string())
            }
        };
        let estats = exec.stats();
        println!(
            "# backend=sparse engine={engine_label} order={} threads={threads} \
             pool_workers={} policy={} wall={:.3}s imbalance={:.2} steals={} pinned={}",
            order.name(),
            exec.worker_count(),
            policy.name(),
            run.stats.wall,
            run.stats.imbalance(),
            estats.steals,
            estats.pinned_workers
        );
        run.census
    } else {
        let coord = Coordinator::start(CoordinatorConfig {
            artifacts_dir: Some(PathBuf::from(artifacts)),
            sparse,
            engine: engine_name,
            pool_threads,
            pin,
            ..CoordinatorConfig::default()
        })?;
        let out = coord.census_ordered(&g, Some(order))?;
        // out.ordering is what actually ran — dense routes ignore the
        // requested ordering and report natural
        println!(
            "# backend={:?} engine={} order={} dense_enabled={} wall={:.3}s",
            out.route,
            coord.engine_name(),
            out.ordering.name(),
            coord.dense_enabled(),
            out.seconds
        );
        out.census
    };
    println!(
        "# graph={} nodes={} arcs={} elapsed={:.3}s",
        name,
        g.node_count(),
        g.arc_count(),
        t0.elapsed().as_secs_f64()
    );
    print!("{}", census.table());
    Ok(())
}

/// Parse `--pin` (worker CPU affinity: cpus|sockets|none). PinMode's
/// FromStr names the valid modes in its error, mirroring the other
/// "unknown value" contracts.
fn parse_pin(args: &Args) -> Result<PinMode> {
    args.str_or("pin", "sockets").parse::<PinMode>().map_err(Error::msg)
}

/// Parse and range-check `--sample-p` (the CLI spelling of the wire
/// protocol's `fidelity: sampled:P` knob).
fn parse_sample_p(args: &Args) -> Result<Option<f64>> {
    match args.opt_str("sample-p") {
        Some(s) => {
            let p = s
                .parse::<f64>()
                .map_err(|e| Error::msg(format!("bad --sample-p {s:?}: {e}")))?;
            if !(p > 0.0 && p <= 1.0) {
                bail!("--sample-p {p} out of range (valid: 0 < P <= 1)");
            }
            Ok(Some(p))
        }
        None => Ok(None),
    }
}

/// `repro census --sample-p P`: the approximate census path. Filters
/// the graph down to the deterministically kept dyads, runs the
/// selected sparse engine over the sample, and prints the rounded
/// unbiased table (byte-identical to the exact table at `p = 1.0`)
/// followed by one `# interval` comment per class.
fn census_sampled_cli(
    name: &str,
    g: &CsrGraph,
    p: f64,
    pool_threads: usize,
    pin: PinMode,
    sparse: ParallelConfig,
    engine_name: &str,
) -> Result<()> {
    let t0 = std::time::Instant::now();
    let sampled = sample_base(g, p, DEFAULT_SAMPLE_SEED);
    let exec = Executor::new(ExecutorConfig {
        workers: pool_threads,
        max_concurrent_jobs: 0,
        pin,
    });
    let registry = EngineRegistry::builtin(sparse);
    let engine = registry.get_or_err(engine_name).map_err(Error::msg)?;
    let run = engine.census(&sampled, &exec);
    let est = estimate_sampled(
        &run.census,
        g.node_count(),
        sampled.dyad_count(),
        p,
        DEFAULT_CONFIDENCE_Z,
    );
    println!(
        "# graph={name} nodes={} arcs={} fidelity=sampled:{p} sampled_arcs={} \
         engine={} elapsed={:.3}s",
        g.node_count(),
        g.arc_count(),
        sampled.arc_count(),
        engine.name(),
        t0.elapsed().as_secs_f64()
    );
    print!("{}", est.census().table());
    print_intervals(&est);
    Ok(())
}

/// One `# interval LABEL estimate std_err lo hi` comment per class —
/// the machine-readable tail shared by `census --sample-p` and
/// `stream --sample-p` (scripts join it against an exact table).
fn print_intervals(est: &triadic::census::SampledEstimate) {
    for &t in TriadType::ALL.iter() {
        let c = est.class(t);
        println!(
            "# interval {} {:.3} {:.3} {:.3} {:.3}",
            t.label(),
            c.estimate,
            c.std_err,
            c.lo,
            c.hi
        );
    }
}

fn cmd_generate(args: &Args) -> Result<()> {
    let spec = graph_spec_from(args).map_err(Error::msg)?;
    let out = args.opt_str("out").context("--out FILE required")?;
    let format = args.str_or("format", "txt");
    args.reject_unknown().map_err(Error::msg)?;

    let g = spec.generate();
    match format.as_str() {
        "txt" => io::write_edge_list_file(&g, &out)?,
        "bin" => io::write_binary_file(&g, &out)?,
        "v2" | "csr" => io::write_binary_v2_file(&g, &out)?,
        other => bail!("unknown format {other:?} (txt|bin|v2)"),
    }
    let gamma = degree::fit_out_degree_exponent(&g).unwrap_or(f64::NAN);
    println!(
        "wrote {}: n={} arcs={} fitted_gamma={:.3}",
        out,
        g.node_count(),
        g.arc_count(),
        gamma
    );
    Ok(())
}

/// Re-encode any readable graph file into the zero-copy v2 layout and
/// prove the round trip: the written file is mapped back and compared
/// structurally before reporting success.
fn cmd_convert(args: &Args) -> Result<()> {
    let input = args.opt_str("input").context("--input FILE required")?;
    let out = args.opt_str("out").context("--out FILE required")?;
    let threads = args.get_or("threads", default_threads()).map_err(Error::msg)?;
    let verify = args.flag("verify");
    args.reject_unknown().map_err(Error::msg)?;

    let t0 = std::time::Instant::now();
    let g = io::load_auto(&input, threads)?;
    let t_load = t0.elapsed().as_secs_f64();

    let t1 = std::time::Instant::now();
    io::write_binary_v2_file(&g, &out)?;
    let t_write = t1.elapsed().as_secs_f64();

    let t2 = std::time::Instant::now();
    let mapped = io::load_mmap_file(&out)?;
    let t_map = t2.elapsed().as_secs_f64();
    if mapped.node_count() != g.node_count()
        || mapped.entry_count() != g.entry_count()
        || mapped.arc_count() != g.arc_count()
    {
        bail!("round-trip mismatch after convert — file {out} is not trustworthy");
    }
    if verify {
        mapped.validate().map_err(Error::msg)?;
        ensure_census_matches(&g, &mapped)?;
    }
    println!(
        "converted {input} -> {out}: n={} arcs={} parse={t_load:.3}s write={t_write:.3}s \
         mmap_load={t_map:.3}s",
        g.node_count(),
        g.arc_count()
    );
    Ok(())
}

fn ensure_census_matches(a: &triadic::graph::CsrGraph, b: &triadic::graph::CsrGraph) -> Result<()> {
    let ca = merged::census(a);
    let cb = merged::census(b);
    if ca != cb {
        bail!("census mismatch between in-memory and mapped graphs");
    }
    Ok(())
}

/// CI perf smoke: generate a power-law graph, census it on every path
/// (selected engine on the persistent executor, serial merged oracle,
/// mmap-loaded copy), assert exact agreement, print timings so
/// regressions show in job logs, and optionally emit a machine-readable
/// result file (`--json`) for the bench-trajectory artifact.
fn cmd_smoke(args: &Args) -> Result<()> {
    let nodes = args.get_or("nodes", 100_000usize).map_err(Error::msg)?;
    let threads = args.get_or("threads", default_threads()).map_err(Error::msg)?;
    let seed = args.get_or("seed", 2012u64).map_err(Error::msg)?;
    let engine_name = args.str_or("engine", "parallel");
    let pool_threads = args.get_or("pool-threads", 0usize).map_err(Error::msg)?;
    let order = VertexOrdering::parse(&args.str_or("order", "natural")).map_err(Error::msg)?;
    let json_path = args.opt_str("json");
    let pin = parse_pin(args)?;
    args.reject_unknown().map_err(Error::msg)?;

    let t0 = std::time::Instant::now();
    let g = triadic::graph::generators::power_law(nodes, 2.2, 8.0, seed);
    let t_gen = t0.elapsed().as_secs_f64();
    println!(
        "smoke: n={} arcs={} dyads={} gen={t_gen:.3}s threads={threads} engine={engine_name}",
        g.node_count(),
        g.arc_count(),
        g.dyad_count()
    );

    let cfg = ParallelConfig {
        threads,
        policy: Policy::dynamic_default(),
        accumulation: Accumulation::Banked,
    };
    let exec = Executor::new(ExecutorConfig {
        workers: pool_threads,
        max_concurrent_jobs: 0,
        pin,
    });
    let registry = EngineRegistry::builtin(cfg);
    let engine = registry.get_or_err(&engine_name).map_err(Error::msg)?;

    let t1 = std::time::Instant::now();
    let run = engine.census(&g, &exec);
    let t_par = t1.elapsed().as_secs_f64();

    let t2 = std::time::Instant::now();
    let want = merged::census(&g);
    let t_serial = t2.elapsed().as_secs_f64();
    if run.census != want {
        bail!("{} census disagrees with merged serial census", engine.name());
    }

    // mmap round trip: convert once, map, census again from the map
    let path = std::env::temp_dir().join(format!("triadic_smoke_{seed}.csr"));
    let t3 = std::time::Instant::now();
    io::write_binary_v2_file(&g, &path)?;
    let t_write = t3.elapsed().as_secs_f64();
    let t4 = std::time::Instant::now();
    let mapped = io::load_mmap_file_unverified(&path)?;
    let t_map = t4.elapsed().as_secs_f64();
    let t5 = std::time::Instant::now();
    let mapped_run = engine.census(&mapped, &exec);
    let t_mapped = t5.elapsed().as_secs_f64();
    let _ = std::fs::remove_file(&path);
    if mapped_run.census != want {
        bail!("census over the mmap-loaded graph disagrees with the in-memory census");
    }

    // degree-ordering cross-check: the relabeled + direction-split
    // census must be byte-identical (a census is a graph invariant)
    if order == VertexOrdering::Degree {
        let t6 = std::time::Instant::now();
        let (_relabeling, split) = relabel::degree_split(&g, threads.max(1));
        let split = HubSplit::build(split);
        let t_prep = t6.elapsed().as_secs_f64();
        let split_registry = hybrid_registry(cfg);
        let split_engine = split_registry.get_or_err(&engine_name).map_err(Error::msg)?;
        let t7 = std::time::Instant::now();
        let ordered_run = split_engine.census(&split, &exec);
        let t_ordered = t7.elapsed().as_secs_f64();
        if ordered_run.census != want {
            bail!("degree-ordered census disagrees with the natural-order census");
        }
        println!(
            "smoke ordering: prep={t_prep:.3}s census_degree={t_ordered:.3}s \
             (natural {t_par:.3}s) — tables identical"
        );
    }

    println!(
        "smoke timings: parallel={t_par:.3}s serial_merged={t_serial:.3}s \
         v2_write={t_write:.3}s mmap_load={t_map:.6}s parallel_mapped={t_mapped:.3}s"
    );
    println!(
        "smoke: imbalance={:.2} utilization={:.2} speedup_vs_serial={:.2}x pinned_workers={}",
        run.stats.imbalance(),
        run.stats.utilization(),
        t_serial / t_par.max(1e-9),
        exec.stats().pinned_workers
    );
    if let Some(path) = json_path {
        let estats = exec.stats();
        // schema_version lets downstream perf-trajectory tooling evolve
        // the format: bump it on any field rename/removal (additions are
        // compatible). v2 = v1 + this field.
        let json = format!(
            concat!(
                "{{\"schema_version\":2,\"bench\":\"smoke\",\"nodes\":{},\"arcs\":{},\"dyads\":{},",
                "\"threads\":{},\"pool_workers\":{},\"engine\":\"{}\",\"policy\":\"{}\",",
                "\"gen_seconds\":{:.6},\"census_seconds\":{:.6},",
                "\"serial_merged_seconds\":{:.6},\"v2_write_seconds\":{:.6},",
                "\"mmap_load_seconds\":{:.6},\"census_mapped_seconds\":{:.6},",
                "\"imbalance\":{:.4},\"utilization\":{:.4},\"speedup_vs_serial\":{:.4},",
                "\"executor_jobs\":{},\"executor_steals\":{}}}\n"
            ),
            g.node_count(),
            g.arc_count(),
            g.dyad_count(),
            threads,
            exec.worker_count(),
            engine.name(),
            Policy::dynamic_default().name(),
            t_gen,
            t_par,
            t_serial,
            t_write,
            t_map,
            t_mapped,
            run.stats.imbalance(),
            run.stats.utilization(),
            t_serial / t_par.max(1e-9),
            estats.jobs,
            estats.steals,
        );
        std::fs::write(&path, json)?;
        println!("smoke: wrote machine-readable results to {path}");
    }
    println!("smoke OK: all census paths agree");
    Ok(())
}

fn cmd_figures(args: &Args) -> Result<()> {
    let which = args.str_or("fig", "all");
    let scale = Scale::parse(&args.str_or("scale", "small")).map_err(Error::msg)?;
    let out_dir = args.opt_str("out");
    args.reject_unknown().map_err(Error::msg)?;

    let figs: Vec<(&str, String)> = match which.as_str() {
        "all" => figures::all_figures(scale),
        "6" => vec![("fig06_degree", figures::fig6(scale))],
        "9" => vec![("fig09_utilization", figures::fig9(scale))],
        "10" => vec![("fig10_patents", figures::fig10(scale))],
        "11" => vec![("fig11_orkut", figures::fig11(scale))],
        "12" => vec![("fig12_numa_detail", figures::fig12(scale))],
        "13" => vec![("fig13_webgraph", figures::fig13(scale))],
        "sched" => vec![("sched_policies", figures::fig_sched(scale))],
        other => bail!("unknown figure {other:?} (6|9|10|11|12|13|sched|all)"),
    };
    for (name, text) in figs {
        if let Some(dir) = &out_dir {
            std::fs::create_dir_all(dir)?;
            let path = PathBuf::from(dir).join(format!("{name}.tsv"));
            std::fs::write(&path, &text)?;
            eprintln!("wrote {}", path.display());
        } else {
            println!("{text}");
        }
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let machine = args.str_or("machine", "xmt");
    let spec = graph_spec_from(args).map_err(Error::msg)?;
    let procs = args
        .list_or("procs", &[1usize, 2, 4, 8, 16, 32, 64, 128])
        .map_err(Error::msg)?;
    let policy = Policy::parse(&args.str_or("policy", "dynamic")).map_err(Error::msg)?;
    args.reject_unknown().map_err(Error::msg)?;

    let m: Box<dyn Machine> = match machine.as_str() {
        "xmt" => Box::new(XmtMachine::pnnl()),
        "xmt512" => Box::new(XmtMachine::cray512()),
        "numa" => Box::new(NumaMachine::magny_cours()),
        "superdome" => Box::new(SuperdomeMachine::sd64()),
        other => bail!("unknown machine {other:?}"),
    };
    eprintln!("generating {} (n={})...", spec.name, spec.n);
    let g = spec.generate();
    let prof = WorkloadProfile::from_graph(spec.name, &g);
    println!(
        "# machine={} workload={} slots={} total_cost={} imbalance={:.1}",
        m.name(),
        prof.name,
        prof.len(),
        prof.total_cost,
        prof.imbalance()
    );
    println!("procs\tseconds\tbalance\tchunks");
    for p in procs {
        let r = simulate(m.as_ref(), &prof, p, policy);
        println!("{p}\t{:.6}\t{:.3}\t{}", r.makespan, r.balance(), r.chunks);
    }
    Ok(())
}

fn cmd_monitor(args: &Args) -> Result<()> {
    let hosts = args.get_or("hosts", 400u64).map_err(Error::msg)?;
    let rate = args.get_or("rate", 120.0f64).map_err(Error::msg)?;
    let duration = args.get_or("duration", 60.0f64).map_err(Error::msg)?;
    let window = args.get_or("window", 1.0f64).map_err(Error::msg)?;
    let attack = args.str_or("attack", "all");
    args.reject_unknown().map_err(Error::msg)?;

    let mut gen = TrafficGenerator::background(hosts, rate, 2012);
    let quarter = duration / 4.0;
    let add = |g: TrafficGenerator, which: &str| -> TrafficGenerator {
        match which {
            "scan" => g.with(TrafficScenario::PortScan {
                start: quarter,
                end: quarter + window * 0.8,
                attacker: 5,
                targets: 60,
            }),
            "ddos" => g.with(TrafficScenario::Ddos {
                start: 2.0 * quarter,
                end: 2.0 * quarter + window * 0.8,
                victim: 2,
                sources: 60,
            }),
            "relay" => g.with(TrafficScenario::Relay {
                start: 2.5 * quarter,
                end: 2.5 * quarter + window * 0.8,
                first_hop: 4_000_000,
                length: 16,
                chains: 12,
            }),
            "botnet" => g.with(TrafficScenario::BotnetSync {
                start: 3.0 * quarter,
                end: 3.0 * quarter + window * 0.8,
                first_peer: 3_000_000,
                peers: 12,
            }),
            _ => g,
        }
    };
    if attack == "all" {
        for a in ["scan", "ddos", "relay", "botnet"] {
            gen = add(gen, a);
        }
    } else {
        gen = add(gen, &attack);
    }

    let events = gen.generate(duration);
    println!("# {} events over {duration}s, window {window}s", events.len());
    let series = census_series(&events, window, |g| {
        census_parallel(g, &ParallelConfig::default()).census
    });
    let mut mon = TriadMonitor::new(MonitorConfig::default(), builtin_patterns());
    let mut total_alerts = 0;
    for w in &series {
        for a in mon.observe(w) {
            total_alerts += 1;
            println!(
                "ALERT t={:.0}s pattern={} score={:.1} top={},{},{}",
                a.window_start,
                a.pattern,
                a.score,
                a.top_classes[0],
                a.top_classes[1],
                a.top_classes[2]
            );
        }
    }
    println!(
        "# {} windows, {} alerts ({} hosts peak)",
        series.len(),
        total_alerts,
        series.iter().map(|w| w.hosts).max().unwrap_or(0)
    );
    Ok(())
}

/// Parse one edge-stream line. Accepted forms (whitespace separated,
/// `#`/`%` comments skipped by the caller):
///
/// * `u v`          — insert (replay of a plain edge list)
/// * `+ u v` / `- u v`
/// * `TS + u v`     — leading timestamp; replay order is file order, the
///   timestamp is parsed for validation and otherwise ignored
fn parse_stream_line(line: &str, lineno: usize) -> Result<EdgeOp> {
    let fields: Vec<&str> = line.split_whitespace().collect();
    let parse_id = |s: &str| -> Result<u32> {
        s.parse::<u32>()
            .map_err(|e| Error::msg(format!("line {lineno}: bad node id {s:?}: {e}")))
    };
    let op_of = |sign: &str, u: &str, v: &str| -> Result<EdgeOp> {
        let (u, v) = (parse_id(u)?, parse_id(v)?);
        match sign {
            "+" => Ok(EdgeOp::Insert(u, v)),
            "-" => Ok(EdgeOp::Delete(u, v)),
            other => Err(Error::msg(format!(
                "line {lineno}: bad op {other:?} (want + or -)"
            ))),
        }
    };
    match fields.as_slice() {
        [u, v] => Ok(EdgeOp::Insert(parse_id(u)?, parse_id(v)?)),
        [sign, u, v] => op_of(sign, u, v),
        [ts, sign, u, v] => {
            ts.parse::<f64>()
                .map_err(|e| Error::msg(format!("line {lineno}: bad timestamp {ts:?}: {e}")))?;
            op_of(sign, u, v)
        }
        _ => Err(Error::msg(format!(
            "line {lineno}: expected `u v`, `op u v` or `ts op u v`"
        ))),
    }
}

/// Replay a timestamped edge-mutation stream through the incremental
/// census. The final census table is the only non-`#` stdout output, so
/// scripts can diff it against `repro census` of the end-state graph.
fn cmd_stream(args: &Args) -> Result<()> {
    let input = args.opt_str("input").context("--input FILE required")?;
    let base_path = args.opt_str("base");
    let nodes_flag = args.opt_str("nodes");
    let batch = args.get_or("batch", 1024usize).map_err(Error::msg)?.max(1);
    let threads = args.get_or("threads", default_threads()).map_err(Error::msg)?;
    let pool_threads = args.get_or("pool-threads", 0usize).map_err(Error::msg)?;
    let compact_every = args.get_or("compact-every", 0usize).map_err(Error::msg)?;
    let verify_every = args.get_or("verify-every", 0usize).map_err(Error::msg)?;
    let order = VertexOrdering::parse(&args.str_or("order", "natural")).map_err(Error::msg)?;
    let oracle = args.flag("oracle");
    let oracle_interval = args.flag("oracle-interval");
    let sample_p = parse_sample_p(args)?;
    let json_path = args.opt_str("json");
    args.reject_unknown().map_err(Error::msg)?;
    if oracle_interval && sample_p.is_none() {
        bail!("--oracle-interval requires --sample-p (the exact path has --oracle)");
    }

    // parse the whole stream up front (replay order = file order)
    let text = std::fs::read_to_string(&input)
        .with_context(|| format!("reading stream file {input}"))?;
    let mut ops = Vec::new();
    let mut max_id = 0u32;
    for (i, line) in text.lines().enumerate() {
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') || t.starts_with('%') {
            continue;
        }
        let op = parse_stream_line(t, i + 1)?;
        let (u, v) = op.endpoints();
        max_id = max_id.max(u).max(v);
        ops.push(op);
    }

    // the base graph: an explicit file, or an empty graph sized by
    // --nodes / the stream's max id (matching edge-list inference)
    let base = match &base_path {
        Some(p) => io::load_auto(p, threads.max(1))?,
        None => {
            let n = match nodes_flag {
                Some(s) => s.parse::<usize>().map_err(|e| Error::msg(format!("bad --nodes: {e}")))?,
                None if ops.is_empty() => 0,
                None => max_id as usize + 1,
            };
            CsrGraph::empty(n)
        }
    };
    // degree ordering: relabel the base and map every op's endpoints
    // through the same permutation. The census is relabeling-invariant,
    // so the final table is byte-identical to a natural-order replay.
    let (base, ops) = if order == VertexOrdering::Degree {
        let r = Relabeling::degree_descending(&base);
        // ids outside the base stay as-is — the overlay rejects them
        // per-op either way, keeping the rejected count unchanged
        let m = |x: u32| {
            if (x as usize) < r.len() {
                r.map(x)
            } else {
                x
            }
        };
        let mapped: Vec<EdgeOp> = ops
            .iter()
            .map(|op| match *op {
                EdgeOp::Insert(u, v) => EdgeOp::Insert(m(u), m(v)),
                EdgeOp::Delete(u, v) => EdgeOp::Delete(m(u), m(v)),
            })
            .collect();
        let relabeled = relabel::relabel_with(&base, &r, threads.max(1));
        eprintln!("stream: degree-descending relabel applied to base + ops");
        (relabeled, mapped)
    } else {
        (base, ops)
    };
    let n = base.node_count();
    eprintln!(
        "stream: base n={} arcs={} | {} ops, batch={batch}, compact_every={compact_every}",
        n,
        base.arc_count(),
        ops.len()
    );
    if let Some(p) = sample_p {
        return stream_sampled(
            base,
            ops,
            p,
            batch,
            threads,
            pool_threads,
            compact_every,
            verify_every,
            oracle,
            oracle_interval,
            json_path,
        );
    }

    let exec = Executor::new(ExecutorConfig {
        workers: pool_threads,
        max_concurrent_jobs: 0,
        pin: PinMode::default(),
    });
    let t_seed = std::time::Instant::now();
    let mut sc = StreamingCensus::new(Arc::new(base));
    let seed_seconds = t_seed.elapsed().as_secs_f64();

    let verify = |sc: &StreamingCensus, what: &str| -> Result<()> {
        // the merged engine recomputes straight over the overlay view —
        // no compaction materialization on the verify path
        let want = merged::census(sc.overlay());
        if sc.census() != want {
            bail!("incremental census diverged from the full recompute ({what})");
        }
        Ok(())
    };

    let t0 = std::time::Instant::now();
    let mut batches = 0usize;
    for chunk in ops.chunks(batch) {
        sc.apply_batch(chunk, &exec, threads.max(1));
        batches += 1;
        if compact_every > 0 && batches % compact_every == 0 {
            sc.compact_with(threads.max(1));
        }
        if verify_every > 0 && batches % verify_every == 0 {
            verify(&sc, &format!("after batch {batches}"))?;
        }
    }
    let replay_seconds = t0.elapsed().as_secs_f64();

    let oracle_status = if oracle {
        verify(&sc, "final")?;
        eprintln!("stream oracle OK: live census == full merged recompute");
        "ok"
    } else {
        "skipped"
    };

    let s = sc.stats();
    println!(
        "# stream: ops={} applied={} no_ops={} rejected={} reclassified={} \
         batches={} rounds={} compactions={}",
        ops.len(),
        s.applied,
        s.no_ops,
        s.rejected,
        s.reclassified,
        s.batches,
        s.rounds,
        s.compactions
    );
    println!(
        "# stream timings: seed={seed_seconds:.3}s replay={replay_seconds:.3}s \
         ({:.0} ops/s) final_arcs={} edits={}",
        ops.len() as f64 / replay_seconds.max(1e-9),
        sc.overlay().arc_count(),
        sc.overlay().edit_count()
    );
    print!("{}", sc.census().table());

    if let Some(path) = json_path {
        let json = format!(
            concat!(
                "{{\"schema_version\":1,\"bench\":\"stream_replay\",\"nodes\":{},\"ops\":{},",
                "\"batch\":{},\"applied\":{},\"no_ops\":{},\"rejected\":{},",
                "\"reclassified\":{},\"batches\":{},\"rounds\":{},\"compactions\":{},",
                "\"seed_seconds\":{:.6},\"replay_seconds\":{:.6},\"ops_per_second\":{:.1},",
                "\"final_arcs\":{},\"oracle\":\"{}\"}}\n"
            ),
            n,
            ops.len(),
            batch,
            s.applied,
            s.no_ops,
            s.rejected,
            s.reclassified,
            s.batches,
            s.rounds,
            s.compactions,
            seed_seconds,
            replay_seconds,
            ops.len() as f64 / replay_seconds.max(1e-9),
            sc.overlay().arc_count(),
            oracle_status,
        );
        std::fs::write(&path, json)?;
        eprintln!("stream: wrote machine-readable results to {path}");
    }
    Ok(())
}

/// Band widening for the single-run `--oracle-interval` gate: one
/// deterministic replay is one realization, so the z-interval alone
/// would fail a fair fraction of honest runs. See
/// `SampledEstimate::covers` for the gate's semantics.
const ORACLE_BAND: f64 = 4.0;
const ORACLE_SLACK: f64 = 2.0;

/// `repro stream --sample-p P`: replay the stream through the sampled
/// incremental census. With `--oracle-interval`, an exact overlay is
/// maintained alongside and every class's widened confidence interval
/// must cover the exact end-state count, or the run exits nonzero.
#[allow(clippy::too_many_arguments)]
fn stream_sampled(
    base: CsrGraph,
    ops: Vec<EdgeOp>,
    p: f64,
    batch: usize,
    threads: usize,
    pool_threads: usize,
    compact_every: usize,
    verify_every: usize,
    oracle: bool,
    oracle_interval: bool,
    json_path: Option<String>,
) -> Result<()> {
    let n = base.node_count();
    let exec = Executor::new(ExecutorConfig {
        workers: pool_threads,
        max_concurrent_jobs: 0,
        pin: PinMode::default(),
    });
    let base = Arc::new(base);
    let t_seed = std::time::Instant::now();
    let mut sc = SampledCensus::new(base.clone(), p, DEFAULT_SAMPLE_SEED);
    let seed_seconds = t_seed.elapsed().as_secs_f64();
    eprintln!(
        "stream: fidelity=sampled:{p} kept_arcs={} of {}",
        sc.overlay().arc_count(),
        base.arc_count()
    );
    // the exact side of the interval oracle: a plain overlay replayed
    // op-by-op, recomputed once at the end (no incremental maintenance)
    let mut exact = oracle_interval.then(|| DeltaOverlay::new(base));

    let verify = |sc: &SampledCensus, what: &str| -> Result<()> {
        let want = merged::census(sc.overlay());
        if sc.sampled_census() != want {
            bail!("sampled incremental census diverged from the recompute ({what})");
        }
        Ok(())
    };

    let t0 = std::time::Instant::now();
    let mut batches = 0usize;
    for chunk in ops.chunks(batch) {
        sc.apply_batch(chunk, &exec, threads.max(1));
        if let Some(overlay) = exact.as_mut() {
            for op in chunk {
                overlay.apply(*op);
            }
        }
        batches += 1;
        if compact_every > 0 && batches % compact_every == 0 {
            sc.compact_with(threads.max(1));
        }
        if verify_every > 0 && batches % verify_every == 0 {
            verify(&sc, &format!("after batch {batches}"))?;
        }
    }
    let replay_seconds = t0.elapsed().as_secs_f64();

    if oracle {
        verify(&sc, "final")?;
        eprintln!("stream oracle OK: sampled live census == sampled recompute");
    }

    let est = sc.estimate();
    let s = sc.stats();
    println!(
        "# stream: fidelity=sampled:{p} ops={} applied={} sampled_out={} rejected={} \
         batches={} compactions={}",
        ops.len(),
        s.applied,
        sc.skipped(),
        s.rejected,
        s.batches,
        s.compactions
    );
    println!(
        "# stream timings: seed={seed_seconds:.3}s replay={replay_seconds:.3}s \
         ({:.0} ops/s) final_arcs={}",
        ops.len() as f64 / replay_seconds.max(1e-9),
        sc.overlay().arc_count()
    );
    print!("{}", est.census().table());
    print_intervals(&est);

    let mut missed = Vec::new();
    if let Some(overlay) = exact {
        let want = merged::census(&overlay);
        for &t in TriadType::ALL.iter() {
            if !est.covers(t, want[t], ORACLE_BAND, ORACLE_SLACK) {
                let c = est.class(t);
                eprintln!(
                    "interval miss {}: exact={} estimate={:.1} interval=[{:.1}, {:.1}]",
                    t.label(),
                    want[t],
                    c.estimate,
                    c.lo,
                    c.hi
                );
                missed.push(t.label());
            }
        }
        if missed.is_empty() {
            eprintln!("interval oracle OK: every class interval covers the exact count");
        }
    }

    if let Some(path) = json_path {
        let json = format!(
            concat!(
                "{{\"schema_version\":1,\"bench\":\"stream_replay_sampled\",\"nodes\":{},",
                "\"ops\":{},\"p\":{},\"applied\":{},\"sampled_out\":{},",
                "\"seed_seconds\":{:.6},\"replay_seconds\":{:.6},\"ops_per_second\":{:.1},",
                "\"interval_misses\":{},\"pass\":{}}}\n"
            ),
            n,
            ops.len(),
            p,
            s.applied,
            sc.skipped(),
            seed_seconds,
            replay_seconds,
            ops.len() as f64 / replay_seconds.max(1e-9),
            missed.len(),
            missed.is_empty(),
        );
        std::fs::write(&path, json)?;
        eprintln!("stream: wrote machine-readable results to {path}");
    }
    if !missed.is_empty() {
        bail!(
            "sampled interval oracle failed for {} class(es): {}",
            missed.len(),
            missed.join(",")
        );
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let artifacts = args.str_or("artifacts", "artifacts");
    let threads = args.get_or("threads", default_threads()).map_err(Error::msg)?;
    let trusted = args.flag("trusted");
    let engine = args.str_or("engine", "parallel");
    let pool_threads = args.get_or("pool-threads", 0usize).map_err(Error::msg)?;
    let max_jobs = args.get_or("max-jobs", 0usize).map_err(Error::msg)?;
    let job_workers = args.get_or("job-workers", 0usize).map_err(Error::msg)?;
    let max_request_nodes = args
        .get_or("max-request-nodes", CoordinatorConfig::default().max_request_nodes)
        .map_err(Error::msg)?;
    let listen = args.str_or("listen", "127.0.0.1:7333");
    let stdin_mode = args.flag("stdin");
    let workers = worker_pool_from(args)?;
    let reactor_threads = args.get_or("reactor-threads", 2usize).map_err(Error::msg)?;
    let max_conns = args.get_or("max-conns", 4096usize).map_err(Error::msg)?;
    let tenant_config = args.opt_str("tenant-config");
    let scan_backend = args.flag("scan-backend");
    let legacy_accept = args.flag("legacy-accept");
    let pin = parse_pin(args)?;
    args.reject_unknown().map_err(Error::msg)?;

    let coord = Arc::new(Coordinator::start(CoordinatorConfig {
        artifacts_dir: Some(PathBuf::from(artifacts)),
        sparse: ParallelConfig {
            threads,
            ..ParallelConfig::default()
        },
        trusted_mmap: trusted,
        engine,
        pool_threads,
        max_concurrent_jobs: max_jobs,
        job_workers,
        max_request_nodes,
        workers,
        pin,
        ..CoordinatorConfig::default()
    })?);
    eprintln!(
        "coordinator up: dense={} engine={} pool_workers={} job_workers={} max_jobs={} \
         distributed_workers={}",
        coord.dense_enabled(),
        coord.engine_name(),
        coord.executor().worker_count(),
        coord.job_worker_count(),
        if max_jobs == 0 {
            "unlimited".to_string()
        } else {
            max_jobs.to_string()
        },
        coord.worker_pool().len()
    );

    if stdin_mode {
        return serve_stdin(&coord);
    }

    if legacy_accept {
        // the thread-per-connection ablation path: same dispatch core,
        // no reactor, no admission control
        let server = CensusServer::bind(coord.clone(), listen.as_str())?;
        // machine-parseable: CI and scripts read the bound address off
        // stdout (std's stdout is line-buffered, so this flushes even piped)
        println!("listening on {}", server.local_addr());
        server.run()?;
    } else {
        let tenants = match &tenant_config {
            Some(path) => {
                let text = std::fs::read_to_string(path)
                    .with_context(|| format!("reading tenant config {path}"))?;
                TenantTable::parse_config(&text).map_err(Error::msg)?
            }
            None => TenantTable::default(),
        };
        let config = GatewayConfig {
            reactor_threads,
            max_conns,
            scan_backend,
            ..GatewayConfig::default()
        };
        let gateway = Gateway::bind(coord.clone(), listen.as_str(), tenants, config)?;
        eprintln!(
            "gateway up: reactors={reactor_threads} max_conns={max_conns} backend={}",
            if scan_backend { "scan" } else { "auto" }
        );
        println!("listening on {}", gateway.local_addr());
        gateway.run()?;
    }
    // shutdown received: new submissions are already rejected, so the
    // in-flight gauge only drains — let admitted jobs finish before the
    // process (and its job runners) goes away
    while coord.metrics().gauge("jobs_inflight") > 0 {
        std::thread::sleep(std::time::Duration::from_millis(25));
    }
    println!("{}", coord.metrics().render());
    Ok(())
}

/// Collect the distributed worker pool from `--workers a,b,c` and/or
/// `--workers-file FILE` (one `host:port` per line, `#` comments and
/// blank lines skipped). Both may be given; the lists concatenate.
fn worker_pool_from(args: &Args) -> Result<Vec<String>> {
    let mut pool = Vec::new();
    if let Some(list) = args.opt_str("workers") {
        pool.extend(
            list.split(',')
                .map(str::trim)
                .filter(|a| !a.is_empty())
                .map(String::from),
        );
    }
    if let Some(file) = args.opt_str("workers-file") {
        let text = std::fs::read_to_string(&file)
            .with_context(|| format!("reading workers file {file}"))?;
        pool.extend(
            text.lines()
                .map(str::trim)
                .filter(|a| !a.is_empty() && !a.starts_with('#'))
                .map(String::from),
        );
    }
    Ok(pool)
}

/// `repro worker` — one distributed census worker: a sparse-only
/// coordinator (no dense artifacts, no worker pool of its own) behind
/// the standard TCP server. The planning coordinator ships it
/// sub-requests carrying a `shard` vertex range; path graph sources are
/// mmapped locally by each worker, so the graph bytes never cross the
/// wire. Prints `listening on HOST:PORT` for harnesses to parse.
fn cmd_worker(args: &Args) -> Result<()> {
    let listen = args.str_or("listen", "127.0.0.1:0");
    let threads = args.get_or("threads", default_threads()).map_err(Error::msg)?;
    let pool_threads = args.get_or("pool-threads", 0usize).map_err(Error::msg)?;
    let max_jobs = args.get_or("max-jobs", 0usize).map_err(Error::msg)?;
    let job_workers = args.get_or("job-workers", 0usize).map_err(Error::msg)?;
    let trusted = args.flag("trusted");
    let max_request_nodes = args
        .get_or("max-request-nodes", CoordinatorConfig::default().max_request_nodes)
        .map_err(Error::msg)?;
    let pin = parse_pin(args)?;
    args.reject_unknown().map_err(Error::msg)?;

    let coord = Arc::new(Coordinator::start(CoordinatorConfig {
        artifacts_dir: None,
        sparse: ParallelConfig {
            threads,
            ..ParallelConfig::default()
        },
        trusted_mmap: trusted,
        pool_threads,
        max_concurrent_jobs: max_jobs,
        job_workers,
        max_request_nodes,
        pin,
        ..CoordinatorConfig::default()
    })?);
    eprintln!(
        "worker up: pool_workers={} job_workers={}",
        coord.executor().worker_count(),
        coord.job_worker_count()
    );
    let server = CensusServer::bind(coord.clone(), listen.as_str())?;
    println!("listening on {}", server.local_addr());
    server.run()?;
    while coord.metrics().gauge("jobs_inflight") > 0 {
        std::thread::sleep(std::time::Duration::from_millis(25));
    }
    println!("{}", coord.metrics().render());
    Ok(())
}

/// The legacy stdin loop (`serve --stdin`): one graph file path per
/// line. A bad path logs one structured JSON error line on stderr and
/// the loop continues — a malformed request must never take the server
/// down.
fn serve_stdin(coord: &Coordinator) -> Result<()> {
    eprintln!(
        "stdin mode: send one graph path per line (edge list, TRIADIC1 or mmap-served TRIADIC2)"
    );
    let stdin = std::io::stdin();
    for line in stdin.lock().lines() {
        let path = line?;
        let path = path.trim();
        if path.is_empty() {
            continue;
        }
        match coord.census_path(path) {
            Ok(out) => {
                println!("# {path} route={:?} {:.3}s", out.route, out.seconds);
                print!("{}", out.census.table());
            }
            Err(e) => {
                // the stdin loop only loads-and-runs, and the sparse run
                // path is infallible, so load failures are what lands here
                coord.metrics().inc("serve_stdin_errors_total", 1);
                let err = WireError::new(ErrorCode::GraphLoad, format!("{e:#}"));
                let report = Json::Obj(vec![
                    ("path".into(), Json::from(path)),
                    ("error".into(), err.to_json()),
                ]);
                eprintln!("{report}");
            }
        }
    }
    println!("{}", coord.metrics().render());
    Ok(())
}

/// Build a census request from `client` flags (path source via
/// `--input`, generator source via `--graph`/`--nodes`/`--seed`).
fn client_request(args: &Args) -> Result<CensusRequest> {
    let mut req = if let Some(input) = args.opt_str("input") {
        CensusRequest::path(input)
    } else {
        let name = args.str_or("graph", "patents");
        let nodes = args.get_or("nodes", 10_000usize).map_err(Error::msg)?;
        let mut r = CensusRequest::generator(name, nodes);
        if let Some(seed) = args.opt_str("seed") {
            r = r.seed(seed.parse().map_err(|e| Error::msg(format!("bad --seed: {e}")))?);
        }
        r
    };
    if let Some(engine) = args.opt_str("engine") {
        req = req.engine(engine);
    }
    if let Some(threads) = args.opt_str("threads") {
        let t = threads
            .parse()
            .map_err(|e| Error::msg(format!("bad --threads: {e}")))?;
        req = req.threads(t);
    }
    if let Some(policy) = args.opt_str("policy") {
        req = req.policy(Policy::parse(&policy).map_err(Error::msg)?);
    }
    if let Some(order) = args.opt_str("order") {
        req = req.ordering(VertexOrdering::parse(&order).map_err(Error::msg)?);
    }
    if let Some(classes) = args.opt_str("classes") {
        let mut parsed = Vec::new();
        for label in classes.split(',').filter(|s| !s.is_empty()) {
            parsed.push(
                TriadType::from_label(label)
                    .with_context(|| format!("unknown triad class {label:?}"))?,
            );
        }
        req = req.classes(parsed);
    }
    Ok(req)
}

fn print_response(resp: &CensusResponse, raw: bool) {
    if raw {
        println!("{}", resp.to_json());
        return;
    }
    println!(
        "# job={} engine={} route={} order={} source={} nodes={} arcs={} seconds={:.3}",
        resp.job,
        resp.provenance.engine,
        resp.provenance.route,
        resp.provenance.ordering,
        resp.provenance.source,
        resp.provenance.nodes,
        resp.provenance.arcs,
        resp.seconds
    );
    if let Some(s) = &resp.stats {
        println!(
            "# stats: seats={} chunks={} items={} wall={:.3}s imbalance={:.2}",
            s.seats, s.chunks, s.items, s.wall_seconds, s.imbalance
        );
    }
    for (t, c) in resp.selected_counts() {
        println!("{:>5}  {:>16}", t.label(), c);
    }
}

fn cmd_client(args: &Args) -> Result<()> {
    let addr = args.str_or("addr", "127.0.0.1:7333");
    let verb = args.str_or("verb", "census");
    let raw = args.flag("raw");

    let mut client = TriadicClient::connect(addr.as_str()).map_err(Error::msg)?;
    match verb.as_str() {
        "status" => {
            args.reject_unknown().map_err(Error::msg)?;
            println!("{}", client.status().map_err(Error::msg)?);
        }
        "metrics" => {
            args.reject_unknown().map_err(Error::msg)?;
            print!("{}", client.metrics_text().map_err(Error::msg)?);
        }
        "shutdown" => {
            args.reject_unknown().map_err(Error::msg)?;
            client.shutdown().map_err(Error::msg)?;
            println!("server stopping");
        }
        "poll" => {
            let job = args.get_or("job", 0u64).map_err(Error::msg)?;
            args.reject_unknown().map_err(Error::msg)?;
            println!("{}", client.poll(job).map_err(Error::msg)?.to_json());
        }
        "cancel" => {
            let job = args.get_or("job", 0u64).map_err(Error::msg)?;
            args.reject_unknown().map_err(Error::msg)?;
            let cancelled = client.cancel(job).map_err(Error::msg)?;
            println!("job {job} cancelled={cancelled}");
        }
        "census" => {
            let req = client_request(args)?;
            args.reject_unknown().map_err(Error::msg)?;
            let report = client.submit(&req).map_err(Error::msg)?;
            let job = report.job;
            eprintln!("submitted job {job} ({})", report.state.as_str());
            // poll to completion to exercise the job lifecycle end to
            // end; the final wait returns immediately on a terminal job
            let mut last = report.state;
            while !last.is_terminal() {
                std::thread::sleep(std::time::Duration::from_millis(25));
                let state = client.poll(job).map_err(Error::msg)?.state;
                if state != last {
                    eprintln!("job {job}: {}", state.as_str());
                    last = state;
                }
            }
            if last == JobStateKind::Cancelled {
                bail!("job {job} was cancelled server-side");
            }
            let resp = client.wait(job).map_err(Error::msg)?;
            print_response(&resp, raw);
        }
        other => {
            bail!("unknown client verb {other:?} (census|status|metrics|poll|cancel|shutdown)")
        }
    }
    Ok(())
}
