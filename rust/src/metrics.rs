//! Lightweight process metrics: monotonic counters and duration
//! histograms with a text exposition format (Prometheus-style lines),
//! used by the coordinator service and the figures harness.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Fixed histogram buckets (seconds) for latency tracking.
const BUCKETS: [f64; 12] = [
    1e-6, 1e-5, 1e-4, 5e-4, 1e-3, 5e-3, 1e-2, 5e-2, 1e-1, 5e-1, 1.0, 5.0,
];

/// A labelled duration histogram.
#[derive(Debug, Default)]
pub struct Histogram {
    counts: [AtomicU64; 13], // 12 buckets + overflow
    sum_micros: AtomicU64,
    total: AtomicU64,
}

impl Histogram {
    /// Record one observation in seconds.
    pub fn observe(&self, seconds: f64) {
        let idx = BUCKETS.partition_point(|&b| b < seconds);
        self.counts[idx].fetch_add(1, Ordering::Relaxed);
        self.sum_micros
            .fetch_add((seconds * 1e6) as u64, Ordering::Relaxed);
        self.total.fetch_add(1, Ordering::Relaxed);
    }

    /// Number of observations.
    pub fn count(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    /// Mean observation in seconds.
    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum_micros.load(Ordering::Relaxed) as f64 / 1e6 / n as f64
        }
    }

    /// Approximate quantile from the bucket boundaries.
    pub fn quantile(&self, q: f64) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        let want = (q * n as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            acc += c.load(Ordering::Relaxed);
            if acc >= want {
                return if i < BUCKETS.len() { BUCKETS[i] } else { f64::INFINITY };
            }
        }
        f64::INFINITY
    }
}

/// Process-wide metrics registry.
#[derive(Debug, Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, u64>>,
    gauges: Mutex<BTreeMap<String, i64>>,
    histograms: Mutex<BTreeMap<String, std::sync::Arc<Histogram>>>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Increment a named counter.
    pub fn inc(&self, name: &str, by: u64) {
        *self.counters.lock().unwrap().entry(name.to_string()).or_insert(0) += by;
    }

    /// Read a counter.
    pub fn get(&self, name: &str) -> u64 {
        self.counters.lock().unwrap().get(name).copied().unwrap_or(0)
    }

    /// Move a named gauge by a (signed) delta — up-and-down quantities
    /// like in-flight jobs or open connections; counters stay monotonic.
    pub fn add_gauge(&self, name: &str, delta: i64) {
        *self.gauges.lock().unwrap().entry(name.to_string()).or_insert(0) += delta;
    }

    /// Set a named gauge to an absolute value.
    pub fn set_gauge(&self, name: &str, value: i64) {
        *self.gauges.lock().unwrap().entry(name.to_string()).or_insert(0) = value;
    }

    /// Raise a gauge to `value` if it is below it — high-water marks
    /// like peak open connections, updated atomically under the
    /// registry lock so racing reactor threads cannot lower the peak.
    pub fn set_gauge_max(&self, name: &str, value: i64) {
        let mut gauges = self.gauges.lock().unwrap();
        let v = gauges.entry(name.to_string()).or_insert(0);
        *v = (*v).max(value);
    }

    /// Read a gauge.
    pub fn gauge(&self, name: &str) -> i64 {
        self.gauges.lock().unwrap().get(name).copied().unwrap_or(0)
    }

    /// Fetch (or create) a histogram handle.
    pub fn histogram(&self, name: &str) -> std::sync::Arc<Histogram> {
        self.histograms
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    /// Time a closure into a histogram.
    pub fn time<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        let h = self.histogram(name);
        let t = Instant::now();
        let out = f();
        h.observe(t.elapsed().as_secs_f64());
        out
    }

    /// Prometheus-style text exposition.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (k, v) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("{k} {v}\n"));
        }
        for (k, v) in self.gauges.lock().unwrap().iter() {
            out.push_str(&format!("{k} {v}\n"));
        }
        for (k, h) in self.histograms.lock().unwrap().iter() {
            out.push_str(&format!("{k}_count {}\n", h.count()));
            out.push_str(&format!("{k}_mean_seconds {:.6}\n", h.mean()));
            out.push_str(&format!("{k}_p50_seconds {:.6}\n", h.quantile(0.5)));
            out.push_str(&format!("{k}_p99_seconds {:.6}\n", h.quantile(0.99)));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters() {
        let m = Metrics::new();
        m.inc("requests_total", 1);
        m.inc("requests_total", 2);
        assert_eq!(m.get("requests_total"), 3);
        assert_eq!(m.get("missing"), 0);
    }

    #[test]
    fn histogram_stats() {
        let h = Histogram::default();
        for _ in 0..100 {
            h.observe(0.002);
        }
        assert_eq!(h.count(), 100);
        assert!((h.mean() - 0.002).abs() < 1e-4);
        let p50 = h.quantile(0.5);
        assert!(p50 >= 0.002 && p50 <= 0.01, "p50={p50}");
    }

    #[test]
    fn time_records() {
        let m = Metrics::new();
        let v = m.time("op", || 42);
        assert_eq!(v, 42);
        assert_eq!(m.histogram("op").count(), 1);
    }

    #[test]
    fn render_contains_everything() {
        let m = Metrics::new();
        m.inc("a_total", 5);
        m.add_gauge("inflight", 2);
        m.histogram("lat").observe(0.1);
        let text = m.render();
        assert!(text.contains("a_total 5"));
        assert!(text.contains("inflight 2"));
        assert!(text.contains("lat_count 1"));
    }

    #[test]
    fn gauges_move_both_ways() {
        let m = Metrics::new();
        m.add_gauge("inflight", 3);
        m.add_gauge("inflight", -2);
        assert_eq!(m.gauge("inflight"), 1);
        m.set_gauge("inflight", 10);
        assert_eq!(m.gauge("inflight"), 10);
        assert_eq!(m.gauge("missing"), 0);
    }

    #[test]
    fn gauge_max_is_a_high_water_mark() {
        let m = Metrics::new();
        m.set_gauge_max("peak", 5);
        m.set_gauge_max("peak", 3);
        assert_eq!(m.gauge("peak"), 5);
        m.set_gauge_max("peak", 9);
        assert_eq!(m.gauge("peak"), 9);
    }
}
