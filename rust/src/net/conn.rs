//! Per-connection buffering state machines shared by the gateway and
//! the legacy accept loop: bounded frame accumulation with protocol
//! sniffing on the read side, a drainable write buffer with partial
//! write tracking on the write side, and the bounded blocking line
//! reader the legacy thread-per-connection server uses.
//!
//! Everything here is transport-free — the structs never own a socket,
//! they only consume and produce byte slices — which is what makes the
//! partial/pipelined/oversized frame behavior unit-testable without a
//! reactor or even a TCP connection.

use std::collections::VecDeque;
use std::io::{BufRead, Write};
use std::time::Duration;

use super::http::{self, HttpRequest};

/// Slow-client protection knobs, enforced by both transports.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConnLimits {
    /// A connection that sends no bytes for this long is dropped.
    pub idle_timeout: Duration,
    /// Largest frame (JSON line, or HTTP headers + body) the server
    /// buffers before answering `bad_request` and disconnecting.
    pub max_frame_bytes: usize,
}

impl Default for ConnLimits {
    fn default() -> ConnLimits {
        ConnLimits {
            idle_timeout: Duration::from_secs(60),
            // inline graph sources are the big payloads; 8 MiB covers
            // ~300k inline arcs while still bounding a hostile peer
            max_frame_bytes: 8 * 1024 * 1024,
        }
    }
}

/// Outcome of one bounded line read on the legacy blocking path.
pub enum BoundedLine {
    /// A complete line (newline stripped, may be empty).
    Line(String),
    /// The line outgrew the limit before a newline arrived.
    TooLong,
    /// Clean end of stream.
    Eof,
}

/// Read one newline-terminated line without ever buffering more than
/// `max` bytes — the blocking-path twin of [`FrameBuffer`]'s cap. A
/// final unterminated line before EOF is still returned (matching
/// `BufRead::lines`); invalid UTF-8 is replaced rather than fatal,
/// leaving frame validation to the protocol decoder.
pub fn read_bounded_line(r: &mut impl BufRead, max: usize) -> std::io::Result<BoundedLine> {
    let mut acc: Vec<u8> = Vec::new();
    loop {
        let available = r.fill_buf()?;
        if available.is_empty() {
            return Ok(if acc.is_empty() {
                BoundedLine::Eof
            } else {
                BoundedLine::Line(strip_cr(acc))
            });
        }
        match available.iter().position(|&b| b == b'\n') {
            Some(i) => {
                if acc.len() + i > max {
                    return Ok(BoundedLine::TooLong);
                }
                acc.extend_from_slice(&available[..i]);
                r.consume(i + 1);
                return Ok(BoundedLine::Line(strip_cr(acc)));
            }
            None => {
                let n = available.len();
                if acc.len() + n > max {
                    return Ok(BoundedLine::TooLong);
                }
                acc.extend_from_slice(available);
                r.consume(n);
            }
        }
    }
}

fn strip_cr(mut bytes: Vec<u8>) -> String {
    if bytes.last() == Some(&b'\r') {
        bytes.pop();
    }
    String::from_utf8_lossy(&bytes).into_owned()
}

/// The protocol a connection turned out to speak, decided by its first
/// non-whitespace byte and sticky for the connection's lifetime.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Protocol {
    /// Nothing received yet.
    Undecided,
    /// Newline-delimited JSON frames (first byte `{`).
    Jsonl,
    /// HTTP/1.1 (first byte an ASCII letter — a method name).
    Http,
}

/// One decoded inbound frame.
#[derive(Debug)]
pub enum FrameEvent {
    /// A complete JSON line (newline stripped).
    Jsonl(String),
    /// A complete HTTP request (headers + body).
    Http(HttpRequest),
}

/// Why a connection must be answered with `bad_request` and closed.
#[derive(Debug, PartialEq, Eq)]
pub enum FrameError {
    /// The buffered frame outgrew the limit without completing.
    TooBig { limit: usize },
    /// The bytes are recognizably HTTP but malformed or unsupported.
    BadHttp(String),
}

/// Read-side state machine for one nonblocking connection: bytes go in
/// via [`FrameBuffer::extend`], complete frames come out via
/// [`FrameBuffer::next`]. Handles partial frames (bytes wait in the
/// buffer), pipelined frames (each `next` call yields one), protocol
/// sniffing, and the max-frame cap.
#[derive(Debug)]
pub struct FrameBuffer {
    buf: VecDeque<u8>,
    max: usize,
    protocol: Protocol,
}

impl FrameBuffer {
    pub fn new(max: usize) -> FrameBuffer {
        FrameBuffer {
            buf: VecDeque::new(),
            max,
            protocol: Protocol::Undecided,
        }
    }

    /// Append received bytes. Growth past the cap is reported by the
    /// next [`FrameBuffer::next`] call, not here, so a frame completed
    /// by the same read is still honored.
    pub fn extend(&mut self, bytes: &[u8]) {
        self.buf.extend(bytes.iter().copied());
    }

    /// The sniffed protocol (sticky once decided).
    pub fn protocol(&self) -> Protocol {
        self.protocol
    }

    /// Buffered-but-unconsumed byte count.
    pub fn pending_bytes(&self) -> usize {
        self.buf.len()
    }

    /// Extract the next complete frame, if one is buffered. `Ok(None)`
    /// means "need more bytes".
    pub fn next(&mut self) -> Result<Option<FrameEvent>, FrameError> {
        // inter-frame whitespace (blank lines, trailing CRLF after an
        // HTTP body) is meaningless in both protocols
        while matches!(self.buf.front(), Some(b' ' | b'\t' | b'\r' | b'\n')) {
            self.buf.pop_front();
        }
        if self.buf.is_empty() {
            return Ok(None);
        }
        if self.protocol == Protocol::Undecided {
            self.protocol = match self.buf.front() {
                Some(b'{') => Protocol::Jsonl,
                Some(b) if b.is_ascii_alphabetic() => Protocol::Http,
                // not a frame either protocol could start — let the
                // JSON decoder produce the structured bad_frame error
                _ => Protocol::Jsonl,
            };
        }
        match self.protocol {
            Protocol::Jsonl => self.next_jsonl(),
            Protocol::Http => self.next_http(),
            Protocol::Undecided => unreachable!("sniffed above"),
        }
    }

    fn next_jsonl(&mut self) -> Result<Option<FrameEvent>, FrameError> {
        match self.buf.iter().position(|&b| b == b'\n') {
            Some(i) => {
                let mut line: Vec<u8> = self.buf.drain(..=i).collect();
                line.pop(); // the newline
                if line.last() == Some(&b'\r') {
                    line.pop();
                }
                Ok(Some(FrameEvent::Jsonl(
                    String::from_utf8_lossy(&line).into_owned(),
                )))
            }
            None if self.buf.len() > self.max => Err(FrameError::TooBig { limit: self.max }),
            None => Ok(None),
        }
    }

    fn next_http(&mut self) -> Result<Option<FrameEvent>, FrameError> {
        self.buf.make_contiguous();
        let (head, _) = self.buf.as_slices();
        match http::parse_request(head, self.max) {
            Ok(Some((request, consumed))) => {
                self.buf.drain(..consumed);
                Ok(Some(FrameEvent::Http(request)))
            }
            Ok(None) if self.buf.len() > self.max => Err(FrameError::TooBig { limit: self.max }),
            Ok(None) => Ok(None),
            Err(e) => Err(FrameError::BadHttp(e)),
        }
    }
}

/// Write-side buffer for one nonblocking connection: replies are queued
/// with [`WriteBuffer::push`] and drained by [`WriteBuffer::flush_to`]
/// as the socket accepts them, tracking partial writes across calls.
#[derive(Debug, Default)]
pub struct WriteBuffer {
    buf: Vec<u8>,
    pos: usize,
}

impl WriteBuffer {
    pub fn new() -> WriteBuffer {
        WriteBuffer::default()
    }

    pub fn push(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Bytes still waiting to reach the socket.
    pub fn len(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Write as much as the sink accepts. Returns `Ok(true)` when the
    /// buffer fully drained, `Ok(false)` on a partial write
    /// (`WouldBlock` is a partial write, not an error).
    pub fn flush_to(&mut self, w: &mut impl Write) -> std::io::Result<bool> {
        while self.pos < self.buf.len() {
            match w.write(&self.buf[self.pos..]) {
                Ok(0) => {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::WriteZero,
                        "socket accepted zero bytes",
                    ))
                }
                Ok(n) => self.pos += n,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
        if self.pos == self.buf.len() {
            self.buf.clear();
            self.pos = 0;
            return Ok(true);
        }
        // reclaim drained prefix once it dominates the allocation
        if self.pos > 64 * 1024 && self.pos * 2 > self.buf.len() {
            self.buf.drain(..self.pos);
            self.pos = 0;
        }
        Ok(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::BufReader;

    fn line_of(ev: Option<FrameEvent>) -> String {
        match ev {
            Some(FrameEvent::Jsonl(l)) => l,
            other => panic!("expected a jsonl frame, got {other:?}"),
        }
    }

    #[test]
    fn partial_frame_waits_for_the_rest() {
        let mut fb = FrameBuffer::new(1024);
        fb.extend(b"{\"id\":1,\"verb\"");
        assert!(matches!(fb.next(), Ok(None)));
        fb.extend(b":\"status\"}\n");
        assert_eq!(line_of(fb.next().unwrap()), "{\"id\":1,\"verb\":\"status\"}");
        assert!(matches!(fb.next(), Ok(None)));
    }

    #[test]
    fn pipelined_frames_come_out_one_per_call() {
        let mut fb = FrameBuffer::new(1024);
        fb.extend(b"{\"a\":1}\n{\"b\":2}\r\n{\"c\":3}\n");
        assert_eq!(line_of(fb.next().unwrap()), "{\"a\":1}");
        assert_eq!(line_of(fb.next().unwrap()), "{\"b\":2}");
        assert_eq!(line_of(fb.next().unwrap()), "{\"c\":3}");
        assert!(matches!(fb.next(), Ok(None)));
    }

    #[test]
    fn oversized_frame_is_rejected_not_buffered_forever() {
        let mut fb = FrameBuffer::new(64);
        fb.extend(&vec![b'{'; 100]);
        assert!(matches!(fb.next(), Err(FrameError::TooBig { limit: 64 })));
    }

    #[test]
    fn frame_completed_by_the_overflowing_read_still_parses() {
        let mut fb = FrameBuffer::new(8);
        fb.extend(b"{\"a\":123}\n"); // 9 bytes + newline, cap is 8
        // a *complete* line is extracted regardless of the cap — the cap
        // bounds waiting-for-more, not finished frames one read brought
        assert_eq!(line_of(fb.next().unwrap()), "{\"a\":123}");
    }

    #[test]
    fn blank_lines_between_frames_are_skipped() {
        let mut fb = FrameBuffer::new(1024);
        fb.extend(b"\r\n  \n{\"a\":1}\n\n");
        assert_eq!(line_of(fb.next().unwrap()), "{\"a\":1}");
        assert!(matches!(fb.next(), Ok(None)));
    }

    #[test]
    fn sniffs_http_and_yields_a_request() {
        let mut fb = FrameBuffer::new(1024);
        fb.extend(b"GET /v1/status HTTP/1.1\r\nHost: x\r\n\r\n");
        assert_eq!(fb.protocol(), Protocol::Undecided);
        match fb.next().unwrap() {
            Some(FrameEvent::Http(req)) => {
                assert_eq!(req.method, "GET");
                assert_eq!(req.path, "/v1/status");
            }
            other => panic!("expected an http frame, got {other:?}"),
        }
        assert_eq!(fb.protocol(), Protocol::Http);
    }

    #[test]
    fn partial_http_headers_wait_then_complete_with_body() {
        let mut fb = FrameBuffer::new(4096);
        fb.extend(b"POST /v1/census HTTP/1.1\r\nContent-Length: 7\r\n");
        assert!(matches!(fb.next(), Ok(None)));
        fb.extend(b"\r\n{\"x\"");
        assert!(matches!(fb.next(), Ok(None))); // body still short
        fb.extend(b":1}");
        match fb.next().unwrap() {
            Some(FrameEvent::Http(req)) => assert_eq!(req.body, b"{\"x\":1}"),
            other => panic!("expected an http frame, got {other:?}"),
        }
    }

    #[test]
    fn pipelined_http_requests_on_one_connection() {
        let mut fb = FrameBuffer::new(4096);
        fb.extend(b"GET /metrics HTTP/1.1\r\n\r\nGET /v1/status HTTP/1.1\r\n\r\n");
        let paths: Vec<String> = (0..2)
            .map(|_| match fb.next().unwrap() {
                Some(FrameEvent::Http(req)) => req.path,
                other => panic!("expected an http frame, got {other:?}"),
            })
            .collect();
        assert_eq!(paths, ["/metrics", "/v1/status"]);
    }

    #[test]
    fn bounded_line_reader_matches_lines_semantics() {
        let mut r = BufReader::new(&b"alpha\nbeta\r\ngamma"[..]);
        assert!(matches!(read_bounded_line(&mut r, 64), Ok(BoundedLine::Line(l)) if l == "alpha"));
        assert!(matches!(read_bounded_line(&mut r, 64), Ok(BoundedLine::Line(l)) if l == "beta"));
        // final unterminated line still comes back, then clean EOF
        assert!(matches!(read_bounded_line(&mut r, 64), Ok(BoundedLine::Line(l)) if l == "gamma"));
        assert!(matches!(read_bounded_line(&mut r, 64), Ok(BoundedLine::Eof)));
    }

    #[test]
    fn bounded_line_reader_stops_at_the_cap() {
        let big = vec![b'x'; 100];
        let mut r = BufReader::new(&big[..]);
        assert!(matches!(read_bounded_line(&mut r, 64), Ok(BoundedLine::TooLong)));
    }

    #[test]
    fn write_buffer_tracks_partial_writes() {
        struct Trickle(Vec<u8>);
        impl Write for Trickle {
            fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
                let n = buf.len().min(3);
                self.0.extend_from_slice(&buf[..n]);
                if n < buf.len() {
                    // simulate the kernel buffer filling after n bytes
                    Ok(n)
                } else {
                    Ok(n)
                }
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }
        let mut wb = WriteBuffer::new();
        wb.push(b"0123456789");
        let mut sink = Trickle(Vec::new());
        assert!(wb.flush_to(&mut sink).unwrap());
        assert_eq!(sink.0, b"0123456789");
        assert!(wb.is_empty());
    }

    #[test]
    fn write_buffer_resumes_after_would_block() {
        struct BlockAfter(usize, Vec<u8>);
        impl Write for BlockAfter {
            fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
                if self.0 == 0 {
                    return Err(std::io::Error::new(std::io::ErrorKind::WouldBlock, "full"));
                }
                let n = buf.len().min(self.0);
                self.0 -= n;
                self.1.extend_from_slice(&buf[..n]);
                Ok(n)
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }
        let mut wb = WriteBuffer::new();
        wb.push(b"hello world");
        let mut sink = BlockAfter(4, Vec::new());
        assert!(!wb.flush_to(&mut sink).unwrap());
        assert_eq!(wb.len(), 7);
        sink.0 = 64;
        assert!(wb.flush_to(&mut sink).unwrap());
        assert_eq!(sink.1, b"hello world");
    }
}
