//! The nonblocking multi-tenant serving gateway: a small fixed set of
//! reactor threads multiplexes every connection — newline-JSON and
//! HTTP/1.1 on the same listener — through readiness polling
//! ([`super::reactor`]), per-connection state machines
//! ([`super::conn`]) and per-tenant admission ([`super::tenant`]).
//!
//! Architecture, per reactor thread (no cross-thread handoff at all):
//!
//! ```text
//!   listener clone (nonblocking, SO_REUSE via try_clone)
//!        │ accept
//!        ▼
//!   Poller (epoll / scan) ── readiness ──▶ Conn
//!        ▲                                 │ FrameBuffer → sniff
//!        │ ~50ms tick                      │  ├─ jsonl frame ─▶ decode
//!   parked waits, tenant                   │  └─ http request ─▶ route
//!   releases, idle sweep                   ▼
//!                              admission (token bucket, inflight)
//!                                          │ Coordinator::submit
//!                                          ▼
//!                              WriteBuffer ─▶ socket (backpressure)
//! ```
//!
//! Blocking verbs never block a reactor: `wait` (and every HTTP
//! census, which is synchronous by nature) *parks* the connection on
//! its [`JobHandle`] and is resolved on a later tick; frames that
//! arrive behind a parked wait stay buffered so responses keep strict
//! request order, which the [`TriadicClient`] protocol requires.
//!
//! Load shedding is always structured: over-quota tenants get
//! `rate_limited` on a healthy connection, a full gateway answers the
//! first decoded frame with `overloaded` (in the peer's own protocol)
//! and closes after the reply — never a silent drop.
//!
//! [`TriadicClient`]: crate::coordinator::TriadicClient

use std::collections::HashMap;
use std::io::{self, Read};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::os::unix::io::AsRawFd;
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::conn::{ConnLimits, FrameBuffer, FrameError, FrameEvent, Protocol, WriteBuffer};
use super::http::{self, HttpRequest};
use super::reactor::{Event, Interest, Poller};
use super::tenant::{TenantTable, DEFAULT_TENANT};
use crate::coordinator::protocol::{
    CensusRequest, ErrorCode, Json, JobStateKind, RequestFrame, ResponseFrame, Verb, WireError,
};
use crate::coordinator::server::{execute, oversize_error, salvage_id, ServiceState};
use crate::coordinator::service::{Coordinator, JobHandle};
use crate::error::{Context, Result};
use crate::metrics::Metrics;

/// The listener's polling token; connection tokens are their fds,
/// which can never collide with this.
const LISTENER_TOKEN: u64 = u64::MAX;

/// Reactor tick: the poll timeout, and therefore the cadence of
/// parked-wait resolution, tenant inflight release, idle sweeps and
/// shutdown-latch checks.
const TICK: Duration = Duration::from_millis(50);

/// Gateway tuning. `Default` is what `repro serve` uses out of the box.
#[derive(Debug, Clone, Copy)]
pub struct GatewayConfig {
    /// Reactor threads; each owns its own poller and listener clone.
    pub reactor_threads: usize,
    /// Open-connection cap across all reactor threads; connections
    /// beyond it are answered `overloaded` and closed.
    pub max_conns: usize,
    /// Slow-client protection (idle timeout, max frame bytes).
    pub limits: ConnLimits,
    /// Per-connection outbound buffer level above which the gateway
    /// stops reading from that connection until the peer drains.
    pub max_write_buffer: usize,
    /// Force the portable scan poller even where epoll is available.
    pub scan_backend: bool,
}

impl Default for GatewayConfig {
    fn default() -> GatewayConfig {
        GatewayConfig {
            reactor_threads: 2,
            max_conns: 4096,
            limits: ConnLimits::default(),
            max_write_buffer: 4 * 1024 * 1024,
            scan_backend: false,
        }
    }
}

/// The gateway: bind, then [`Gateway::run`] until a client sends the
/// `shutdown` verb.
pub struct Gateway {
    listener: TcpListener,
    state: Arc<ServiceState>,
    tenants: Arc<TenantTable>,
    config: GatewayConfig,
    addr: SocketAddr,
}

impl Gateway {
    pub fn bind<A: ToSocketAddrs + std::fmt::Debug>(
        coordinator: Arc<Coordinator>,
        addr: A,
        tenants: TenantTable,
        config: GatewayConfig,
    ) -> Result<Gateway> {
        let listener =
            TcpListener::bind(&addr).with_context(|| format!("binding gateway {addr:?}"))?;
        let local = listener.local_addr().context("reading bound address")?;
        Ok(Gateway {
            listener,
            state: Arc::new(ServiceState::new(coordinator)),
            tenants: Arc::new(tenants),
            config,
            addr: local,
        })
    }

    /// The actually-bound address (resolves `:0` to the assigned port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Run the reactor threads; returns once a `shutdown` verb has been
    /// acked and every thread has drained out.
    pub fn run(self) -> Result<()> {
        // one fd per connection: lift the conservative default soft
        // limit the way long-running servers conventionally do
        let _ = super::reactor::raise_nofile_limit();
        let threads = self.config.reactor_threads.max(1);
        let per_thread_conns = (self.config.max_conns / threads).max(1);
        let mut joins = Vec::new();
        for i in 1..threads {
            let listener = self.listener.try_clone().context("cloning gateway listener")?;
            let state = self.state.clone();
            let tenants = self.tenants.clone();
            let config = self.config;
            let handle = std::thread::Builder::new()
                .name(format!("gateway-reactor-{i}"))
                .spawn(move || reactor_loop(listener, state, tenants, config, per_thread_conns))
                .context("spawning reactor thread")?;
            joins.push(handle);
        }
        reactor_loop(
            self.listener,
            self.state.clone(),
            self.tenants.clone(),
            self.config,
            per_thread_conns,
        );
        for handle in joins {
            let _ = handle.join();
        }
        Ok(())
    }
}

/// Why a connection is parked: the reply it owes, held until the job
/// turns terminal.
enum Parked {
    /// A `wait` verb; reply is the job report keyed by the frame id.
    Jsonl { id: u64, handle: JobHandle },
    /// A `POST /v1/census`; reply is an HTTP response with the report.
    Http { handle: JobHandle },
}

impl Parked {
    fn handle(&self) -> &JobHandle {
        match self {
            Parked::Jsonl { handle, .. } => handle,
            Parked::Http { handle } => handle,
        }
    }
}

/// One multiplexed connection's full state.
struct Conn {
    stream: TcpStream,
    token: u64,
    frames: FrameBuffer,
    out: WriteBuffer,
    last_activity: Instant,
    parked: Option<Parked>,
    interest: Interest,
    /// Accepted over the connection cap: the first decoded frame is
    /// answered `overloaded` (in the peer's protocol) and then closed.
    shedding: bool,
    /// Peer closed its write side; keep only to finish pending output.
    read_closed: bool,
    close_after_flush: bool,
    /// This connection carried the `shutdown` verb: once its ack is on
    /// the wire, flip the server-wide latch.
    shutdown_after_flush: bool,
    dead: bool,
}

impl Conn {
    fn queue_jsonl(&mut self, frame: ResponseFrame) {
        let mut line = frame.encode();
        line.push('\n');
        self.out.push(line.as_bytes());
    }

    fn queue_http_error(&mut self, error: &WireError) {
        let body = format!("{}", Json::Obj(vec![("error".into(), error.to_json())]));
        let status = http::status_for(error.code);
        self.out.push(&http::response(status, "application/json", body.as_bytes()));
    }
}

/// One reactor thread: its own poller, listener clone and connections.
/// Fatal poller failures flip the shutdown latch so sibling threads
/// exit too, rather than leaving a half-alive gateway.
fn reactor_loop(
    listener: TcpListener,
    state: Arc<ServiceState>,
    tenants: Arc<TenantTable>,
    config: GatewayConfig,
    max_conns: usize,
) {
    let metrics = state.coordinator.metrics();
    let mut poller = if config.scan_backend {
        Poller::new_scan()
    } else {
        match Poller::new() {
            Ok(p) => p,
            Err(e) => {
                eprintln!("gateway: poller setup failed: {e}");
                state.begin_shutdown();
                return;
            }
        }
    };
    if listener.set_nonblocking(true).is_err() {
        state.begin_shutdown();
        return;
    }
    let listener_fd = listener.as_raw_fd();
    if let Err(e) = poller.register(listener_fd, LISTENER_TOKEN, Interest::Read) {
        eprintln!("gateway: registering listener failed: {e}");
        state.begin_shutdown();
        return;
    }
    let mut conns: HashMap<u64, Conn> = HashMap::new();
    let mut admitted: Vec<(String, JobHandle)> = Vec::new();
    let mut events: Vec<Event> = Vec::new();
    loop {
        if state.is_shutting_down() {
            break;
        }
        if let Err(e) = poller.wait(&mut events, TICK) {
            eprintln!("gateway: poll failed: {e}");
            state.begin_shutdown();
            break;
        }
        for &ev in &events {
            if ev.token == LISTENER_TOKEN {
                accept_ready(&listener, &mut poller, &mut conns, &metrics, &config, max_conns);
                continue;
            }
            let Some(conn) = conns.get_mut(&ev.token) else {
                continue;
            };
            if ev.error {
                conn.dead = true;
                continue;
            }
            if ev.readable && !conn.dead {
                read_ready(conn, &state, &tenants, &mut admitted, &metrics, &config);
            }
            if ev.writable && !conn.dead {
                flush_conn(conn, &state, &metrics);
            }
        }
        tick(&state, &tenants, &mut conns, &mut admitted, &metrics, &config);
        sync_interest_and_reap(&mut poller, &mut conns, &metrics, &config);
    }
    // tear-down: every surviving connection closes when dropped
    metrics.add_gauge("gateway_connections_open", -(conns.len() as i64));
}

/// Drain the accept queue. Connections over the cap are still accepted
/// but marked shedding — they get a structured `overloaded` refusal on
/// their first frame instead of a mysterious RST.
fn accept_ready(
    listener: &TcpListener,
    poller: &mut Poller,
    conns: &mut HashMap<u64, Conn>,
    metrics: &Metrics,
    config: &GatewayConfig,
    max_conns: usize,
) {
    loop {
        let stream = match listener.accept() {
            Ok((stream, _)) => stream,
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => {
                eprintln!("gateway: accept failed: {e}");
                break;
            }
        };
        if stream.set_nonblocking(true).is_err() {
            continue;
        }
        let _ = stream.set_nodelay(true);
        let token = stream.as_raw_fd() as u64;
        let shedding = conns.len() >= max_conns;
        if poller.register(stream.as_raw_fd(), token, Interest::Read).is_err() {
            continue;
        }
        metrics.inc("gateway_connections_total", 1);
        if shedding {
            metrics.inc("gateway_shed_connections_total", 1);
        }
        metrics.add_gauge("gateway_connections_open", 1);
        let open = metrics.gauge("gateway_connections_open");
        metrics.set_gauge_max("gateway_connections_peak", open);
        conns.insert(
            token,
            Conn {
                stream,
                token,
                frames: FrameBuffer::new(config.limits.max_frame_bytes),
                out: WriteBuffer::new(),
                last_activity: Instant::now(),
                parked: None,
                interest: Interest::Read,
                shedding,
                read_closed: false,
                close_after_flush: false,
                shutdown_after_flush: false,
                dead: false,
            },
        );
    }
}

/// Pull everything the socket has, then run the frame state machine.
fn read_ready(
    conn: &mut Conn,
    state: &Arc<ServiceState>,
    tenants: &Arc<TenantTable>,
    admitted: &mut Vec<(String, JobHandle)>,
    metrics: &Metrics,
    config: &GatewayConfig,
) {
    // backpressure: a peer that won't read its replies doesn't get to
    // keep feeding us requests
    if conn.out.len() > config.max_write_buffer {
        return;
    }
    let mut buf = [0u8; 16 * 1024];
    loop {
        match conn.stream.read(&mut buf) {
            Ok(0) => {
                conn.read_closed = true;
                break;
            }
            Ok(n) => {
                conn.frames.extend(&buf[..n]);
                conn.last_activity = Instant::now();
                if conn.frames.pending_bytes() > config.limits.max_frame_bytes {
                    break; // the state machine will report TooBig
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(_) => {
                conn.dead = true;
                return;
            }
        }
    }
    drive_frames(conn, state, tenants, admitted, metrics);
    flush_conn(conn, state, metrics);
}

/// Extract and dispatch buffered frames until exhausted, parked, or
/// condemned. Called after reads and after a park resolves.
fn drive_frames(
    conn: &mut Conn,
    state: &Arc<ServiceState>,
    tenants: &Arc<TenantTable>,
    admitted: &mut Vec<(String, JobHandle)>,
    metrics: &Metrics,
) {
    while conn.parked.is_none() && !conn.close_after_flush && !conn.dead {
        match conn.frames.next() {
            Ok(Some(FrameEvent::Jsonl(line))) => {
                handle_jsonl(conn, &line, state, tenants, admitted, metrics);
            }
            Ok(Some(FrameEvent::Http(request))) => {
                handle_http(conn, &request, state, tenants, admitted, metrics);
            }
            Ok(None) => break,
            Err(FrameError::TooBig { limit }) => {
                metrics.inc("gateway_oversize_disconnects_total", 1);
                let error = oversize_error(limit);
                match conn.frames.protocol() {
                    Protocol::Http => conn.queue_http_error(&error),
                    _ => conn.queue_jsonl(ResponseFrame::err(0, error)),
                }
                conn.close_after_flush = true;
            }
            Err(FrameError::BadHttp(reason)) => {
                metrics.inc("gateway_errors_total", 1);
                conn.queue_http_error(&WireError::new(ErrorCode::BadRequest, reason));
                conn.close_after_flush = true;
            }
        }
    }
}

/// Dispatch one newline-JSON frame, mirroring the legacy server's
/// semantics except that `submit` passes tenant admission and `wait`
/// parks instead of blocking.
fn handle_jsonl(
    conn: &mut Conn,
    line: &str,
    state: &Arc<ServiceState>,
    tenants: &Arc<TenantTable>,
    admitted: &mut Vec<(String, JobHandle)>,
    metrics: &Metrics,
) {
    metrics.inc("gateway_frames_total", 1);
    if conn.shedding {
        conn.queue_jsonl(ResponseFrame::err(salvage_id(line), overloaded_error()));
        conn.close_after_flush = true;
        return;
    }
    let frame = match RequestFrame::decode(line) {
        Ok(f) => f,
        Err(e) => {
            metrics.inc("gateway_errors_total", 1);
            conn.queue_jsonl(ResponseFrame::err(salvage_id(line), e));
            return;
        }
    };
    match frame.verb {
        Verb::Submit => match admit_and_submit(frame.request.clone(), state, tenants, admitted) {
            Ok(report) => conn.queue_jsonl(ResponseFrame::ok(frame.id, report)),
            Err(e) => {
                count_refusal(metrics, &e);
                conn.queue_jsonl(ResponseFrame::err(frame.id, e));
            }
        },
        Verb::Wait => {
            let handle = frame.job.ok_or_else(no_job_error).and_then(|id| {
                state
                    .job(id)
                    .ok_or_else(|| WireError::new(ErrorCode::UnknownJob, format!("no job {id}")))
            });
            match handle {
                Err(e) => {
                    metrics.inc("gateway_errors_total", 1);
                    conn.queue_jsonl(ResponseFrame::err(frame.id, e));
                }
                Ok(handle) if handle.report().state.is_terminal() => {
                    conn.queue_jsonl(ResponseFrame::ok(frame.id, handle.report().to_json()));
                }
                Ok(handle) => {
                    metrics.inc("gateway_parked_waits_total", 1);
                    conn.parked = Some(Parked::Jsonl { id: frame.id, handle });
                }
            }
        }
        Verb::Shutdown => {
            conn.queue_jsonl(ResponseFrame::ok(
                frame.id,
                Json::Obj(vec![("stopping".into(), Json::Bool(true))]),
            ));
            conn.shutdown_after_flush = true;
            conn.close_after_flush = true;
        }
        _ => match execute(state, &frame) {
            Ok(result) => conn.queue_jsonl(ResponseFrame::ok(frame.id, result)),
            Err(e) => {
                metrics.inc("gateway_errors_total", 1);
                conn.queue_jsonl(ResponseFrame::err(frame.id, e));
            }
        },
    }
}

/// Route one HTTP request. The census route parks until the job is
/// terminal, so plain `curl` sees a synchronous API.
fn handle_http(
    conn: &mut Conn,
    request: &HttpRequest,
    state: &Arc<ServiceState>,
    tenants: &Arc<TenantTable>,
    admitted: &mut Vec<(String, JobHandle)>,
    metrics: &Metrics,
) {
    metrics.inc("gateway_http_requests_total", 1);
    if conn.shedding {
        conn.queue_http_error(&overloaded_error());
        conn.close_after_flush = true;
        return;
    }
    match (request.method.as_str(), request.path.as_str()) {
        ("POST", "/v1/census") => {
            let parsed = std::str::from_utf8(&request.body)
                .map_err(|_| {
                    WireError::new(ErrorCode::BadRequest, "census body is not valid UTF-8")
                })
                .and_then(|text| {
                    Json::parse(text).map_err(|e| {
                        WireError::new(ErrorCode::BadRequest, format!("census body: {e}"))
                    })
                })
                .and_then(|v| CensusRequest::from_json(&v));
            let submitted =
                parsed.and_then(|req| admit_and_submit_handle(req, state, tenants, admitted));
            match submitted {
                Err(e) => {
                    count_refusal(metrics, &e);
                    conn.queue_http_error(&e);
                }
                Ok(handle) if handle.report().state.is_terminal() => {
                    queue_http_report(conn, &handle);
                }
                Ok(handle) => {
                    metrics.inc("gateway_parked_waits_total", 1);
                    conn.parked = Some(Parked::Http { handle });
                }
            }
        }
        ("GET", "/v1/status") => {
            match execute(state, &RequestFrame::new(0, Verb::Status)) {
                Ok(result) => {
                    let body = format!("{result}");
                    conn.out
                        .push(&http::response(200, "application/json", body.as_bytes()));
                }
                Err(e) => conn.queue_http_error(&e),
            }
        }
        ("GET", "/metrics") => {
            let text = state.coordinator.metrics().render();
            conn.out
                .push(&http::response(200, "text/plain; version=0.0.4", text.as_bytes()));
        }
        (_, "/v1/census") | (_, "/v1/status") | (_, "/metrics") => {
            let e = WireError::new(
                ErrorCode::BadRequest,
                format!("method {} not allowed on {}", request.method, request.path),
            );
            let body = format!("{}", Json::Obj(vec![("error".into(), e.to_json())]));
            conn.out
                .push(&http::response(405, "application/json", body.as_bytes()));
        }
        (_, path) => {
            let e = WireError::new(
                ErrorCode::BadRequest,
                format!("no route {path}; routes are /v1/census, /v1/status, /metrics"),
            );
            let body = format!("{}", Json::Obj(vec![("error".into(), e.to_json())]));
            conn.out
                .push(&http::response(404, "application/json", body.as_bytes()));
        }
    }
}

/// Tenant admission + submit, returning the intake report (the
/// newline-JSON `submit` reply).
fn admit_and_submit(
    request: Option<CensusRequest>,
    state: &Arc<ServiceState>,
    tenants: &Arc<TenantTable>,
    admitted: &mut Vec<(String, JobHandle)>,
) -> std::result::Result<Json, WireError> {
    let request = request
        .ok_or_else(|| WireError::new(ErrorCode::BadRequest, "submit frame carries no request"))?;
    let handle = admit_and_submit_handle(request, state, tenants, admitted)?;
    Ok(handle.report().to_json())
}

/// The shared admission path: resolve the tenant, pass the token
/// bucket and inflight gates, inherit the tenant's default priority,
/// submit, and start tracking the job for quota release.
fn admit_and_submit_handle(
    mut request: CensusRequest,
    state: &Arc<ServiceState>,
    tenants: &Arc<TenantTable>,
    admitted: &mut Vec<(String, JobHandle)>,
) -> std::result::Result<JobHandle, WireError> {
    if state.is_shutting_down() {
        return Err(WireError::new(ErrorCode::ShuttingDown, "server is shutting down"));
    }
    let tenant = request.tenant.clone().unwrap_or_else(|| DEFAULT_TENANT.to_string());
    let default_priority = tenants.admit(&tenant)?;
    if request.priority.is_none() {
        request.priority = Some(default_priority);
    }
    let handle = state.coordinator.submit(request);
    state.insert_job(handle.clone());
    admitted.push((tenant, handle.clone()));
    Ok(handle)
}

/// Format a terminal job report as the HTTP census response.
fn queue_http_report(conn: &mut Conn, handle: &JobHandle) {
    let report = handle.report();
    let status = match report.state {
        JobStateKind::Done => 200,
        JobStateKind::Cancelled => 409,
        _ => report.error.as_ref().map_or(500, |e| http::status_for(e.code)),
    };
    let body = format!("{}", report.to_json());
    conn.out.push(&http::response(status, "application/json", body.as_bytes()));
}

/// The per-tick housekeeping pass: resolve parked waits (and resume
/// their pipelines), release tenant inflight slots for terminal jobs,
/// and sweep idle connections.
fn tick(
    state: &Arc<ServiceState>,
    tenants: &Arc<TenantTable>,
    conns: &mut HashMap<u64, Conn>,
    admitted: &mut Vec<(String, JobHandle)>,
    metrics: &Metrics,
    config: &GatewayConfig,
) {
    let now = Instant::now();
    for conn in conns.values_mut() {
        if conn.dead {
            continue;
        }
        let resolved = match &conn.parked {
            Some(parked) if parked.handle().report().state.is_terminal() => conn.parked.take(),
            _ => None,
        };
        if let Some(parked) = resolved {
            match parked {
                Parked::Jsonl { id, handle } => {
                    conn.queue_jsonl(ResponseFrame::ok(id, handle.report().to_json()));
                }
                Parked::Http { handle } => queue_http_report(conn, &handle),
            }
            conn.last_activity = now;
            // frames pipelined behind the wait can run now
            drive_frames(conn, state, tenants, admitted, metrics);
        }
        let idle = now.duration_since(conn.last_activity) > config.limits.idle_timeout;
        if idle && conn.parked.is_none() && conn.out.is_empty() {
            metrics.inc("gateway_idle_disconnects_total", 1);
            conn.dead = true;
            continue;
        }
        if !conn.out.is_empty() || conn.read_closed || conn.close_after_flush {
            flush_conn(conn, state, metrics);
        }
    }
    admitted.retain(|(tenant, handle)| {
        if handle.report().state.is_terminal() {
            tenants.release(tenant);
            false
        } else {
            true
        }
    });
}

/// Push pending bytes; on full drain, handle deferred closes and the
/// shutdown handshake.
fn flush_conn(conn: &mut Conn, state: &Arc<ServiceState>, metrics: &Metrics) {
    match conn.out.flush_to(&mut conn.stream) {
        Ok(true) => {
            if conn.shutdown_after_flush {
                // the ack is on the wire: now stop the world
                state.begin_shutdown();
            }
            if conn.close_after_flush || (conn.read_closed && conn.parked.is_none()) {
                conn.dead = true;
            }
        }
        Ok(false) => {}
        Err(_) => {
            metrics.inc("gateway_errors_total", 1);
            conn.dead = true;
        }
    }
}

/// Keep each connection's poller registration in line with what it
/// can actually make progress on, then reap dead connections.
fn sync_interest_and_reap(
    poller: &mut Poller,
    conns: &mut HashMap<u64, Conn>,
    metrics: &Metrics,
    config: &GatewayConfig,
) {
    let mut dead = Vec::new();
    for conn in conns.values_mut() {
        if conn.dead {
            dead.push(conn.token);
            continue;
        }
        let wanted = if conn.out.is_empty() {
            Interest::Read
        } else if conn.out.len() > config.max_write_buffer {
            Interest::Write
        } else {
            Interest::ReadWrite
        };
        if wanted != conn.interest {
            let fd = conn.stream.as_raw_fd();
            if poller.modify(fd, conn.token, wanted).is_ok() {
                conn.interest = wanted;
            }
        }
    }
    for token in dead {
        if let Some(conn) = conns.remove(&token) {
            poller.deregister(conn.stream.as_raw_fd(), token);
            metrics.add_gauge("gateway_connections_open", -1);
        }
    }
}

fn overloaded_error() -> WireError {
    WireError::new(
        ErrorCode::Overloaded,
        "gateway is at its connection limit; retry against a less loaded window",
    )
}

fn no_job_error() -> WireError {
    WireError::new(ErrorCode::BadRequest, "frame carries no job id")
}

/// Count a refused submit under the right metric.
fn count_refusal(metrics: &Metrics, error: &WireError) {
    match error.code {
        ErrorCode::RateLimited => metrics.inc("gateway_rate_limited_total", 1),
        ErrorCode::Overloaded => metrics.inc("gateway_overloaded_total", 1),
        _ => metrics.inc("gateway_errors_total", 1),
    }
}
