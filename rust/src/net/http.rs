//! Minimal HTTP/1.1 support for the gateway: enough to serve
//! `POST /v1/census`, `GET /v1/status` and `GET /metrics` to stock
//! tools (`curl`, python's `http.client`) without a dependency.
//!
//! Deliberately small: `Content-Length` bodies only (chunked transfer
//! encoding is rejected with a structured 400), a 16 KiB header cap,
//! keep-alive connections, no multipart/TLS/compression. The gateway's
//! JSON-over-TCP protocol remains the first-class interface; HTTP is
//! the drop-in integration path.

use crate::coordinator::protocol::ErrorCode;

/// Cap on the request line + headers, independent of the body cap — no
/// client needs kilobytes of headers to name a graph.
pub const MAX_HEADER_BYTES: usize = 16 * 1024;

/// One parsed request. Header names are stored lowercased.
#[derive(Debug, Clone)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl HttpRequest {
    /// Case-insensitive header lookup.
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers
            .iter()
            .find(|(k, _)| *k == name)
            .map(|(_, v)| v.as_str())
    }
}

/// Try to parse one request from the front of `buf`.
///
/// - `Ok(Some((request, consumed)))` — a complete request; the caller
///   drains `consumed` bytes (pipelined requests may follow).
/// - `Ok(None)` — incomplete; read more bytes and retry.
/// - `Err(reason)` — malformed or unsupported; answer 400 and close.
pub fn parse_request(buf: &[u8], max_body: usize) -> Result<Option<(HttpRequest, usize)>, String> {
    let Some(head_end) = find_head_end(buf) else {
        if buf.len() > MAX_HEADER_BYTES {
            return Err(format!("request headers exceed {MAX_HEADER_BYTES} bytes"));
        }
        return Ok(None);
    };
    if head_end.head > MAX_HEADER_BYTES {
        return Err(format!("request headers exceed {MAX_HEADER_BYTES} bytes"));
    }
    let head = std::str::from_utf8(&buf[..head_end.head])
        .map_err(|_| "request headers are not valid UTF-8".to_string())?;
    let mut lines = head.split('\n').map(|l| l.trim_end_matches('\r'));
    let request_line = lines.next().unwrap_or_default();
    let mut parts = request_line.split_ascii_whitespace();
    let (method, path, version) = match (parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(p), Some(v)) => (m, p, v),
        _ => return Err(format!("malformed request line {request_line:?}")),
    };
    if !version.starts_with("HTTP/1.") {
        return Err(format!("unsupported protocol version {version:?}"));
    }
    let mut headers = Vec::new();
    for line in lines.filter(|l| !l.is_empty()) {
        let Some((name, value)) = line.split_once(':') else {
            return Err(format!("malformed header line {line:?}"));
        };
        headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
    }
    let request = HttpRequest {
        method: method.to_string(),
        path: path.to_string(),
        headers,
        body: Vec::new(),
    };
    if let Some(te) = request.header("transfer-encoding") {
        if te.to_ascii_lowercase().contains("chunked") {
            return Err("chunked transfer encoding is not supported; \
                        send a Content-Length body"
                .to_string());
        }
    }
    let content_length = match request.header("content-length") {
        None => 0,
        Some(v) => v
            .parse::<usize>()
            .map_err(|_| format!("unparseable Content-Length {v:?}"))?,
    };
    if content_length > max_body {
        return Err(format!(
            "request body of {content_length} bytes exceeds this server's limit of {max_body}"
        ));
    }
    let body_start = head_end.total;
    if buf.len() < body_start + content_length {
        return Ok(None);
    }
    let mut request = request;
    request.body = buf[body_start..body_start + content_length].to_vec();
    Ok(Some((request, body_start + content_length)))
}

struct HeadEnd {
    /// Bytes of request line + headers (excluding the blank line).
    head: usize,
    /// Bytes up to and including the blank line (body starts here).
    total: usize,
}

/// Find the header/body boundary: `\r\n\r\n`, tolerating bare `\n\n`.
fn find_head_end(buf: &[u8]) -> Option<HeadEnd> {
    let mut i = 0;
    while i + 1 < buf.len() {
        if buf[i] == b'\n' {
            if buf[i + 1] == b'\n' {
                return Some(HeadEnd { head: i, total: i + 2 });
            }
            if i + 2 < buf.len() && buf[i + 1] == b'\r' && buf[i + 2] == b'\n' {
                return Some(HeadEnd { head: i, total: i + 3 });
            }
        }
        i += 1;
    }
    None
}

/// Build a complete response with `Content-Length` and keep-alive.
pub fn response(status: u16, content_type: &str, body: &[u8]) -> Vec<u8> {
    let mut out = format!(
        "HTTP/1.1 {status} {}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: keep-alive\r\n\r\n",
        reason(status),
        body.len()
    )
    .into_bytes();
    out.extend_from_slice(body);
    out
}

/// Canonical reason phrase for the statuses this server emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        502 => "Bad Gateway",
        503 => "Service Unavailable",
        _ => "Error",
    }
}

/// The HTTP status a structured wire error maps to, so the same
/// [`ErrorCode`] taxonomy drives both protocols.
pub fn status_for(code: ErrorCode) -> u16 {
    match code {
        ErrorCode::BadVersion
        | ErrorCode::BadFrame
        | ErrorCode::BadRequest
        | ErrorCode::UnknownVerb
        | ErrorCode::GraphLoad => 400,
        ErrorCode::UnknownEngine | ErrorCode::UnknownJob | ErrorCode::UnknownStream => 404,
        ErrorCode::Cancelled => 409,
        ErrorCode::RateLimited => 429,
        ErrorCode::ShuttingDown | ErrorCode::WorkerUnavailable | ErrorCode::Overloaded => 503,
        ErrorCode::Transport => 502,
        ErrorCode::Internal => 500,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_a_get_with_headers() {
        let raw = b"GET /v1/status HTTP/1.1\r\nHost: localhost:7333\r\nAccept: */*\r\n\r\n";
        let (req, consumed) = parse_request(raw, 1024).unwrap().unwrap();
        assert_eq!(consumed, raw.len());
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/v1/status");
        assert_eq!(req.header("host"), Some("localhost:7333"));
        assert_eq!(req.header("Accept"), Some("*/*"));
        assert!(req.body.is_empty());
    }

    #[test]
    fn parses_a_post_with_content_length_body() {
        let raw = b"POST /v1/census HTTP/1.1\r\nContent-Length: 11\r\n\r\nhello world";
        let (req, consumed) = parse_request(raw, 1024).unwrap().unwrap();
        assert_eq!(consumed, raw.len());
        assert_eq!(req.body, b"hello world");
    }

    #[test]
    fn incomplete_requests_ask_for_more_bytes() {
        assert!(parse_request(b"GET /v1/st", 1024).unwrap().is_none());
        assert!(parse_request(b"POST /x HTTP/1.1\r\nContent-Length: 5\r\n\r\nab", 1024)
            .unwrap()
            .is_none());
    }

    #[test]
    fn tolerates_bare_lf_line_endings() {
        let raw = b"GET /metrics HTTP/1.1\nHost: x\n\n";
        let (req, consumed) = parse_request(raw, 1024).unwrap().unwrap();
        assert_eq!(consumed, raw.len());
        assert_eq!(req.path, "/metrics");
    }

    #[test]
    fn rejects_chunked_oversized_and_garbage() {
        let chunked = b"POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n";
        assert!(parse_request(chunked, 1024).unwrap_err().contains("chunked"));
        let big = b"POST /x HTTP/1.1\r\nContent-Length: 99999\r\n\r\n";
        assert!(parse_request(big, 1024).unwrap_err().contains("exceeds"));
        let garbage = b"NONSENSE\r\n\r\n";
        assert!(parse_request(garbage, 1024).is_err());
        let old = b"GET /x HTTP/0.9\r\n\r\n";
        assert!(parse_request(old, 1024).unwrap_err().contains("version"));
    }

    #[test]
    fn response_carries_length_and_keepalive() {
        let r = response(200, "application/json", b"{}");
        let text = String::from_utf8(r).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(text.contains("Content-Length: 2\r\n"));
        assert!(text.contains("Connection: keep-alive\r\n"));
        assert!(text.ends_with("\r\n\r\n{}"));
    }

    #[test]
    fn error_codes_map_to_sensible_statuses() {
        assert_eq!(status_for(ErrorCode::RateLimited), 429);
        assert_eq!(status_for(ErrorCode::Overloaded), 503);
        assert_eq!(status_for(ErrorCode::BadRequest), 400);
        assert_eq!(status_for(ErrorCode::UnknownJob), 404);
        assert_eq!(status_for(ErrorCode::Internal), 500);
    }
}
