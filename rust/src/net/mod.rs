//! Nonblocking serving infrastructure: the multi-tenant gateway that
//! fronts the [`crate::coordinator`] job pipeline at high connection
//! counts.
//!
//! The legacy server ([`crate::coordinator::CensusServer`]) spends one
//! OS thread per connection — simple, and still available behind
//! `repro serve --legacy-accept` — but a monitoring deployment with
//! thousands of mostly-idle subscriber connections wants the paper's
//! serving posture instead: a small fixed thread count multiplexing
//! all sockets through readiness polling, with explicit admission
//! control per tenant.
//!
//! * [`reactor`] — readiness polling: raw-syscall epoll on Linux
//!   (no libc dependency), a portable level-triggered scan fallback
//!   elsewhere.
//! * [`conn`] — per-connection state machines: bounded frame
//!   accumulation with protocol sniffing (newline-JSON and HTTP/1.1 on
//!   one listener), partial-write tracking, slow-client limits.
//! * [`http`] — a deliberately minimal HTTP/1.1 layer for
//!   `POST /v1/census`, `GET /v1/status` and `GET /metrics`.
//! * [`tenant`] — token-bucket rate limits, max-inflight quotas and
//!   default priorities per tenant, with structured `rate_limited`
//!   refusals.
//! * [`gateway`] — the reactor threads tying it together; dispatch
//!   reuses the coordinator's job pipeline, so a census submitted over
//!   HTTP can be polled over newline-JSON.

pub mod conn;
pub mod gateway;
pub mod http;
pub mod reactor;
pub mod tenant;

pub use conn::ConnLimits;
pub use gateway::{Gateway, GatewayConfig};
pub use reactor::{raise_nofile_limit, Event, Interest, Poller};
pub use tenant::{TenantPolicy, TenantTable, DEFAULT_TENANT};
