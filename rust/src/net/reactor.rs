//! Readiness polling for the gateway's reactor threads, with no libc
//! dependency: on Linux (x86_64 / aarch64) the epoll syscalls are
//! invoked directly through `asm!`; everywhere else (and under the
//! `--scan-backend` flag, which CI uses to keep the fallback honest) a
//! portable level-triggered scan poller stands in.
//!
//! The scan backend cannot observe kernel readiness without libc, so
//! it reports every registered token as ready each ~2ms tick and
//! relies on the connection layer treating `WouldBlock` as "not
//! actually ready" — semantically identical to level-triggered epoll
//! (spurious readiness is allowed there too), just less efficient.
//! That trade is deliberate: the paper's serving story is measured on
//! the Linux/epoll path; the scan path exists for portability and for
//! exercising the same state machines under a different readiness
//! schedule.
//!
//! Everything is level-triggered — no `EPOLLET` — so a partially
//! drained buffer simply reports ready again on the next wait.

use std::io;
use std::time::Duration;

/// What a registration wants to hear about.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Interest {
    Read,
    Write,
    ReadWrite,
}

impl Interest {
    fn wants_read(self) -> bool {
        matches!(self, Interest::Read | Interest::ReadWrite)
    }

    fn wants_write(self) -> bool {
        matches!(self, Interest::Write | Interest::ReadWrite)
    }
}

/// One readiness report. `error` covers `EPOLLERR`/`EPOLLHUP`; such
/// connections should be read (to observe the EOF/error) and closed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Event {
    pub token: u64,
    pub readable: bool,
    pub writable: bool,
    pub error: bool,
}

/// A readiness poller: epoll where available, scan otherwise.
pub enum Poller {
    #[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
    Epoll(epoll::EpollPoller),
    Scan(scan::ScanPoller),
}

impl Poller {
    /// The best backend for this platform.
    pub fn new() -> io::Result<Poller> {
        #[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
        let poller = Poller::Epoll(epoll::EpollPoller::new()?);
        #[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
        let poller = Poller::Scan(scan::ScanPoller::new());
        Ok(poller)
    }

    /// The portable fallback, explicitly (CI exercises it on Linux).
    pub fn new_scan() -> Poller {
        Poller::Scan(scan::ScanPoller::new())
    }

    /// Backend name for logs and metrics.
    pub fn backend(&self) -> &'static str {
        match self {
            #[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
            Poller::Epoll(_) => "epoll",
            Poller::Scan(_) => "scan",
        }
    }

    /// Start watching `fd` under `token`.
    pub fn register(&mut self, fd: i32, token: u64, interest: Interest) -> io::Result<()> {
        match self {
            #[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
            Poller::Epoll(p) => p.ctl(epoll::EPOLL_CTL_ADD, fd, token, interest),
            Poller::Scan(p) => p.register(fd, token, interest),
        }
    }

    /// Change what an existing registration wants to hear about.
    pub fn modify(&mut self, fd: i32, token: u64, interest: Interest) -> io::Result<()> {
        match self {
            #[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
            Poller::Epoll(p) => p.ctl(epoll::EPOLL_CTL_MOD, fd, token, interest),
            Poller::Scan(p) => p.register(fd, token, interest),
        }
    }

    /// Stop watching `fd`. Harmless if already removed.
    pub fn deregister(&mut self, fd: i32, token: u64) {
        match self {
            #[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
            Poller::Epoll(p) => p.deregister(fd),
            Poller::Scan(p) => p.deregister(token),
        }
    }

    /// Block until readiness or `timeout`, appending into `out`
    /// (cleared first). A timeout with no events is `Ok` and empty.
    pub fn wait(&mut self, out: &mut Vec<Event>, timeout: Duration) -> io::Result<()> {
        out.clear();
        match self {
            #[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
            Poller::Epoll(p) => p.wait(out, timeout),
            Poller::Scan(p) => {
                p.wait(out, timeout);
                Ok(())
            }
        }
    }
}

/// Raise the process's open-file soft limit to its hard limit,
/// returning the resulting soft limit.
///
/// A reactor multiplexing hundreds of sockets (or the e2e soak test
/// that drives one) hits the conservative default soft limit — often
/// 1024 — long before any real resource bound, so the gateway raises
/// it at startup the way long-running servers conventionally do. Where
/// the raw `prlimit64` syscall is unavailable this is a no-op
/// returning 0.
pub fn raise_nofile_limit() -> io::Result<u64> {
    #[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        epoll::raise_nofile_limit()
    }
    #[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
    {
        Ok(0)
    }
}

/// Raw-syscall epoll, Linux x86_64/aarch64 only. The asm follows the
/// kernel syscall ABI directly (`syscall` clobbers rcx/r11 and the
/// flags on x86_64; `svc 0` takes the number in x8 on aarch64), so no
/// libc is involved anywhere in the serving path.
#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
pub mod epoll {
    use super::{Event, Interest};
    use std::io;
    use std::time::Duration;

    #[cfg(target_arch = "x86_64")]
    mod nr {
        pub const CLOSE: usize = 3;
        pub const EPOLL_CTL: usize = 233;
        pub const EPOLL_PWAIT: usize = 281;
        pub const EPOLL_CREATE1: usize = 291;
        pub const PRLIMIT64: usize = 302;
    }

    #[cfg(target_arch = "aarch64")]
    mod nr {
        pub const EPOLL_CREATE1: usize = 20;
        pub const EPOLL_CTL: usize = 21;
        pub const EPOLL_PWAIT: usize = 22;
        pub const CLOSE: usize = 57;
        pub const PRLIMIT64: usize = 261;
    }

    pub(super) const EPOLL_CTL_ADD: i32 = 1;
    pub(super) const EPOLL_CTL_DEL: i32 = 2;
    pub(super) const EPOLL_CTL_MOD: i32 = 3;
    const EPOLL_CLOEXEC: usize = 0x80000;
    const EPOLLIN: u32 = 0x1;
    const EPOLLOUT: u32 = 0x4;
    const EPOLLERR: u32 = 0x8;
    const EPOLLHUP: u32 = 0x10;

    /// `struct epoll_event`: packed on x86_64 (the kernel ABI has no
    /// padding between the u32 mask and the u64 data there), naturally
    /// aligned on aarch64.
    #[cfg(target_arch = "x86_64")]
    #[derive(Clone, Copy)]
    #[repr(C, packed)]
    struct EpollEvent {
        events: u32,
        data: u64,
    }

    #[cfg(target_arch = "aarch64")]
    #[derive(Clone, Copy)]
    #[repr(C)]
    struct EpollEvent {
        events: u32,
        data: u64,
    }

    #[cfg(target_arch = "x86_64")]
    #[inline]
    unsafe fn syscall6(
        nr: usize,
        a1: usize,
        a2: usize,
        a3: usize,
        a4: usize,
        a5: usize,
        a6: usize,
    ) -> isize {
        let ret: isize;
        std::arch::asm!(
            "syscall",
            inlateout("rax") nr as isize => ret,
            in("rdi") a1,
            in("rsi") a2,
            in("rdx") a3,
            in("r10") a4,
            in("r8") a5,
            in("r9") a6,
            lateout("rcx") _,
            lateout("r11") _,
            options(nostack),
        );
        ret
    }

    #[cfg(target_arch = "aarch64")]
    #[inline]
    unsafe fn syscall6(
        nr: usize,
        a1: usize,
        a2: usize,
        a3: usize,
        a4: usize,
        a5: usize,
        a6: usize,
    ) -> isize {
        let ret: isize;
        std::arch::asm!(
            "svc 0",
            in("x8") nr,
            inlateout("x0") a1 as isize => ret,
            in("x1") a2,
            in("x2") a3,
            in("x3") a4,
            in("x4") a5,
            in("x5") a6,
            options(nostack),
        );
        ret
    }

    fn check(ret: isize) -> io::Result<isize> {
        if ret < 0 {
            Err(io::Error::from_raw_os_error(-ret as i32))
        } else {
            Ok(ret)
        }
    }

    /// `struct rlimit64` for `prlimit64(2)`.
    #[repr(C)]
    struct RLimit64 {
        cur: u64,
        max: u64,
    }

    const RLIMIT_NOFILE: usize = 7;

    /// See [`super::raise_nofile_limit`]. `prlimit64(pid = 0, …)`
    /// operates on the calling process; a null new-limit pointer reads,
    /// a null old-limit pointer writes.
    pub(super) fn raise_nofile_limit() -> io::Result<u64> {
        let mut old = RLimit64 { cur: 0, max: 0 };
        check(unsafe {
            syscall6(
                nr::PRLIMIT64,
                0,
                RLIMIT_NOFILE,
                0,
                &mut old as *mut RLimit64 as usize,
                0,
                0,
            )
        })?;
        if old.cur >= old.max {
            return Ok(old.cur);
        }
        let new = RLimit64 {
            cur: old.max,
            max: old.max,
        };
        check(unsafe {
            syscall6(
                nr::PRLIMIT64,
                0,
                RLIMIT_NOFILE,
                &new as *const RLimit64 as usize,
                0,
                0,
                0,
            )
        })?;
        Ok(new.cur)
    }

    pub struct EpollPoller {
        epfd: i32,
    }

    impl EpollPoller {
        pub fn new() -> io::Result<EpollPoller> {
            let fd = check(unsafe { syscall6(nr::EPOLL_CREATE1, EPOLL_CLOEXEC, 0, 0, 0, 0, 0) })?;
            Ok(EpollPoller { epfd: fd as i32 })
        }

        pub(super) fn ctl(
            &mut self,
            op: i32,
            fd: i32,
            token: u64,
            interest: Interest,
        ) -> io::Result<()> {
            let mut mask = 0u32;
            if interest.wants_read() {
                mask |= EPOLLIN;
            }
            if interest.wants_write() {
                mask |= EPOLLOUT;
            }
            let ev = EpollEvent {
                events: mask,
                data: token,
            };
            check(unsafe {
                syscall6(
                    nr::EPOLL_CTL,
                    self.epfd as usize,
                    op as usize,
                    fd as usize,
                    &ev as *const EpollEvent as usize,
                    0,
                    0,
                )
            })
            .map(|_| ())
        }

        pub(super) fn deregister(&mut self, fd: i32) {
            let ev = EpollEvent { events: 0, data: 0 };
            // pre-2.6.9 kernels required a non-null event for DEL; cheap
            // to satisfy. Failure (fd already closed) is fine to ignore.
            let _ = unsafe {
                syscall6(
                    nr::EPOLL_CTL,
                    self.epfd as usize,
                    EPOLL_CTL_DEL as usize,
                    fd as usize,
                    &ev as *const EpollEvent as usize,
                    0,
                    0,
                )
            };
        }

        pub(super) fn wait(&mut self, out: &mut Vec<Event>, timeout: Duration) -> io::Result<()> {
            const MAX_EVENTS: usize = 256;
            let mut raw = [EpollEvent { events: 0, data: 0 }; MAX_EVENTS];
            let timeout_ms = timeout.as_millis().min(i32::MAX as u128) as i32;
            let n = loop {
                let ret = unsafe {
                    syscall6(
                        nr::EPOLL_PWAIT,
                        self.epfd as usize,
                        raw.as_mut_ptr() as usize,
                        MAX_EVENTS,
                        timeout_ms as usize,
                        0, // sigmask: null — plain epoll_wait semantics
                        0,
                    )
                };
                match check(ret) {
                    Ok(n) => break n as usize,
                    Err(e) if e.raw_os_error() == Some(4) => continue, // EINTR
                    Err(e) => return Err(e),
                }
            };
            for ev in raw.iter().take(n) {
                // copy out of the (possibly packed) struct before use
                let mask = ev.events;
                let token = ev.data;
                out.push(Event {
                    token,
                    readable: mask & (EPOLLIN | EPOLLHUP) != 0,
                    writable: mask & EPOLLOUT != 0,
                    error: mask & (EPOLLERR | EPOLLHUP) != 0,
                });
            }
            Ok(())
        }
    }

    impl Drop for EpollPoller {
        fn drop(&mut self) {
            let _ = unsafe { syscall6(nr::CLOSE, self.epfd as usize, 0, 0, 0, 0, 0) };
        }
    }
}

/// The portable fallback: report every registered token as ready per
/// ~2ms tick and let nonblocking I/O sort out who actually was.
pub mod scan {
    use super::{Event, Interest};
    use std::collections::BTreeMap;
    use std::time::Duration;

    /// Smaller of the caller's timeout and this between scans, bounding
    /// both busy-spin (when idle) and added latency (when loaded).
    const SCAN_TICK: Duration = Duration::from_millis(2);

    pub struct ScanPoller {
        registered: BTreeMap<u64, Interest>,
    }

    impl ScanPoller {
        #[allow(clippy::new_without_default)]
        pub fn new() -> ScanPoller {
            ScanPoller {
                registered: BTreeMap::new(),
            }
        }

        pub(super) fn register(
            &mut self,
            _fd: i32,
            token: u64,
            interest: Interest,
        ) -> std::io::Result<()> {
            self.registered.insert(token, interest);
            Ok(())
        }

        pub(super) fn deregister(&mut self, token: u64) {
            self.registered.remove(&token);
        }

        pub(super) fn wait(&mut self, out: &mut Vec<Event>, timeout: Duration) {
            std::thread::sleep(timeout.min(SCAN_TICK));
            for (&token, &interest) in &self.registered {
                out.push(Event {
                    token,
                    readable: interest.wants_read(),
                    writable: interest.wants_write(),
                    error: false,
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};
    use std::net::{TcpListener, TcpStream};
    use std::os::unix::io::AsRawFd;
    use std::time::Duration;

    fn socket_pair() -> (TcpStream, TcpStream) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        (client, server)
    }

    fn wait_for_token(poller: &mut Poller, token: u64, want_read: bool) -> Event {
        let mut events = Vec::new();
        for _ in 0..500 {
            poller.wait(&mut events, Duration::from_millis(20)).unwrap();
            if let Some(ev) = events
                .iter()
                .find(|e| e.token == token && (!want_read || e.readable))
            {
                return *ev;
            }
        }
        panic!("token {token} never became ready");
    }

    #[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
    #[test]
    fn epoll_reports_readability_when_bytes_arrive() {
        let (mut client, server) = socket_pair();
        let mut poller = Poller::new().unwrap();
        assert_eq!(poller.backend(), "epoll");
        server.set_nonblocking(true).unwrap();
        poller.register(server.as_raw_fd(), 7, Interest::Read).unwrap();

        // nothing to read yet: a short wait comes back empty
        let mut events = Vec::new();
        poller.wait(&mut events, Duration::from_millis(10)).unwrap();
        assert!(events.iter().all(|e| e.token != 7 || !e.readable));

        client.write_all(b"ping\n").unwrap();
        let ev = wait_for_token(&mut poller, 7, true);
        assert!(ev.readable);

        let mut server = server;
        let mut buf = [0u8; 16];
        let n = server.read(&mut buf).unwrap();
        assert_eq!(&buf[..n], b"ping\n");
    }

    #[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
    #[test]
    fn epoll_reports_writability_and_honors_modify() {
        let (_client, server) = socket_pair();
        let mut poller = Poller::new().unwrap();
        server.set_nonblocking(true).unwrap();
        let fd = server.as_raw_fd();
        poller.register(fd, 9, Interest::Write).unwrap();
        let ev = wait_for_token(&mut poller, 9, false);
        assert!(ev.writable, "an idle socket's send buffer has room");

        // back to read-only interest: writability reports stop
        poller.modify(fd, 9, Interest::Read).unwrap();
        let mut events = Vec::new();
        poller.wait(&mut events, Duration::from_millis(10)).unwrap();
        assert!(events.iter().all(|e| e.token != 9 || !e.writable));

        poller.deregister(fd, 9);
        poller.wait(&mut events, Duration::from_millis(10)).unwrap();
        assert!(events.iter().all(|e| e.token != 9));
    }

    #[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
    #[test]
    fn epoll_flags_a_peer_hangup() {
        let (client, server) = socket_pair();
        let mut poller = Poller::new().unwrap();
        server.set_nonblocking(true).unwrap();
        poller.register(server.as_raw_fd(), 3, Interest::Read).unwrap();
        drop(client);
        let ev = wait_for_token(&mut poller, 3, true);
        // HUP surfaces as readable (the read observes EOF) and error
        assert!(ev.readable);
    }

    #[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
    #[test]
    fn nofile_limit_raises_to_the_hard_limit_and_is_idempotent() {
        let first = raise_nofile_limit().unwrap();
        assert!(first > 0);
        // already at the hard limit now: a second call reports the same
        assert_eq!(raise_nofile_limit().unwrap(), first);
    }

    #[test]
    fn scan_backend_reports_registered_tokens() {
        let mut poller = Poller::new_scan();
        assert_eq!(poller.backend(), "scan");
        poller.register(0, 1, Interest::Read).unwrap();
        poller.register(0, 2, Interest::ReadWrite).unwrap();
        let mut events = Vec::new();
        poller.wait(&mut events, Duration::from_millis(5)).unwrap();
        assert_eq!(events.len(), 2);
        assert!(events.iter().any(|e| e.token == 1 && e.readable && !e.writable));
        assert!(events.iter().any(|e| e.token == 2 && e.readable && e.writable));
        poller.deregister(0, 1);
        poller.wait(&mut events, Duration::from_millis(5)).unwrap();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].token, 2);
    }
}
