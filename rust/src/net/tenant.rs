//! Multi-tenant admission control for the serving gateway: per-tenant
//! token-bucket rate limiting, max-inflight quotas, and default
//! priorities, keyed by the `tenant` field of a census request.
//!
//! Admission is two gates in order: the token bucket (sustained `rate`
//! admissions/second with capacity `burst`) and the inflight quota
//! (jobs admitted but not yet terminal). Either refusal is the
//! structured [`ErrorCode::RateLimited`] — the client keeps its
//! connection and can retry; nothing is silently dropped. Server-wide
//! overload (connection caps) is the gateway's `overloaded`, not a
//! tenant verdict.
//!
//! Time is injected into [`TenantTable::admit_at`] so refill behavior
//! is testable deterministically; the serving path uses
//! [`TenantTable::admit`].

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Instant;

use crate::coordinator::protocol::{ErrorCode, WireError, DEFAULT_PRIORITY, MAX_PRIORITY};

/// The bucket unnamed (and unconfigured) tenants land in.
pub const DEFAULT_TENANT: &str = "default";

/// Limits and defaults for one tenant.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TenantPolicy {
    /// Sustained admissions per second refilled into the bucket.
    pub rate: f64,
    /// Bucket capacity — the burst admitted after an idle period.
    pub burst: f64,
    /// Maximum jobs admitted but not yet terminal.
    pub max_inflight: usize,
    /// Submit-queue priority for requests that don't set their own.
    pub priority: u8,
}

impl TenantPolicy {
    /// No limits at all — the default for unconfigured deployments, so
    /// turning the gateway on changes nothing until a tenant config
    /// opts into limits.
    pub fn unlimited() -> TenantPolicy {
        TenantPolicy {
            rate: f64::INFINITY,
            burst: f64::INFINITY,
            max_inflight: usize::MAX,
            priority: DEFAULT_PRIORITY,
        }
    }

    pub fn new(rate: f64, burst: f64, max_inflight: usize) -> TenantPolicy {
        TenantPolicy {
            rate,
            burst,
            max_inflight,
            priority: DEFAULT_PRIORITY,
        }
    }

    pub fn with_priority(mut self, priority: u8) -> TenantPolicy {
        self.priority = priority;
        self
    }
}

/// Mutable per-tenant accounting.
#[derive(Debug)]
struct TenantState {
    tokens: f64,
    last_refill: Instant,
    inflight: usize,
}

/// All tenants' policies plus their live accounting. One table is
/// shared (behind an `Arc`) by every reactor thread; the interior
/// mutex is held only for the few arithmetic steps of a decision.
#[derive(Debug)]
pub struct TenantTable {
    policies: HashMap<String, TenantPolicy>,
    default_policy: TenantPolicy,
    state: Mutex<HashMap<String, TenantState>>,
}

impl Default for TenantTable {
    fn default() -> TenantTable {
        TenantTable::new(TenantPolicy::unlimited())
    }
}

impl TenantTable {
    /// A table where unconfigured tenants get `default_policy`.
    pub fn new(default_policy: TenantPolicy) -> TenantTable {
        TenantTable {
            policies: HashMap::new(),
            default_policy,
            state: Mutex::new(HashMap::new()),
        }
    }

    /// Configure one tenant. Naming [`DEFAULT_TENANT`] replaces the
    /// policy every unconfigured tenant falls back to.
    pub fn set_policy(&mut self, tenant: &str, policy: TenantPolicy) {
        if tenant == DEFAULT_TENANT {
            self.default_policy = policy;
        }
        self.policies.insert(tenant.to_string(), policy);
    }

    /// The policy a tenant resolves to.
    pub fn policy(&self, tenant: &str) -> TenantPolicy {
        self.policies.get(tenant).copied().unwrap_or(self.default_policy)
    }

    /// Parse the tenant config file format: one tenant per line,
    /// `name rate burst max_inflight [priority]`, `#` comments and
    /// blank lines ignored. `unlimited` is accepted for `rate`, `burst`
    /// and `max_inflight`. A line named `default` re-bounds the bucket
    /// unnamed tenants share.
    pub fn parse_config(text: &str) -> Result<TenantTable, String> {
        let mut table = TenantTable::default();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or_default().trim();
            if line.is_empty() {
                continue;
            }
            let fields: Vec<&str> = line.split_ascii_whitespace().collect();
            let at = |msg: String| format!("tenant config line {}: {msg}", lineno + 1);
            if fields.len() < 4 || fields.len() > 5 {
                return Err(at(format!(
                    "expected `name rate burst max_inflight [priority]`, got {} fields",
                    fields.len()
                )));
            }
            let rate = parse_limit_f64(fields[1]).map_err(&at)?;
            let burst = parse_limit_f64(fields[2]).map_err(&at)?;
            let max_inflight = parse_limit_usize(fields[3]).map_err(&at)?;
            let mut policy = TenantPolicy::new(rate, burst, max_inflight);
            if let Some(p) = fields.get(4) {
                let p: u8 = p
                    .parse()
                    .ok()
                    .filter(|&p| p <= MAX_PRIORITY)
                    .ok_or_else(|| at(format!("priority {p:?} out of range 0..={MAX_PRIORITY}")))?;
                policy = policy.with_priority(p);
            }
            table.set_policy(fields[0], policy);
        }
        Ok(table)
    }

    /// Admit one request for `tenant` at the serving clock.
    pub fn admit(&self, tenant: &str) -> Result<u8, WireError> {
        self.admit_at(tenant, Instant::now())
    }

    /// Admit one request for `tenant` as of `now`. `Ok` carries the
    /// tenant's default priority and counts one inflight slot (release
    /// it with [`TenantTable::release`] when the job turns terminal);
    /// `Err` is the structured `rate_limited` verdict.
    pub fn admit_at(&self, tenant: &str, now: Instant) -> Result<u8, WireError> {
        let policy = self.policy(tenant);
        let mut state = self.state.lock().unwrap();
        let s = state.entry(tenant.to_string()).or_insert(TenantState {
            tokens: policy.burst,
            last_refill: now,
            inflight: 0,
        });
        if policy.burst.is_finite() {
            if policy.rate.is_finite() {
                let dt = now.saturating_duration_since(s.last_refill).as_secs_f64();
                s.tokens = (s.tokens + policy.rate * dt).min(policy.burst);
            } else {
                // unlimited rate with a finite burst: instant refill
                s.tokens = policy.burst;
            }
        }
        s.last_refill = now;
        if s.tokens < 1.0 {
            return Err(WireError::new(
                ErrorCode::RateLimited,
                format!(
                    "tenant {tenant:?} exceeded its request rate \
                     ({}/s, burst {}); retry shortly",
                    policy.rate, policy.burst
                ),
            ));
        }
        if s.inflight >= policy.max_inflight {
            return Err(WireError::new(
                ErrorCode::RateLimited,
                format!(
                    "tenant {tenant:?} has {} jobs in flight (limit {}); \
                     wait for one to finish",
                    s.inflight, policy.max_inflight
                ),
            ));
        }
        if s.tokens.is_finite() {
            s.tokens -= 1.0;
        }
        s.inflight += 1;
        Ok(policy.priority)
    }

    /// Return one inflight slot (the admitted job turned terminal).
    pub fn release(&self, tenant: &str) {
        let mut state = self.state.lock().unwrap();
        if let Some(s) = state.get_mut(tenant) {
            s.inflight = s.inflight.saturating_sub(1);
        }
    }

    /// Jobs currently counted against a tenant's inflight quota.
    pub fn inflight(&self, tenant: &str) -> usize {
        self.state.lock().unwrap().get(tenant).map_or(0, |s| s.inflight)
    }
}

fn parse_limit_f64(s: &str) -> Result<f64, String> {
    if s == "unlimited" {
        return Ok(f64::INFINITY);
    }
    s.parse::<f64>()
        .ok()
        .filter(|v| *v > 0.0)
        .ok_or_else(|| format!("expected a positive number or `unlimited`, got {s:?}"))
}

fn parse_limit_usize(s: &str) -> Result<usize, String> {
    if s == "unlimited" {
        return Ok(usize::MAX);
    }
    s.parse::<usize>()
        .ok()
        .filter(|v| *v > 0)
        .ok_or_else(|| format!("expected a positive integer or `unlimited`, got {s:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn burst_is_the_hard_ceiling() {
        let mut table = TenantTable::default();
        table.set_policy("acme", TenantPolicy::new(1.0, 3.0, usize::MAX));
        let t0 = Instant::now();
        for _ in 0..3 {
            table.admit_at("acme", t0).expect("within burst");
        }
        let err = table.admit_at("acme", t0).unwrap_err();
        assert_eq!(err.code, ErrorCode::RateLimited);
        // a long idle refills to burst, never beyond it
        let later = t0 + Duration::from_secs(3600);
        for _ in 0..3 {
            table.admit_at("acme", later).expect("refilled to burst");
        }
        assert_eq!(table.admit_at("acme", later).unwrap_err().code, ErrorCode::RateLimited);
    }

    #[test]
    fn tokens_refill_at_the_configured_rate() {
        let mut table = TenantTable::default();
        table.set_policy("acme", TenantPolicy::new(2.0, 2.0, usize::MAX));
        let t0 = Instant::now();
        table.admit_at("acme", t0).unwrap();
        table.admit_at("acme", t0).unwrap();
        assert!(table.admit_at("acme", t0).is_err());
        // rate 2/s → one token back after half a second
        let t1 = t0 + Duration::from_millis(500);
        table.admit_at("acme", t1).expect("one token refilled");
        assert!(table.admit_at("acme", t1).is_err());
    }

    #[test]
    fn tenants_are_isolated() {
        let mut table = TenantTable::default();
        table.set_policy("noisy", TenantPolicy::new(1.0, 1.0, usize::MAX));
        table.set_policy("quiet", TenantPolicy::new(1.0, 1.0, usize::MAX));
        let t0 = Instant::now();
        table.admit_at("noisy", t0).unwrap();
        assert!(table.admit_at("noisy", t0).is_err());
        table.admit_at("quiet", t0).expect("quiet tenant has its own bucket");
    }

    #[test]
    fn inflight_quota_blocks_until_release() {
        let mut table = TenantTable::default();
        table.set_policy("acme", TenantPolicy::new(f64::INFINITY, f64::INFINITY, 2));
        let t0 = Instant::now();
        table.admit_at("acme", t0).unwrap();
        table.admit_at("acme", t0).unwrap();
        let err = table.admit_at("acme", t0).unwrap_err();
        assert_eq!(err.code, ErrorCode::RateLimited);
        assert!(err.message.contains("in flight"));
        table.release("acme");
        assert_eq!(table.inflight("acme"), 1);
        table.admit_at("acme", t0).expect("slot freed by release");
    }

    #[test]
    fn unknown_tenants_fall_back_to_the_default_policy() {
        let mut table = TenantTable::default();
        table.set_policy(DEFAULT_TENANT, TenantPolicy::new(1.0, 1.0, usize::MAX));
        let t0 = Instant::now();
        table.admit_at("never-configured", t0).unwrap();
        assert!(table.admit_at("never-configured", t0).is_err());
        // ...and an out-of-the-box table admits everything
        let open = TenantTable::default();
        for _ in 0..10_000 {
            open.admit_at("anyone", t0).unwrap();
        }
    }

    #[test]
    fn default_priority_comes_from_the_policy() {
        let mut table = TenantTable::default();
        table.set_policy("batch", TenantPolicy::new(10.0, 10.0, 8).with_priority(1));
        let t0 = Instant::now();
        assert_eq!(table.admit_at("batch", t0).unwrap(), 1);
        assert_eq!(table.admit_at("other", t0).unwrap(), DEFAULT_PRIORITY);
    }

    #[test]
    fn config_file_round_trip() {
        let text = "\
# tenants for the staging gateway
default   100 200 64
acme      5   10  4   8   # latency-sensitive
batch     1   2   unlimited 0
";
        let table = TenantTable::parse_config(text).unwrap();
        assert_eq!(table.policy("acme"), TenantPolicy::new(5.0, 10.0, 4).with_priority(8));
        assert_eq!(table.policy("batch").max_inflight, usize::MAX);
        assert_eq!(table.policy("batch").priority, 0);
        assert_eq!(table.policy("anyone-else"), TenantPolicy::new(100.0, 200.0, 64));
    }

    #[test]
    fn config_errors_name_the_line() {
        let err = TenantTable::parse_config("acme 5 10\n").unwrap_err();
        assert!(err.contains("line 1"), "{err}");
        let err = TenantTable::parse_config("ok 1 1 1\nacme -3 10 4\n").unwrap_err();
        assert!(err.contains("line 2"), "{err}");
        let err = TenantTable::parse_config("acme 1 1 1 99\n").unwrap_err();
        assert!(err.contains("priority"), "{err}");
    }
}
