//! Small deterministic PRNG (xoshiro256++ seeded via splitmix64).
//!
//! Every stochastic component in the crate (graph generators, synthetic
//! traffic, workload jitter) draws from this generator so that all
//! experiments are exactly reproducible from a `u64` seed, with no
//! dependency on an external RNG crate.

/// splitmix64 step — used to expand a single `u64` seed into the
/// xoshiro256++ state, as recommended by the xoshiro authors.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG. Deterministic, fast, passes BigCrush; good enough
/// for synthetic workload generation (not for cryptography).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform `f64` in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 high bits -> [0,1) double.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `u64` in `[0, bound)` using Lemire's multiply-shift
    /// (slight modulo bias at 2^64 scale is irrelevant here).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform `u32` node id in `[0, n)`.
    #[inline]
    pub fn node(&mut self, n: u32) -> u32 {
        self.below(n as u64) as u32
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample from a discrete power law `P(k) ∝ k^(-gamma)` for
    /// `k ∈ [kmin, kmax]` by inverse transform on the continuous
    /// approximation, then floor. This is the standard generator for
    /// scale-free degree sequences.
    #[inline]
    pub fn power_law(&mut self, gamma: f64, kmin: f64, kmax: f64) -> u64 {
        let u = self.next_f64();
        let e = 1.0 - gamma;
        // inverse CDF of truncated continuous power law
        let x = (kmin.powf(e) + u * (kmax.powf(e) - kmin.powf(e))).powf(1.0 / e);
        x.floor() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Rng::new(9);
        for bound in [1u64, 2, 3, 10, 1000] {
            for _ in 0..1000 {
                assert!(r.below(bound) < bound);
            }
        }
    }

    #[test]
    fn below_covers_range() {
        let mut r = Rng::new(11);
        let mut seen = [false; 8];
        for _ in 0..1000 {
            seen[r.below(8) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn power_law_within_bounds_and_heavy_tailed() {
        let mut r = Rng::new(5);
        let (kmin, kmax) = (1.0, 1000.0);
        let n = 200_000;
        let mut big = 0usize;
        let mut sum = 0u64;
        for _ in 0..n {
            let k = r.power_law(2.1, kmin, kmax);
            assert!(k >= 1 && k <= 1000);
            if k >= 100 {
                big += 1;
            }
            sum += k;
        }
        // Heavy tail: some mass above 100x the minimum, but most draws small.
        assert!(big > 0);
        assert!((sum as f64 / n as f64) < 20.0);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // overwhelmingly likely
    }
}
