//! The PJRT executor: artifact discovery, one-time compilation, and the
//! execute path used by the coordinator's dense backend.
//!
//! The PJRT bindings (`xla` crate) are not in the offline vendor set,
//! so the real executor is feature-gated behind `xla` (off by default).
//! The default build ships a stub with the identical API whose
//! `load_dir` performs full manifest/artifact validation — preserving
//! every failure mode the coordinator and the failure-injection tests
//! depend on — and then reports that the dense backend is unavailable.
//! The coordinator treats that as "run sparse-only" when no manifest
//! exists, and as a loud startup error when artifacts are present but
//! cannot be served.

use std::path::{Path, PathBuf};

use crate::error::{Context, Result};

/// Cumulative execution statistics of the dense backend.
#[derive(Debug, Clone, Copy, Default)]
pub struct RuntimeStats {
    /// Artifacts compiled at startup.
    pub compiled: usize,
    /// Census executions served.
    pub executions: u64,
    /// Total seconds inside PJRT execute calls.
    pub execute_seconds: f64,
    /// Total seconds spent padding/staging inputs.
    pub staging_seconds: f64,
}

/// Whether this build can actually execute dense artifacts.
pub const DENSE_AVAILABLE: bool = cfg!(feature = "xla");

/// Parse `<dir>/manifest.tsv` into `(size, artifact path)` rows,
/// skipping unknown artifact kinds. Shared between the real executor
/// and the stub so error behaviour is identical.
fn read_manifest(dir: &Path) -> Result<Vec<(usize, PathBuf)>> {
    let manifest = dir.join("manifest.tsv");
    let text = std::fs::read_to_string(&manifest)
        .with_context(|| format!("reading {}; run `make artifacts` first", manifest.display()))?;
    let mut rows = Vec::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut cols = line.split('\t');
        let (kind, size, file) = match (cols.next(), cols.next(), cols.next()) {
            (Some(k), Some(s), Some(f)) => (k, s, f),
            _ => crate::bail!("malformed manifest row: {line:?}"),
        };
        if kind != "census_dense" {
            continue; // future artifact kinds are ignored, not fatal
        }
        let size: usize = size
            .parse()
            .with_context(|| format!("bad size in {line:?}"))?;
        rows.push((size, dir.join(file)));
    }
    if rows.is_empty() {
        crate::bail!(
            "manifest {} lists no census_dense artifacts",
            manifest.display()
        );
    }
    Ok(rows)
}

#[cfg(feature = "xla")]
mod enabled {
    //! Real PJRT path. Compiling this module requires vendoring the
    //! `xla` crate (not in the offline set) and enabling the `xla`
    //! feature.

    use std::collections::BTreeMap;
    use std::path::{Path, PathBuf};
    use std::time::Instant;

    use super::RuntimeStats;
    use crate::bail;
    use crate::error::{Context, Result};
    use crate::census::{Census, TriadType};
    use crate::graph::CsrGraph;
    use crate::runtime::{dyad_tallies, padding_correction};

    /// A compiled dense-census executable for one fixed adjacency size.
    struct SizedExecutable {
        exe: xla::PjRtLoadedExecutable,
        size: usize,
    }

    /// The dense census backend: a PJRT CPU client plus one compiled
    /// executable per artifact size. Construction compiles everything
    /// once; execution is allocation-light and Python-free.
    pub struct DenseCensusRuntime {
        client: xla::PjRtClient,
        by_size: BTreeMap<usize, SizedExecutable>,
        stats: RuntimeStats,
        dir: PathBuf,
    }

    impl DenseCensusRuntime {
        /// Load every artifact listed in `<dir>/manifest.tsv` and
        /// compile it on a fresh PJRT CPU client.
        pub fn load_dir<P: AsRef<Path>>(dir: P) -> Result<DenseCensusRuntime> {
            let dir = dir.as_ref().to_path_buf();
            let rows = super::read_manifest(&dir)?;
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            let mut by_size = BTreeMap::new();
            for (size, path) in rows {
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().context("non-utf8 artifact path")?,
                )
                .with_context(|| format!("parsing HLO text {}", path.display()))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client
                    .compile(&comp)
                    .with_context(|| format!("compiling {}", path.display()))?;
                by_size.insert(size, SizedExecutable { exe, size });
            }
            let compiled = by_size.len();
            Ok(DenseCensusRuntime {
                client,
                by_size,
                stats: RuntimeStats {
                    compiled,
                    ..RuntimeStats::default()
                },
                dir,
            })
        }

        /// Artifact directory this runtime was loaded from.
        pub fn artifact_dir(&self) -> &Path {
            &self.dir
        }

        /// Available dense sizes, ascending.
        pub fn sizes(&self) -> Vec<usize> {
            self.by_size.keys().copied().collect()
        }

        /// Largest size this runtime can serve.
        pub fn max_size(&self) -> usize {
            *self.by_size.keys().last().unwrap()
        }

        /// The smallest artifact size that fits a graph of `n` nodes.
        pub fn size_for(&self, n: usize) -> Option<usize> {
            self.by_size.range(n..).next().map(|(&s, _)| s)
        }

        /// PJRT platform string (diagnostics).
        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Cumulative stats.
        pub fn stats(&self) -> RuntimeStats {
            self.stats
        }

        /// Compute the exact triad census of `g` on the dense AOT path:
        /// pad the adjacency to the best-fitting artifact size, execute,
        /// round to integers and undo the padding contribution.
        pub fn census(&mut self, g: &CsrGraph) -> Result<Census> {
            let n = g.node_count();
            let size = self.size_for(n).with_context(|| {
                format!("graph ({n} nodes) exceeds dense capacity {}", self.max_size())
            })?;

            let t0 = Instant::now();
            // stage the padded adjacency
            let mut a = vec![0f32; size * size];
            for (u, v) in g.arcs() {
                a[u as usize * size + v as usize] = 1.0;
            }
            let lit = xla::Literal::vec1(&a)
                .reshape(&[size as i64, size as i64])
                .context("reshaping adjacency literal")?;
            self.stats.staging_seconds += t0.elapsed().as_secs_f64();

            let t1 = Instant::now();
            let sized = &self.by_size[&size];
            debug_assert_eq!(sized.size, size);
            let result = sized
                .exe
                .execute::<xla::Literal>(&[lit])
                .context("PJRT execute")?[0][0]
                .to_literal_sync()
                .context("device->host literal")?;
            self.stats.execute_seconds += t1.elapsed().as_secs_f64();
            self.stats.executions += 1;

            // lowered with return_tuple=True: unwrap the 1-tuple
            let out = result.to_tuple1().context("unwrapping result tuple")?;
            let values = out.to_vec::<f32>().context("reading census vector")?;
            if values.len() != 16 {
                bail!("artifact returned {} values, expected 16", values.len());
            }

            let mut padded = Census::zero();
            for (i, &v) in values.iter().enumerate() {
                let r = v.round();
                if (v - r).abs() > 1e-3 || r < 0.0 {
                    bail!("non-integral census component {i}: {v}");
                }
                padded.add_count(TriadType::from_index(i + 1), r as u64);
            }

            let (mutual, asym) = dyad_tallies(g);
            Ok(padding_correction(&padded, n, size - n, mutual, asym))
        }
    }

    // PjRtLoadedExecutable and PjRtClient wrap C++ objects behind
    // pointers; the xla crate does not mark them Send. The coordinator
    // confines the runtime to a dedicated service thread (see
    // coordinator::service), so no cross-thread sharing happens
    // through this type.
}

#[cfg(feature = "xla")]
pub use enabled::DenseCensusRuntime;

#[cfg(not(feature = "xla"))]
mod disabled {
    //! API-identical stub used when the `xla` feature is off. It can
    //! never be constructed: `load_dir` validates the manifest and
    //! artifacts exactly like the real path, then reports the backend
    //! unavailable.

    use std::path::Path;

    use super::RuntimeStats;
    use crate::census::Census;
    use crate::error::{Context, Result};
    use crate::graph::CsrGraph;

    /// Uninhabited stand-in for the PJRT runtime.
    pub struct DenseCensusRuntime {
        never: std::convert::Infallible,
    }

    impl DenseCensusRuntime {
        /// Validate `<dir>/manifest.tsv` and its artifacts, then fail:
        /// this build cannot execute dense artifacts.
        pub fn load_dir<P: AsRef<Path>>(dir: P) -> Result<DenseCensusRuntime> {
            let dir = dir.as_ref();
            let rows = super::read_manifest(dir)?;
            for (size, path) in &rows {
                std::fs::metadata(path).with_context(|| {
                    format!("artifact for size {size} missing: {}", path.display())
                })?;
            }
            crate::bail!(
                "dense backend unavailable: built without the `xla` feature \
                 ({} artifacts found in {} but PJRT is not compiled in)",
                rows.len(),
                dir.display()
            )
        }

        /// Artifact directory (unreachable: construction always fails).
        pub fn artifact_dir(&self) -> &Path {
            match self.never {}
        }

        /// Available dense sizes (unreachable).
        pub fn sizes(&self) -> Vec<usize> {
            match self.never {}
        }

        /// Largest servable size (unreachable).
        pub fn max_size(&self) -> usize {
            match self.never {}
        }

        /// Best-fitting artifact size (unreachable).
        pub fn size_for(&self, _n: usize) -> Option<usize> {
            match self.never {}
        }

        /// Platform string (unreachable).
        pub fn platform(&self) -> String {
            match self.never {}
        }

        /// Cumulative stats (unreachable).
        pub fn stats(&self) -> RuntimeStats {
            match self.never {}
        }

        /// Dense census (unreachable).
        pub fn census(&mut self, _g: &CsrGraph) -> Result<Census> {
            match self.never {}
        }
    }
}

#[cfg(not(feature = "xla"))]
pub use disabled::DenseCensusRuntime;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn missing_dir_is_informative() {
        let err = match DenseCensusRuntime::load_dir("/nonexistent") {
            Ok(_) => panic!("load of /nonexistent succeeded"),
            Err(e) => e,
        };
        assert!(format!("{err:#}").contains("make artifacts"));
    }

    #[test]
    fn manifest_parser_skips_unknown_kinds_and_rejects_garbage() {
        let dir = std::env::temp_dir().join("triadic_exec_manifest");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();

        std::fs::write(
            dir.join("manifest.tsv"),
            "# comment\nfrobnicator\t9\tx.bin\ncensus_dense\t64\ta.hlo.txt\n",
        )
        .unwrap();
        let rows = read_manifest(&dir).unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].0, 64);

        std::fs::write(dir.join("manifest.tsv"), "census_dense\tonly-two\n").unwrap();
        assert!(read_manifest(&dir).is_err());

        std::fs::write(dir.join("manifest.tsv"), "census_dense\tNaN\tx.hlo.txt\n").unwrap();
        assert!(read_manifest(&dir).is_err());

        std::fs::write(dir.join("manifest.tsv"), "# empty\n").unwrap();
        assert!(read_manifest(&dir).is_err());
        let _ = std::fs::remove_dir_all(dir);
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn stub_reports_unavailable_after_validation() {
        let dir = std::env::temp_dir().join("triadic_exec_stub");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.tsv"), "census_dense\t64\ta.hlo.txt\n").unwrap();
        std::fs::write(dir.join("a.hlo.txt"), "HloModule placeholder").unwrap();
        let err = DenseCensusRuntime::load_dir(&dir).unwrap_err();
        assert!(err.to_string().contains("xla"), "{err}");
        assert!(!DENSE_AVAILABLE);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[cfg(feature = "xla")]
    mod with_artifacts {
        use super::super::*;
        use crate::census::merged;
        use crate::graph::generators;
        use std::path::PathBuf;

        fn artifacts_dir() -> Option<PathBuf> {
            let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
            dir.join("manifest.tsv").exists().then_some(dir)
        }

        #[test]
        fn runtime_census_matches_sparse_engines() {
            let Some(dir) = artifacts_dir() else {
                eprintln!("skipping: artifacts not built (`make artifacts`)");
                return;
            };
            let mut rt = DenseCensusRuntime::load_dir(dir).unwrap();
            assert!(rt.sizes().contains(&64));
            for seed in 0..3 {
                let g = generators::power_law(50, 2.2, 5.0, seed);
                let want = merged::census(&g);
                let got = rt.census(&g).unwrap();
                assert_eq!(got, want, "seed {seed}");
            }
            // exact-size (no padding) path
            let g = generators::power_law(64, 2.0, 6.0, 7);
            assert_eq!(rt.census(&g).unwrap(), merged::census(&g));
            assert!(rt.stats().executions >= 4);
        }

        #[test]
        fn size_routing() {
            let Some(dir) = artifacts_dir() else {
                eprintln!("skipping: artifacts not built (`make artifacts`)");
                return;
            };
            let rt = DenseCensusRuntime::load_dir(dir).unwrap();
            assert_eq!(rt.size_for(10), Some(64));
            assert_eq!(rt.size_for(64), Some(64));
            assert_eq!(rt.size_for(65), Some(128));
            assert_eq!(rt.size_for(200), Some(256));
            assert_eq!(rt.size_for(257), None);
        }
    }
}
