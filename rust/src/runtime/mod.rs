//! PJRT runtime: loads the AOT-compiled dense census artifacts
//! (HLO text lowered from the JAX/Pallas model by `make artifacts`) and
//! executes them from Rust. Python is never on this path.
//!
//! The artifact contract:
//!
//! * `artifacts/manifest.tsv` — rows `kind \t size \t file`;
//! * each `census_dense_<n>.hlo.txt` computes the 16-class census of an
//!   `n×n` f32 adjacency matrix (census order, 003 first), as a 1-tuple.
//!
//! Graphs smaller than an available size are zero-padded; padding adds
//! only null (003) and dyadic (012/102) triads, which
//! [`padding_correction`] removes exactly (see
//! `python/tests/test_model.py::test_padding_adds_only_null_and_dyadic`
//! for the property and the derivation).

pub mod executor;

pub use executor::{DenseCensusRuntime, RuntimeStats, DENSE_AVAILABLE};

use crate::census::{Census, TriadType};
use crate::graph::CsrGraph;

/// Number of mutual and asymmetric dyads of a graph (the inputs to the
/// padding correction).
pub fn dyad_tallies(g: &CsrGraph) -> (u64, u64) {
    let mut mutual = 0u64;
    let mut asym = 0u64;
    for (_, _, dir) in g.dyads() {
        match dir {
            crate::graph::Dir::Both => mutual += 1,
            _ => asym += 1,
        }
    }
    (mutual, asym)
}

/// Remove the triads contributed by `pad` isolated padding nodes from a
/// census computed over the padded graph, restoring the census of the
/// real `n`-node graph.
///
/// Padding nodes have no arcs, so every triad touching one has at most
/// one connected dyad: classes with ≥ 2 connected dyads are untouched;
/// `012`/`102` gain `pad * (#asym / #mutual dyads)`; `003` absorbs the
/// rest and is recomputed from `C(n,3)`.
pub fn padding_correction(
    padded: &Census,
    n_real: usize,
    pad: usize,
    mutual_dyads: u64,
    asym_dyads: u64,
) -> Census {
    let mut c = *padded;
    let extra_012 = pad as u64 * asym_dyads;
    let extra_102 = pad as u64 * mutual_dyads;
    assert!(
        c[TriadType::T012] >= extra_012 && c[TriadType::T102] >= extra_102,
        "padding correction underflow: census inconsistent with dyad tallies"
    );
    c[TriadType::T012] -= extra_012;
    c[TriadType::T102] -= extra_102;
    c.close_with_null(n_real);
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::census::merged;
    use crate::graph::generators;

    #[test]
    fn dyad_tallies_counts() {
        let g = crate::graph::builder::from_arcs(4, &[(0, 1), (1, 0), (2, 3), (1, 2)]);
        let (m, a) = dyad_tallies(&g);
        assert_eq!(m, 1);
        assert_eq!(a, 2);
    }

    #[test]
    fn padding_correction_round_trip() {
        // Build g, embed it in a larger empty graph, and check that the
        // corrected census of the padded graph equals the original.
        let n = 30;
        let pad = 14;
        let g = generators::power_law(n, 2.2, 4.0, 9);
        let mut b = crate::graph::builder::GraphBuilder::new(n + pad);
        b.extend(g.arcs());
        let padded_graph = b.build();

        let want = merged::census(&g);
        let padded_census = merged::census(&padded_graph);
        let (m, a) = dyad_tallies(&g);
        let got = padding_correction(&padded_census, n, pad, m, a);
        assert_eq!(got, want);
    }

    #[test]
    #[should_panic(expected = "underflow")]
    fn padding_correction_rejects_inconsistent_tallies() {
        let c = Census::zero();
        padding_correction(&c, 10, 5, 100, 100);
    }
}
