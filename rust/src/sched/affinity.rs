//! CPU affinity for executor workers, with no libc dependency.
//!
//! The paper's NUMA runs (the 48-core Magny-Cours in particular) only
//! behave when threads stay put: a worker that migrates off its socket
//! turns every "local" accumulation bank and chunk slab remote. The
//! executor therefore pins each worker at spawn according to
//! [`PinMode`]: to its socket's full CPU set (`sockets`), to one
//! dedicated CPU round-robined within the socket (`cpus`), or not at
//! all (`none`, the PR 7 structural-placement behavior).
//!
//! On Linux x86_64/aarch64 the pin is a raw `sched_setaffinity(2)`
//! syscall through `asm!`, same idiom as `net/reactor.rs`'s epoll
//! shims. Everywhere else it is a no-op that *reports* the thread as
//! unpinned instead of erroring, so portable builds and masked-sysfs
//! containers keep working with `pinned: false` telemetry.

use std::fmt;
use std::str::FromStr;

/// How executor workers bind to the CPUs their socket owns.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PinMode {
    /// No affinity calls — placement stays structural (deques and
    /// banks are socket-grouped but the kernel may migrate threads).
    None,
    /// Pin each worker to its socket's full CPU set; the kernel
    /// balances within the socket but never migrates across sockets.
    #[default]
    Sockets,
    /// Pin each worker to a single CPU, round-robined over its
    /// socket's CPU list — the strictest placement, matching the
    /// paper's one-thread-per-core runs.
    Cpus,
}

impl PinMode {
    /// All modes, for CLI help strings and exhaustive tests.
    pub const ALL: [PinMode; 3] = [PinMode::None, PinMode::Sockets, PinMode::Cpus];
}

impl fmt::Display for PinMode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            PinMode::None => "none",
            PinMode::Sockets => "sockets",
            PinMode::Cpus => "cpus",
        })
    }
}

impl FromStr for PinMode {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "none" => Ok(PinMode::None),
            "sockets" => Ok(PinMode::Sockets),
            "cpus" => Ok(PinMode::Cpus),
            other => Err(format!("unknown pin mode '{other}' (expected cpus|sockets|none)")),
        }
    }
}

/// Bind the calling thread to `cpus` (kernel CPU ids). Returns `true`
/// when the affinity call succeeded and the thread is now pinned,
/// `false` when the set is empty, the syscall failed (e.g. the cgroup
/// mask excludes those CPUs), or the platform has no affinity shim.
/// Never errors: pinning is an optimization, not a correctness
/// requirement, and the caller records the outcome in telemetry.
pub fn pin_current_thread(cpus: &[usize]) -> bool {
    if cpus.is_empty() {
        return false;
    }
    #[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        setaffinity::pin(cpus)
    }
    #[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
    {
        false
    }
}

/// Raw-syscall `sched_setaffinity`, Linux x86_64/aarch64 only. Same
/// ABI notes as the epoll shim: `syscall` clobbers rcx/r11 on x86_64,
/// `svc 0` takes the number in x8 on aarch64.
#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
mod setaffinity {
    #[cfg(target_arch = "x86_64")]
    const NR_SCHED_SETAFFINITY: usize = 203;
    #[cfg(target_arch = "aarch64")]
    const NR_SCHED_SETAFFINITY: usize = 122;

    /// Words in the cpu_set_t we pass: 16 × u64 = 1024 CPUs, the
    /// kernel's conventional CPU_SETSIZE.
    const MASK_WORDS: usize = 16;

    #[cfg(target_arch = "x86_64")]
    #[inline]
    unsafe fn syscall3(nr: usize, a1: usize, a2: usize, a3: usize) -> isize {
        let ret: isize;
        std::arch::asm!(
            "syscall",
            inlateout("rax") nr as isize => ret,
            in("rdi") a1,
            in("rsi") a2,
            in("rdx") a3,
            lateout("rcx") _,
            lateout("r11") _,
            options(nostack),
        );
        ret
    }

    #[cfg(target_arch = "aarch64")]
    #[inline]
    unsafe fn syscall3(nr: usize, a1: usize, a2: usize, a3: usize) -> isize {
        let ret: isize;
        std::arch::asm!(
            "svc 0",
            in("x8") nr,
            inlateout("x0") a1 as isize => ret,
            in("x1") a2,
            in("x2") a3,
            options(nostack),
        );
        ret
    }

    /// `sched_setaffinity(pid = 0, …)` binds the calling thread (the
    /// kernel resolves pid 0 to the current task, and affinity is
    /// per-thread). CPUs beyond the mask width are silently dropped;
    /// if every requested CPU is out of range the mask is empty and
    /// the kernel rejects it with EINVAL, reported here as `false`.
    pub(super) fn pin(cpus: &[usize]) -> bool {
        let mut mask = [0u64; MASK_WORDS];
        let mut any = false;
        for &cpu in cpus {
            if cpu < MASK_WORDS * 64 {
                mask[cpu / 64] |= 1u64 << (cpu % 64);
                any = true;
            }
        }
        if !any {
            return false;
        }
        let ret = unsafe {
            syscall3(
                NR_SCHED_SETAFFINITY,
                0,
                std::mem::size_of_val(&mask),
                mask.as_ptr() as usize,
            )
        };
        ret == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pin_mode_round_trips_through_str() {
        for mode in PinMode::ALL {
            assert_eq!(mode.to_string().parse::<PinMode>().unwrap(), mode);
        }
        assert!("socket".parse::<PinMode>().is_err());
        assert_eq!(PinMode::default(), PinMode::Sockets);
    }

    #[test]
    fn empty_set_reports_unpinned_without_erroring() {
        // the no-op / fallback contract: `false`, never a panic or Err
        assert!(!pin_current_thread(&[]));
    }

    #[test]
    fn out_of_range_cpus_report_unpinned() {
        // ids beyond the 1024-CPU mask can't be expressed; the call
        // must degrade to "not pinned", not error
        assert!(!pin_current_thread(&[100_000]));
    }

    #[test]
    fn pinning_to_all_cpus_is_accepted_where_supported() {
        // pin to every CPU the process could run on — semantically a
        // no-op mask, so it succeeds wherever the shim exists and
        // reports false only on fallback platforms
        let all: Vec<usize> = (0..1024).collect();
        let pinned = pin_current_thread(&all);
        if cfg!(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))) {
            assert!(pinned, "full-mask pin should succeed on Linux");
        } else {
            assert!(!pinned);
        }
    }
}
